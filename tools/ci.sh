#!/usr/bin/env bash
# Tier-1 verify + smoke run, as used by .github/workflows/ci.yml.
#
#   bash tools/ci.sh
#
# The host-device-count flag gives the in-process tests 8 simulated CPU
# devices; subprocess-based multi-device tests set their own flag.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8"

echo "== tier-1 test suite =="
python -m pytest -x -q

echo "== train smoke run (3 steps, reduced hymba) =="
python -m repro.launch.train --arch hymba-1p5b --reduced --steps 3 \
    --seq 32 --batch 8

echo "== ci.sh OK =="
