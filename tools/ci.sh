#!/usr/bin/env bash
# Tier-1 verify + smoke run, as used by .github/workflows/ci.yml.
#
#   bash tools/ci.sh
#
# The host-device-count flag gives the in-process tests 8 simulated CPU
# devices; subprocess-based multi-device tests set their own flag.
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export XLA_FLAGS="--xla_force_host_platform_device_count=8"

echo "== tier-1 test suite =="
python -m pytest -x -q

echo "== static analysis (trace-only invariants, no device execution) =="
# comms plan (one psum per bucket per level, zero all-gathers), retrace
# signatures, sharding/dtype lint, host-sync lint — diffed against the
# checked-in tools/*_baseline.json. The CLI re-pins its own fake device
# count (32 = data 16 x model 2), independent of the XLA_FLAGS above.
python -m repro.analysis --all

echo "== train smoke run (3 steps, reduced hymba) =="
python -m repro.launch.train --arch hymba-1p5b --reduced --steps 3 \
    --seq 32 --batch 8

echo "== fused combine benchmark smoke (tiny shapes) =="
python -m benchmarks.combine_fused --smoke | grep -q "combine_fused smoke OK" || {
    echo "combine_fused smoke failed"; exit 1; }

echo "== delayed combine benchmark smoke (overlap hides the exchange) =="
python -m benchmarks.delayed_combine --smoke | grep -q "delayed_combine smoke OK" || {
    echo "delayed_combine smoke failed"; exit 1; }

echo "== adaptive batch benchmark smoke (>=1 controller resize) =="
python -m benchmarks.adaptive_batch --smoke | grep -q "adaptive_batch smoke OK" || {
    echo "adaptive_batch smoke failed"; exit 1; }

echo "== serve smoke (3 staggered requests, continuous batching) =="
serve_out=$(python -m repro.launch.serve --arch qwen3-32b --reduced \
    --requests 3 --prompt-len 16 --gen 8 --max-slots 2 --stagger 2)
echo "$serve_out"
echo "$serve_out" | grep -q "completed=3" || {
    echo "serve smoke: not all requests completed"; exit 1; }
echo "$serve_out" | grep -q "tok_s=" || {
    echo "serve smoke: missing throughput fields"; exit 1; }

echo "== paged KV smoke (shared system prompt, dense-vs-paged bitwise) =="
python -m benchmarks.serve_paged --smoke | grep -q "serve_paged smoke OK" || {
    echo "serve_paged smoke failed"; exit 1; }

echo "== speculative decoding smoke (spec-vs-plain bitwise, acceptance > 0) =="
python -m benchmarks.serve_spec --smoke | grep -q "serve_spec smoke OK" || {
    echo "serve_spec smoke failed"; exit 1; }

echo "== chaos soak smoke (seeded faults, resilience invariants) =="
python -m benchmarks.chaos_soak --smoke | grep -q "chaos_soak smoke OK" || {
    echo "chaos_soak smoke failed"; exit 1; }

echo "== ci.sh OK =="
