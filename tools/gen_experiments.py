"""Renders EXPERIMENTS.md from results/dryrun* JSONs + the perf log."""
import glob
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]


def load(d):
    out = {}
    for f in sorted(glob.glob(str(ROOT / d / "*.json"))):
        r = json.loads(Path(f).read_text())
        out[(r["arch"], r["shape"], r["mesh"])] = r
    return out


def fmt_cell(r):
    if r["status"] == "SKIP":
        return None
    rf = r["roofline"]
    mem = r["memory"]["total_hbm_bytes"] / 2 ** 30
    return (rf["compute_s"], rf["memory_s"], rf["collective_s"],
            rf["dominant"], rf.get("useful_ratio", 0), mem)


HEADER = """# EXPERIMENTS — Adasum on TPU (JAX)

All numbers produced in this container (CPU host; TPU v5e is the *target*:
197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI). Roofline terms are
PER-DEVICE seconds derived from the compiled SPMD module via the
trip-count-aware HLO analyzer (`repro.launch.hlo_cost`) — XLA's own
`cost_analysis()` counts loop bodies once and was only kept as a
cross-check. Collective seconds use wire-byte conventions
(all-reduce = 2·N·(n-1)/n etc.). Known measurement caveat: XLA:CPU
promotes bf16 buffers to f32, inflating *capacity* numbers for bf16
tensors by up to 2x vs the TPU target (convert traffic is excluded from
the bytes term; buffer capacity is reported as measured).

## Paper-claim validation (benchmarks/run.py)

| Paper claim | Our result | Verdict |
|---|---|---|
| Fig. 6 / §5.4: at an aggressive untuned LR, Sum stops converging as DP widens; Adasum converges | lr=0.8 momentum, synthetic LM: Sum diverges (NaN at 32 lanes; stuck at 16), Adasum reaches target at 16 AND 32 lanes, faster at 32 | REPRODUCED |
| §5.1.2: Adasum keeps algorithmic efficiency at larger batch | steps-to-target at moderate LR: sum 47/49 (b16/b32) vs adasum 43/36 | REPRODUCED |
| Fig. 4 / §4.2.3: ADASUMRVH costs ~ a sum allreduce | wire bytes parsed from partitioned HLO: ratio 1.00-1.01 across 256KB-16MB messages (wall-clock on CPU-simulated devices is dispatch-bound and not meaningful) | REPRODUCED (structurally) |
| Fig. 1 / §3.6: gradients start parallel, become orthogonal | mean per-layer orthogonality 0.77 -> 0.93 over 60 steps (floor 0.125) | REPRODUCED |
| Fig. 2 / §3.7: Adasum closer to exact-Hessian sequential emulation than Sum | aggressive-LR regime (the paper's LeNet setup): adasum 0.82 vs sum 1.43 rel. err — adasum wins; conservative-LR regime: sum wins (the exact emulation degenerates to a plain sum) | REPRODUCED in the paper's regime, with an honest boundary |
| Table 1 / §4.3: partitioned Adasum + optimizer state | 1.25x faster update, 8x less state/device (8-way) | REPRODUCED |
| Table 2 / §5.2: local steps before communicating | k=4: 4x fewer sync rounds; algorithmic-efficiency cost visible (loss 4.86 vs 2.75 at equal tokens at this tiny scale — the paper's 84-vs-68-epoch trade, amplified by model size) | REPRODUCED (directionally) |
| §4.1/Fig. 3: post-optimizer combination for Adam/LAMB | implemented + tested (per-lane optimizer states diverge; see tests/test_system.py::test_post_optimizer_semantics) | REPRODUCED |
| Convergence lemmas A.2/A.3 | hypothesis property tests: angle bound cos>=0.9428, eigenvalue bounds [1,2], norm bounds, positive inner product | VERIFIED |

## §Dry-run

Every (architecture x shape x mesh) cell lowers AND compiles with
`jax.jit(...).lower(**input_specs).compile()` on the production meshes —
single-pod (16,16)=('data','model') and multi-pod (2,16,16)=
('pod','data','model') with 512 host devices. 40 cells x 2 meshes:
**66 OK + 14 SKIP (long_500k on pure full-attention archs, per
DESIGN.md §Arch-applicability), 0 FAIL.** Memory analysis + cost analysis
+ the collective schedule per cell are archived in `results/dryrun/`
(optimized) and `results/dryrun_baseline/` (paper-faithful baseline
before §Perf). The multi-pod pass proves the `pod` axis shards: the
hierarchical combine (sum inside pod, Adasum across pods — paper §4.2.2)
lowers to collective-permutes over the pod axis plus grouped psums.
"""


def table(results, mesh, title):
    lines = [f"\n### {title}\n",
             "| arch | shape | compute_s | memory_s | collective_s | "
             "dominant | useful | HBM GiB/dev |",
             "|---|---|---|---|---|---|---|---|"]
    for (arch, shape, m), r in sorted(results.items()):
        if m != mesh:
            continue
        c = fmt_cell(r)
        if c is None:
            lines.append(f"| {arch} | {shape} | — | — | — | SKIP | — | — |")
            continue
        comp, mem, coll, dom, useful, gib = c
        lines.append(f"| {arch} | {shape} | {comp:.3f} | {mem:.2f} | "
                     f"{coll:.3f} | {dom} | {useful:.3f} | {gib:.1f} |")
    return "\n".join(lines)


PERF = """

## §Perf — hypothesis -> change -> measure -> validate log

Three cells were hillclimbed (worst roofline fraction / most
collective-bound / most representative of the paper's technique); every
other cell reports baseline-only. The paper-faithful BASELINE numbers are
archived in `results/dryrun_baseline/`; the optimized system in
`results/dryrun/`. Roofline terms are per-device seconds.

### Cell A: mixtral-8x22b x train_4k (worst memory; hierarchical Adasum)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| A1 | 2.6 TiB/dev temp comes from the gspmd-tree combiner flattening each stacked leaf (`reshape(n//2,2,-1)`), destroying TP/FSDP sharding of the 45B-element expert leaves (napkin: 45e9 x 4B x copies ~ TiB) | combine over the lane axis only; reduce dots over the leaf's own (still-sharded) axes; pin per-lane delta + combined delta shardings in DistributedOptimizer | 2621 -> 319 GiB/dev | CONFIRMED (8.2x) |
| A2 | saved per-layer activations (56 x full lane batch) dominate: 84 GiB stack (napkin: 56 x 2 x 128 x 4096 x 384 x 4B) | microbatch gradient accumulation (A=8 -> 16), attn_chunk 512->256 | 319 -> 51 (A=8) / 31 (A=16) GiB/dev | CONFIRMED; A=16 breaks row/data divisibility (128 rows / A must divide 16) -> keep A=8 |
| A3 | per-lane fp32 Adam m,v (2 lanes x 1.13 TB global) + fp32 accumulators are the next 24 GiB | bf16 optimizer-state storage (update math fp32) + bf16 grad accumulators | within 51 -> (see A5 combined) | CONFIRMED (composition via buffer dump) |
| A4 | 1.9e13 collective B/dev is NOT FSDP gathers (insensitive to A); buffer probe shows f32 [tokens,d] psums from contraction-sharded kv projections (kv=8 does not divide tp=16) + (E,C,d) expert psums from globally-coordinated dispatch | (i) exact TP head alignment: block-duplicate kv heads 8->16, zero-wo-pad q heads (Megatron trick, bit-exact); (ii) shard-local MoE dispatch: per-data-shard capacity slices, batched row-local gather/scatter | collective 303 -> 179 s/dev; memory traffic 565 -> 748 s (accumulation re-reads weights 8x — the FSDP/accum trade, documented) | PARTIALLY CONFIRMED: head fix halved collectives; local dispatch bytes dominated by the expert-grad reduction, not dispatch |
| A5 | net | all of the above | HBM capacity 2621 -> 51 GiB/dev (CPU-measured; ~31 GiB TPU-corrected for bf16 promotion); collective 303 -> 179 s | 51x memory; 1.7x collective |

Remaining gap to 16 GiB/chip: the per-lane optimizer state is inherent to
the paper's post-optimizer mode (each Adasum leaf owns an optimizer); the
next lever is 8-bit blockwise state quantization (future work) or span=2
-> pre-optimizer mode (departs from the paper's Adam prescription).

### Cell B: llava-next-34b x prefill_32k (most collective-bound)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| B1 | 1175 s/dev collective = contraction-sharded attention projections (56 q heads, 8 kv heads don't divide 16) psum a full f32 [32, 32768, 7168] activation per projection per layer (napkin: 4.7 GB x ~3 x 60L ~ 1 TB/dev) | exact TP head alignment (q 56->64 zero-padded, kv 8->16 duplicated) | collective 1175 -> 40 s/dev; memory 889 -> 447 s; dominant flips collective->memory | CONFIRMED (29x) |
| B2 | remaining 447 s memory = quadratic score traffic (chunked attention writes/reads [c, 32768] f32 tiles to HBM; napkin: 2 x 4 x 32768^2 x 4B x 60L ~ 2 TB/dev) | Pallas flash-attention kernel (forward-only, online softmax, scores stay in VMEM) — validated vs oracle across shapes/windows in interpret mode; enabled on TPU backends. Modeled TPU effect: score traffic eliminated -> memory term ~ weights+activations ~ 40-60 s | measured-on-CPU not representative (interpret-mode pallas lowers to pathological HLO — documented); kernel validated, effect modeled | VALIDATED KERNEL + MODELED 7-10x |
| B3 | net (compiled path) | head alignment | step bound 1175 -> 447 s/dev (2.6x); with the flash kernel on real TPU, modeled ~60 s (19x) | |

### Cell C: hymba-1.5b x train_4k (paper-representative: span=dp RVH Adasum)

| # | hypothesis | change | before -> after | verdict |
|---|---|---|---|---|
| C1 | 84 s/dev memory + useful-FLOPs ratio 0.26: 25 attention + 25 mamba heads don't divide tp=16 -> attention/mixer compute REPLICATED 16x across the model axis (visible as x16 score traffic) | TP head alignment: q 25->80, kv 5->80 (MHA-ization; 3.2x nominal q-head compute but 16x-> 1x replication) | memory 84 -> 48 s/dev; compute 0.67 -> 0.46 s; useful 0.26 -> 0.38; collective 3.8 -> 9.2 s (new TP psums — expected trade, small vs the 36 s memory win) | CONFIRMED (1.8x step bound) |
| C2 | RVH combine cost: fused-buffer Adasum at span=16 moves 2N bytes/rank (N down + N up), confirmed == sum-allreduce wire bytes (fig4 bench ratio 1.00) | (already optimal; Pallas fused dot/combine kernels cover the compute side) | — | — |

### Beyond-paper optimizations (summary)

1. **RVH/GSPMD hybrid combine** — the paper's Algorithm 1 verbatim in
   shard_map (used when span==dp) plus a GSPMD-native tree for the
   hierarchical spans, with sharding pins that keep every intermediate
   distributed (A1).
2. **Exact TP head alignment** (A4/B1/C1) — bit-exact kv duplication +
   zero-wo q padding; removed the dominant collective on 3 archs and the
   16x compute replication on hymba.
3. **Shard-local MoE dispatch** (A4) — per-shard capacity, row-local
   gather/scatter.
4. **Flash-attention Pallas kernel** (B2) — forward-only serving path.
5. **bf16 optimizer state + bf16 grad accumulators** (A3).
6. **Microbatch gradient accumulation** (A2) with fp32-carry option.
7. **ZeRO-1/2/3 family**: optimizer-state scatter (always), lane-grad
   scatter (span<dp), FSDP params — all via sharding specs, composable
   with the paper's hierarchical Adasum exactly as §4.3 prescribes.

### Perf score (roofline fraction, optimized single-pod)

For TRAIN cells the meaningful roofline fraction is
MODEL_FLOPS / (step_bound x chips x peak):
useful-MFU = useful_ratio x compute_s / max(compute_s, memory_s,
collective_s). See the roofline tables: the best cells
(seamless train 1.0/0.16=~best, gemma train ~0.73 useful at 14.7s
memory-bound) are memory-bound on activation traffic — the universal
next lever is fused-attention training kernels (forward done here;
backward future work).
"""


def main():
    opt = load("results/dryrun")
    base = load("results/dryrun_baseline")
    parts = [HEADER]
    parts.append("\n## §Roofline — baseline (paper-faithful, single-pod "
                 "16x16)\n")
    parts.append("One row per assigned (arch x shape) cell. MODEL_FLOPS = "
                 "6·N·D (dense) / 6·N_active·D (MoE) for train, 2·N·D "
                 "prefill, 2·N/token decode; `useful` = MODEL_FLOPS / "
                 "(device_FLOPs x chips) — the compiled-vs-useful compute "
                 "ratio (catches remat/replication waste).")
    parts.append(table(base, "pod16x16", "Baseline, single pod"))
    parts.append("\n\n## §Roofline — optimized (post-§Perf, single-pod)\n")
    parts.append(table(opt, "pod16x16", "Optimized, single pod"))
    parts.append("\n\n### Multi-pod (2x16x16) — optimized\n")
    parts.append(table(opt, "pod2x16x16", "Optimized, multi-pod"))
    ok = sum(1 for r in opt.values() if r["status"] == "OK")
    skip = sum(1 for r in opt.values() if r["status"] == "SKIP")
    fail = sum(1 for r in opt.values() if r["status"] == "FAIL")
    parts.append(f"\n\nCell status (both meshes): OK={ok} SKIP={skip} "
                 f"FAIL={fail}.\n")
    parts.append(PERF)
    (ROOT / "EXPERIMENTS.md").write_text("\n".join(parts))
    print(f"EXPERIMENTS.md written: OK={ok} SKIP={skip} FAIL={fail}")


if __name__ == "__main__":
    main()
