"""Paper Fig. 1 study: per-layer gradient orthogonality over training.

Prints an ASCII trajectory of the mean orthogonality (the figure's bold
red line) plus the per-layer min/max band. Expected shape: starts low
(gradients agree early) and climbs toward 1 (orthogonal) as training
proceeds; per-layer curves move at different rates (§3.6 — the reason
Adasum is applied per layer).

    PYTHONPATH=src python examples/orthogonality_study.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.core.orthogonality import per_layer_orthogonality
from repro.core.adasum import adasum_tree_reduce
from repro.data import DataConfig, make_source


def main(nodes: int = 8, steps: int = 60):
    cfg = ModelConfig("ortho-lm", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
    model = build_model(cfg, attn_chunk=32)
    params = model.init(jax.random.key(0))
    src = make_source(DataConfig(seq_len=64, global_batch=nodes * 4,
                                 vocab_size=cfg.vocab_size, seed=3), cfg)
    grad = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
    print(f"step  mean_orthogonality  [per-layer min..max]   "
          f"(floor=1/{nodes}={1/nodes:.3f}, ceiling=1.0)")
    for step in range(steps):
        b = {k: jnp.asarray(v) for k, v in src.batch(step).items()}
        lanes = [{kk: v[i::nodes] for kk, v in b.items()}
                 for i in range(nodes)]
        gs = [grad(params, lb) for lb in lanes]
        o = per_layer_orthogonality(gs)
        vals = np.array([float(v) for k, v in o.items() if k != "__mean__"])
        mean = float(o["__mean__"])
        combined = adasum_tree_reduce(gs)
        params = jax.tree.map(lambda p, g: p - 0.3 * g.astype(p.dtype),
                              params, combined)
        if step % 5 == 0 or step == steps - 1:
            bar = "#" * int(mean * 40)
            print(f"{step:4d}  {mean:.3f} {bar:<40s} "
                  f"[{vals.min():.3f}..{vals.max():.3f}]")


if __name__ == "__main__":
    main()
