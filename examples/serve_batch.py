"""Batched serving example: prefill a batch of prompts and greedy-decode
continuations from a reduced assigned architecture (rwkv6 by default —
constant-memory decode state).

    PYTHONPATH=src python examples/serve_batch.py [arch]
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main


if __name__ == "__main__":
    arch = sys.argv[1] if len(sys.argv) > 1 else "rwkv6-7b"
    serve_main(["--arch", arch, "--reduced", "--batch", "4",
                "--prompt-len", "16", "--gen", "16"])
