"""Paper §5.4 (LeNet-5/Fig. 6) case study at CPU scale: Sum vs Adasum
across DP widths under an aggressive, *untuned* learning-rate schedule.

The paper's finding: Sum stops converging beyond 8 workers without
re-tuning the LR; Adasum keeps converging at 32 workers with the same
base hyperparameters. Run:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/convergence_study.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_local_mesh


def run(op: str, span: int, lr: float, steps: int = 120) -> float:
    mesh = make_local_mesh(min(span, len(jax.devices())), 1)
    mcfg = ModelConfig("study-lm", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
    cfg = EngineConfig(combine=op, span=span, backend="gspmd_tree",
                       optimizer="momentum", lr=lr, seq_len=64,
                       global_batch=span * 4, data_seed=5)
    sess = TrainSession.from_config(cfg, model=build_model(mcfg, attn_chunk=32),
                                    mesh=mesh, callbacks=[])
    loss = float("nan")
    for step in range(steps):
        loss = sess.step(sess.batch(step))["loss"]
        if not np.isfinite(loss):
            return loss
    return loss


def main():
    lr = 0.8     # aggressive base schedule, deliberately not re-tuned
    print(f"{'workers':>8s} {'Sum':>10s} {'Adasum':>10s}   (final loss, "
          f"lr={lr} untuned)")
    for span in (2, 4, 8):
        ls = run("sum", span, lr)
        la = run("adasum", span, lr)
        verdict = "adasum converges" if (np.isfinite(la) and
                                         (not np.isfinite(ls) or la < ls)) \
            else ""
        print(f"{span:8d} {ls:10.4f} {la:10.4f}   {verdict}")


if __name__ == "__main__":
    main()
