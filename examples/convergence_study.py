"""Paper §5.4 (LeNet-5/Fig. 6) case study at CPU scale: Sum vs Adasum
across DP widths under an aggressive, *untuned* learning-rate schedule.

The paper's finding: Sum stops converging beyond 8 workers without
re-tuning the LR; Adasum keeps converging at 32 workers with the same
base hyperparameters. Run:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/convergence_study.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.parallel import make_runtime
from repro.parallel.policy import RunPolicy
from repro.data import DataConfig, make_source
from repro.launch.mesh import make_local_mesh


def run(op: str, span: int, lr: float, steps: int = 120) -> float:
    mesh = make_local_mesh(min(span, len(jax.devices())), 1)
    cfg = ModelConfig("study-lm", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
    model = build_model(cfg, attn_chunk=32)
    rpol = RunPolicy(span=span, backend="gspmd_tree", optimizer="momentum",
                     combine_op=op)
    rt = make_runtime(model, mesh, rpol, lr=lr)
    state = rt.init_state(jax.random.key(0))
    src = make_source(DataConfig(seq_len=64, global_batch=span * 4,
                                 vocab_size=cfg.vocab_size, seed=5), cfg)
    step_fn = jax.jit(rt.train_step, donate_argnums=(0,))
    loss = float("nan")
    for step in range(steps):
        b = {k: jnp.asarray(v) for k, v in src.batch(step).items()}
        state, m = step_fn(state, b)
        loss = float(m["loss"])
        if not np.isfinite(loss):
            return loss
    return loss


def main():
    lr = 0.8     # aggressive base schedule, deliberately not re-tuned
    print(f"{'workers':>8s} {'Sum':>10s} {'Adasum':>10s}   (final loss, "
          f"lr={lr} untuned)")
    for span in (2, 4, 8):
        ls = run("sum", span, lr)
        la = run("adasum", span, lr)
        verdict = "adasum converges" if (np.isfinite(la) and
                                         (not np.isfinite(ls) or la < ls)) \
            else ""
        print(f"{span:8d} {ls:10.4f} {la:10.4f}   {verdict}")


if __name__ == "__main__":
    main()
