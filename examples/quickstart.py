"""Quickstart: train a small LM with Adasum data parallelism.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py

This is the Horovod 3-liner of the paper (§4.1) in this framework:
    opt = hvd.DistributedOptimizer(opt, op=hvd.Adasum)
becomes a RunPolicy(combine_op="adasum") handed to make_runtime.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.parallel import make_runtime
from repro.parallel.policy import RunPolicy
from repro.data import DataConfig, make_source
from repro.launch.mesh import make_local_mesh


def main():
    n_dev = len(jax.devices())
    data_par = max(1, n_dev // 2) if n_dev > 1 else 1
    model_par = 2 if n_dev >= 2 else 1
    mesh = make_local_mesh(data_par, model_par)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    cfg = ModelConfig("quickstart-lm", "dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=257,
                      head_dim=16)
    model = build_model(cfg, attn_chunk=32)

    # the paper's one-flag switch: op="adasum" (vs the "sum" baseline)
    rpol = RunPolicy(span=0, backend="rvh" if data_par > 1 else "gspmd_tree",
                     optimizer="adam", combine_op="adasum")
    rt = make_runtime(model, mesh, rpol, lr=2e-3)
    state = rt.init_state(jax.random.key(0))

    src = make_source(DataConfig(seq_len=64, global_batch=max(8, data_par),
                                 vocab_size=cfg.vocab_size), cfg)
    step_fn = jax.jit(rt.train_step, donate_argnums=(0,))
    for step in range(40):
        batch = {k: jnp.asarray(v) for k, v in src.batch(step).items()}
        state, metrics = step_fn(state, batch)
        if step % 10 == 0 or step == 39:
            print(f"step {step:3d}  loss {float(metrics['loss']):.4f}  "
                  f"(adasum over {rt.span} lanes)")
    print("done — swap combine_op='sum' to see the synchronous-SGD baseline")


if __name__ == "__main__":
    main()
