"""Quickstart: train a small LM with Adasum data parallelism.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/quickstart.py

This is the Horovod 3-liner of the paper (§4.1) in this framework:
    opt = hvd.DistributedOptimizer(opt, op=hvd.Adasum)
becomes

    cfg = EngineConfig(arch=..., combine="adasum")
    session = TrainSession.from_config(cfg)
    session.fit(steps)

Below we pass a hand-built tiny model instead of a registry arch to show
the custom-model path; swap combine="sum" for the synchronous baseline.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax

from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model


def main():
    n_dev = len(jax.devices())
    cfg = EngineConfig(
        combine="adasum",          # the paper's one-flag switch (vs "sum")
        optimizer="adam", lr=2e-3,
        model_mesh=2 if n_dev >= 2 else 1,
        seq_len=64, global_batch=max(8, n_dev), steps=40, log_every=10)

    mcfg = ModelConfig("quickstart-lm", "dense", n_layers=2, d_model=64,
                       n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=257,
                       head_dim=16)
    session = TrainSession.from_config(
        cfg, model=build_model(mcfg, attn_chunk=32))
    print(f"mesh: {dict(zip(session.mesh.axis_names, session.mesh.devices.shape))}")
    session.fit(cfg.steps)
    print("done — swap combine='sum' to see the synchronous-SGD baseline")


if __name__ == "__main__":
    main()
