"""End-to-end training example: a multi-layer LM trained for a few
hundred steps with Adasum DP, checkpointing, and fault-tolerant resume —
all through the engine API (TrainSession handles resume + checkpoints).

Default: ~5M params x 300 steps (CPU-friendly). `--big` switches to a
~100M-param model (10L x 640d, 50k vocab) on the same code path — the
configuration the paper-scale run would use; budget hours on a 1-core
CPU container, minutes on a real accelerator.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/train_e2e.py [--big] [--steps N]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model, count_params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="runs/train_e2e")
    args = ap.parse_args()

    if args.big:
        mcfg = ModelConfig("e2e-100m", "dense", n_layers=10, d_model=640,
                           n_heads=10, n_kv_heads=5, d_ff=2560,
                           vocab_size=50_000, head_dim=64)
    else:
        mcfg = ModelConfig("e2e-5m", "dense", n_layers=4, d_model=128,
                           n_heads=4, n_kv_heads=2, d_ff=512,
                           vocab_size=8_192, head_dim=32)
    model = build_model(mcfg, attn_chunk=min(128, args.seq))
    print(f"[e2e] {mcfg.name}: {count_params(mcfg)/1e6:.1f}M params")

    cfg = EngineConfig(combine="adasum", optimizer="adam", lr=1e-3,
                       seq_len=args.seq, global_batch=args.batch,
                       data_seed=11, steps=args.steps, ckpt_dir=args.ckpt,
                       ckpt_every=100, log_every=25)
    session = TrainSession.from_config(cfg, model=model)
    session.fit(args.steps)
    print("[e2e] done.")


if __name__ == "__main__":
    main()
