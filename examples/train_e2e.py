"""End-to-end training example: a multi-layer LM trained for a few
hundred steps with Adasum DP, checkpointing, and fault-tolerant resume.

Default: ~5M params x 300 steps (CPU-friendly). `--big` switches to a
~100M-param model (10L x 640d, 50k vocab) on the same code path — the
configuration the paper-scale run would use; budget hours on a 1-core
CPU container, minutes on a real accelerator.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/train_e2e.py [--big] [--steps N]
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import build_model, count_params
from repro.parallel import make_runtime
from repro.parallel.policy import RunPolicy
from repro.data import DataConfig, make_source
from repro.checkpoint import CheckpointManager
from repro.runtime import StepMonitor
from repro.launch.mesh import make_local_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--big", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="runs/train_e2e")
    args = ap.parse_args()

    if args.big:
        cfg = ModelConfig("e2e-100m", "dense", n_layers=10, d_model=640,
                          n_heads=10, n_kv_heads=5, d_ff=2560,
                          vocab_size=50_000, head_dim=64)
    else:
        cfg = ModelConfig("e2e-5m", "dense", n_layers=4, d_model=128,
                          n_heads=4, n_kv_heads=2, d_ff=512,
                          vocab_size=8_192, head_dim=32)
    model = build_model(cfg, attn_chunk=min(128, args.seq))
    print(f"[e2e] {cfg.name}: {count_params(cfg)/1e6:.1f}M params")

    n = len(jax.devices())
    mesh = make_local_mesh(max(1, n // 1), 1)
    rpol = RunPolicy(span=0, backend="rvh" if n > 1 else "gspmd_tree",
                     optimizer="adam", combine_op="adasum")
    rt = make_runtime(model, mesh, rpol, lr=1e-3)
    state = rt.init_state(jax.random.key(0))

    ckpt = CheckpointManager(args.ckpt, keep=2)
    start = 0
    if ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start = int(jax.device_get(state["step"]))
        print(f"[e2e] resumed at step {start}")

    src = make_source(DataConfig(seq_len=args.seq, global_batch=args.batch,
                                 vocab_size=cfg.vocab_size, seed=11), cfg)
    step_fn = jax.jit(rt.train_step, donate_argnums=(0,))
    mon = StepMonitor()
    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in src.batch(step).items()}
        mon.start()
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        mon.stop()
        if step % 25 == 0 or step == args.steps - 1:
            print(f"[e2e] step {step:4d} loss {loss:.4f} "
                  f"({(time.time()-t0)/max(step-start+1,1):.2f}s/step avg)")
        if (step + 1) % 100 == 0:
            ckpt.save(step + 1, state)
    ckpt.save(args.steps, state)
    print(f"[e2e] done. monitor={mon.summary()}")


if __name__ == "__main__":
    main()
