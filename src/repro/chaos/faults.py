"""Checkpoint fault injection: mutate checkpoint bytes on disk.

Each helper damages the NEWEST complete step under a checkpoint root in
one specific way, returning the step it hit (None when there is nothing
to damage). They model the storage faults the integrity layer
(`repro.checkpoint.manager`) must catch:

    bitflip_leaf    silent single-bit corruption -> crc32 mismatch
    tear_leaf       truncated (torn) write       -> np.load failure
    drop_leaf       lost leaf file               -> missing leaf
    drop_manifest   lost manifest.json           -> step invisible

`drop_manifest` is the one class restore cannot *diagnose* — without a
manifest the dir no longer matches `all_steps()` at all — so recovery is
silent fallback to the previous step rather than quarantine.
"""
from __future__ import annotations

import re
from pathlib import Path
from typing import Optional


def _newest_step_dir(root) -> Optional[Path]:
    """The newest fully-renamed step dir still carrying a manifest."""
    best, best_step = None, -1
    for p in Path(root).iterdir():
        m = re.fullmatch(r"step_(\d+)", p.name)
        if m and (p / "manifest.json").exists():
            s = int(m.group(1))
            if s > best_step:
                best, best_step = p, s
    return best


def _leaf_file(d: Path, index: int) -> Optional[Path]:
    leaves = sorted(d.glob("leaf-*.npy"))
    return leaves[index % len(leaves)] if leaves else None


def bitflip_leaf(root, index: int = 0) -> Optional[int]:
    """Flip one bit in a leaf payload (last byte — inside the array data,
    past the .npy header, so np.load still succeeds and only the crc32
    catches it)."""
    d = _newest_step_dir(root)
    if d is None:
        return None
    f = _leaf_file(d, index)
    if f is None:
        return None
    raw = bytearray(f.read_bytes())
    raw[-1] ^= 0x01
    f.write_bytes(bytes(raw))
    return int(d.name.split("_")[1])


def tear_leaf(root, index: int = 0) -> Optional[int]:
    """Truncate a leaf file to half its length — the torn-write case;
    np.load fails on the short payload."""
    d = _newest_step_dir(root)
    if d is None:
        return None
    f = _leaf_file(d, index)
    if f is None:
        return None
    raw = f.read_bytes()
    f.write_bytes(raw[:max(1, len(raw) // 2)])
    return int(d.name.split("_")[1])


def drop_leaf(root, index: int = 0) -> Optional[int]:
    """Delete a leaf file outright."""
    d = _newest_step_dir(root)
    if d is None:
        return None
    f = _leaf_file(d, index)
    if f is None:
        return None
    f.unlink()
    return int(d.name.split("_")[1])


def drop_manifest(root) -> Optional[int]:
    """Delete manifest.json — the step stops matching `all_steps()`, so
    restores silently resolve to the previous step."""
    d = _newest_step_dir(root)
    if d is None:
        return None
    (d / "manifest.json").unlink()
    return int(d.name.split("_")[1])


APPLIERS = {"ckpt_bitflip": bitflip_leaf, "ckpt_torn": tear_leaf,
            "ckpt_drop_leaf": drop_leaf,
            "ckpt_drop_manifest": lambda root, index=0: drop_manifest(root),
            "reload_corrupt": bitflip_leaf}


def apply_ckpt_fault(kind: str, root, index: int = 0) -> Optional[int]:
    """Dispatch a checkpoint fault class to its byte-level applier."""
    return APPLIERS[kind](root, index)
