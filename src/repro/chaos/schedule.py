"""Deterministic chaos schedules.

A `ChaosSchedule` is a seeded, pre-generated list of `FaultEvent`s — the
generalization of `runtime.monitor.FailureInjector`'s fixed step set to
every fault class the stack recovers from. Determinism is the whole
point: the same (seed, steps, kinds) always yields the same faults in
the same order, so a chaos run that trips an invariant is replayable
bit-for-bit, and CI can pin a seed known to exercise every class.

Fault classes (`ChaosSchedule.KINDS`):

    node_loss          participant gone mid-run  -> NodeLossError
    straggler          persistent slow node      -> monitor flag -> restart
    sigterm            preemption notice         -> SIGTERM to own pid
    comm_spike         interconnect latency      -> DelayedCombineStream.comm_delay
    ckpt_bitflip       silent corruption         -> crc32 mismatch on restore
    ckpt_torn          torn write                -> unreadable leaf .npy
    ckpt_drop_leaf     lost leaf file            -> missing leaf
    ckpt_drop_manifest lost manifest             -> step invisible to restore
    slow_prefill       serve-side slow prefill   -> deadline pressure
    page_exhaustion    KV pool pressure          -> pressure ladder / preempt
    reload_corrupt     corrupt newest ckpt       -> hot-reload last-good fallback

The schedule only *describes* faults; `repro.chaos.inject` applies the
train-side ones through the Callback protocol and `repro.chaos.faults`
mutates checkpoint bytes on disk.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fire at `step`, of class `kind`, with an
    optional magnitude `arg` (seconds for latency-type faults)."""
    step: int
    kind: str
    arg: float = 0.0


class ChaosSchedule:
    """An ordered, consumable fault schedule (events pop when applied)."""

    KINDS: Tuple[str, ...] = (
        "node_loss", "straggler", "sigterm", "comm_spike",
        "ckpt_bitflip", "ckpt_torn", "ckpt_drop_leaf",
        "ckpt_drop_manifest", "slow_prefill", "page_exhaustion",
        "reload_corrupt")

    def __init__(self, events: Sequence[FaultEvent] = ()):
        for e in events:
            if e.kind not in self.KINDS:
                raise ValueError(f"unknown fault kind {e.kind!r} "
                                 f"(known: {', '.join(self.KINDS)})")
        self._events: List[FaultEvent] = sorted(events,
                                                key=lambda e: e.step)
        self.applied: List[FaultEvent] = []

    # --------------------------------------------------------------- build
    @classmethod
    def generate(cls, seed: int, steps: int, *,
                 kinds: Optional[Sequence[str]] = None,
                 rate: float = 0.05, min_step: int = 1,
                 max_arg_s: float = 0.05) -> "ChaosSchedule":
        """Seeded random schedule: each step in [min_step, steps) draws a
        fault with probability `rate`, uniform over `kinds` (default: all
        classes), latency args uniform in (0, max_arg_s]. Pure function
        of its arguments — RandomState, not the global generator."""
        kinds = tuple(kinds) if kinds is not None else cls.KINDS
        for k in kinds:
            if k not in cls.KINDS:
                raise ValueError(f"unknown fault kind {k!r}")
        rng = np.random.RandomState(seed)
        events = []
        for step in range(min_step, steps):
            if rng.rand() < rate:
                kind = kinds[rng.randint(len(kinds))]
                arg = float(rng.uniform(0.0, max_arg_s))
                events.append(FaultEvent(step, kind, arg))
        return cls(events)

    # ------------------------------------------------------------- consume
    def at(self, step: int,
           kinds: Optional[Sequence[str]] = None) -> List[FaultEvent]:
        """Pop (and return) every event scheduled at exactly `step`,
        optionally restricted to `kinds`."""
        hit, rest = [], []
        for e in self._events:
            if e.step == step and (kinds is None or e.kind in kinds):
                hit.append(e)
            else:
                rest.append(e)
        self._events = rest
        self.applied += hit
        return hit

    def take(self, kinds: Sequence[str]) -> List[FaultEvent]:
        """Pop every event of the given kinds regardless of step — for
        consumers that fire at boundaries (restart hooks) rather than on
        a step counter."""
        hit, rest = [], []
        for e in self._events:
            (hit if e.kind in kinds else rest).append(e)
        self._events = rest
        self.applied += hit
        return hit

    def take_one(self, kinds: Sequence[str]) -> Optional[FaultEvent]:
        """Pop the earliest-scheduled event of the given kinds, if any."""
        for i, e in enumerate(self._events):
            if e.kind in kinds:
                del self._events[i]
                self.applied.append(e)
                return e
        return None

    def pending(self) -> List[FaultEvent]:
        """Events not yet consumed."""
        return list(self._events)

    def __len__(self) -> int:
        return len(self._events)
