"""Chaos-injection subsystem: deterministic, seeded fault schedules and
the injectors that apply them to live train/serve runs. See
`benchmarks/chaos_soak.py` for the end-to-end resilience harness."""
from .faults import (apply_ckpt_fault, bitflip_leaf, drop_leaf,
                     drop_manifest, tear_leaf)
from .inject import (CapacityReturnCallback, ChaosCallback,
                     make_chaos_on_restart, slow_prefill)
from .schedule import ChaosSchedule, FaultEvent

__all__ = [
    "CapacityReturnCallback", "ChaosCallback", "ChaosSchedule",
    "FaultEvent", "apply_ckpt_fault", "bitflip_leaf", "drop_leaf",
    "drop_manifest", "make_chaos_on_restart", "slow_prefill", "tear_leaf",
]
