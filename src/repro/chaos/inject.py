"""Apply a ChaosSchedule to live runs — train and serve side.

Train-side faults ride the existing Callback protocol (duck-typed so
this module never imports jax/engine at import time):

  * `node_loss`  -> raise NodeLossError at step start (what a real lost
    participant surfaces as); `fit_elastic` shrinks DP and resumes.
  * `sigterm`    -> SIGTERM to our own pid at step start; the checkpoint
    manager's preemption handler saves-and-exits(143).
  * `straggler`  -> force the StragglerCallback monitors' flag; the
    pipeline raises RestartSignal at the step boundary.
  * `comm_spike` -> one step of injected interconnect latency through
    `DelayedCombineStream.comm_delay` (restored the next step).
    Latency-only: the delayed engine's math is unchanged, so the run
    stays bitwise identical to an un-spiked one — the soak asserts it.

Checkpoint faults don't fire on a step counter; `make_chaos_on_restart`
adapts them to `fit_elastic(on_restart=...)`, damaging the just-written
boundary checkpoint so the subsequent restore must prove its fallback.

Serve-side, `slow_prefill` wraps an engine's admission prefill in a
sleep (deadline pressure); page exhaustion and reload corruption need no
injector — the soak provokes them with a tiny `kv_pages` pool and
`faults.bitflip_leaf` on the watched checkpoint dir.
"""
from __future__ import annotations

import os
import signal as _signal
import time
from typing import Callable

from repro.runtime import GrowBackSignal, NodeLossError

from .faults import apply_ckpt_fault
from .schedule import ChaosSchedule

_CKPT_KINDS = ("ckpt_bitflip", "ckpt_torn", "ckpt_drop_leaf",
               "ckpt_drop_manifest")


class ChaosCallback:
    """Feeds a schedule's train-side faults into the step loop."""

    def __init__(self, schedule: ChaosSchedule):
        self.schedule = schedule
        self._spiked = None   # (stream, saved comm_delay) to restore

    def on_fit_start(self, session, start_step):
        pass

    def on_step_start(self, session, step):
        for e in self.schedule.at(step, kinds=("node_loss", "sigterm")):
            if e.kind == "node_loss":
                raise NodeLossError(
                    f"chaos: injected node loss at step {step}")
            print(f"[chaos] SIGTERM at step {step}")
            os.kill(os.getpid(), _signal.SIGTERM)

    def on_step_end(self, session, step, metrics, dt):
        if self._spiked is not None:
            stream, old = self._spiked
            stream.comm_delay = old
            self._spiked = None
        for e in self.schedule.at(step, kinds=("comm_spike", "straggler")):
            if e.kind == "comm_spike":
                stream = getattr(session, "_delayed_stream", None)
                if stream is not None:
                    self._spiked = (stream, stream.comm_delay)
                    stream.comm_delay = e.arg
                    print(f"[chaos] comm spike {e.arg * 1e3:.0f}ms "
                          f"after step {step}")
            else:
                from repro.engine.session import StragglerCallback
                for cb in session.callbacks:
                    if isinstance(cb, StragglerCallback):
                        cb.monitor.flagged = True
                print(f"[chaos] straggler flagged after step {step}")

    def on_fit_end(self, session, history):
        pass


class CapacityReturnCallback:
    """Models lost capacity coming back: once the run is below its full
    DP degree (post-shrink) for `delay` steps, raise `GrowBackSignal` so
    `fit_elastic` re-expands through the same save -> rebuild -> resume
    machinery. Re-arms after each firing — capacity can return after
    every loss (fit_elastic's max_grow_backs bounds the total); `fired`
    counts the firings."""

    def __init__(self, delay: int = 2):
        self.delay = delay
        self.fired = 0
        self._full = 0
        self._count = 0

    def on_fit_start(self, session, start_step):
        self._full = max(self._full, session.runtime.dp_total)
        self._count = 0

    def on_step_start(self, session, step):
        pass

    def on_step_end(self, session, step, metrics, dt):
        if session.runtime.dp_total >= self._full:
            return
        self._count += 1
        if self._count >= self.delay:
            self._count = 0
            self.fired += 1
            raise GrowBackSignal(step + 1, target_dp=self._full)

    def on_fit_end(self, session, history):
        pass


def make_chaos_on_restart(schedule: ChaosSchedule,
                          ckpt_root) -> Callable:
    """Adapter for `fit_elastic(on_restart=...)`: at each elastic
    boundary (after the driver's `save_sync`, before the rebuild) pop
    ONE pending checkpoint fault from the schedule and apply it to the
    just-written step — the restore on the other side of the rebuild
    must fall back to last-good."""
    def on_restart(session, sig):
        e = schedule.take_one(_CKPT_KINDS)
        if e is None:
            return
        hit = apply_ckpt_fault(e.kind, ckpt_root)
        print(f"[chaos] {e.kind} applied to checkpoint step {hit} "
              f"at elastic boundary ({sig})")
    return on_restart


def slow_prefill(engine, delay_s: float) -> Callable[[], None]:
    """Serve-side fault: every admission prefill sleeps `delay_s` first
    (a slow/overloaded prefill path). Returns an undo callable."""
    orig = engine._admit_batch

    def slowed(admitted):
        time.sleep(delay_s)
        return orig(admitted)

    engine._admit_batch = slowed

    def undo():
        engine._admit_batch = orig
    return undo
