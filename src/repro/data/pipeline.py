"""Deterministic, restart-safe data pipeline.

Design for 1000+ nodes: every batch is a pure function of (seed, step,
host_slice) — no shared queue, no coordinator. A restarted (or
re-sharded) job resumes the exact stream position from the checkpointed
step counter alone. Hosts materialize only their slice of the global
batch (`host_slice` from the mesh addressing); on this single-host test
container the slice is the whole batch.

Sources:
  * SyntheticLM  — zipf-ish token stream with a planted bigram structure
    (so models actually have something learnable; loss curves are
    meaningful in the convergence benchmarks).
  * MemmapTokens — fixed token file (np.memmap), deterministic chunking.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seq_len: int
    global_batch: int
    vocab_size: int
    seed: int = 0
    kind: str = "synthetic"      # synthetic | memmap
    path: Optional[str] = None
    host_start: int = 0          # this host's slice of the global batch
    host_rows: int = 0           # 0 => all rows


class SyntheticLM:
    """Learnable synthetic stream: per-document Markov chain whose
    transition table is derived from a fixed seed."""

    def __init__(self, cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
        self.cfg = cfg
        self.model_cfg = model_cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        self._succ = rng.integers(0, v, size=(v, 4), dtype=np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = cfg.host_rows or cfg.global_batch
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) % (2 ** 63))
        B, T, v = rows, cfg.seq_len, cfg.vocab_size
        toks = np.empty((B, T), np.int32)
        toks[:, 0] = rng.integers(0, v, size=B)
        branch = rng.integers(0, 4, size=(B, T))
        noise = rng.random((B, T)) < 0.1
        rand = rng.integers(0, v, size=(B, T))
        for t in range(1, T):
            nxt = self._succ[toks[:, t - 1], branch[:, t]]
            toks[:, t] = np.where(noise[:, t], rand[:, t], nxt)
        out = {"tokens": toks, "labels": np.roll(toks, -1, axis=1)}
        out["labels"][:, -1] = -100
        mc = self.model_cfg
        if mc is not None and mc.frontend != "none":
            ft = mc.frontend_tokens or max(T // 2, 1)
            if mc.is_encoder_decoder:
                ft = T // 2
            out["frontend_embeds"] = rng.standard_normal(
                (B, ft, mc.frontend_dim)).astype(np.float32) * 0.02
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class MemmapTokens:
    def __init__(self, cfg: DataConfig):
        assert cfg.path, "memmap source needs a path"
        self.cfg = cfg
        self.data = np.memmap(cfg.path, dtype=np.int32, mode="r")

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rows = cfg.host_rows or cfg.global_batch
        T = cfg.seq_len
        n_chunks = len(self.data) // (T + 1)
        rng = np.random.default_rng((cfg.seed * 9_999_991 + step) % (2 ** 63))
        idx = rng.integers(0, n_chunks, size=rows)
        toks = np.stack([self.data[i * (T + 1): i * (T + 1) + T]
                         for i in idx]).astype(np.int32)
        labels = np.stack([self.data[i * (T + 1) + 1: i * (T + 1) + T + 1]
                           for i in idx]).astype(np.int32)
        return {"tokens": toks, "labels": labels}


def make_source(cfg: DataConfig, model_cfg: Optional[ModelConfig] = None):
    if cfg.kind == "synthetic":
        return SyntheticLM(cfg, model_cfg)
    if cfg.kind == "memmap":
        return MemmapTokens(cfg)
    raise KeyError(cfg.kind)
