"""Atomic, mesh-agnostic, elastic checkpointing.

Layout (one directory per step):
    <root>/step_000120.tmp/          # written first
        manifest.json                # leaf paths, shapes, dtypes, step
        <leaf-000>.npy ...           # one file per pytree leaf
    <root>/step_000120/              # atomic rename on completion

Properties needed at 1000+ nodes (DESIGN.md §6):
  * atomic: readers never see a partial checkpoint (tmp + rename);
  * mesh-agnostic: leaves are stored as FULL logical arrays, so a reload
    may use any mesh/DP degree (elastic scaling) — Adasum needs no
    hyperparameter change when the DP degree changes, which is what makes
    elastic restarts safe (paper §5.4);
  * per-host sharded writes at scale: each host writes only leaves it
    owns (`host_owns` hook); on this single-host container that is all
    of them;
  * keep-N garbage collection + SIGTERM-safe save.

Elastic note: optimizer state in post-optimizer mode has a leading lane
axis; `reshard_lanes` folds/splits it when the Adasum span changes
(deltas of merged lanes are averaged — the same degradation Horovod
accepts when nodes change).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import sys
import time
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


class CheckpointIntegrityError(ValueError):
    """A checkpoint step failed restore-time validation (missing, torn,
    or bit-flipped leaf files; missing/unreadable manifest). The manager
    quarantines the offending step before raising, so a retry against
    `latest_step()` lands on the previous (last-good) step."""

    def __init__(self, step: int, problems: List[str]):
        super().__init__(
            f"checkpoint step {step} failed integrity validation: "
            + "; ".join(problems))
        self.step = step
        self.problems = list(problems)


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _leaf_files(tree: PyTree) -> List[str]:
    leaves = jax.tree.leaves(tree)
    return [f"leaf-{i:05d}.npy" for i in range(len(leaves))]


def _leaf_paths(tree: PyTree) -> List[str]:
    """Stable string path per leaf (jax keystr), e.g. "['params']['embed']".
    Written into the manifest so subtree restores (restore_params) can
    address leaves by name instead of by flatten position."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in flat]


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = Path(root)
        self.keep = keep
        self.root.mkdir(parents=True, exist_ok=True)
        self._in_save = False
        self._pending_sigterm = False
        # resilience counters (surfaced in run_metadata()/throughput())
        self.restore_fallbacks = 0
        self.quarantined: List[Dict[str, Any]] = []

    # ------------------------------------------------------------- save
    def save(self, step: int, state: PyTree, host_owns=None) -> Path:
        self._in_save = True
        try:
            name = f"step_{step:08d}"
            tmp = self.root / (name + ".tmp")
            final = self.root / name
            if final.exists():
                return final
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            leaves, treedef = jax.tree.flatten(state)
            files = _leaf_files(state)
            paths = _leaf_paths(state)
            meta = {"step": step, "n_leaves": len(leaves),
                    "time": time.time(),
                    "leaves": []}
            for i, (leaf, fname, lpath) in enumerate(
                    zip(leaves, files, paths)):
                if host_owns is not None and not host_owns(i):
                    continue
                arr = np.asarray(jax.device_get(leaf))
                np.save(tmp / fname, arr)
                meta["leaves"].append({"file": fname, "path": lpath,
                                       "shape": list(arr.shape),
                                       "dtype": str(arr.dtype),
                                       "crc32": _crc(arr)})
            (tmp / "manifest.json").write_text(json.dumps(meta))
            os.rename(tmp, final)
            self._gc()
            return final
        finally:
            self._in_save = False
            if self._pending_sigterm and sys.exc_info()[0] is None:
                # SIGTERM arrived mid-save and the save succeeded: the
                # step is durable, exit as a clean preemption. (A failed
                # save must keep propagating its own error instead.)
                self._pending_sigterm = False
                raise SystemExit(143)

    def _gc(self):
        # explicitly the base listing: the async subclass turns all_steps
        # into a writer barrier, and _gc runs ON the writer thread
        steps = CheckpointManager.all_steps(self)
        for s in steps[:-self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)
        bad = sorted(p.name for p in self.root.iterdir()
                     if re.fullmatch(r"step_\d+\.bad", p.name))
        for name in bad[:-self.keep] if self.keep else bad:
            shutil.rmtree(self.root / name, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for p in self.root.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------ integrity / quarantine
    def _load_step(self, step: int) -> Tuple[Dict, Dict[str, np.ndarray]]:
        """Read every leaf file the manifest names, verifying existence,
        np.load-ability (torn writes fail here), shape/dtype against the
        manifest, and the per-leaf crc32 written at save time (absent in
        pre-integrity checkpoints — tolerated). Returns (manifest,
        {file: array}); raises CheckpointIntegrityError listing EVERY
        problem found, not just the first."""
        d = self.root / f"step_{step:08d}"
        mf = d / "manifest.json"
        if not mf.exists():
            raise CheckpointIntegrityError(
                step, [f"missing manifest.json under {d}"])
        try:
            meta = json.loads(mf.read_text())
        except (json.JSONDecodeError, OSError) as e:
            raise CheckpointIntegrityError(
                step, [f"unreadable manifest.json: {e}"])
        problems, arrays = [], {}
        for entry in meta.get("leaves", []):
            fname = entry["file"]
            lpath = entry.get("path", "?")
            f = d / fname
            if not f.exists():
                problems.append(f"missing leaf {fname} ({lpath})")
                continue
            try:
                arr = np.load(f)
            except Exception as e:  # torn write: bad .npy header/payload
                problems.append(f"unreadable leaf {fname} ({lpath}): "
                                f"{type(e).__name__}")
                continue
            if (list(arr.shape) != list(entry["shape"])
                    or str(arr.dtype) != entry["dtype"]):
                problems.append(
                    f"leaf {fname} ({lpath}): stored "
                    f"{arr.dtype}{list(arr.shape)} != manifest "
                    f"{entry['dtype']}{entry['shape']}")
                continue
            crc = entry.get("crc32")
            if crc is not None and _crc(arr) != crc:
                problems.append(f"checksum mismatch in {fname} ({lpath})")
                continue
            arrays[fname] = arr
        if problems:
            raise CheckpointIntegrityError(step, problems)
        return meta, arrays

    def validate_step(self, step: int) -> List[str]:
        """Integrity problems for `step` ([] = valid)."""
        try:
            self._load_step(step)
        except CheckpointIntegrityError as e:
            return e.problems
        return []

    def quarantine(self, step: int, problems: List[str]) -> None:
        """Rename the step dir to `step_XXXXXXXX.bad` — a name
        `all_steps()` (and hence `latest_step()`/`_gc`) never matches —
        so subsequent restores fall through to the previous step. The
        dir is kept (not deleted) for post-mortem inspection until _gc
        trims old .bad dirs."""
        d = self.root / f"step_{step:08d}"
        bad = self.root / f"step_{step:08d}.bad"
        if d.exists():
            if bad.exists():
                shutil.rmtree(bad, ignore_errors=True)
            os.rename(d, bad)
        self.quarantined.append({"step": step, "problems": list(problems)})
        print(f"[ckpt] quarantined step {step} -> {bad.name}: "
              + "; ".join(problems))

    def _resolve_verified(self, step: Optional[int]):
        """(step, manifest, arrays) for an explicitly requested `step`
        (quarantine + raise if invalid), or — when step is None — the
        NEWEST step that passes validation, quarantining invalid ones on
        the way down and counting each skip as a restore fallback."""
        if step is not None:
            try:
                meta, arrays = self._load_step(step)
            except CheckpointIntegrityError as e:
                self.quarantine(step, e.problems)
                raise
            return step, meta, arrays
        steps = self.all_steps()
        if not steps:
            raise ValueError(f"no checkpoints under {self.root}")
        for s in reversed(steps):
            try:
                meta, arrays = self._load_step(s)
            except CheckpointIntegrityError as e:
                self.quarantine(s, e.problems)
                self.restore_fallbacks += 1
                print(f"[ckpt] falling back past corrupt step {s} "
                      f"to last good")
                continue
            return s, meta, arrays
        raise ValueError(
            f"no valid checkpoints under {self.root}: every step failed "
            f"integrity validation (all quarantined)")

    def restore(self, like: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Loads into the structure of `like` (shapes may differ on the
        lane axis — see reshard_lanes). Every leaf is validated against
        the manifest checksums first; with step=None a corrupt newest
        step is quarantined and the previous (last-good) one restored
        automatically."""
        step, meta, arrays = self._resolve_verified(step)
        leaves, treedef = jax.tree.flatten(like)
        files = _leaf_files(like)
        if meta.get("n_leaves", len(leaves)) != len(leaves):
            raise ValueError(
                f"checkpoint step {step} holds {meta['n_leaves']} leaves "
                f"but the restore template has {len(leaves)} — saved from "
                f"a different model/optimizer structure?")
        missing = [f for f in files if f not in arrays]
        if missing:
            raise ValueError(
                f"checkpoint step {step} is missing {len(missing)} leaf "
                f"file(s): {', '.join(missing[:5])}"
                + ("..." if len(missing) > 5 else ""))
        out = []
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        for leaf, fname, sh in zip(leaves, files, shard_leaves):
            arr = arrays[fname]
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                arr = reshard_lanes(arr, want)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree.unflatten(treedef, out)

    def restore_params(self, template: PyTree, step: Optional[int] = None,
                       shardings: Optional[PyTree] = None) -> PyTree:
        """Params-only restore from a full train-state checkpoint: loads
        the leaves under the "params" subtree, addressed by manifest
        *path* (not flatten position), into the structure of `template`
        (a params pytree — concrete arrays or ShapeDtypeStructs).

        This is what lets a ServeEngine/ServeSession serve trained
        weights without reconstructing the optimizer state the training
        run checkpointed alongside them. Same integrity contract as
        `restore`: validated leaves, quarantine + last-good fallback
        with step=None, one clear ValueError (naming the step and every
        missing leaf) on structural mismatch."""
        step, meta, arrays = self._resolve_verified(step)
        by_path = {l["path"]: l["file"] for l in meta["leaves"]
                   if "path" in l}
        if not by_path:
            raise ValueError(
                f"checkpoint step {step} predates path-indexed manifests; "
                f"re-save the checkpoint (or restore the full state and "
                f"take state['params'])")
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        missing = []
        for path, _ in flat:
            key = "['params']" + jax.tree_util.keystr(path)
            if key not in by_path:
                missing.append(key)
        if missing:
            raise ValueError(
                f"checkpoint step {step} is missing {len(missing)} params "
                f"leaf/leaves: {', '.join(missing[:5])}"
                + ("..." if len(missing) > 5 else "")
                + "; was it saved from a compatible model?")
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(flat))
        out = []
        for (path, leaf), sh in zip(flat, shard_leaves):
            key = "['params']" + jax.tree_util.keystr(path)
            arr = arrays[by_path[key]]
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                                 f"model shape {tuple(leaf.shape)}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------- SIGTERM handling
    def install_preemption_handler(self, save_fn):
        """Preemption-safe: on SIGTERM finish/do one save, then exit."""
        def handler(signum, frame):
            if self._in_save:
                self._pending_sigterm = True
                return
            save_fn()
            raise SystemExit(143)
        signal.signal(signal.SIGTERM, handler)


class AsyncCheckpointManager(CheckpointManager):
    """Overlapped checkpointing: the device->host snapshot happens in the
    caller's thread (it must — the train step donates the state buffers,
    so the arrays are gone by the next step), but serialization + file
    I/O run on a background writer thread, so the step loop resumes after
    the snapshot instead of after the fsync.

    Barriers (the only places the loop may block on the writer):
      * a new `save` overlapping an in-flight one waits for the previous
        write first (at most one checkpoint in flight);
      * `restore` / `all_steps` / `latest_step` wait for pending writes,
        so readers never miss the checkpoint they just scheduled.
    Writer-thread exceptions surface at the next barrier, never silently.
    """

    def __init__(self, root: str, keep: int = 3):
        super().__init__(root, keep)
        from concurrent.futures import ThreadPoolExecutor
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="repro-ckpt")
        self._future = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: PyTree, host_owns=None) -> Path:
        self.wait()
        # deep host snapshot: device_get on the CPU backend can alias the
        # donated device buffer, so force a copy
        host_state = jax.tree.map(
            lambda x: np.array(jax.device_get(x), copy=True), state)
        self._future = self._pool.submit(
            CheckpointManager.save, self, step, host_state, host_owns)
        return self.root / f"step_{step:08d}"

    def wait(self):
        """Barrier: block until the in-flight write (if any) completes,
        re-raising any writer-thread failure."""
        import threading
        if threading.current_thread().name.startswith("repro-ckpt"):
            return   # reentrant barrier from the writer itself: vacuous
        fut, self._future = self._future, None
        if fut is not None:
            fut.result()

    @property
    def in_flight(self) -> bool:
        return self._future is not None and not self._future.done()

    # ---------------------------------------------------------- readers
    def all_steps(self):
        self.wait()
        return super().all_steps()

    def restore(self, like: PyTree, step=None, shardings=None) -> PyTree:
        self.wait()
        return super().restore(like, step, shardings)

    def restore_params(self, template, step=None, shardings=None) -> PyTree:
        self.wait()
        return super().restore_params(template, step, shardings)

    def validate_step(self, step: int) -> List[str]:
        self.wait()
        return super().validate_step(step)

    def close(self):
        self.wait()
        self._pool.shutdown(wait=True)

    # --------------------------------------------------------- preemption
    def install_preemption_handler(self, save_fn):
        """SIGTERM: drain the in-flight background write, then one final
        save + exit. (The base class's `_in_save` deferral would span the
        entire background write here and drop the signal — `_in_save` is
        set by the WRITER thread, not the caller.)"""
        def handler(signum, frame):
            self.wait()
            save_fn()      # session.save_sync: snapshot + barrier
            raise SystemExit(143)
        signal.signal(signal.SIGTERM, handler)


def reshard_lanes(arr: np.ndarray, want: tuple) -> np.ndarray:
    """Elastic lane-axis resharding: fold (mean) or repeat the leading
    lane axis of per-lane optimizer state when the Adasum span changes."""
    if len(arr.shape) == len(want) and arr.shape[1:] == tuple(want[1:]):
        old, new = arr.shape[0], want[0]
        if old == new:
            return arr
        if old % new == 0:       # shrink: average lane groups
            return arr.reshape(new, old // new, *arr.shape[1:]).mean(axis=1)
        if new % old == 0:       # grow: replicate lanes
            return np.repeat(arr, new // old, axis=0)
    raise ValueError(f"cannot reshard {arr.shape} -> {want}")
