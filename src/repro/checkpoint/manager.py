"""Atomic, mesh-agnostic, elastic checkpointing.

Layout (one directory per step):
    <root>/step_000120.tmp/          # written first
        manifest.json                # leaf paths, shapes, dtypes, step
        <leaf-000>.npy ...           # one file per pytree leaf
    <root>/step_000120/              # atomic rename on completion

Properties needed at 1000+ nodes (DESIGN.md §6):
  * atomic: readers never see a partial checkpoint (tmp + rename);
  * mesh-agnostic: leaves are stored as FULL logical arrays, so a reload
    may use any mesh/DP degree (elastic scaling) — Adasum needs no
    hyperparameter change when the DP degree changes, which is what makes
    elastic restarts safe (paper §5.4);
  * per-host sharded writes at scale: each host writes only leaves it
    owns (`host_owns` hook); on this single-host container that is all
    of them;
  * keep-N garbage collection + SIGTERM-safe save.

Elastic note: optimizer state in post-optimizer mode has a leading lane
axis; `reshard_lanes` folds/splits it when the Adasum span changes
(deltas of merged lanes are averaged — the same degradation Horovod
accepts when nodes change).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import sys
import time
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _leaf_files(tree: PyTree) -> List[str]:
    leaves = jax.tree.leaves(tree)
    return [f"leaf-{i:05d}.npy" for i in range(len(leaves))]


def _leaf_paths(tree: PyTree) -> List[str]:
    """Stable string path per leaf (jax keystr), e.g. "['params']['embed']".
    Written into the manifest so subtree restores (restore_params) can
    address leaves by name instead of by flatten position."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(path) for path, _ in flat]


class CheckpointManager:
    def __init__(self, root: str, keep: int = 3):
        self.root = Path(root)
        self.keep = keep
        self.root.mkdir(parents=True, exist_ok=True)
        self._in_save = False
        self._pending_sigterm = False

    # ------------------------------------------------------------- save
    def save(self, step: int, state: PyTree, host_owns=None) -> Path:
        self._in_save = True
        try:
            name = f"step_{step:08d}"
            tmp = self.root / (name + ".tmp")
            final = self.root / name
            if final.exists():
                return final
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir(parents=True)
            leaves, treedef = jax.tree.flatten(state)
            files = _leaf_files(state)
            paths = _leaf_paths(state)
            meta = {"step": step, "n_leaves": len(leaves),
                    "time": time.time(),
                    "leaves": []}
            for i, (leaf, fname, lpath) in enumerate(
                    zip(leaves, files, paths)):
                if host_owns is not None and not host_owns(i):
                    continue
                arr = np.asarray(jax.device_get(leaf))
                np.save(tmp / fname, arr)
                meta["leaves"].append({"file": fname, "path": lpath,
                                       "shape": list(arr.shape),
                                       "dtype": str(arr.dtype)})
            (tmp / "manifest.json").write_text(json.dumps(meta))
            os.rename(tmp, final)
            self._gc()
            return final
        finally:
            self._in_save = False
            if self._pending_sigterm and sys.exc_info()[0] is None:
                # SIGTERM arrived mid-save and the save succeeded: the
                # step is durable, exit as a clean preemption. (A failed
                # save must keep propagating its own error instead.)
                self._pending_sigterm = False
                raise SystemExit(143)

    def _gc(self):
        # explicitly the base listing: the async subclass turns all_steps
        # into a writer barrier, and _gc runs ON the writer thread
        steps = CheckpointManager.all_steps(self)
        for s in steps[:-self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # ---------------------------------------------------------- restore
    def all_steps(self) -> List[int]:
        out = []
        for p in self.root.iterdir():
            m = re.fullmatch(r"step_(\d+)", p.name)
            if m and (p / "manifest.json").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: PyTree, step: Optional[int] = None,
                shardings: Optional[PyTree] = None) -> PyTree:
        """Loads into the structure of `like` (shapes may differ on the
        lane axis — see reshard_lanes)."""
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoints under {self.root}"
        d = self.root / f"step_{step:08d}"
        leaves, treedef = jax.tree.flatten(like)
        files = _leaf_files(like)
        out = []
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(leaves))
        for leaf, fname, sh in zip(leaves, files, shard_leaves):
            arr = np.load(d / fname)
            want = tuple(leaf.shape)
            if tuple(arr.shape) != want:
                arr = reshard_lanes(arr, want)
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree.unflatten(treedef, out)

    def restore_params(self, template: PyTree, step: Optional[int] = None,
                       shardings: Optional[PyTree] = None) -> PyTree:
        """Params-only restore from a full train-state checkpoint: loads
        the leaves under the "params" subtree, addressed by manifest
        *path* (not flatten position), into the structure of `template`
        (a params pytree — concrete arrays or ShapeDtypeStructs).

        This is what lets a ServeEngine/ServeSession serve trained
        weights without reconstructing the optimizer state the training
        run checkpointed alongside them."""
        step = step if step is not None else self.latest_step()
        assert step is not None, f"no checkpoints under {self.root}"
        d = self.root / f"step_{step:08d}"
        meta = json.loads((d / "manifest.json").read_text())
        by_path = {l["path"]: l["file"] for l in meta["leaves"]
                   if "path" in l}
        if not by_path:
            raise ValueError(
                f"{d} predates path-indexed manifests; re-save the "
                f"checkpoint (or restore the full state and take "
                f"state['params'])")
        flat, treedef = jax.tree_util.tree_flatten_with_path(template)
        shard_leaves = (jax.tree.leaves(shardings)
                        if shardings is not None else [None] * len(flat))
        out = []
        for (path, leaf), sh in zip(flat, shard_leaves):
            key = "['params']" + jax.tree_util.keystr(path)
            if key not in by_path:
                raise KeyError(f"checkpoint {d} has no leaf {key}; "
                               f"was it saved from a compatible model?")
            arr = np.load(d / by_path[key])
            if tuple(arr.shape) != tuple(leaf.shape):
                raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                                 f"model shape {tuple(leaf.shape)}")
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jnp.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    # ------------------------------------------------- SIGTERM handling
    def install_preemption_handler(self, save_fn):
        """Preemption-safe: on SIGTERM finish/do one save, then exit."""
        def handler(signum, frame):
            if self._in_save:
                self._pending_sigterm = True
                return
            save_fn()
            raise SystemExit(143)
        signal.signal(signal.SIGTERM, handler)


class AsyncCheckpointManager(CheckpointManager):
    """Overlapped checkpointing: the device->host snapshot happens in the
    caller's thread (it must — the train step donates the state buffers,
    so the arrays are gone by the next step), but serialization + file
    I/O run on a background writer thread, so the step loop resumes after
    the snapshot instead of after the fsync.

    Barriers (the only places the loop may block on the writer):
      * a new `save` overlapping an in-flight one waits for the previous
        write first (at most one checkpoint in flight);
      * `restore` / `all_steps` / `latest_step` wait for pending writes,
        so readers never miss the checkpoint they just scheduled.
    Writer-thread exceptions surface at the next barrier, never silently.
    """

    def __init__(self, root: str, keep: int = 3):
        super().__init__(root, keep)
        from concurrent.futures import ThreadPoolExecutor
        self._pool = ThreadPoolExecutor(max_workers=1,
                                        thread_name_prefix="repro-ckpt")
        self._future = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: PyTree, host_owns=None) -> Path:
        self.wait()
        # deep host snapshot: device_get on the CPU backend can alias the
        # donated device buffer, so force a copy
        host_state = jax.tree.map(
            lambda x: np.array(jax.device_get(x), copy=True), state)
        self._future = self._pool.submit(
            CheckpointManager.save, self, step, host_state, host_owns)
        return self.root / f"step_{step:08d}"

    def wait(self):
        """Barrier: block until the in-flight write (if any) completes,
        re-raising any writer-thread failure."""
        import threading
        if threading.current_thread().name.startswith("repro-ckpt"):
            return   # reentrant barrier from the writer itself: vacuous
        fut, self._future = self._future, None
        if fut is not None:
            fut.result()

    @property
    def in_flight(self) -> bool:
        return self._future is not None and not self._future.done()

    # ---------------------------------------------------------- readers
    def all_steps(self):
        self.wait()
        return super().all_steps()

    def restore(self, like: PyTree, step=None, shardings=None) -> PyTree:
        self.wait()
        return super().restore(like, step, shardings)

    def restore_params(self, template, step=None, shardings=None) -> PyTree:
        self.wait()
        return super().restore_params(template, step, shardings)

    def close(self):
        self.wait()
        self._pool.shutdown(wait=True)

    # --------------------------------------------------------- preemption
    def install_preemption_handler(self, save_fn):
        """SIGTERM: drain the in-flight background write, then one final
        save + exit. (The base class's `_in_save` deferral would span the
        entire background write here and drop the signal — `_in_save` is
        set by the WRITER thread, not the caller.)"""
        def handler(signum, frame):
            self.wait()
            save_fn()      # session.save_sync: snapshot + barrier
            raise SystemExit(143)
        signal.signal(signal.SIGTERM, handler)


def reshard_lanes(arr: np.ndarray, want: tuple) -> np.ndarray:
    """Elastic lane-axis resharding: fold (mean) or repeat the leading
    lane axis of per-lane optimizer state when the Adasum span changes."""
    if len(arr.shape) == len(want) and arr.shape[1:] == tuple(want[1:]):
        old, new = arr.shape[0], want[0]
        if old == new:
            return arr
        if old % new == 0:       # shrink: average lane groups
            return arr.reshape(new, old // new, *arr.shape[1:]).mean(axis=1)
        if new % old == 0:       # grow: replicate lanes
            return np.repeat(arr, new // old, axis=0)
    raise ValueError(f"cannot reshard {arr.shape} -> {want}")
