from .manager import CheckpointManager, reshard_lanes
