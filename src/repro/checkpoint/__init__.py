from .manager import (AsyncCheckpointManager, CheckpointIntegrityError,
                      CheckpointManager, reshard_lanes)
