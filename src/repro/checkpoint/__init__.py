from .manager import (AsyncCheckpointManager, CheckpointManager,
                      reshard_lanes)
