"""Retrace-hazard checker: the serve decode step sees ONE signature.

`ServeEngine`'s tick loop promises the jitted decode step compiles
exactly once, no matter how slots churn, page tables rewrite, prefill
rows scatter in, or hot-reload decodes the same cache under two param
versions. The runtime tests assert this for a handful of workloads; this
pass proves it statically: starting from the steady cache signature
(`abstract_serve_state` — the same eval_shape fixed point the engine
computes), every transition the engine can apply to the cache

  decode / sampled decode            (the tick itself)
  paged_insert_rows / insert_rows_at (admission, any group size)
  set_page_tables                    (page churn: growth, COW, release)
  copy_pages                         (COW backing-store moves)
  select_rows(_paged)                (hot-reload dual-version merge)
  verify / set_positions             (speculation: fused k+1 scoring,
                                      accept/rollback pos rewrite)
  draft propose / insert             (the draft's own dense cache, held
                                      to its own steady signature)

is eval_shaped and its output signature compared leaf-for-leaf against
the steady signature. Any drift — a recurrent leaf re-emitted in the
compute dtype (the quietly-dense rwkv/mamba class), a shape that grew
with position, a branch that changed a dtype — is a retrace hazard and
fails the check. No device executes anything.

The same promise holds on the train side for the delayed-combine step
(`combine_delay=1`): the jitted step must see ONE signature at every
step, INCLUDING the step-0 cold start, where the pending carry is the
zeros `init_state_fn` plants (Adasum of zeros is zero — no cond, no
second trace). `check_delayed_train` eval_shapes the delayed step from
the init-state signature — which IS the step-0 input — and requires the
output state to reproduce it leaf-for-leaf (a fixed point, so every
later step sees the same signature too). The split-stream pieces
(`local_fn` / `correction_fn` / `fold_fn`, what `DelayedCombineStream`
runs) are held to the same bar so the overlapped execution path cannot
diverge in trace shape from the single-program one.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

ARCHS = ("qwen3-32b", "mixtral-8x22b", "minicpm3-4b", "hymba-1.5b",
         "rwkv6-7b")
LAYOUTS = ("paged", "dense")
# delayed-combine train cells: one dense and one MoE preset, spans
# filtered at runtime to those the (possibly clamped) mesh supports
TRAIN_ARCHS = ("qwen3-32b", "moonshot-v1-16b-a3b")
TRAIN_SPANS = (1, 2, 4)
TRAIN_MESH = (4, 1)             # (data, model) — clamped by make_local_mesh


def _sig(tree) -> List[Tuple[str, Tuple[int, ...], str]]:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out.append((jax.tree_util.keystr(path), tuple(leaf.shape),
                    str(jnp.dtype(leaf.dtype))))
    return out


def signature_violations(steady, transitions) -> List[str]:
    """`transitions` is [(name, tree)]. Returns one line per leaf whose
    (path, shape, dtype) diverges from the steady cache signature —
    i.e. per distinct trace signature the decode step would see."""
    want = _sig(steady)
    want_map = dict((p, (s, d)) for p, s, d in want)
    bad: List[str] = []
    for name, tree in transitions:
        got = _sig(tree)
        if len(got) != len(want):
            bad.append(f"{name}: {len(got)} leaves != steady {len(want)}")
            continue
        for p, s, d in got:
            if p not in want_map:
                bad.append(f"{name}: unexpected leaf {p}")
            elif want_map[p] != (s, d):
                ws, wd = want_map[p]
                bad.append(f"{name}: {p} {s}/{d} != steady {ws}/{wd}")
    return bad


def check_arch(arch: str, layout: str, *, max_slots: int = 4,
               max_len: int = 64) -> Dict[str, Any]:
    """One (arch, requested layout) cell: build the abstract serve state
    and push the cache through every engine transition."""
    from repro.configs.base import get_reduced
    from repro.engine.build import (make_batched_decode_step,
                                    make_sampling_decode_step)
    from repro.engine.config import EngineConfig
    from repro.engine.serving.slots import (copy_pages, insert_rows_at,
                                            paged_insert_rows, select_rows,
                                            select_rows_paged,
                                            set_page_tables)
    from repro.engine.serving.engine import abstract_serve_state
    from repro.models import build_model

    config = EngineConfig(arch=arch, reduced=True, max_slots=max_slots,
                          max_len=max_len, kv_layout=layout,
                          speculation_k=2)
    model = build_model(get_reduced(arch))
    st = abstract_serve_state(config, model)
    cache, params = st["cache"], st["params"]
    B = st["max_slots"]
    i32 = jnp.int32
    tok = jax.ShapeDtypeStruct((B, 1), i32)
    transitions: List[Tuple[str, Any]] = []

    d = make_batched_decode_step(model)
    nxt, out = jax.eval_shape(d, params, tok, cache)
    transitions.append(("decode", out))
    tok_errs = []
    if (tuple(nxt.shape), jnp.dtype(nxt.dtype)) != ((B, 1), jnp.dtype(i32)):
        tok_errs.append(f"decode token out {nxt.shape}/{nxt.dtype} != "
                        f"({B}, 1)/int32 (breaks the tick's token feed)")
    ds = make_sampling_decode_step(model)
    policy = (jax.ShapeDtypeStruct((B, 2), jnp.uint32),
              jax.ShapeDtypeStruct((B,), i32),
              jax.ShapeDtypeStruct((B,), jnp.float32),
              jax.ShapeDtypeStruct((B,), i32),
              jax.ShapeDtypeStruct((B,), jnp.float32))
    transitions.append(
        ("decode_sampled", jax.eval_shape(ds, params, tok, cache,
                                          *policy)[1]))

    group_sizes = sorted({1, B})
    if st["layout"] == "paged":
        pps = st["pages"]["pages_per_slot"]
        num_pages = st["pages"]["num_pages"]
        for n in group_sizes:
            t = jax.ShapeDtypeStruct((n, pps), i32)
            transitions.append((f"paged_insert[n={n}]", jax.eval_shape(
                paged_insert_rows, cache, st["rows"][n],
                jax.ShapeDtypeStruct((n,), i32), t, t)))
        transitions.append(("set_page_tables", jax.eval_shape(
            set_page_tables, cache, jax.ShapeDtypeStruct((B, pps), i32))))
        one = jax.ShapeDtypeStruct((1,), i32)
        transitions.append(("copy_pages(cow)", jax.eval_shape(
            copy_pages, cache, one, one)))
        transitions.append(("select_rows_paged(hot_reload)", jax.eval_shape(
            select_rows_paged, jax.ShapeDtypeStruct((B,), jnp.bool_),
            jax.ShapeDtypeStruct((num_pages,), jnp.bool_), cache, cache)))
    else:
        for n in group_sizes:
            transitions.append((f"insert_rows_at[n={n}]", jax.eval_shape(
                insert_rows_at, cache, st["rows"][n],
                jax.ShapeDtypeStruct((n,), i32))))
        transitions.append(("select_rows(hot_reload)", jax.eval_shape(
            select_rows, jax.ShapeDtypeStruct((B,), jnp.bool_), cache,
            cache)))

    # speculation transitions: the verify step must map the TARGET cache
    # signature onto itself (it is dispatched on the same jitted cache
    # the decode tick owns), and the draft's dense cache — a separate
    # steady signature — must survive its own propose/prefill-insert
    # cycle. Absent for recurrent targets (speculation disables itself).
    spec = st["speculation"]
    draft_transitions: List[Tuple[str, Any]] = []
    if spec is not None:
        from repro.engine.build import (make_draft_propose,
                                        make_verify_step)
        from repro.engine.serving.slots import set_positions
        k = spec["k"]
        posB = jax.ShapeDtypeStruct((B,), i32)
        vtok = jax.ShapeDtypeStruct((B, k + 1), i32)
        nxt, g, acc, vout = jax.eval_shape(make_verify_step(model),
                                           params, vtok, cache)
        transitions.append(("verify", vout))
        for what, got, shape in (("verify nxt", nxt, (B, 1)),
                                 ("verify g", g, (B, k + 1)),
                                 ("verify acc", acc, (B,))):
            if (tuple(got.shape), jnp.dtype(got.dtype)) != (
                    shape, jnp.dtype(i32)):
                tok_errs.append(f"{what} {got.shape}/{got.dtype} != "
                                f"{shape}/int32")
        transitions.append(("set_positions(accept/rollback)",
                            jax.eval_shape(set_positions, cache, posB)))
        dmodel, dparams = spec["draft_model"], spec["draft_params"]
        dcache = spec["draft_cache"]
        drafts, dout = jax.eval_shape(make_draft_propose(dmodel, k),
                                      dparams, tok, dcache, posB)
        draft_transitions.append(("draft_propose", dout))
        if (tuple(drafts.shape), jnp.dtype(drafts.dtype)) != (
                (B, k), jnp.dtype(i32)):
            tok_errs.append(f"draft tokens {drafts.shape}/{drafts.dtype} "
                            f"!= ({B}, {k})/int32")
        for n in group_sizes:
            draft_transitions.append(
                (f"draft_insert[n={n}]", jax.eval_shape(
                    insert_rows_at, dcache, spec["draft_rows"][n],
                    jax.ShapeDtypeStruct((n,), i32))))

    violations = tok_errs + signature_violations(cache, transitions)
    if spec is not None:
        violations += signature_violations(spec["draft_cache"],
                                           draft_transitions)
    return {
        "arch": arch,
        "layout_requested": layout,
        "layout": st["layout"],
        "fallback_reason": st["fallback_reason"],
        "prefill_mode": st["prefill_mode"],
        "dense_fallback_leaves": st["dense_fallback"][0],
        "dense_fallback_bytes": st["dense_fallback"][1],
        "transitions": len(transitions) + len(draft_transitions),
        "speculation_checked": spec is not None,
        "violations": violations,
    }


def check_delayed_train(arch: str, span: int, mesh) -> Dict[str, Any]:
    """One delayed-combine train cell: eval_shape the combine_delay=1
    step on the init-state signature — which IS the step-0 cold-start
    input (pending = zeros, same avals every round) — and require the
    output state to reproduce it leaf-for-leaf. A signature fixed point
    means the jitted step compiles once for step 0 and every step after.
    The split-stream pieces (`local_fn`, `correction_fn` + `fold_fn` —
    the overlapped execution `DelayedCombineStream` runs) are pushed
    through the same check so the two delayed execution paths cannot
    diverge in trace shape."""
    import dataclasses

    import jax.numpy as jnp

    from repro.configs.base import get_reduced
    from repro.engine.build import build_runtime
    from repro.engine.config import EngineConfig
    from repro.models import build_model

    ecfg = EngineConfig.preset(arch, reduced=True)
    rpol = dataclasses.replace(ecfg.run_policy(), combine_delay=1,
                               span=span, accum_steps=1)
    mcfg = get_reduced(arch)
    model = build_model(mcfg, param_dtype=jnp.dtype(ecfg.param_dtype))
    rt = build_runtime(model, mesh, rpol)

    k = max(rpol.local_steps, 1)
    i32 = jnp.int32
    batch = {"tokens": jax.ShapeDtypeStruct((span * k, 16), i32),
             "labels": jax.ShapeDtypeStruct((span * k, 16), i32)}

    steady = rt.state_shapes                 # == the step-0 input state
    out_state, _ = jax.eval_shape(rt.train_step, steady, batch)
    transitions: List[Tuple[str, Any]] = [("delayed_step", out_state)]
    local_out, _ = jax.eval_shape(rt.local_fn, steady, batch)
    transitions.append(("local_step(stream)", local_out))
    corr = jax.eval_shape(rt.correction_fn, steady["pending"])
    folded = jax.eval_shape(rt.fold_fn, steady["params"], corr)

    violations = signature_violations(steady, transitions)
    violations += [f"fold(params, correction): {v.split(': ', 1)[-1]}"
                   for v in signature_violations(
                       steady["params"],
                       [("fold(params, correction)", folded)])]
    return {
        "arch": arch,
        "span": span,
        "dp": rt.dp_total,
        "local_steps": k,
        "combine_path": rt.combine_path,
        "transitions": len(transitions) + 1,     # + the fold check
        "violations": violations,
    }


def check_retrace(*, archs=ARCHS, layouts=LAYOUTS,
                  train_archs=TRAIN_ARCHS, train_spans=TRAIN_SPANS
                  ) -> Tuple[Dict[str, Any], List[str]]:
    report: Dict[str, Any] = {"cases": {}, "train": {}}
    violations: List[str] = []
    for arch in archs:
        for layout in layouts:
            entry = check_arch(arch, layout)
            report["cases"][f"{arch}|{layout}"] = entry
            violations += [f"{arch}|{layout}: {v}"
                           for v in entry["violations"]]

    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(*TRAIN_MESH)
    sizes = dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape)))
    dp = sizes.get("data", 1)
    spans = [s for s in train_spans if s <= dp and dp % s == 0] or [dp]
    for arch in train_archs:
        for span in spans:
            entry = check_delayed_train(arch, span, mesh)
            key = f"{arch}|delay=1|span={span}"
            report["train"][key] = entry
            violations += [f"{key}: {v}" for v in entry["violations"]]
    return report, violations


def render(report: Dict[str, Any]) -> str:
    lines = ["retrace signatures"]
    for key in sorted(report["cases"]):
        e = report["cases"][key]
        status = "OK" if not e["violations"] else "FAIL"
        extra = (f" dense_fallback={e['dense_fallback_leaves']} leaves"
                 if e["dense_fallback_leaves"] else "")
        lines.append(f"  {key:<28} layout={e['layout']:<6} "
                     f"prefill={e['prefill_mode']:<8} "
                     f"transitions={e['transitions']} {status}{extra}")
        lines += [f"      {v}" for v in e["violations"]]
    if report.get("train"):
        lines.append("delayed train-step signatures (combine_delay=1, "
                     "incl. step-0 cold start)")
        for key in sorted(report["train"]):
            e = report["train"][key]
            status = "OK" if not e["violations"] else "FAIL"
            lines.append(f"  {key:<40} dp={e['dp']} "
                         f"combine={e['combine_path'] or '-':<15} "
                         f"transitions={e['transitions']} {status}")
            lines += [f"      {v}" for v in e["violations"]]
    return "\n".join(lines)
