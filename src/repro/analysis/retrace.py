"""Retrace-hazard checker: the serve decode step sees ONE signature.

`ServeEngine`'s tick loop promises the jitted decode step compiles
exactly once, no matter how slots churn, page tables rewrite, prefill
rows scatter in, or hot-reload decodes the same cache under two param
versions. The runtime tests assert this for a handful of workloads; this
pass proves it statically: starting from the steady cache signature
(`abstract_serve_state` — the same eval_shape fixed point the engine
computes), every transition the engine can apply to the cache

  decode / sampled decode            (the tick itself)
  paged_insert_rows / insert_rows_at (admission, any group size)
  set_page_tables                    (page churn: growth, COW, release)
  copy_pages                         (COW backing-store moves)
  select_rows(_paged)                (hot-reload dual-version merge)

is eval_shaped and its output signature compared leaf-for-leaf against
the steady signature. Any drift — a recurrent leaf re-emitted in the
compute dtype (the quietly-dense rwkv/mamba class), a shape that grew
with position, a branch that changed a dtype — is a retrace hazard and
fails the check. No device executes anything.
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

ARCHS = ("qwen3-32b", "mixtral-8x22b", "minicpm3-4b", "hymba-1.5b",
         "rwkv6-7b")
LAYOUTS = ("paged", "dense")


def _sig(tree) -> List[Tuple[str, Tuple[int, ...], str]]:
    out = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        out.append((jax.tree_util.keystr(path), tuple(leaf.shape),
                    str(jnp.dtype(leaf.dtype))))
    return out


def signature_violations(steady, transitions) -> List[str]:
    """`transitions` is [(name, tree)]. Returns one line per leaf whose
    (path, shape, dtype) diverges from the steady cache signature —
    i.e. per distinct trace signature the decode step would see."""
    want = _sig(steady)
    want_map = dict((p, (s, d)) for p, s, d in want)
    bad: List[str] = []
    for name, tree in transitions:
        got = _sig(tree)
        if len(got) != len(want):
            bad.append(f"{name}: {len(got)} leaves != steady {len(want)}")
            continue
        for p, s, d in got:
            if p not in want_map:
                bad.append(f"{name}: unexpected leaf {p}")
            elif want_map[p] != (s, d):
                ws, wd = want_map[p]
                bad.append(f"{name}: {p} {s}/{d} != steady {ws}/{wd}")
    return bad


def check_arch(arch: str, layout: str, *, max_slots: int = 4,
               max_len: int = 64) -> Dict[str, Any]:
    """One (arch, requested layout) cell: build the abstract serve state
    and push the cache through every engine transition."""
    from repro.configs.base import get_reduced
    from repro.engine.build import (make_batched_decode_step,
                                    make_sampling_decode_step)
    from repro.engine.config import EngineConfig
    from repro.engine.serving.slots import (copy_pages, insert_rows_at,
                                            paged_insert_rows, select_rows,
                                            select_rows_paged,
                                            set_page_tables)
    from repro.engine.serving.engine import abstract_serve_state
    from repro.models import build_model

    config = EngineConfig(arch=arch, reduced=True, max_slots=max_slots,
                          max_len=max_len, kv_layout=layout)
    model = build_model(get_reduced(arch))
    st = abstract_serve_state(config, model)
    cache, params = st["cache"], st["params"]
    B = st["max_slots"]
    i32 = jnp.int32
    tok = jax.ShapeDtypeStruct((B, 1), i32)
    transitions: List[Tuple[str, Any]] = []

    d = make_batched_decode_step(model)
    nxt, out = jax.eval_shape(d, params, tok, cache)
    transitions.append(("decode", out))
    tok_errs = []
    if (tuple(nxt.shape), jnp.dtype(nxt.dtype)) != ((B, 1), jnp.dtype(i32)):
        tok_errs.append(f"decode token out {nxt.shape}/{nxt.dtype} != "
                        f"({B}, 1)/int32 (breaks the tick's token feed)")
    ds = make_sampling_decode_step(model)
    policy = (jax.ShapeDtypeStruct((B, 2), jnp.uint32),
              jax.ShapeDtypeStruct((B,), i32),
              jax.ShapeDtypeStruct((B,), jnp.float32),
              jax.ShapeDtypeStruct((B,), i32),
              jax.ShapeDtypeStruct((B,), jnp.float32))
    transitions.append(
        ("decode_sampled", jax.eval_shape(ds, params, tok, cache,
                                          *policy)[1]))

    group_sizes = sorted({1, B})
    if st["layout"] == "paged":
        pps = st["pages"]["pages_per_slot"]
        num_pages = st["pages"]["num_pages"]
        for n in group_sizes:
            t = jax.ShapeDtypeStruct((n, pps), i32)
            transitions.append((f"paged_insert[n={n}]", jax.eval_shape(
                paged_insert_rows, cache, st["rows"][n],
                jax.ShapeDtypeStruct((n,), i32), t, t)))
        transitions.append(("set_page_tables", jax.eval_shape(
            set_page_tables, cache, jax.ShapeDtypeStruct((B, pps), i32))))
        one = jax.ShapeDtypeStruct((1,), i32)
        transitions.append(("copy_pages(cow)", jax.eval_shape(
            copy_pages, cache, one, one)))
        transitions.append(("select_rows_paged(hot_reload)", jax.eval_shape(
            select_rows_paged, jax.ShapeDtypeStruct((B,), jnp.bool_),
            jax.ShapeDtypeStruct((num_pages,), jnp.bool_), cache, cache)))
    else:
        for n in group_sizes:
            transitions.append((f"insert_rows_at[n={n}]", jax.eval_shape(
                insert_rows_at, cache, st["rows"][n],
                jax.ShapeDtypeStruct((n,), i32))))
        transitions.append(("select_rows(hot_reload)", jax.eval_shape(
            select_rows, jax.ShapeDtypeStruct((B,), jnp.bool_), cache,
            cache)))

    violations = tok_errs + signature_violations(cache, transitions)
    return {
        "arch": arch,
        "layout_requested": layout,
        "layout": st["layout"],
        "fallback_reason": st["fallback_reason"],
        "prefill_mode": st["prefill_mode"],
        "dense_fallback_leaves": st["dense_fallback"][0],
        "dense_fallback_bytes": st["dense_fallback"][1],
        "transitions": len(transitions),
        "violations": violations,
    }


def check_retrace(*, archs=ARCHS, layouts=LAYOUTS
                  ) -> Tuple[Dict[str, Any], List[str]]:
    report: Dict[str, Any] = {"cases": {}}
    violations: List[str] = []
    for arch in archs:
        for layout in layouts:
            entry = check_arch(arch, layout)
            report["cases"][f"{arch}|{layout}"] = entry
            violations += [f"{arch}|{layout}: {v}"
                           for v in entry["violations"]]
    return report, violations


def render(report: Dict[str, Any]) -> str:
    lines = ["retrace signatures"]
    for key in sorted(report["cases"]):
        e = report["cases"][key]
        status = "OK" if not e["violations"] else "FAIL"
        extra = (f" dense_fallback={e['dense_fallback_leaves']} leaves"
                 if e["dense_fallback_leaves"] else "")
        lines.append(f"  {key:<28} layout={e['layout']:<6} "
                     f"prefill={e['prefill_mode']:<8} "
                     f"transitions={e['transitions']} {status}{extra}")
        lines += [f"      {v}" for v in e["violations"]]
    return "\n".join(lines)
