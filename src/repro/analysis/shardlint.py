"""Sharding / dtype linter.

Three sub-passes, all pure host logic or trace-only:

  specs     every PartitionSpec the engine plans (param_specs,
            plan_lane_specs lane+stacked gradient specs, cache_specs)
            is valid against the canonical mesh axis sizes: the axis
            exists, the dim is divisible, no axis lands on two dims
            (`parallel.sharding.spec_violations`);
  zero2     the ZeRO-2 lane-plan invariant: span < dp => the stacked
            gradient's lane dim is replicated (lead entry None) and the
            payload is scattered; span == dp => the lane dim carries
            exactly the DP axes (RVH input layout);
  accdtype  the fused and reference combiners are traced (mesh-free
            global semantics, `jax.make_jaxpr`) and every floating
            reduction in the jaxpr is checked against the policy's
            acc_dtype — no silent bf16 accumulation (paper §4.4.1).
"""
from __future__ import annotations

from typing import Any, Dict, List, Tuple

import jax
import numpy as np

ARCHS = ("qwen3-32b", "moonshot-v1-16b-a3b", "mixtral-8x22b")
SPANS = (2, 4, 8, 16)
MESH_SHAPE = {"data": 16, "model": 2}
_CACHE_BATCH, _CACHE_LEN = 16, 64


def _lead(spec) -> Any:
    entries = tuple(spec or ())
    return entries[0] if entries else None


def check_sharding(*, archs=ARCHS, spans=SPANS, sizes=None
                   ) -> Tuple[Dict[str, Any], List[str]]:
    """Returns (report, violations) over archs x spans on the declared
    axis sizes — no mesh, no devices."""
    from repro.configs.base import get_reduced
    from repro.core.combine import CombineConfig
    from repro.engine.build import plan_lane_specs
    from repro.engine.config import EngineConfig
    from repro.engine.registry import make_combiner
    from repro.models import build_model
    from repro.parallel.sharding import (ShardingPolicy, cache_specs,
                                         param_specs, spec_violations)
    from .jaxpr_utils import acc_dtype_violations, trace
    import jax.numpy as jnp

    sizes = dict(sizes or MESH_SHAPE)
    tp_axis = "model"
    dp_axes = tuple(ax for ax in sizes if ax != tp_axis)
    dp_total = int(np.prod([sizes[a] for a in dp_axes]))

    report: Dict[str, Any] = {"meta": {"mesh": sizes, "archs": list(archs),
                                       "spans": list(spans)},
                              "cells": {}}
    violations: List[str] = []

    def flag(key, msgs):
        violations.extend(f"{key}: {m}" for m in msgs)
        return len(msgs)

    for arch in archs:
        ecfg = EngineConfig.preset(arch, reduced=True)
        rpol = ecfg.run_policy()
        mcfg = get_reduced(arch)
        model = build_model(mcfg, param_dtype=jnp.dtype(ecfg.param_dtype))
        kshape = jax.eval_shape(lambda: jax.random.key(0))
        pshapes = jax.eval_shape(model.init, kshape)
        spol = ShardingPolicy(tp_axis=tp_axis,
                              fsdp_axis="data" if rpol.fsdp else None,
                              tp_size=sizes.get(tp_axis, 1),
                              fsdp_size=sizes.get("data", 1))

        n = 0
        pspecs = param_specs(mcfg, pshapes, spol)
        n += flag(f"{arch}|param_specs",
                  [f"{p}: {m}" for p, m in
                   spec_violations(pspecs, pshapes, sizes)])

        cshapes = jax.eval_shape(
            lambda: model.init_cache(None, _CACHE_BATCH, _CACHE_LEN))
        cspecs = cache_specs(cshapes, mcfg, spol, dp_axes,
                             _CACHE_BATCH, dp_total)
        n += flag(f"{arch}|cache_specs",
                  [f"{p}: {m}" for p, m in
                   spec_violations(cspecs, cshapes, sizes)])

        leaves, treedef = jax.tree.flatten(pshapes)
        for span in spans:
            key = f"{arch}|span={span}"
            lane_specs, gspecs = plan_lane_specs(
                mcfg, pshapes, spol, rpol, span, dp_total, dp_axes)
            n += flag(f"{key}|lane_specs",
                      [f"{p}: {m}" for p, m in
                       spec_violations(lane_specs, pshapes, sizes)])
            stacked = jax.tree.unflatten(treedef, [
                jax.ShapeDtypeStruct((span,) + tuple(l.shape), l.dtype)
                for l in leaves])
            n += flag(f"{key}|gspecs",
                      [f"{p}: {m}" for p, m in
                       spec_violations(gspecs, stacked, sizes)])

            want_lead = tuple(dp_axes) if span == dp_total else None
            bad_leads = []
            # PartitionSpec is a registered pytree leaf, so this walks
            # one spec per param leaf
            for path, g in jax.tree_util.tree_flatten_with_path(gspecs)[0]:
                if _lead(g) != want_lead:
                    bad_leads.append(
                        f"{jax.tree_util.keystr(path)}: lane dim {_lead(g)!r}"
                        f" != {want_lead!r} ({'RVH: lane dim carries DP' if span == dp_total else 'ZeRO-2: lane dim replicated'})")
            n += flag(f"{key}|zero2", bad_leads)

        # acc-dtype: trace both combiner paths mesh-free (global
        # semantics — dp_total=1 keeps every span hierarchical) and scan
        # the jaxpr for sub-acc_dtype floating reductions
        span = min(spans)
        stacked = jax.tree.unflatten(treedef, [
            jax.ShapeDtypeStruct((span,) + tuple(l.shape), l.dtype)
            for l in leaves])
        acc_errs: List[str] = []
        for fused in (True, False):
            ccfg = CombineConfig(op="adasum", backend="gspmd_tree",
                                 span=span, per_layer=rpol.per_layer,
                                 acc_dtype=rpol.acc_dtype, fused=fused,
                                 fusion_threshold_mb=rpol.fusion_threshold_mb)
            combiner = make_combiner(ccfg, mesh=None)
            jaxpr = trace(combiner, stacked)
            acc_errs += [f"{'fused' if fused else 'reference'}: {m}"
                         for m in acc_dtype_violations(jaxpr,
                                                       rpol.acc_dtype)]
        n += flag(f"{arch}|accdtype", acc_errs)

        report["cells"][arch] = {
            "param_dtype": str(ecfg.param_dtype),
            "acc_dtype": str(np.dtype(rpol.acc_dtype).name),
            "fsdp": bool(rpol.fsdp),
            "scatter_grads": bool(rpol.scatter_grads),
            "spans": list(spans),
            "violations": n,
        }
    return report, violations


def render(report: Dict[str, Any]) -> str:
    lines = [f"sharding lint @ mesh {report['meta']['mesh']} "
             f"spans={report['meta']['spans']}"]
    for arch in sorted(report["cells"]):
        e = report["cells"][arch]
        status = "OK" if not e["violations"] else f"FAIL({e['violations']})"
        lines.append(f"  {arch:<22} param={e['param_dtype']:<9} "
                     f"acc={e['acc_dtype']:<8} fsdp={e['fsdp']} "
                     f"scatter={e['scatter_grads']} {status}")
    return "\n".join(lines)
