"""Recursive jaxpr walking: collectives, hazardous reshapes, reductions.

Everything here operates on the *trace* (``jax.make_jaxpr`` output) —
inner jaxprs of pjit / scan / cond / while / shard_map / custom_* eqns
are descended into, tracking whether the walk is inside a shard_map
manual region (where local-shard reshapes are safe by construction).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, List, Tuple

import jax
import numpy as np
from jax import core as jcore

# primitives that move data across devices; each entry maps the
# primitive name to the param key carrying its axis names
COLLECTIVE_PRIMS = {
    "psum": "axes",
    "all_gather": "axis_name",
    "all_to_all": "axis_name",
    "ppermute": "axis_name",
    "reduce_scatter": "axis_name",
    "psum_scatter": "axis_name",
}

# reductions whose output dtype must respect acc_dtype (paper §4.4.1)
REDUCTION_PRIMS = ("reduce_sum", "dot_general", "scatter-add", "add_any")


def _norm_axes(axes) -> Tuple[str, ...]:
    if axes is None:
        return ()
    if not isinstance(axes, (tuple, list)):
        axes = (axes,)
    return tuple(str(a) for a in axes)


def _sub_jaxprs(params: Dict[str, Any]) -> Iterator[jcore.Jaxpr]:
    for v in params.values():
        if isinstance(v, jcore.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jcore.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for x in v:
                if isinstance(x, jcore.ClosedJaxpr):
                    yield x.jaxpr
                elif isinstance(x, jcore.Jaxpr):
                    yield x


def iter_eqns(jaxpr, manual: bool = False):
    """Yields (eqn, inside_shard_map) over the jaxpr and every inner
    jaxpr reachable through eqn params."""
    if isinstance(jaxpr, jcore.ClosedJaxpr):
        jaxpr = jaxpr.jaxpr
    for eqn in jaxpr.eqns:
        yield eqn, manual
        inner_manual = manual or eqn.primitive.name == "shard_map"
        for sub in _sub_jaxprs(eqn.params):
            yield from iter_eqns(sub, inner_manual)


def collect_collectives(jaxpr) -> List[Dict[str, Any]]:
    """Every cross-device collective in the trace:
    [{"prim", "axes", "manual"}]."""
    out = []
    for eqn, manual in iter_eqns(jaxpr):
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            axes = eqn.params.get(COLLECTIVE_PRIMS[name])
            out.append({"prim": name, "axes": _norm_axes(axes),
                        "manual": manual})
    return out


def _non_unit(shape) -> List[int]:
    return sorted(int(d) for d in shape if d != 1)


def count_merge_reshapes(jaxpr) -> int:
    """Payload-merging reshapes OUTSIDE shard_map manual regions — the
    `_split_lanes` hazard: collapsing several non-unit dims of a
    (potentially sharded) global array into one destroys axis-aligned
    sharding and replicates the result. Splitting a dim (rank increase)
    and squeezing size-1 dims are benign and not counted; reshapes on
    local shards inside shard_map are safe by construction."""
    n = 0
    for eqn, manual in iter_eqns(jaxpr):
        if manual or eqn.primitive.name != "reshape":
            continue
        ishape = eqn.invars[0].aval.shape
        oshape = eqn.outvars[0].aval.shape
        if len(oshape) < len(ishape) and _non_unit(ishape) != _non_unit(oshape):
            n += 1
    return n


def acc_dtype_violations(jaxpr, acc_dtype) -> List[str]:
    """Reduction eqns whose floating output dtype is narrower than
    `acc_dtype` — the silent-downcast class the paper's fp32/fp64
    accumulation requirement (§4.4.1) exists to prevent. Integer
    reductions (segment ids, argmax plumbing) are exempt."""
    import jax.numpy as jnp

    acc = np.dtype(acc_dtype)
    bad = []
    for eqn, _ in iter_eqns(jaxpr):
        if eqn.primitive.name not in REDUCTION_PRIMS:
            continue
        for ov in eqn.outvars:
            dt = np.dtype(ov.aval.dtype)
            # jnp.issubdtype: bfloat16 is an ml_dtypes extension type
            # that np.issubdtype does NOT class as floating
            if jnp.issubdtype(dt, jnp.floating) and dt.itemsize < acc.itemsize:
                bad.append(f"{eqn.primitive.name} accumulates in {dt.name} "
                           f"(acc_dtype={acc.name})")
    return bad


def trace(fn: Callable, *args) -> jcore.ClosedJaxpr:
    """`jax.make_jaxpr` on ShapeDtypeStruct (or concrete) args — the
    one entry point every checker traces through, so 'no device
    execution' has a single place to hold."""
    return jax.make_jaxpr(fn)(*args)
