"""Comms-plan checker: the fused combine emits exactly one psum per
sharded bucket per tree level, and NO combiner path all-gathers.

For every (arch preset x span x fused/reference x granularity) cell the
checker:

  1. plans the lane sharding exactly as `build_runtime` would
     (`plan_lane_specs` — same hook, same zpol2 ZeRO-2 logic);
  2. recomputes the fused bucketing on the LOCAL shard shapes
     (`core.combine.fused_plan` — the very function the hot path calls
     inside shard_map), predicting `levels x sharded_buckets` psums with
     each bucket's exact axes;
  3. traces the real combiner (`make_combiner`, the registry dispatch
     the trainer uses) to a jaxpr with `jax.make_jaxpr` on
     ShapeDtypeStructs — nothing runs on a device — and walks it;
  4. asserts trace == prediction: psum multiset matches, zero
     all_gather / all_to_all / ppermute / reduce_scatter anywhere, and
     zero payload-merging reshapes outside shard_map (the `_split_lanes`
     336 GiB replication class);
  4b. traces the stats-enabled combiner (`make_combiner(...,
     with_stats=True)` — the CombineStats path the controller feeds on)
     and holds it to the SAME psum multiset as the plain combiner: the
     per-level triples piggyback on values the combine already psums,
     so surfacing them adds ZERO collectives (the ISSUE budget allows
     one extra small psum per bucket per level; we hold the stricter
     bar) and zero all-gathers;
  5. traces the delayed-combine correction (`build_delayed_correction`,
     the combine_delay=1 exchange that overlaps the next round's
     compute) for the same cell and holds it to the same bar: the fused
     path must emit exactly the combine's psum multiset — one per
     sharded bucket per level, the lane-mean side adds NO collective —
     and the reference path stays free of explicit collectives.

The machine-readable report diffs against tools/comms_baseline.json, so
a change to bucketing (e.g. `fusion_threshold_mb` handling), psum
placement, or sharding rules fails CI until re-baselined.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

ARCHS = ("qwen3-32b", "moonshot-v1-16b-a3b", "mixtral-8x22b")
SPANS = (2, 4, 8)
# canonical topology: dp=16 keeps every span strictly hierarchical
# (span < dp, the fused gspmd_tree regime) with TP=2 alongside
MESH_SHAPE = {"data": 16, "model": 2}


def _config_key(arch: str, span: int, fused: bool, per_layer: bool) -> str:
    return (f"{arch}|span={span}|{'fused' if fused else 'reference'}"
            f"|{'per_layer' if per_layer else 'whole'}")


def _arch_parts(arch: str):
    """(model_cfg, stacked pshapes, spol, rpol) for one preset — all via
    eval_shape, params never materialize."""
    from repro.configs.base import get_reduced
    from repro.engine.config import EngineConfig
    from repro.models import build_model
    import jax.numpy as jnp

    ecfg = EngineConfig.preset(arch, reduced=True)
    rpol = ecfg.run_policy()
    mcfg = get_reduced(arch)
    model = build_model(mcfg, param_dtype=jnp.dtype(ecfg.param_dtype))
    kshape = jax.eval_shape(lambda: jax.random.key(0))
    pshapes = jax.eval_shape(model.init, kshape)
    return mcfg, pshapes, rpol


def check_comms(*, archs=ARCHS, spans=SPANS, mesh=None,
                combine_overrides: Optional[Dict[str, Any]] = None
                ) -> Tuple[Dict[str, Any], List[str]]:
    """Returns (report, violations). `mesh` defaults to the canonical
    data=16 x model=2 topology (clamped to available devices by
    make_local_mesh — baseline diffs then flag the meta.mesh mismatch,
    pointing at the CLI which pins the device count).
    `combine_overrides` perturbs the CombineConfig — used by the
    mutation tests to prove the baseline diff fires."""
    from repro.core.combine import (CombineConfig, build_delayed_correction,
                                    fused_plan, plan_summary)
    from repro.engine.build import plan_lane_specs
    from repro.engine.registry import make_combiner
    from repro.kernels.backend import backend_summary
    from repro.launch.mesh import make_local_mesh
    from repro.parallel.sharding import (ShardingPolicy, local_shape,
                                         spec_violations)
    from .jaxpr_utils import (collect_collectives, count_merge_reshapes,
                              trace)

    if mesh is None:
        mesh = make_local_mesh(MESH_SHAPE["data"], MESH_SHAPE["model"])
    sizes = dict(zip(mesh.axis_names, (int(s) for s in mesh.devices.shape)))
    tp_axis = "model"
    dp_axes = tuple(ax for ax in mesh.axis_names if ax != tp_axis)
    dp_total = int(np.prod([sizes[a] for a in dp_axes]))
    rvh_axes = tuple(reversed(dp_axes))

    report: Dict[str, Any] = {
        "meta": {"mesh": sizes, "archs": list(archs), "spans": list(spans),
                 "backend": backend_summary()},
        "plans": {},
    }
    violations: List[str] = []

    for arch in archs:
        mcfg, pshapes, rpol = _arch_parts(arch)
        spol = ShardingPolicy(tp_axis=tp_axis,
                              fsdp_axis="data" if rpol.fsdp else None,
                              tp_size=sizes.get(tp_axis, 1),
                              fsdp_size=sizes.get("data", 1))
        for span in spans:
            lane_specs, _gspecs = plan_lane_specs(
                mcfg, pshapes, spol, rpol, span, dp_total, dp_axes)
            bad = spec_violations(lane_specs, pshapes, sizes)
            violations += [f"{arch}|span={span}: lane spec {p}: {m}"
                           for p, m in bad]
            leaves, treedef = jax.tree.flatten(pshapes)
            specs = treedef.flatten_up_to(lane_specs)
            stacked = jax.tree.unflatten(treedef, [
                jax.ShapeDtypeStruct((span,) + tuple(l.shape), l.dtype)
                for l in leaves])
            for fused in (True, False):
                for per_layer in (True, False):
                    kw = dict(op="adasum", backend="gspmd_tree", span=span,
                              per_layer=per_layer, acc_dtype=rpol.acc_dtype,
                              fused=fused,
                              fusion_threshold_mb=rpol.fusion_threshold_mb)
                    kw.update(combine_overrides or {})
                    ccfg = CombineConfig(**kw)
                    key = _config_key(arch, span, fused, per_layer)
                    entry, errs = _check_one(
                        ccfg, stacked, lane_specs, leaves, specs, mesh,
                        rvh_axes, sizes, fused_plan, plan_summary,
                        make_combiner, build_delayed_correction,
                        local_shape, collect_collectives,
                        count_merge_reshapes, trace)
                    report["plans"][key] = entry
                    violations += [f"{key}: {e}" for e in errs]
    return report, violations


def _check_one(ccfg, stacked, lane_specs, leaves, specs, mesh, rvh_axes,
               sizes, fused_plan, plan_summary, make_combiner,
               build_delayed_correction, local_shape, collect_collectives,
               count_merge_reshapes, trace):
    combiner = make_combiner(ccfg, mesh=mesh, dp_axes=rvh_axes,
                             leaf_specs=lane_specs)
    jaxpr = trace(combiner, stacked)
    colls = collect_collectives(jaxpr)
    merges = count_merge_reshapes(jaxpr)
    psums = [c for c in colls if c["prim"] == "psum"]
    others = [c for c in colls if c["prim"] != "psum"]
    errs: List[str] = []
    if others:
        kinds = sorted({c["prim"] for c in others})
        errs.append(f"combiner path emits {kinds} "
                    f"({len(others)} eqns) — must be psum-only")
    if merges:
        errs.append(f"{merges} payload-merging reshape(s) outside "
                    f"shard_map (the _split_lanes replication hazard)")
    levels = int(math.log2(ccfg.span)) if ccfg.span > 1 else 0
    entry: Dict[str, Any] = {
        "levels": levels,
        "psums": len(psums),
        "all_gather": len(others),
        "merge_reshapes": merges,
    }
    if ccfg.fused:
        # predict from the plan on LOCAL shard shapes — exactly what
        # fused_combine_tree sees inside shard_map
        local = [jax.ShapeDtypeStruct(
            (ccfg.span,) + local_shape(l.shape, spec, sizes), l.dtype)
            for l, spec in zip(leaves, specs)]
        plan = fused_plan(local, specs, ccfg, psum=True)
        buckets = plan_summary(plan)
        sharded = [b for b in buckets if b["axes"]]
        want = sorted(tuple(b["axes"]) for b in sharded for _ in
                      range(levels))
        got = sorted(c["axes"] for c in psums)
        got = [tuple(a) for a in got]
        want = [tuple(a) for a in want]
        if got != want:
            errs.append(f"psum plan mismatch: traced {got} != "
                        f"predicted one-per-bucket-per-level {want}")
        if any(not c["manual"] for c in psums):
            errs.append("psum outside shard_map manual region")
        entry.update({
            "buckets": buckets,
            "n_buckets": len(buckets),
            "n_sharded_buckets": len(sharded),
            "expected_psums": len(want),
        })
    else:
        # reference gspmd_tree: GSPMD chooses collectives at compile
        # time; the TRACE must contain no explicit ones at all
        if psums:
            errs.append(f"reference path emits {len(psums)} explicit "
                        f"psum(s); collective choice belongs to GSPMD")
        entry["buckets"] = []
        entry["n_buckets"] = 0
        entry["n_sharded_buckets"] = 0
        entry["expected_psums"] = 0

    # stats-enabled combiner (CombineStats piggyback): the controller's
    # noise/orthogonality/gain telemetry must ride on the combine's own
    # psummed values — same psum multiset as the plain combiner (zero
    # extra collectives), no all-gathers, no merging reshapes.
    scombiner = make_combiner(ccfg, mesh=mesh, dp_axes=rvh_axes,
                              leaf_specs=lane_specs, with_stats=True)
    sjaxpr = trace(scombiner, stacked)
    scolls = collect_collectives(sjaxpr)
    spsums = [c for c in scolls if c["prim"] == "psum"]
    sothers = [c for c in scolls if c["prim"] != "psum"]
    smerges = count_merge_reshapes(sjaxpr)
    base_axes = sorted(tuple(c["axes"]) for c in psums)
    stat_axes = sorted(tuple(c["axes"]) for c in spsums)
    if stat_axes != base_axes:
        errs.append(f"stats combiner psum multiset {stat_axes} != plain "
                    f"combiner's {base_axes} — CombineStats must add "
                    f"zero collectives")
    if sothers:
        kinds = sorted({c["prim"] for c in sothers})
        errs.append(f"stats combiner emits {kinds} ({len(sothers)} eqns)"
                    f" — must be psum-only")
    if smerges:
        errs.append(f"stats combiner: {smerges} payload-merging "
                    f"reshape(s) outside shard_map")
    if ccfg.fused and any(not c["manual"] for c in spsums):
        errs.append("stats combiner psum outside shard_map manual region")
    entry["stats"] = {"psums": len(spsums), "all_gather": len(sothers),
                      "merge_reshapes": smerges,
                      "extra_psums": len(spsums) - len(psums)}

    # delayed-combine correction (combine_delay=1): the exchange that
    # overlaps the next round's compute must be comms-identical to the
    # synchronous combine — correction = combine(pending) - lane_mean,
    # and the lane mean is lane-axis arithmetic, local under shard_map,
    # so it may add NO collective and no extra psum.
    corr = build_delayed_correction(ccfg, mesh=mesh, dp_axes=rvh_axes,
                                    leaf_specs=lane_specs)
    djaxpr = trace(corr, stacked)
    dcolls = collect_collectives(djaxpr)
    dpsums = [c for c in dcolls if c["prim"] == "psum"]
    dothers = [c for c in dcolls if c["prim"] != "psum"]
    dmerges = count_merge_reshapes(djaxpr)
    if dothers:
        kinds = sorted({c["prim"] for c in dothers})
        errs.append(f"delayed correction emits {kinds} "
                    f"({len(dothers)} eqns) — must be psum-only")
    if dmerges:
        errs.append(f"delayed correction: {dmerges} payload-merging "
                    f"reshape(s) outside shard_map")
    if ccfg.fused:
        dgot = sorted(tuple(c["axes"]) for c in dpsums)
        if dgot != want:
            errs.append(f"delayed correction psum plan mismatch: traced "
                        f"{dgot} != the combine's one-per-bucket-per-level "
                        f"{want}")
        if any(not c["manual"] for c in dpsums):
            errs.append("delayed correction psum outside shard_map "
                        "manual region")
    elif dpsums:
        errs.append(f"delayed reference correction emits {len(dpsums)} "
                    f"explicit psum(s); collective choice belongs to GSPMD")
    entry["delayed"] = {"psums": len(dpsums), "all_gather": len(dothers),
                        "merge_reshapes": dmerges}
    return entry, errs


def render(report: Dict[str, Any]) -> str:
    """Human-readable comms-plan report (what CI prints)."""
    lines = [f"comms plan @ mesh {report['meta']['mesh']}"]
    for key in sorted(report["plans"]):
        e = report["plans"][key]
        d = e.get("delayed", {})
        lines.append(
            f"  {key:<55} levels={e['levels']} buckets={e['n_buckets']}"
            f" sharded={e['n_sharded_buckets']} psums={e['psums']}"
            f"/{e['expected_psums']} all_gather={e['all_gather']}"
            f" merge_reshapes={e['merge_reshapes']}"
            f" stats_psums={e.get('stats', {}).get('psums', '-')}"
            f" delayed_psums={d.get('psums', '-')}")
        for b in e["buckets"]:
            lines.append(
                f"      bucket leaves={b['leaves']:>3} dtype={b['dtype']:<9}"
                f" axes={','.join(b['axes']) or '-':<11}"
                f" block={b['block_elems']} bytes={b['payload_bytes']}")
    return "\n".join(lines)
