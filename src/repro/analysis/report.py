"""Baseline files for the analysis passes: load / save / diff.

A baseline is a checked-in JSON snapshot of a pass's machine-readable
report. The diff is exact per key — any drift in the comms plan (bucket
count, psum axes, payload bytes) or any new hostsync finding fails CI
until the change is either fixed or deliberately re-baselined with
``python -m repro.analysis --update-baselines``.

The ``meta`` block (environment stamp, mesh topology) is compared only
for the fields that parameterize the plan (the mesh); provenance fields
(platform, device count) are informational and excluded.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Optional


def load(path) -> Optional[Dict[str, Any]]:
    p = Path(path)
    if not p.exists():
        return None
    return json.loads(p.read_text())


def save(path, data: Dict[str, Any]) -> None:
    Path(path).write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")


def _fmt(v) -> str:
    return json.dumps(v, sort_keys=True)


def diff_plans(computed: Dict[str, Any], baseline: Dict[str, Any],
               *, meta_keys=("mesh",)) -> List[str]:
    """Exact two-way diff of {'meta', 'plans'} reports. Returns
    human-readable drift lines (empty == in sync)."""
    out = []
    cm, bm = computed.get("meta", {}), baseline.get("meta", {})
    for k in meta_keys:
        if cm.get(k) != bm.get(k):
            out.append(f"meta.{k}: computed {_fmt(cm.get(k))} != baseline "
                       f"{_fmt(bm.get(k))} — rerun via `python -m "
                       f"repro.analysis` (it pins the canonical topology)")
    cp, bp = computed.get("plans", {}), baseline.get("plans", {})
    for key in sorted(cp):
        if key not in bp:
            out.append(f"{key}: not in baseline (new config — "
                       f"re-baseline if intended)")
        elif cp[key] != bp[key]:
            got, want = cp[key], bp[key]
            fields = sorted(set(got) | set(want))
            delta = [f for f in fields if got.get(f) != want.get(f)]
            for f in delta:
                out.append(f"{key}: {f} changed {_fmt(want.get(f))} -> "
                           f"{_fmt(got.get(f))}")
    for key in sorted(set(bp) - set(cp)):
        out.append(f"{key}: in baseline but no longer computed")
    return out


def diff_findings(findings: List[Dict[str, Any]],
                  baseline: Optional[Dict[str, Any]]) -> List[str]:
    """New lint findings not covered by the baseline. Baseline entries
    are {(file, rule, code): count} — line numbers are deliberately NOT
    part of the key, so unrelated edits above a known site don't churn
    the baseline."""
    budget: Dict[tuple, int] = {}
    for e in (baseline or {}).get("findings", []):
        k = (e["file"], e["rule"], e["code"])
        budget[k] = budget.get(k, 0) + int(e.get("count", 1))
    out = []
    for f in findings:
        k = (f["file"], f["rule"], f["code"])
        if budget.get(k, 0) > 0:
            budget[k] -= 1
        else:
            out.append(f"{f['file']}:{f['line']}: [{f['rule']}] {f['code']}")
    return out


def findings_baseline(findings: List[Dict[str, Any]]) -> Dict[str, Any]:
    """Collapse current findings into the baseline format."""
    counts: Dict[tuple, int] = {}
    for f in findings:
        k = (f["file"], f["rule"], f["code"])
        counts[k] = counts.get(k, 0) + 1
    return {"findings": [
        {"file": fl, "rule": r, "code": c, "count": n}
        for (fl, r, c), n in sorted(counts.items())]}
