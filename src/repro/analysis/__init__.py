"""Static invariant checkers for the repro codebase.

Four passes, all trace-only (``jax.make_jaxpr`` / ``jax.eval_shape`` —
no device executes anything) plus one AST lint:

  comms     one psum per bucket per tree level on the fused combine
            path, zero all-gathers on any combiner path, no global
            payload-flattening reshapes (the `_split_lanes` 336 GiB
            failure class); report diffable vs tools/comms_baseline.json
  retrace   every slot-churn / page-table / hot-reload transition maps
            the serve decode cache signature onto itself, so the decode
            step compiles exactly once
  sharding  every PartitionSpec valid against the mesh axes (axis
            exists, dim divisible, ZeRO-2 lane plans consistent with
            span<dp), and no accumulation jaxpr silently downcasts
            below acc_dtype
  hostsync  AST lint of the serving/pipeline hot loops for device-sync
            hazards, with `# lint: allow(<rule>)` suppression and a
            baseline file (tools/hostsync_baseline.json)

CLI: ``python -m repro.analysis [--check ...|--all]``.

This module deliberately imports nothing at package level: ``__main__``
must be able to pin the host device count before jax loads.
"""

__all__ = ["comms", "retrace", "shardlint", "hostsync", "report",
           "jaxpr_utils"]
