"""Host-sync AST lint for the serving / pipeline hot loops.

A serve tick or train step that blocks on the device — or worse, pulls
a value to the host inside a jit-traced function — serializes the
pipeline the overlap engine exists to hide. This lint walks the AST of
the hot files (``engine/pipeline.py`` and everything under
``engine/serving/``) and flags:

  block-until-ready    any ``.block_until_ready()`` call — benchmarks
                       belong in benchmarks/, not the hot loop
  host-pull            ``.item()`` anywhere; ``float(...)`` /
                       ``np.asarray(...)`` / ``np.array(...)`` applied
                       to a call result or subscript (the patterns that
                       pull a freshly computed device value; plain
                       names are usually host ints already)
  host-mutation-in-jit python-side state mutation (global/nonlocal,
                       ``self.x = ...`` / closure ``.append(...)`` /
                       ``print``) inside a function that is jit-traced
                       — it runs once at trace time and silently never
                       again

Suppression: append ``# lint: allow(<rule>)`` to the offending line.
Known legacy sites live in ``tools/hostsync_baseline.json`` (keyed by
(file, rule, code) — line-number free, so edits above a known site
don't churn it); anything new fails CI.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

HOT_FILES = ("src/repro/engine/pipeline.py",)
HOT_DIRS = ("src/repro/engine/serving",)

RULES = ("block-until-ready", "host-pull", "host-mutation-in-jit")

# a function is considered jit-traced if it is passed to one of these
# (jax.jit(f), jax.lax.scan(f, ...), shard_map(f, ...)) or returned by
# a make_* / _make_* builder (the repo's convention for step builders)
_TRACING_CALLS = ("jit", "scan", "while_loop", "cond", "fori_loop",
                  "shard_map", "vmap", "grad", "value_and_grad", "remat",
                  "checkpoint", "eval_shape", "make_jaxpr")

_MUTATING_METHODS = ("append", "extend", "insert", "pop", "update",
                     "setdefault", "add", "remove", "clear")

_ALLOW_RE = re.compile(r"#\s*lint:\s*allow\(([a-z-]+)\)")


def _callee_name(node: ast.Call) -> str:
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        return f.attr
    return ""


def _is_np(func: ast.expr) -> bool:
    return (isinstance(func, ast.Attribute)
            and isinstance(func.value, ast.Name)
            and func.value.id in ("np", "numpy")
            and func.attr in ("asarray", "array"))


def _traced_fn_names(tree: ast.AST) -> set:
    """Names of functions this module jit-traces: args to tracing
    transforms, plus inner defs returned from make_*/_make_* builders."""
    traced: set = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            if _callee_name(node) in _TRACING_CALLS:
                for a in node.args:
                    if isinstance(a, ast.Name):
                        traced.add(a.id)
                    elif isinstance(a, ast.Lambda):
                        pass  # lambdas handled via enclosing scan etc.
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) and \
                node.name.lstrip("_").startswith("make_"):
            inner = {n.name for n in node.body
                     if isinstance(n, ast.FunctionDef)}
            for ret in ast.walk(node):
                if isinstance(ret, ast.Return) and \
                        isinstance(ret.value, ast.Name) and \
                        ret.value.id in inner:
                    traced.add(ret.value.id)
    return traced


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, lines: List[str], traced: set):
        self.path = path
        self.lines = lines
        self.traced = traced
        self.findings: List[Dict[str, Any]] = []
        self._in_traced = 0
        self._local_names: List[set] = []

    # -- helpers ------------------------------------------------------
    def _code(self, node) -> str:
        try:
            return ast.unparse(node)
        except Exception:
            return self.lines[node.lineno - 1].strip()

    def _allowed(self, node, rule: str) -> bool:
        line = self.lines[node.lineno - 1] if \
            0 < node.lineno <= len(self.lines) else ""
        m = _ALLOW_RE.search(line)
        return bool(m) and m.group(1) == rule

    def _flag(self, node, rule: str) -> None:
        if self._allowed(node, rule):
            return
        self.findings.append({"file": self.path, "line": node.lineno,
                              "rule": rule, "code": self._code(node)})

    # -- scope tracking -----------------------------------------------
    def visit_FunctionDef(self, node):
        entered = node.name in self.traced
        if entered:
            self._in_traced += 1
            self._local_names.append(
                {a.arg for a in (node.args.args + node.args.kwonlyargs
                                 + node.args.posonlyargs)})
        self.generic_visit(node)
        if entered:
            self._in_traced -= 1
            self._local_names.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _note_local(self, target):
        if self._in_traced and isinstance(target, ast.Name) and \
                self._local_names:
            self._local_names[-1].add(target.id)

    # -- rules --------------------------------------------------------
    def visit_Call(self, node):
        name = _callee_name(node)
        if name == "block_until_ready":
            self._flag(node, "block-until-ready")
        elif name == "item" and isinstance(node.func, ast.Attribute):
            self._flag(node, "host-pull")
        elif (name == "float" and isinstance(node.func, ast.Name)
              or _is_np(node.func)):
            if node.args and isinstance(node.args[0],
                                        (ast.Call, ast.Subscript)):
                self._flag(node, "host-pull")
        elif self._in_traced and name == "print":
            self._flag(node, "host-mutation-in-jit")
        elif self._in_traced and name in _MUTATING_METHODS and \
                isinstance(node.func, ast.Attribute) and \
                isinstance(node.func.value, ast.Name) and \
                self._local_names and \
                node.func.value.id not in self._local_names[-1]:
            # mutating a closed-over container from inside the trace
            self._flag(node, "host-mutation-in-jit")
        self.generic_visit(node)

    def visit_Global(self, node):
        if self._in_traced:
            self._flag(node, "host-mutation-in-jit")

    def visit_Nonlocal(self, node):
        if self._in_traced:
            self._flag(node, "host-mutation-in-jit")

    def visit_Assign(self, node):
        for t in node.targets:
            self._note_local(t)
            if self._in_traced and isinstance(t, ast.Attribute) and \
                    isinstance(t.value, ast.Name) and t.value.id == "self":
                self._flag(node, "host-mutation-in-jit")
        self.generic_visit(node)

    def visit_AugAssign(self, node):
        self._note_local(node.target)
        if self._in_traced and isinstance(node.target, ast.Attribute) and \
                isinstance(node.target.value, ast.Name) and \
                node.target.value.id == "self":
            self._flag(node, "host-mutation-in-jit")
        self.generic_visit(node)


def lint_source(src: str, path: str = "<string>") -> List[Dict[str, Any]]:
    """Lint one file's source. Returns findings
    [{"file", "line", "rule", "code"}], line-sorted."""
    tree = ast.parse(src)
    v = _Visitor(path, src.splitlines(), _traced_fn_names(tree))
    v.visit(tree)
    return sorted(v.findings, key=lambda f: (f["file"], f["line"]))


def hot_files(root) -> List[Path]:
    root = Path(root)
    out = [root / f for f in HOT_FILES]
    for d in HOT_DIRS:
        out += sorted((root / d).glob("*.py"))
    return [p for p in out if p.exists()]


def check_hostsync(root, baseline: Optional[Dict[str, Any]] = None
                   ) -> Tuple[Dict[str, Any], List[str]]:
    """Lint every hot file under repo `root`; violations are findings
    the baseline doesn't cover."""
    from .report import diff_findings

    findings: List[Dict[str, Any]] = []
    for p in hot_files(root):
        rel = p.relative_to(root).as_posix()
        findings += lint_source(p.read_text(), rel)
    report = {"files": [p.relative_to(root).as_posix()
                        for p in hot_files(root)],
              "findings": findings}
    return report, diff_findings(findings, baseline)


def render(report: Dict[str, Any]) -> str:
    lines = [f"hostsync lint over {len(report['files'])} hot files: "
             f"{len(report['findings'])} finding(s)"]
    for f in report["findings"]:
        lines.append(f"  {f['file']}:{f['line']}: [{f['rule']}] {f['code']}")
    return "\n".join(lines)
