"""``python -m repro.analysis`` — run the static invariant checkers.

Pins ``--xla_force_host_platform_device_count`` BEFORE jax imports so
the comms checker traces against the canonical data=16 x model=2
topology regardless of the host's real device count. Everything is
trace-only; no device executes a computation.
"""
from __future__ import annotations

import argparse
import os
import re
import sys
from pathlib import Path

CHECKS = ("comms", "retrace", "sharding", "hostsync")
_DEV_RE = re.compile(r"--xla_force_host_platform_device_count=\d+\s*")


def _pin_devices(n: int) -> None:
    if "jax" in sys.modules:
        print("warning: jax already imported; device pin may not apply",
              file=sys.stderr)
    flags = _DEV_RE.sub("", os.environ.get("XLA_FLAGS", "")).strip()
    os.environ["XLA_FLAGS"] = (
        f"{flags} --xla_force_host_platform_device_count={n}".strip())


def _repo_root() -> Path:
    # src/repro/analysis/__main__.py -> repo root is 3 up from src/
    return Path(__file__).resolve().parents[3]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="trace-only static analysis of the train/serve paths")
    ap.add_argument("--check", action="append", choices=CHECKS,
                    help="run one pass (repeatable); default: --all")
    ap.add_argument("--all", action="store_true",
                    help="run every pass")
    ap.add_argument("--devices", type=int, default=32,
                    help="fake host device count to pin (default 32 = "
                         "data 16 x model 2)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="rewrite tools/*_baseline.json from this run")
    ap.add_argument("--comms-baseline", type=Path, default=None)
    ap.add_argument("--hostsync-baseline", type=Path, default=None)
    args = ap.parse_args(argv)

    checks = tuple(dict.fromkeys(args.check or ()))
    if args.all or not checks:
        checks = CHECKS

    root = _repo_root()
    comms_path = args.comms_baseline or root / "tools/comms_baseline.json"
    hs_path = args.hostsync_baseline or root / "tools/hostsync_baseline.json"

    if any(c != "hostsync" for c in checks):
        _pin_devices(args.devices)
    from . import report as R

    failed = False
    for check in checks:
        print(f"== {check} ==")
        if check == "comms":
            from . import comms
            rep, viols = comms.check_comms()
            print(comms.render(rep))
            if args.update_baselines:
                R.save(comms_path, rep)
                print(f"baseline written: {comms_path}")
            else:
                base = R.load(comms_path)
                if base is None:
                    viols.append(f"missing baseline {comms_path} "
                                 f"(run --update-baselines)")
                else:
                    viols += R.diff_plans(rep, base)
        elif check == "retrace":
            from . import retrace
            rep, viols = retrace.check_retrace()
            print(retrace.render(rep))
        elif check == "sharding":
            from . import shardlint
            rep, viols = shardlint.check_sharding()
            print(shardlint.render(rep))
        else:
            from . import hostsync
            if args.update_baselines:
                rep, _ = hostsync.check_hostsync(root, None)
                viols = []
                R.save(hs_path, R.findings_baseline(rep["findings"]))
                print(f"baseline written: {hs_path}")
            else:
                rep, viols = hostsync.check_hostsync(root, R.load(hs_path))
            print(hostsync.render(rep))
        if viols:
            failed = True
            print(f"-- {check}: {len(viols)} violation(s)")
            for v in viols:
                print(f"   {v}")
        else:
            print(f"-- {check}: OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
