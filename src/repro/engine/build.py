"""Runtime construction: model + mesh + policy -> jit-able step functions.

Moved here from `repro.parallel.steps` (which remains as a deprecated
compat shim); `repro.engine.TrainSession` is the public entry point and
`build_runtime` the low-level builder for callers that manage their own
training loop (dry-runs, benchmarks).

train_step anatomy (paper Fig. 3 + §4):
  1. reshape the global batch to `span` lanes; one lane = one Adasum leaf;
  2. vmap(value_and_grad) over lanes — per-lane gradients, TP handled by
     GSPMD from the parameter shardings; when span < dp the per-lane
     gradients are plain sums over the lane's DP group (the paper's
     hierarchical intra-node reduce, emitted as reduce-scatter overlapped
     with backward when `scatter_grads`);
  3. combine lanes with Sum (baseline) or Adasum (pre- or post-optimizer
     per the optimizer kind), RVH backend when span == dp;
  4. apply the combined delta; optimizer state is ZeRO-1-sharded.

`local_steps > 1` reproduces §5.2 (TensorFlow ResNet-50 on slow TCP):
each lane performs k *local* optimizer steps and the combined quantity is
the model delta since the last sync.

`combine_delay = 1` (DaSGD-style, Zhou et al.) turns that sync from a
barrier into a background stream: round i launches the Adasum exchange
for round i-1's deltas (no data dependency on round i's batch, so XLA
overlaps the per-bucket psum chains with forward/backward), applies the
lane-mean delta immediately, and folds the combined remote correction —
`Adasum(deltas) - lane_mean(deltas)` — into the params at the end of the
round. The in-flight carry lives in `state["pending"]`, so checkpoints
capture it and an (elastic) restart replays the pending exchange instead
of dropping or double-applying it.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.control.noise import summarize_stats
from repro.core.combine import CombineConfig
from repro.core.dist_opt import DistributedOptimizer
from repro.models.api import Model
from repro.optim.optimizers import Optimizer, get_optimizer
from repro.parallel.policy import RunPolicy
from repro.parallel.sharding import ShardingPolicy, param_specs

from .registry import make_combiner

PyTree = Any


class EngineWarning(UserWarning):
    """Non-fatal engine degradations (e.g. backend fallback)."""


@dataclasses.dataclass
class Runtime:
    """Everything the launcher needs for one (arch, mesh) training setup."""
    model: Model
    mesh: jax.sharding.Mesh
    spol: ShardingPolicy
    rpol: RunPolicy
    dp_axes: Tuple[str, ...]
    dp_total: int
    span: int
    pspecs: PyTree
    state_shapes: PyTree
    state_specs: PyTree
    train_step: Callable
    init_state: Callable
    lane_specs: PyTree = None    # payload sharding of one lane's tensors
    gspecs: PyTree = None        # stacked [span, ...] gradient specs
    combine_path: str = ""       # the combiner implementation that will
                                 # actually run (e.g. 'gspmd-fused' vs
                                 # 'gspmd-reference' after a fallback)
    combine_stats: bool = False  # per-step CombineStats metrics emitted
                                 # (grad-noise / orthogonality / gain)
    # delayed-combine split pieces (combine_delay > 0 only): train_step
    # == fold(local_fn, correction_fn(pending)); DelayedCombineStream
    # runs correction_fn on a host thread for observable overlap
    correction_fn: Optional[Callable] = None
    local_fn: Optional[Callable] = None
    fold_fn: Optional[Callable] = None


def _dp_axes(mesh: jax.sharding.Mesh, tp_axis: str) -> Tuple[str, ...]:
    return tuple(ax for ax in mesh.axis_names if ax != tp_axis)


def _prepend(spec: P, entry) -> P:
    return P(entry, *tuple(spec))


def _drop_axes(spec: P, axes) -> P:
    def ent(e):
        if e is None:
            return None
        if isinstance(e, tuple):
            kept = tuple(a for a in e if a not in axes)
            return kept or None
        return None if e in axes else e

    return P(*[ent(e) for e in tuple(spec)])


def _resolve_combine_cfg(rpol: RunPolicy, span: int, dp_total: int,
                         explicit: Optional[CombineConfig],
                         strict: bool) -> CombineConfig:
    """Build the CombineConfig, plumbing every policy knob through, and
    replace the old *silent* rvh -> gspmd_tree fallback with an explicit
    warning (or a hard error under strict mode)."""
    if explicit is not None:
        ccfg = explicit
        requested = explicit.backend
    else:
        # "" = auto: paper-faithful RVH when one lane per DP rank,
        # GSPMD tree for sub-dp spans (hierarchical mode, no warning —
        # the user never asked for rvh)
        requested = rpol.backend or ("rvh" if span == dp_total
                                     else "gspmd_tree")
        ccfg = CombineConfig(
            op=rpol.combine_op, point=rpol.combine_point,
            backend=requested, span=span, per_layer=rpol.per_layer,
            acc_dtype=rpol.acc_dtype, use_pallas=rpol.use_pallas,
            compress=rpol.compress, fused=rpol.fused_combine,
            fusion_threshold_mb=rpol.fusion_threshold_mb)
    if ccfg.op in ("sum", "mean"):
        return ccfg
    if requested == "rvh" and span != dp_total:
        msg = (f"backend='rvh' needs one lane per DP rank "
               f"(span={span}, dp={dp_total}); falling back to "
               f"'gspmd_tree'. Set span=0 (or span={dp_total}) for the "
               f"paper-faithful RVH reduction.")
        if strict:
            raise ValueError(msg)
        warnings.warn(msg, EngineWarning, stacklevel=3)
        ccfg = dataclasses.replace(ccfg, backend="gspmd_tree")
    return ccfg


def plan_lane_specs(cfg, pshapes: PyTree, spol: ShardingPolicy,
                    rpol: RunPolicy, span: int, dp_total: int,
                    dp_axes: Tuple[str, ...]) -> Tuple[PyTree, PyTree]:
    """Lane-gradient/delta sharding plan: (lane_specs, gspecs).

    When span==dp each lane's tensors live on their DP rank (RVH input
    layout: the lane axis carries the DP axes); when span<dp lanes are
    replicated and the tensors are ZeRO-2-scattered over `data` (zpol2).
    Without these pins GSPMD can replicate full-model per-lane deltas,
    which is catastrophic at MoE scale (found via memory_analysis).

    Pure host logic — no mesh or devices needed, which is what lets the
    sharding linter (`repro.analysis.shardlint`) validate the plan over
    the whole (arch x span) space statically."""
    pspecs = param_specs(cfg, pshapes, spol)
    if span == dp_total:
        lane_axes = tuple(dp_axes)        # pod-major lane index (RVH layout)
        # One lane per DP rank: the lane index IS the dp coordinate, so
        # the payload cannot also be FSDP-sharded over dp — keep only the
        # TP axes (the rvh combiner's leaf_specs contract, and a
        # NamedSharding requirement: one mesh axis, one dim). Found by
        # repro.analysis.shardlint: the unstripped spec is rejected by
        # NamedSharding whenever fsdp engages in the RVH regime.
        lane_specs = jax.tree.map(lambda s: _drop_axes(s, set(lane_axes)),
                                  pspecs)
        gspecs = jax.tree.map(lambda s: _prepend(s, lane_axes), lane_specs)
    else:
        zpol2 = dataclasses.replace(
            spol, fsdp_axis="data" if rpol.scatter_grads else spol.fsdp_axis)
        lane_specs = param_specs(cfg, pshapes, zpol2)
        gspecs = jax.tree.map(lambda s: _prepend(s, None), lane_specs)
    return lane_specs, gspecs


def build_runtime(model: Model, mesh: jax.sharding.Mesh, rpol: RunPolicy,
                  *, tp_axis: str = "model", lr=1e-3,
                  combine: Optional[CombineConfig] = None,
                  optimizer: Optional[Optimizer] = None,
                  strict: bool = False) -> Runtime:
    cfg = model.cfg
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_axes = _dp_axes(mesh, tp_axis)
    dp_total = int(np.prod([sizes[a] for a in dp_axes]))
    span = rpol.span or dp_total
    assert dp_total % span == 0, (span, dp_total)
    spol = ShardingPolicy(tp_axis=tp_axis,
                          fsdp_axis="data" if rpol.fsdp else None,
                          tp_size=sizes.get(tp_axis, 1),
                          fsdp_size=sizes.get("data", 1))

    pshapes = jax.eval_shape(model.init, jax.random.key(0))
    pspecs = param_specs(cfg, pshapes, spol)

    ccfg = _resolve_combine_cfg(rpol, span, dp_total, combine, strict)
    # RVH lane order: innermost mesh axis first (adjacent ranks pair first)
    rvh_axes = tuple(reversed(dp_axes))
    delayed = rpol.combine_delay > 0
    assert not (delayed and rpol.accum_steps > 1), (
        "combine_delay needs accum_steps == 1 (the delayed path combines "
        "per-lane optimizer-step deltas; EngineConfig.validate enforces "
        "this at the config layer)")

    lane_specs, gspecs = plan_lane_specs(cfg, pshapes, spol, rpol,
                                         span, dp_total, dp_axes)

    # The combiner sees the stacked lane tensors, so it gets their true
    # payload sharding (lane_specs == pspecs in the RVH regime; the
    # ZeRO-2-scattered specs in the hierarchical span<dp regime) — the
    # fused bucketed path packs local shards along exactly these specs.
    combiner = make_combiner(ccfg, mesh=mesh, dp_axes=rvh_axes,
                             leaf_specs=lane_specs)
    # CombineStats: the combiner's own dot triples, surfaced as per-step
    # metrics (noise scale / lane orthogonality / adascale gain). The
    # stats-enabled combiner runs the SAME combine program — on the
    # fused path the triples ride the per-bucket psums it already
    # issues — so enabling stats never perturbs the update. Scoped to
    # the synchronous paths: the delayed carry's dots describe the
    # previous round's deltas, not this step's gradients.
    scombiner = None
    if rpol.combine_stats and span > 1 and not delayed:
        scombiner = make_combiner(ccfg, mesh=mesh, dp_axes=rvh_axes,
                                  leaf_specs=lane_specs, with_stats=True)
    opt_kwargs = {}
    if rpol.optimizer in ("adam", "lamb"):
        opt_kwargs["state_dtype"] = jnp.dtype(rpol.opt_state_dtype)
    opt = optimizer or get_optimizer(rpol.optimizer, lr, **opt_kwargs)

    to_shardings = lambda specs: jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))

    dopt = DistributedOptimizer(
        opt, ccfg, combiner, span,
        lane_constraint=lambda d: jax.lax.with_sharding_constraint(
            d, to_shardings(gspecs)),
        delta_constraint=lambda d: jax.lax.with_sharding_constraint(
            d, to_shardings(pspecs)))

    # ---- state shapes + shardings ----
    def init_state_fn(key):
        params = model.init(key)
        state = {"params": params, "opt": dopt.init(params),
                 "step": jnp.zeros((), jnp.int32)}
        if delayed:
            # the in-flight exchange carry: the previous round's stacked
            # lane deltas. Zeros before the first round — Adasum and the
            # lane mean of zeros are both zero (EPS regularization), so
            # the step-0 correction is exactly zero with no cold-start
            # branch in the trace.
            state["pending"] = jax.tree.map(
                lambda p: jnp.zeros((span,) + p.shape, jnp.float32),
                params)
        return state

    state_shapes = jax.eval_shape(init_state_fn, jax.random.key(0))
    # ZeRO-1: optimizer state always (further) scattered over data
    zpol = dataclasses.replace(spol, fsdp_axis="data")
    inner_shapes = state_shapes["opt"]["inner"]
    if dopt.point == "post" and span > 1:
        drop_lane = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype), inner_shapes)
        if span == dp_total:
            # one state per DP rank, living with its lane (paper: per-node
            # optimizer state) — the lane axis IS the distribution, so the
            # payload must not also be FSDP-sharded over dp (same
            # NamedSharding one-axis-one-dim rule plan_lane_specs pins
            # for the lane tensors; with fsdp on, the unstripped spec
            # would name `data` twice).
            ospecs = jax.tree.map(
                lambda s: _drop_axes(s, set(dp_axes)),
                param_specs(cfg, drop_lane, spol))
            lane_entry = tuple(dp_axes)   # pod-major lane index (RVH layout)
        else:
            # lanes replicated; ZeRO-1-scatter the state over `data`.
            ospecs = param_specs(cfg, drop_lane, zpol)
            lane_entry = None
        ospecs = jax.tree.map(lambda s: _prepend(s, lane_entry), ospecs)
    else:
        ospecs = param_specs(cfg, inner_shapes, zpol)
    state_specs = {"params": pspecs,
                   "opt": {"inner": ospecs, "step": P()},
                   "step": P()}
    if delayed:
        # pending deltas are lane-stacked like gradients; checkpoints
        # save/restore them with the rest of the state, and the restore
        # path's reshard_lanes handles a span change across an elastic
        # restart (the pending exchange is replayed, never dropped)
        state_specs["pending"] = gspecs

    init_state = jax.jit(init_state_fn,
                         out_shardings=to_shardings(state_specs))

    # ---- the train step ----
    def split_lanes(batch):
        return jax.tree.map(
            lambda x: x.reshape((span, x.shape[0] // span) + x.shape[1:]),
            batch)

    def lane_loss(p, lb):
        return model.loss(p, lb)

    grad_fn = jax.value_and_grad(lane_loss, has_aux=True)

    def lane_grads(params, lanes):
        """Per-lane gradients, with optional microbatch accumulation
        (paper §2.2 'gradient accumulation'): the lane batch is processed
        in `accum_steps` chunks inside a scan, bounding saved-activation
        memory by 1/A while the gradient sum is carried in fp32."""
        A = rpol.accum_steps
        if A <= 1:
            return jax.vmap(grad_fn, in_axes=(None, 0))(params, lanes)

        acc_dt = jnp.dtype(rpol.accum_dtype)

        def one_lane(lane_batch):
            micro = jax.tree.map(
                lambda x: x.reshape((A, x.shape[0] // A) + x.shape[1:]),
                lane_batch)

            def body(acc, mb):
                (l, m), g = grad_fn(params, mb)
                acc = jax.tree.map(
                    lambda a, gg: (a.astype(jnp.float32)
                                   + gg.astype(jnp.float32)).astype(acc_dt),
                    acc, g)
                return acc, (l, m)

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            gsum, (ls, ms) = jax.lax.scan(body, zeros, micro)
            return (jnp.mean(ls), jax.tree.map(jnp.mean, ms)), gsum

        return jax.vmap(one_lane)(lanes)

    def stat_metrics(stats, batch):
        """CombineStats -> scalar metric dict (lane_rows is static from
        the batch shape, so this traces into the jitted step)."""
        rows = jax.tree.leaves(batch)[0].shape[0]
        return summarize_stats(stats, span, rows // span)

    def sync_step(state, batch):
        params = state["params"]
        lanes = split_lanes(batch)
        (losses, mets), G = lane_grads(params, lanes)
        G = jax.lax.with_sharding_constraint(G, to_shardings(gspecs))
        if scombiner is not None:
            delta, opt_state, stats = dopt.update_stats(
                G, state["opt"], params, scombiner)
        else:
            delta, opt_state = dopt.update(G, state["opt"], params)
            stats = None
        new_params = dopt.apply(params, delta)
        metrics = {k: jnp.mean(v) for k, v in mets.items()}
        metrics["grad_lanes"] = jnp.asarray(span, jnp.int32)
        if stats is not None:
            metrics.update(stat_metrics(stats, batch))
        new_state = {"params": new_params, "opt": opt_state,
                     "step": state["step"] + 1}
        return new_state, metrics

    def local_deltas(params, opt_state, batch):
        """Per-lane local optimizer deltas (paper §5.2): each lane takes
        k = local_steps optimizer steps on its own microbatches. Returns
        (fp32 deltas [span, ...], new inner state, metrics) — the
        metrics carry the mean of the FULL loss dict out of the scan, so
        local-step runs log the same keys sync_step does (the old path
        reported aux as a constant zero)."""
        k = max(rpol.local_steps, 1)
        lanes = split_lanes(batch)   # [span, B/span, ...]
        rows = jax.tree.leaves(lanes)[0].shape[1]
        assert rows % k == 0 and rows >= k, (
            f"local_steps={k} needs global_batch >= span*k "
            f"(got {rows} rows/lane)")
        micro = jax.tree.map(
            lambda x: x.reshape((x.shape[0], k, x.shape[1] // k)
                                + x.shape[2:]), lanes)

        def one_lane(lane_batch, opt_inner):
            def body(carry, mb):
                p, oi, step = carry
                (_, mets), g = grad_fn(p, mb)
                d, oi = dopt.opt.update(g, oi, p, step)
                p = jax.tree.map(lambda a, b: (a.astype(jnp.float32)
                                               + b).astype(a.dtype), p, d)
                return (p, oi, step + 1), mets
            (p_end, oi, _), mets = jax.lax.scan(
                body, (params, opt_inner, opt_state["step"]), lane_batch)
            delta = jax.tree.map(
                lambda e, s: e.astype(jnp.float32) - s.astype(jnp.float32),
                p_end, params)
            return delta, oi, jax.tree.map(jnp.mean, mets)

        # micro is [span, k, micro_b, ...]: vmap span, scan k
        if span > 1 and dopt.point == "post":
            deltas, inner, mets = jax.vmap(one_lane)(
                micro, opt_state["inner"])
        else:
            inner_b = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (span,) + x.shape),
                opt_state["inner"])
            deltas, inner, mets = jax.vmap(one_lane)(micro, inner_b)
            inner = jax.tree.map(lambda x: x[0], inner)
        metrics = {name: jnp.mean(v) for name, v in mets.items()}
        metrics["grad_lanes"] = jnp.asarray(span, jnp.int32)
        return deltas, inner, metrics

    def local_sgd_step(state, batch):
        """Paper §5.2: k local optimizer steps, then Adasum of the deltas."""
        deltas, inner, metrics = local_deltas(
            state["params"], state["opt"], batch)
        if scombiner is not None:
            delta, stats = scombiner(deltas)
            metrics.update(stat_metrics(stats, batch))
        else:
            delta = combiner(deltas)
        new_params = dopt.apply(state["params"], delta)
        new_state = {"params": new_params,
                     "opt": {"inner": inner,
                             "step": state["opt"]["step"] + rpol.local_steps},
                     "step": state["step"] + 1}
        return new_state, metrics

    # ---- delayed combine (combine_delay = 1, DaSGD-style) ----
    correction_fn = local_only_step = delayed_local_step = None
    if delayed:
        from repro.core.combine import build_delayed_correction, lane_mean
        correction_fn = build_delayed_correction(
            ccfg, mesh=mesh, dp_axes=rvh_axes, leaf_specs=lane_specs)

        def local_only_step(state, batch):
            """The compute half of a delayed round: k local optimizer
            steps per lane, the lane-mean delta applied immediately, and
            the stacked deltas parked as the next round's pending carry.
            The pending correction is NOT consumed here — pair with
            `correction_fn` + `fold_fn` (what both `delayed_local_step`
            and DelayedCombineStream do)."""
            deltas, inner, metrics = local_deltas(
                state["params"], state["opt"], batch)
            local = lane_mean(deltas, ccfg.acc)
            new_params = dopt.apply(state["params"], local)
            new_state = {"params": new_params,
                         "opt": {"inner": inner,
                                 "step": state["opt"]["step"]
                                 + max(rpol.local_steps, 1)},
                         "step": state["step"] + 1,
                         "pending": deltas}
            return new_state, metrics

        def delayed_local_step(state, batch):
            """One-round-delayed Adasum: the exchange of the PREVIOUS
            round's deltas (state['pending']) is traced before this
            round's forward/backward and has no data dependency on the
            batch, so XLA schedules its per-bucket psum chains
            concurrently with compute. The lane-mean delta applies
            immediately; the remote correction (combined minus that
            mean) folds into the params at the end of the round, i.e.
            the round AFTER its deltas were produced. Step 0 cold-starts
            on a zero carry — the correction is exactly zero with the
            same trace signature, no cond (the retrace pass pins this)."""
            corr = correction_fn(state["pending"])
            new_state, metrics = local_only_step(state, batch)
            new_state["params"] = dopt.apply(new_state["params"], corr)
            return new_state, metrics

    if delayed:
        step_fn = delayed_local_step
    elif rpol.local_steps > 1:
        step_fn = local_sgd_step
    else:
        step_fn = sync_step

    return Runtime(model, mesh, spol, rpol, dp_axes, dp_total, span, pspecs,
                   state_shapes, state_specs, step_fn, init_state,
                   lane_specs=lane_specs, gspecs=gspecs,
                   combine_path=getattr(combiner, "combine_path", ""),
                   combine_stats=scombiner is not None,
                   correction_fn=correction_fn, local_fn=local_only_step,
                   fold_fn=dopt.apply if delayed else None)


def make_serve_step(model: Model, greedy: bool = True):
    """One decode step: tokens [B,1] -> (next token [B,1], cache)."""
    def serve_step(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache)
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(tokens.dtype)
        return nxt, cache
    return serve_step


def sample_logits(logits: jnp.ndarray, keys: jnp.ndarray, pos: jnp.ndarray,
                  temperature: jnp.ndarray, top_k: jnp.ndarray,
                  top_p: jnp.ndarray) -> jnp.ndarray:
    """Per-row token sampling: [B, V] logits -> [B] int32 tokens.

    Rows with temperature <= 0 take the greedy argmax — bitwise the
    pre-sampling decode path. Sampled rows apply temperature, then top-k
    (k == 0 disables) and nucleus top-p (p >= 1 disables) truncation,
    then a Gumbel-max draw keyed by fold_in(request key, pos): token t of
    a request is a pure function of (seed, t), so decode stays
    reproducible across batch compositions and admission timings.

    keys: [B, 2] uint32 raw PRNG keys (jax.random.PRNGKey rows);
    pos: [B] int32 per-request token positions (generated so far).
    """
    V = logits.shape[-1]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)

    def row(lg, key, p, t, k, tp):
        lg = lg.astype(jnp.float32) / jnp.maximum(t, 1e-6)
        order = jnp.argsort(-lg)                    # descending
        xs = lg[order]
        ranks = jnp.arange(V)
        xs = jnp.where((k > 0) & (ranks >= k), -jnp.inf, xs)
        probs = jax.nn.softmax(xs)
        cum = jnp.cumsum(probs) - probs             # exclusive prefix mass
        xs = jnp.where((cum < tp) | (tp >= 1.0), xs, -jnp.inf)
        g = jax.random.gumbel(jax.random.fold_in(key, p), (V,))
        return order[jnp.argmax(xs + g)].astype(jnp.int32)

    sampled = jax.vmap(row)(logits, keys, pos, temperature, top_k, top_p)
    return jnp.where(temperature > 0, sampled, greedy)


def make_batched_decode_step(model: Model):
    """Slotted decode step for continuous batching: the cache carries a
    per-slot position vector ([B], from `init_cache(per_slot=True)`), so
    one jitted call advances B requests sitting at *different* sequence
    lengths — each row writes its KV at its own position and masks its
    own length. Rows whose slot is free compute garbage that the next
    admission's prefill insert fully overwrites; shapes never depend on
    the active set, so the scheduler's churn never recompiles."""
    def decode_step(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache
    return decode_step


def make_sampling_decode_step(model: Model):
    """`make_batched_decode_step` with per-slot sampling policies: extra
    [B]-shaped key/pos/temperature/top_k/top_p rows select each slot's
    policy (greedy rows stay bitwise-argmax via `sample_logits`). Shapes
    are fixed at [max_slots], so policy churn never recompiles."""
    def decode_step(params, tokens, cache, keys, pos, temperature,
                    top_k, top_p):
        logits, cache = model.decode_step(params, tokens, cache)
        nxt = sample_logits(logits[:, -1, :], keys, pos, temperature,
                            top_k, top_p)
        return nxt[:, None], cache
    return decode_step


def make_verify_step(model: Model):
    """Greedy speculative verification, ONE target dispatch per tick.

    tokens [B, k+1] = [last committed token, draft_1..draft_k]; the
    model's verify_step scores all k+1 positions in one fused forward
    (writing their K/V rows as it goes), then in the SAME jit: per-slot
    greedy argmax g [B, k+1], acceptance = longest prefix of drafts
    matching g, cache pos advanced to pos + accepted + 1 — which both
    commits the accepted rows and rolls back the rejected ones (they
    become masked garbage the next writes overwrite). Shapes are fixed
    at [max_slots, k+1], so slot churn, rollback depth, and hot-reload
    never retrace.

    Returns (next feed token [B,1], greedy tokens [B,k+1], accepted [B],
    cache). Row b commits g[b, :accepted[b]+1]; the next tick feeds
    g[b, accepted[b]] — the last committed token, exactly like plain
    decode."""
    from .serving.slots import set_positions, slot_positions

    def verify(params, tokens, cache):
        pos = slot_positions(cache)
        logits, cache = model.verify_step(params, tokens, cache)
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)        # [B,k+1]
        match = (g[:, :-1] == tokens[:, 1:]).astype(jnp.int32)
        acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)        # [B] 0..k
        cache = set_positions(cache, pos + acc + 1)
        nxt = jnp.take_along_axis(g, acc[:, None], axis=1)       # [B,1]
        return nxt, g, acc, cache
    return verify


def make_draft_propose(draft_model: Model, k: int):
    """k autoregressive greedy draft steps in ONE dispatch: a lax.scan
    of batched decode steps over the draft's dense per-slot cache,
    chaining each argmax into the next feed. `pos` [B] (the host's
    committed position per slot) is written into the draft cache first —
    that single rewrite heals last tick's draft overrun (its rejected
    rows become masked garbage this scan overwrites), so draft rollback
    costs nothing and adds no extra dispatch.

    tokens [B,1] = last committed token; returns (drafts [B,k], cache at
    pos + k + 1).

    The scan runs k+1 steps, not k: step t writes the K/V row for its
    INPUT token, so k steps would leave the last draft d_k proposed but
    never fed — a hole at row pos+k. On full acceptance the target
    commits through d_k and the next propose would attend across that
    hole, collapsing acceptance to zero from then on. The extra step
    feeds d_k (its output d_{k+1} is discarded), keeping the draft cache
    contiguous through every accept depth."""
    from .serving.slots import set_positions

    def propose(params, tokens, cache, pos):
        cache = set_positions(cache, pos)

        def body(carry, _):
            tok, cache = carry
            logits, cache = draft_model.decode_step(params, tok, cache)
            nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(
                jnp.int32)[:, None]
            return (nxt, cache), nxt

        (_, cache), drafts = jax.lax.scan(body, (tokens, cache), None,
                                          length=k + 1)
        return jnp.moveaxis(drafts[:k, ..., 0], 0, 1), cache     # [B,k]
    return propose
