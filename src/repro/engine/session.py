"""TrainSession / ServeSession — the one-line integration the paper sells.

The paper's §4.1 usability claim is

    opt = hvd.DistributedOptimizer(opt, op=hvd.Adasum)

Here the whole setup (model, mesh, policy, combiner, data, checkpoints,
monitoring) collapses to:

    from repro.engine import EngineConfig, TrainSession
    session = TrainSession.from_config(
        EngineConfig(arch="hymba-1p5b", reduced=True, combine="adasum"))
    session.fit(100)

`fit` absorbs the training loop that used to live in launch/train.py:
resume-from-latest, periodic atomic checkpoints, SIGTERM save, straggler
monitoring, and (for drills) failure injection — all expressed as
pluggable callbacks, scheduled by `repro.engine.pipeline.StepPipeline`
(batch prefetch and checkpoint writes overlap the device step).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import AsyncCheckpointManager, CheckpointManager
from repro.configs.base import get_config, get_reduced, pad_heads_for_tp
from repro.control.noise import STAT_KEYS
from repro.control.telemetry import run_fingerprint
from repro.data import make_source
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.models.api import Model
from repro.runtime import StepMonitor, FailureInjector

from .build import Runtime, build_runtime, make_serve_step
from .config import EngineConfig

PyTree = Any


# ------------------------------------------------------------------ callbacks

class Callback:
    """Hook points around the training loop. All default to no-ops."""

    def on_fit_start(self, session: "TrainSession", start_step: int): ...

    def on_step_start(self, session: "TrainSession", step: int): ...

    def on_step_end(self, session: "TrainSession", step: int,
                    metrics: Dict[str, float], dt: float): ...

    def on_fit_end(self, session: "TrainSession",
                   history: List[Dict[str, float]]): ...


class LoggingCallback(Callback):
    def __init__(self, every: int = 10):
        self.every = every

    def on_step_end(self, session, step, metrics, dt):
        last = step == session.config.steps - 1
        if step % self.every == 0 or last:
            print(f"[train] step {step:5d} loss {metrics['loss']:.4f} "
                  f"{dt*1e3:.0f}ms span={session.runtime.span} "
                  f"combine={session.config.combine}")


class CheckpointCallback(Callback):
    """Periodic atomic checkpoints + final save via the session manager."""

    def __init__(self, every: int = 50):
        self.every = every

    def on_step_end(self, session, step, metrics, dt):
        # every <= 0: periodic saves off — only the final on_fit_end
        # save (and driver-side save_sync at elastic/resize boundaries)
        if session.checkpoint and self.every > 0 \
                and (step + 1) % self.every == 0:
            session.save(step + 1)

    def on_fit_end(self, session, history):
        if session.checkpoint and history:
            session.save(int(history[-1]["step"]) + 1)


class StragglerCallback(Callback):
    """Feeds step wall-times to the robust z-score StepMonitor."""

    def __init__(self, monitor: Optional[StepMonitor] = None):
        self.monitor = monitor or StepMonitor()

    def on_step_end(self, session, step, metrics, dt):
        self.monitor.observe(dt)

    def on_fit_end(self, session, history):
        print(f"[train] monitor={self.monitor.summary()}")


class FailureInjectionCallback(Callback):
    """Recovery drills: raise at scheduled steps (simulated node loss)."""

    def __init__(self, fail_at: Sequence[int]):
        self.injector = FailureInjector(list(fail_at))

    def on_step_start(self, session, step):
        self.injector.check(step)


def default_callbacks(cfg: EngineConfig,
                      fail_at: Sequence[int] = ()) -> List[Callback]:
    cbs: List[Callback] = [LoggingCallback(cfg.log_every),
                           StragglerCallback()]
    if cfg.ckpt_dir:
        cbs.append(CheckpointCallback(cfg.ckpt_every))
    if fail_at:
        cbs.insert(0, FailureInjectionCallback(fail_at))
    return cbs


# ---------------------------------------------------------------- TrainSession

class TrainSession:
    """One training run: config -> (model, mesh, runtime, data, state)."""

    def __init__(self, config: EngineConfig, model: Model,
                 mesh: jax.sharding.Mesh, runtime: Runtime, source,
                 callbacks: Optional[List[Callback]] = None,
                 checkpoint: Optional[CheckpointManager] = None):
        self.config = config
        self.model = model
        self.mesh = mesh
        self.runtime = runtime
        self.source = source
        self.callbacks = (default_callbacks(config) if callbacks is None
                          else list(callbacks))
        self.checkpoint = checkpoint
        self.state: PyTree = runtime.init_state(jax.random.key(0))
        self._step_fn = jax.jit(runtime.train_step, donate_argnums=(0,))
        self._delayed_stream = None   # set by use_delayed_stream()
        self._last_stats: Dict[str, float] = {}   # latest CombineStats

    # ------------------------------------------------------------ construction
    @classmethod
    def from_config(cls, config: EngineConfig, *,
                    model: Optional[Model] = None,
                    mesh: Optional[jax.sharding.Mesh] = None,
                    callbacks: Optional[List[Callback]] = None
                    ) -> "TrainSession":
        config.validate()
        if mesh is None:
            model_mesh = config.model_mesh
            data_size = config.data_mesh or max(
                1, len(jax.devices()) // model_mesh)
            mesh = make_local_mesh(data_size, model_mesh)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_total = int(np.prod([s for a, s in sizes.items()
                                if a != "model"]))

        if model is None:
            if not config.arch:
                raise ValueError("EngineConfig.arch is empty — pass a "
                                 "built Model via from_config(model=...)")
            mcfg = (get_reduced(config.arch) if config.reduced
                    else get_config(config.arch))
            if config.pad_heads:
                mcfg = pad_heads_for_tp(mcfg, sizes.get("model", 1))
            model = build_model(
                mcfg, attn_chunk=min(config.attn_chunk, config.seq_len),
                param_dtype=jnp.dtype(config.param_dtype))

        # span can't exceed dp (small host meshes): clamp to one lane per
        # DP rank, as launch/train.py always did
        if config.span > dp_total:
            config = dataclasses.replace(config, span=0)
        config.validate(dp_total)

        runtime = build_runtime(model, mesh, config.run_policy(),
                                lr=config.lr, strict=config.strict)
        source = make_source(config.data_config(model.cfg.vocab_size),
                             model.cfg)
        ckpt_cls = (AsyncCheckpointManager if config.async_checkpoint
                    else CheckpointManager)
        ckpt = ckpt_cls(config.ckpt_dir) if config.ckpt_dir else None
        return cls(config, model, mesh, runtime, source,
                   callbacks=callbacks, checkpoint=ckpt)

    # -------------------------------------------------------------- metadata
    def run_metadata(self) -> Dict[str, Any]:
        """What actually ran — the resolved (post-fallback) combine path
        plus the run's topology. Benchmarks record this next to their
        numbers so a 'fused' result can't silently come from the
        reference tree (the span == dp fallback)."""
        sizes = dict(zip(self.mesh.axis_names,
                         (int(s) for s in self.mesh.devices.shape)))
        rt = self.runtime
        return {"arch": self.config.arch or self.model.cfg.name,
                "combine": self.config.combine,
                "backend": self.config.backend,
                "combine_path": rt.combine_path,
                "span": rt.span,
                "dp": rt.dp_total,
                "local_steps": self.config.local_steps,
                "combine_delay": self.config.combine_delay,
                "devices": int(self.mesh.devices.size),
                "mesh": sizes,
                # CombineStats observability: whether the step emits the
                # grad-noise/orthogonality/gain metrics, and the latest
                # values seen (empty before the first step / when off) —
                # exposed even when the adaptive controller is off
                "stats_enabled": rt.combine_stats,
                "combine_stats": dict(self._last_stats),
                "adaptive_batch": self.config.adaptive_batch,
                "global_batch": self.config.global_batch,
                "lr": self.config.lr,
                "resilience": self._resilience_metadata(),
                **run_fingerprint(self.config)}

    def _resilience_metadata(self) -> Dict[str, Any]:
        """Fault-recovery accounting for the run: checkpoint restore
        fallbacks + quarantined steps (from the manager) and elastic
        restart/grow-back counts (attached by fit_elastic). Benchmarks
        record this so a result that survived faults says so."""
        log = getattr(self, "elastic_log", None) or {}
        out: Dict[str, Any] = {
            # cumulative across elastic rebuilds: earlier sessions'
            # counters are banked in elastic_log by fit_elastic
            "restore_fallbacks": log.get("prior_restore_fallbacks", 0),
            "quarantined_steps": list(log.get("prior_quarantined", [])),
            "restarts": log.get("restarts", 0),
            "grow_backs": log.get("grow_backs", 0)}
        if self.checkpoint is not None:
            out["restore_fallbacks"] += self.checkpoint.restore_fallbacks
            out["quarantined_steps"] += [
                q["step"] for q in self.checkpoint.quarantined]
        return out

    def use_delayed_stream(self, comm_delay: float = 0.0):
        """Route steps through a host-level `DelayedCombineStream`: the
        pending-delta exchange runs on a background thread (optionally
        behind `comm_delay` seconds of injected interconnect latency)
        while the local step computes, and metrics gain compute_s /
        combine_wait_s. Bitwise-identical states to the default
        single-program delayed step. Needs combine_delay=1."""
        from repro.runtime import DelayedCombineStream
        self._delayed_stream = DelayedCombineStream(
            self.runtime, comm_delay=comm_delay)
        return self._delayed_stream

    # ------------------------------------------------------------------ steps
    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        """The deterministic batch for `step` (pure function of config)."""
        return {k: jnp.asarray(v)
                for k, v in self.source.batch(step).items()}

    def step(self, batch: Optional[Dict[str, jnp.ndarray]] = None
             ) -> Dict[str, float]:
        """One optimizer step; advances self.state. With no batch, pulls
        the deterministic batch for the current step counter."""
        if batch is None:
            batch = self.batch(int(jax.device_get(self.state["step"])))
        if self._delayed_stream is not None:
            self.state, metrics = self._delayed_stream.step(self.state,
                                                            batch)
        else:
            self.state, metrics = self._step_fn(self.state, batch)
        out = {k: float(jax.device_get(v)) for k, v in metrics.items()}
        stats = {k: out[k] for k in STAT_KEYS if k in out}
        if stats:
            self._last_stats = stats
        return out

    def fit(self, steps: Optional[int] = None) -> List[Dict[str, float]]:
        """Train to `steps` total (resuming from the latest checkpoint if
        one exists). Returns the per-step history.

        A thin wrapper: the loop itself — prefetch overlap, resume
        decision, callback dispatch, elastic flag consumption, end-of-run
        barriers — lives in `repro.engine.pipeline.StepPipeline`.
        """
        from .pipeline import StepPipeline
        steps = self.config.steps if steps is None else steps
        self.config = dataclasses.replace(self.config, steps=steps)
        return StepPipeline(self).run()

    # ------------------------------------------------------------ checkpoints
    def save(self, step: Optional[int] = None):
        assert self.checkpoint is not None, "no ckpt_dir configured"
        step = (int(jax.device_get(self.state["step"]))
                if step is None else step)
        return self.checkpoint.save(step, self.state)

    def save_sync(self, step: Optional[int] = None):
        """save() + barrier: the checkpoint is durably on disk on return
        (the async writer only guarantees that at the next barrier).
        The path for SIGTERM handlers and elastic restarts."""
        path = self.save(step)
        wait = getattr(self.checkpoint, "wait", None)
        if wait is not None:
            wait()
        return path

    def close(self):
        """Release background resources (the async checkpoint writer and
        the delayed-combine exchange thread). The session is done after
        this — a later save would fail."""
        if self._delayed_stream is not None:
            self._delayed_stream.close()
            self._delayed_stream = None
        if self.checkpoint is not None:
            close = getattr(self.checkpoint, "close", None)
            if close is not None:
                close()

    def restore(self, step: Optional[int] = None) -> int:
        """Restore state from the latest (or given) checkpoint, if any.
        Returns the resumed step (0 when nothing to restore)."""
        assert self.checkpoint is not None, "no ckpt_dir configured"
        if self.checkpoint.latest_step() is None and step is None:
            return 0
        # Re-place restored leaves on the live state's shardings: the
        # manifest hands back host-local arrays, and stepping from those
        # compiles a single-device executable whose reduction order
        # differs from the mesh-sharded one — resume would drift from
        # the uninterrupted run by float rounding every step.
        template = self.state
        restored = self.checkpoint.restore(template, step)
        self.state = jax.tree.map(
            lambda v, old: (jax.device_put(v, old.sharding)
                            if hasattr(old, "sharding") else v),
            restored, template)
        start = int(jax.device_get(self.state["step"]))
        print(f"[train] resumed from step {start}")
        return start


# ---------------------------------------------------------------- ServeSession

class ServeSession:
    """Legacy batched-serving surface, now a thin compat wrapper over
    `repro.engine.serving.ServeEngine`: `generate(prompts, gen_len)`
    submits one request per row and drains the engine (fused prefill +
    slotted continuous batching). `stepped_prefill=True` keeps the old
    one-token-at-a-time loop — the bitwise reference the equivalence
    tests pin the fused path against. Frontend/enc-dec models (per-batch
    encoder state, not per-slot) always take the stepped path."""

    def __init__(self, config: EngineConfig, model: Model,
                 mesh: jax.sharding.Mesh, params: PyTree,
                 checkpoint: Optional[CheckpointManager] = None,
                 loaded_step: Optional[int] = None):
        self.config = config
        self.model = model
        self.mesh = mesh
        self.params = params
        self.checkpoint = checkpoint
        self._loaded_step = loaded_step
        self._step = jax.jit(make_serve_step(model), donate_argnums=(2,))
        self._engine: Optional[Any] = None      # lazily-built ServeEngine

    @classmethod
    def from_config(cls, config: EngineConfig, *,
                    model: Optional[Model] = None,
                    mesh: Optional[jax.sharding.Mesh] = None,
                    params: Optional[PyTree] = None,
                    attn_chunk: int = 64) -> "ServeSession":
        # shared serve bootstrap (ServeEngine.from_config uses it too):
        # with ckpt_dir, serves the trained weights via the params-only
        # restore against the path-indexed manifest
        from .serving.engine import resolve_serve_parts
        model, mesh, params, checkpoint, loaded_step = resolve_serve_parts(
            config, model=model, mesh=mesh, params=params,
            attn_chunk=attn_chunk)
        return cls(config, model, mesh, params, checkpoint=checkpoint,
                   loaded_step=loaded_step)

    # -------------------------------------------------------------- engine
    def engine(self, max_len: Optional[int] = None):
        """The ServeEngine behind this session (one engine, lazily built,
        re-built larger when a call needs more cache capacity). Inherits
        the session's checkpoint manager, so `hot_reload=True` in the
        config works here too. Prefer it directly for request-level
        serving (streaming, staggered arrivals)."""
        from .serving import ServeEngine
        need = max_len or self.config.max_len or self.config.seq_len
        if self._engine is None or self._engine.max_len < need:
            cap = 1 << (need - 1).bit_length()     # pow2: bounds rebuilds
            cfg = dataclasses.replace(self.config, max_len=cap)
            self._engine = ServeEngine(cfg, self.model, self.mesh,
                                       self.params,
                                       checkpoint=self.checkpoint,
                                       loaded_step=self._loaded_step)
        return self._engine

    # ------------------------------------------------------------ generate
    def generate(self, prompts: jnp.ndarray, gen_len: int,
                 max_len: Optional[int] = None,
                 frontend_embeds=None,
                 stepped_prefill: bool = False) -> jnp.ndarray:
        """prompts: [B, T] int32. Returns [B, T+gen_len]."""
        B, T = prompts.shape
        max_len = max_len or (T + gen_len + 1)
        cfg = self.model.cfg
        if (stepped_prefill or frontend_embeds is not None
                or cfg.is_encoder_decoder or cfg.frontend != "none"):
            return self._generate_stepped(prompts, gen_len, max_len,
                                          frontend_embeds)
        from .serving import GenerationRequest
        eng = self.engine(max_len)
        handles = [eng.submit(GenerationRequest(
            prompt=np.asarray(prompts[i]), max_new_tokens=gen_len))
            for i in range(B)]
        eng.drain()
        return jnp.asarray(np.stack([h.output for h in handles]))

    def _generate_stepped(self, prompts, gen_len, max_len,
                          frontend_embeds=None) -> jnp.ndarray:
        """The pre-ServeEngine loop: prompt fed one token at a time
        through the jitted decode step (T dispatches), then greedy
        decode. Cache-exact — the fused paths are tested against it."""
        B, T = prompts.shape
        cfg = self.model.cfg
        if cfg.is_encoder_decoder:
            cache = self.model.init_cache(self.params, B, max_len,
                                          frontend_embeds=frontend_embeds)
        else:
            cache = self.model.init_cache(self.params, B, max_len)
        nxt = prompts[:, :1]
        for t in range(T):
            nxt, cache = self._step(self.params, prompts[:, t:t + 1], cache)
        cur = nxt
        gen = []
        for _ in range(gen_len):
            gen.append(cur)
            cur, cache = self._step(self.params, cur, cache)
        return jnp.concatenate([prompts] + gen, axis=1)
