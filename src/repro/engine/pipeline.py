"""Pipelined execution runtime: the step loop as overlapped stages.

`TrainSession.fit` used to serialize three things the paper's throughput
story says must overlap with useful device work (DaSGD, Zhou et al.):
host-side batch generation, checkpoint file I/O, and straggler handling.
This module is the runtime that overlaps them:

    host thread      :  batch(step+1)  ->  stage host->device   (Prefetcher)
    device           :  train_step(state, batch(step))
    writer thread    :  serialize + write checkpoint(step-k)    (AsyncCheckpointManager)
    monitor          :  robust z-score on step times  ->  RestartSignal

and the elastic driver (`fit_elastic`) that consumes the monitor's flag
or a `NodeLossError` (real or injected participant loss): checkpoint ->
rebuild the mesh at the halved DP degree -> rebuild the runtime
(combiner re-resolved through the registry for the new span) -> resume
from the manifest. Per paper §5.4 Adasum needs *no hyperparameter
change* across the restart, which is what makes the shrink safe.

Determinism: batches are addressed by step (pure (seed, step) functions),
so the prefetched stream is bitwise identical to the synchronous one —
including across save/restore/resume and elastic rebuilds.
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.control.noise import STAT_KEYS
from repro.runtime import (GrowBackSignal, NodeLossError, Prefetcher,
                           RestartSignal, plan_grow_back, plan_shrink)

PyTree = Any


def make_device_stage(mesh, dp_axes):
    """Prefetch staging fn that `jax.device_put`s every batch leaf onto
    the mesh (dim 0 sharded over the DP axes) from the prefetch thread,
    so the step loop never pays the host->device transfer either —
    the explicit-staging arm of the ROADMAP's prefetch-depth item."""
    from jax.sharding import NamedSharding
    from repro.parallel.sharding import batch_specs

    def stage(batch):
        import jax.numpy as jnp
        arrs = {k: jnp.asarray(v) for k, v in batch.items()}
        specs = batch_specs(arrs, dp_axes)
        return {k: jax.device_put(arrs[k], NamedSharding(mesh, specs[k]))
                for k in arrs}

    return stage


class StepPipeline:
    """Drives one `TrainSession`'s training loop with overlapped stages.

    The session owns model/mesh/runtime/state; the pipeline owns the
    *schedule*: resume decision, prefetch lifecycle (depth + staging per
    EngineConfig.prefetch_depth/device_stage), step timing, callback
    dispatch, elastic flag consumption, and the end-of-run barriers
    (pending checkpoint writes, prefetch shutdown).
    """

    def __init__(self, session):
        self.session = session
        self.prefetcher: Optional[Prefetcher] = None

    # ----------------------------------------------------------- plumbing
    def _fetch(self, step: int) -> Dict[str, Any]:
        if self.prefetcher is not None:
            return self.prefetcher.get(step)
        return self.session.batch(step)

    def _flagged_monitors(self):
        from .session import StragglerCallback
        return [cb.monitor for cb in self.session.callbacks
                if isinstance(cb, StragglerCallback) and cb.monitor.flagged]

    def _resolve_start(self) -> int:
        """Continue from the live state unless a checkpoint is AHEAD of it
        (the fresh-process resume case) — never roll back in-session work."""
        s = self.session
        start = int(jax.device_get(s.state["step"]))
        if s.checkpoint:
            latest = s.checkpoint.latest_step()
            if latest is not None and latest > start:
                start = s.restore()
            s.checkpoint.install_preemption_handler(
                lambda: s.save_sync())
        return start

    # ---------------------------------------------------------------- run
    def run(self) -> List[Dict[str, float]]:
        s = self.session
        steps = s.config.steps
        start = self._resolve_start()
        for cb in s.callbacks:
            cb.on_fit_start(s, start)
        if s.config.prefetch and start < steps:
            stage = (make_device_stage(s.mesh, s.runtime.dp_axes)
                     if s.config.device_stage else None)
            self.prefetcher = Prefetcher(s.source, limit=steps,
                                         depth=s.config.prefetch_depth,
                                         stage=stage)
            self.prefetcher.schedule(start)
        history: List[Dict[str, float]] = []
        try:
            for step in range(start, steps):
                for cb in s.callbacks:
                    cb.on_step_start(s, step)
                t0 = time.perf_counter()
                batch = self._fetch(step)
                metrics = s.step(batch)
                # dt covers batch wait + device step: the quantity the
                # overlap hides and the straggler monitor should judge
                dt = time.perf_counter() - t0
                row = {"step": step, "loss": metrics["loss"], "s": dt}
                # delayed-combine split accounting (combine_delay runs
                # through a DelayedCombineStream): how much of the step
                # was compute vs waiting on the exchange — the overlap
                # is observable per step, not just in aggregate.
                # CombineStats metrics (grad-noise scale / orthogonality
                # / gain) ride along when the combiner emits them.
                for key in ("compute_s", "combine_wait_s") + STAT_KEYS:
                    if key in metrics:
                        row[key] = metrics[key]
                history.append(row)
                for cb in s.callbacks:
                    cb.on_step_end(s, step, metrics, dt)
                if s.config.elastic and self._flagged_monitors():
                    raise RestartSignal(step + 1)
            for cb in s.callbacks:
                cb.on_fit_end(s, history)
        except Exception as e:
            # the elastic driver stitches runs together across restarts;
            # hand it the steps this attempt did complete
            e.history = history
            raise
        finally:
            if self.prefetcher is not None:
                self.prefetcher.close()
                self.prefetcher = None
            if s.checkpoint is not None:
                wait = getattr(s.checkpoint, "wait", None)
                if wait is not None and sys.exc_info()[0] is None:
                    wait()
                elif wait is not None:
                    # already unwinding (e.g. RestartSignal): a stale
                    # writer error must not supersede it — drain + report
                    try:
                        wait()
                    except Exception as we:
                        print(f"[pipeline] checkpoint writer error "
                              f"during unwind: {we!r}")
        return history


# ------------------------------------------------------------------ elastic

def fit_elastic(config, steps: Optional[int] = None, *,
                callbacks: Optional[List] = None, max_restarts: int = 2,
                max_grow_backs: int = 4,
                on_restart: Optional[Callable] = None,
                ) -> Tuple[List[Dict[str, float]], Any]:
    """Fault-tolerant driver: run `fit`, and on node loss (injected
    failure) or a flagged persistent straggler do the monitor.py ladder —
    checkpoint, halve the DP degree (power of two), rebuild mesh +
    runtime + combiner from the same EngineConfig, resume from the
    manifest. A `GrowBackSignal` (capacity returned) runs the same
    save -> rebuild -> resume machinery in the other direction: DP
    re-expands toward the run's original degree and the LR is rescaled
    by the AdaScale gain of the growth factor (computed from the live
    CombineStats; 1.0 without stats) — per §5.4 nothing else changes.
    Returns (combined history, final session); the final session carries
    an `elastic_log` dict (restarts / grow_backs / plans).

    The callback list is shared across attempts (a FailureInjector must
    not re-arm a failure it already fired), but straggler monitors are
    reset on restart — evicting the straggler clears the flag.

    `on_restart(session, signal)` — optional hook invoked after each
    boundary `save_sync` and before the rebuild. The chaos harness uses
    it to corrupt the just-written checkpoint and prove the restore
    falls back to last-good.
    """
    from repro.control.noise import gain_for_factor
    from repro.launch.mesh import make_local_mesh
    from repro.runtime import StepMonitor
    from .session import StragglerCallback, TrainSession, default_callbacks

    if not config.ckpt_dir:
        raise ValueError("fit_elastic needs EngineConfig.ckpt_dir (the "
                         "restart resumes from the manifest)")
    cbs = default_callbacks(config) if callbacks is None else list(callbacks)

    def _reset_monitors():
        for cb in cbs:
            if isinstance(cb, StragglerCallback):
                cb.monitor = StepMonitor(cb.monitor.cfg)

    mesh = None
    history: List[Dict[str, float]] = []
    restarts = grow_backs = 0
    full_dp = 0    # the original DP degree: the grow-back target
    elastic_log: Dict[str, Any] = {"restarts": 0, "grow_backs": 0,
                                   "plans": [],
                                   "prior_restore_fallbacks": 0,
                                   "prior_quarantined": []}

    def _bank_counters(session):
        # each rebuild gets a fresh CheckpointManager; bank the closing
        # session's integrity counters so run_metadata stays cumulative
        if session.checkpoint is not None:
            elastic_log["prior_restore_fallbacks"] \
                += session.checkpoint.restore_fallbacks
            elastic_log["prior_quarantined"] \
                += [q["step"] for q in session.checkpoint.quarantined]
    while True:
        session = TrainSession.from_config(config, mesh=mesh, callbacks=cbs)
        session.elastic_log = elastic_log
        if not full_dp:
            full_dp = session.runtime.dp_total
        if restarts or grow_backs:
            # after any elastic rebuild, validate + log the settings
            # actually in force (span can be re-clamped by the smaller
            # dp) — same check the controller-resize driver runs
            from repro.control.resize import log_effective
            log_effective(session,
                          label=f"rebuild #{restarts + grow_backs}")
        try:
            history += session.fit(steps)
            return history, session
        except (RestartSignal, NodeLossError) as e:
            history += getattr(e, "history", [])
            # state sits at a step boundary (failures fire at step start,
            # straggler flags after step end): checkpoint it, barrier
            session.save_sync()
            if on_restart is not None:
                on_restart(session, e)
            plan = plan_shrink(session.runtime.dp_total)
            if not plan.shrunk or restarts >= max_restarts:
                session.close()
                raise
            _bank_counters(session)
            restarts += 1
            elastic_log["restarts"] = restarts
            elastic_log["plans"].append(
                {"kind": "shrink", "old_dp": plan.old_dp,
                 "new_dp": plan.new_dp})
            print(f"[elastic] {e}: restarting at dp={plan.new_dp} "
                  f"(was {plan.old_dp}), no hyperparameter change")
            session.close()    # the abandoned session's writer thread
            mesh = make_local_mesh(plan.new_dp, config.model_mesh)
            _reset_monitors()
        except GrowBackSignal as e:
            history += getattr(e, "history", [])
            session.save_sync()
            if on_restart is not None:
                on_restart(session, e)
            grow_backs += 1
            if grow_backs > max_grow_backs:
                session.close()
                raise
            _bank_counters(session)
            dp_now = session.runtime.dp_total
            target = e.target_dp or full_dp
            prov = plan_grow_back(dp_now, target, config.lr)
            if not prov.grew:
                # nothing to re-expand: resume as-is from the manifest
                session.close()
                continue
            # AdaScale gain of the growth factor from live CombineStats
            stats = getattr(session, "_last_stats", {}) or {}
            # _last_stats is already host floats (device_get in step())
            var = float(stats.get("grad_var", 0.0))    # lint: allow(host-pull)
            mu2 = float(stats.get("grad_mu2", 0.0))    # lint: allow(host-pull)
            factor = prov.new_dp // prov.old_dp
            gain = (gain_for_factor(var, mu2, float(factor))
                    if (var > 0.0 or mu2 > 0.0) else 1.0)
            plan = plan_grow_back(dp_now, target, config.lr, lr_scale=gain)
            elastic_log["grow_backs"] = grow_backs
            elastic_log["plans"].append(
                {"kind": "grow_back", "old_dp": plan.old_dp,
                 "new_dp": plan.new_dp, "old_lr": plan.old_lr,
                 "new_lr": plan.new_lr, "gain": gain})
            print(f"[elastic] {e}: growing back to dp={plan.new_dp} "
                  f"(was {plan.old_dp}), lr {plan.old_lr:g}->"
                  f"{plan.new_lr:g} (adascale gain {gain:.3f} for "
                  f"factor {factor})")
            session.close()
            config = dataclasses.replace(config, lr=plan.new_lr)
            mesh = make_local_mesh(plan.new_dp, config.model_mesh)
            _reset_monitors()
