"""Slotted-cache device ops for continuous batching.

A slotted decode cache (``model.init_cache(..., per_slot=True)``) stacks
layers on axis 0 and keeps the batch (slot) axis at position 1 of EVERY
leaf — including the per-slot ``pos`` counters, which become [L, B].
That invariant is what makes the two primitives here fully generic over
model families (GQA / MLA / SWA / MoE caches, mamba and RWKV recurrent
states alike):

  * ``insert_rows``  — admit: overwrite one slot's rows with a freshly
    prefilled single-row cache (this IS the slot reset: every piece of
    per-slot state lives on the batch axis);
  * ``select_rows``  — merge: per-slot choice between two cache versions
    (used by checkpoint hot-reload, where in-flight slots keep decoding
    on the params they were admitted with).

Both are shape-stable in the slot index, so the scheduler can admit and
retire requests at any rate without triggering recompilation.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


def insert_rows(cache: PyTree, row: PyTree, slot) -> PyTree:
    """Write a 1-row cache pytree into `cache` at slot index `slot`
    (traced scalar — one compilation serves every slot)."""
    return jax.tree.map(
        lambda c, r: jax.lax.dynamic_update_slice_in_dim(c, r.astype(c.dtype),
                                                         slot, axis=1),
        cache, row)


def insert_rows_at(cache: PyTree, rows: PyTree, slots: jnp.ndarray) -> PyTree:
    """Scatter an n-row cache pytree into `cache` at (possibly
    non-contiguous) slot indices `slots` [n] — the admission path when
    several requests prefill together in one tick. Compiles once per
    group size n <= max_slots."""
    return jax.tree.map(
        lambda c, r: c.at[:, slots].set(r.astype(c.dtype)),
        cache, rows)


def select_rows(mask: jnp.ndarray, new: PyTree, old: PyTree) -> PyTree:
    """Per-slot select: rows where mask[b] take `new`, others keep `old`.
    mask: bool [B] over the slot axis (axis 1 of every leaf)."""
    def sel(a, b):
        m = mask.reshape((1, mask.shape[0]) + (1,) * (a.ndim - 2))
        return jnp.where(m, a, b)
    return jax.tree.map(sel, new, old)


def slot_positions(cache: PyTree) -> jnp.ndarray:
    """The per-slot sequence positions [B] (from the first cache leaf
    carrying them) — introspection for tests and stats."""
    for leaf in jax.tree.leaves(cache):
        if leaf.ndim == 2 and leaf.dtype == jnp.int32:
            return leaf[0]
    raise ValueError("cache has no per-slot pos leaf; was it built with "
                     "per_slot=True?")
