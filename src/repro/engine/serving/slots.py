"""Slotted-cache device ops for continuous batching, and the page pool.

A slotted decode cache (``model.init_cache(..., per_slot=True)``) stacks
layers on axis 0 and keeps the batch (slot) axis at position 1 of EVERY
leaf — including the per-slot ``pos`` counters, which become [L, B].
That invariant is what makes the two primitives here fully generic over
model families (GQA / MLA / SWA / MoE caches, mamba and RWKV recurrent
states alike):

  * ``insert_rows``  — admit: overwrite one slot's rows with a freshly
    prefilled single-row cache (this IS the slot reset: every piece of
    per-slot state lives on the batch axis);
  * ``select_rows``  — merge: per-slot choice between two cache versions
    (used by checkpoint hot-reload, where in-flight slots keep decoding
    on the params they were admitted with).

Both are shape-stable in the slot index, so the scheduler can admit and
retire requests at any rate without triggering recompilation.

Paged layout (``init_cache(..., paged=(page_size, num_pages))``): the
attention K/V of every slot lives in one global page arena, addressed
through per-slot int32 page tables (see models/attention.PagedKVCache).
``PagePool`` is the host-side allocator — refcounted physical pages, a
free list, copy-on-write — and the ``paged_*`` device ops below are its
jit-stable counterparts: they rewrite arena rows and tables without ever
changing a shape, so page churn (admission, growth, COW, eviction)
NEVER retraces the decode step. Physical page 0 is the reserved trash
page: free slots and unallocated table entries point at it, making their
garbage writes inert.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as ATT

PyTree = Any

PAGED_TYPES = ATT.PAGED_CACHE_TYPES


def _is_paged(x) -> bool:
    return isinstance(x, PAGED_TYPES)


def insert_rows(cache: PyTree, row: PyTree, slot) -> PyTree:
    """Write a 1-row cache pytree into `cache` at slot index `slot`
    (traced scalar — one compilation serves every slot)."""
    return jax.tree.map(
        lambda c, r: jax.lax.dynamic_update_slice_in_dim(c, r.astype(c.dtype),
                                                         slot, axis=1),
        cache, row)


def insert_rows_at(cache: PyTree, rows: PyTree, slots: jnp.ndarray) -> PyTree:
    """Scatter an n-row cache pytree into `cache` at (possibly
    non-contiguous) slot indices `slots` [n] — the admission path when
    several requests prefill together in one tick. Compiles once per
    group size n <= max_slots."""
    return jax.tree.map(
        lambda c, r: c.at[:, slots].set(r.astype(c.dtype)),
        cache, rows)


def select_rows(mask: jnp.ndarray, new: PyTree, old: PyTree) -> PyTree:
    """Per-slot select: rows where mask[b] take `new`, others keep `old`.
    mask: bool [B] over the slot axis (axis 1 of every leaf)."""
    def sel(a, b):
        m = mask.reshape((1, mask.shape[0]) + (1,) * (a.ndim - 2))
        return jnp.where(m, a, b)
    return jax.tree.map(sel, new, old)


def slot_positions(cache: PyTree) -> jnp.ndarray:
    """The per-slot sequence positions [B] (from the first cache leaf
    carrying them) — introspection for tests and stats."""
    for leaf in jax.tree.leaves(cache):
        if leaf.ndim == 2 and leaf.dtype == jnp.int32:
            return leaf[0]
    raise ValueError("cache has no per-slot pos leaf; was it built with "
                     "per_slot=True?")


_POS_TYPES = PAGED_TYPES + (ATT.KVCache, ATT.MLACache)


def set_positions(cache: PyTree, pos: jnp.ndarray) -> PyTree:
    """Overwrite every attention cache's per-slot pos with `pos` [B]
    (broadcast over the layer axis). This single values-only rewrite IS
    speculative accept AND rollback: advancing pos to
    old_pos + accepted + 1 commits the accepted rows, and everything the
    verify forward wrote beyond that is instantly masked garbage that
    the next decode writes overwrite — no arena copies, no retrace.
    Attention caches only (recurrent ssm/hybrid state has no pos to
    rewrite; the engine never speculates on those families)."""
    def fix(c):
        if isinstance(c, _POS_TYPES):
            return c._replace(
                pos=jnp.broadcast_to(pos.astype(jnp.int32), c.pos.shape))
        return c
    return jax.tree.map(fix, cache,
                        is_leaf=lambda x: isinstance(x, _POS_TYPES))


# ============================================================== page pool
class PagePool:
    """Host-side physical-page allocator for the paged KV arena.

    Pages are refcounted: a page owned by one slot has refcount 1; a
    shared read-only prefix page holds one reference per slot using it
    plus (optionally) one held by the prefix index that keeps it warm for
    future requests. Physical page 0 is the reserved trash page — never
    allocated, never freed; free slots' table entries point at it.

    The pool is pure bookkeeping (no jax): the engine pairs each
    transition with the matching device op (``paged_insert_rows``,
    ``copy_pages``, ``set_page_tables``)."""

    TRASH = 0

    def __init__(self, num_pages: int, page_size: int):
        assert num_pages >= 2, f"need >= 2 pages (1 is trash), {num_pages}"
        assert page_size >= 1, page_size
        self.num_pages = num_pages
        self.page_size = page_size
        self._free: List[int] = list(range(num_pages - 1, 0, -1))  # pop() low
        self._ref = np.zeros((num_pages,), np.int32)
        self._ref[self.TRASH] = 1          # never allocatable

    # ------------------------------------------------------------ alloc
    @property
    def pages_free(self) -> int:
        return len(self._free)

    @property
    def pages_used(self) -> int:
        return self.num_pages - 1 - len(self._free)

    def alloc(self, n: int = 1) -> Optional[List[int]]:
        """n fresh pages (refcount 1 each), or None if the pool cannot
        cover the request (caller evicts/preempts and retries)."""
        if n > len(self._free):
            return None
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            self._ref[p] = 1
        return out

    def ref(self, pages) -> None:
        """Take one extra reference on each page (prefix sharing)."""
        for p in pages:
            assert self._ref[p] > 0, f"ref on free page {p}"
            self._ref[p] += 1

    def release(self, pages) -> None:
        """Drop one reference per page; pages hitting zero return to the
        free list."""
        for p in pages:
            assert p != self.TRASH and self._ref[p] > 0, (p, self._ref[p])
            self._ref[p] -= 1
            if self._ref[p] == 0:
                self._free.append(int(p))

    def refcount(self, page: int) -> int:
        return int(self._ref[page])

    def is_shared(self, page: int) -> bool:
        return self._ref[page] > 1

    def cow(self, page: int) -> Optional[int]:
        """Copy-on-write: drop this slot's reference on a shared `page`
        and allocate a private destination page. Returns the new page id
        (the caller must issue the device ``copy_pages``), or None if the
        pool is exhausted (caller evicts/preempts first)."""
        got = self.alloc(1)
        if got is None:
            return None
        self.release([page])
        return got[0]

    def __repr__(self):
        return (f"PagePool(pages={self.num_pages}, size={self.page_size}, "
                f"used={self.pages_used}, free={self.pages_free})")


# ====================================================== paged device ops
def _zip_paged(fn_paged, fn_leaf, cache: PyTree, *rest: PyTree) -> PyTree:
    """tree.map over `cache` stopping at paged cache nodes: paged nodes
    get fn_paged(node, *corresponding subtrees), plain leaves fn_leaf."""
    def f(c, *r):
        return fn_paged(c, *r) if _is_paged(c) else fn_leaf(c, *r)
    return jax.tree.map(f, cache, *rest, is_leaf=_is_paged)


def paged_insert_rows(cache: PyTree, rows: PyTree, slots: jnp.ndarray,
                      write_tables: jnp.ndarray, new_tables: jnp.ndarray,
                      ) -> PyTree:
    """Admit n freshly-prefilled rows into a paged cache.

    `rows` is the DENSE per-slot cache the prefill paths produce (leaves
    [L, n, cap, ...]); its attention rows are scattered into the arena
    through `write_tables` [n, pages_per_slot] — the slot's new table
    with every non-owned entry (shared prefix pages, unallocated tail)
    pointing at trash page 0, so shared pages are never clobbered and
    rolling/partial layouts transfer row-for-row. Non-attention leaves
    (recurrent state, pos) take the plain per-slot scatter. The slots'
    page-table rows are set to `new_tables` [n, pages_per_slot]."""
    def paged(c, r):
        def scatter(arena, dense_rows):
            Lyr, n = dense_rows.shape[0], dense_rows.shape[1]
            P = write_tables.shape[1]
            psz = arena.shape[2]
            tail = dense_rows.shape[3:]
            src = dense_rows.reshape((Lyr, n, P, psz) + tail)
            return arena.at[:, write_tables].set(src.astype(arena.dtype))

        if isinstance(c, ATT.PagedKVCache):
            k = scatter(c.k, r.k)
            v = scatter(c.v, r.v)
            pt = c.page_table.at[:, slots].set(new_tables)
            pos = c.pos.at[:, slots].set(r.pos)
            return ATT.PagedKVCache(k, v, pt, pos)
        c_kv = scatter(c.c_kv, r.c_kv)
        k_rope = scatter(c.k_rope, r.k_rope)
        pt = c.page_table.at[:, slots].set(new_tables)
        pos = c.pos.at[:, slots].set(r.pos)
        return ATT.PagedMLACache(c_kv, k_rope, pt, pos)

    def leaf(c, r):
        return c.at[:, slots].set(r.astype(c.dtype))

    return _zip_paged(paged, leaf, cache, rows)


def gather_prefix(cache: PyTree, pages: jnp.ndarray) -> PyTree:
    """Read a shared-prefix K/V context back out of the arena: `pages`
    [n_pages] physical ids in logical order -> a DecodeCache-shaped
    pytree of per-layer pairs [L, 1, n_pages * page_size, ...] (leading
    singleton batch axis; the prefill broadcasts it across the admission
    group). Feeds `prefill_cache(prefix_kv=...)`."""
    def paged(c):
        def g(arena):
            sel = arena[:, pages]          # [L, n, ps, ...]
            Lyr, n, psz = sel.shape[:3]
            return sel.reshape((Lyr, 1, n * psz) + sel.shape[3:])
        if isinstance(c, ATT.PagedKVCache):
            return (g(c.k), g(c.v))
        return (g(c.c_kv), g(c.k_rope))

    def leaf(c):
        return None                        # recurrent state has no prefix

    return _zip_paged(paged, leaf, cache)


def copy_pages(cache: PyTree, src: jnp.ndarray, dst: jnp.ndarray) -> PyTree:
    """Copy arena pages src[i] -> dst[i] in every layer (COW backing
    store move). Page tables / positions / plain leaves untouched."""
    def paged(c):
        def cp(arena):
            return arena.at[:, dst].set(arena[:, src])
        if isinstance(c, ATT.PagedKVCache):
            return c._replace(k=cp(c.k), v=cp(c.v))
        return c._replace(c_kv=cp(c.c_kv), k_rope=cp(c.k_rope))

    return _zip_paged(paged, lambda c: c, cache)


def set_page_tables(cache: PyTree, tables: jnp.ndarray) -> PyTree:
    """Install the host-side page tables [B, pages_per_slot] into every
    paged node (broadcast over the layer axis). Values-only churn: the
    decode step never retraces."""
    def paged(c):
        return c._replace(page_table=jnp.broadcast_to(
            tables.astype(jnp.int32), c.page_table.shape))

    return _zip_paged(paged, lambda c: c, cache)


def select_rows_paged(slot_mask: jnp.ndarray, page_mask: jnp.ndarray,
                      new: PyTree, old: PyTree) -> PyTree:
    """Paged counterpart of `select_rows` (hot-reload transition ticks):
    arena leaves merge per PHYSICAL page — `page_mask` [num_pages] marks
    pages owned by slots pinned to the `new` version (shared prefix pages
    are read-only and identical in both, so either side is correct) —
    while per-slot leaves (pos, page_table, recurrent state) merge by
    `slot_mask` [B]."""
    def paged(n, o):
        def sel_arena(a, b):
            m = page_mask.reshape((1, page_mask.shape[0])
                                  + (1,) * (a.ndim - 2))
            return jnp.where(m, a, b)

        def sel_slot(a, b):
            m = slot_mask.reshape((1, slot_mask.shape[0])
                                  + (1,) * (a.ndim - 2))
            return jnp.where(m, a, b)

        if isinstance(n, ATT.PagedKVCache):
            return ATT.PagedKVCache(sel_arena(n.k, o.k),
                                    sel_arena(n.v, o.v),
                                    sel_slot(n.page_table, o.page_table),
                                    sel_slot(n.pos, o.pos))
        return ATT.PagedMLACache(sel_arena(n.c_kv, o.c_kv),
                                 sel_arena(n.k_rope, o.k_rope),
                                 sel_slot(n.page_table, o.page_table),
                                 sel_slot(n.pos, o.pos))

    def leaf(n, o):
        m = slot_mask.reshape((1, slot_mask.shape[0]) + (1,) * (n.ndim - 2))
        return jnp.where(m, n, o)

    return _zip_paged(paged, leaf, new, old)


def cast_paged_like(cache: PyTree, dense_dtypes: PyTree) -> PyTree:
    """Cast a freshly-initialized paged cache to the steady dtypes the
    engine computed on the DENSE layout (same tree shape apart from the
    paged attention nodes, whose arena leaves borrow the dense k/v
    dtypes field-for-field)."""
    def paged(c, d):
        if isinstance(c, ATT.PagedKVCache):
            return c._replace(k=c.k.astype(d.k), v=c.v.astype(d.v))
        return c._replace(c_kv=c.c_kv.astype(d.c_kv),
                          k_rope=c.k_rope.astype(d.k_rope))

    return _zip_paged(paged, lambda c, d: c.astype(d), cache, dense_dtypes)


def dense_fallback_stats(cache: PyTree) -> tuple:
    """(leaves, bytes) of per-slot state living OUTSIDE paged nodes in a
    cache built for `kv_layout='paged'` — the quietly-dense remainder:
    mamba/rwkv recurrent state, per-slot pos counters of dense nodes.
    An all-dense cache (ssm family fallback) counts every leaf. Works on
    arrays and ShapeDtypeStructs alike (the retrace checker calls it on
    eval_shape output)."""
    leaves = 0
    nbytes = 0

    def f(c):
        nonlocal leaves, nbytes
        if not _is_paged(c):
            leaves += 1
            nbytes += int(np.prod(c.shape)) * np.dtype(c.dtype).itemsize
        return c

    jax.tree.map(f, cache, is_leaf=_is_paged)
    return leaves, nbytes


def dense_kv_bytes(cache: PyTree) -> int:
    """Bytes held by the dense attention K/V buffers (pos counters and
    recurrent state excluded) — the footprint the paged arena's
    `kv_bytes_in_use` is compared against."""
    total = 0
    dense_types = (ATT.KVCache, ATT.MLACache)

    def f(c):
        nonlocal total
        if isinstance(c, dense_types):
            arenas = ((c.k, c.v) if isinstance(c, ATT.KVCache)
                      else (c.c_kv, c.k_rope))
            for a in arenas:
                total += int(np.prod(a.shape)) * a.dtype.itemsize
        return c

    jax.tree.map(f, cache, is_leaf=lambda x: isinstance(x, dense_types))
    return total


def paged_kv_page_bytes(cache: PyTree) -> int:
    """Bytes one physical page occupies across all layers and arena
    leaves — the unit of `kv_bytes_in_use` accounting."""
    total = 0

    def paged(c):
        nonlocal total
        arenas = ((c.k, c.v) if isinstance(c, ATT.PagedKVCache)
                  else (c.c_kv, c.k_rope))
        for a in arenas:
            Lyr = a.shape[0]
            per_row = int(np.prod(a.shape[3:])) if a.ndim > 3 else 1
            total += Lyr * a.shape[2] * per_row * a.dtype.itemsize
        return c

    _zip_paged(paged, lambda c: c, cache)
    return total
