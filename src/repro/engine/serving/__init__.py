"""repro.engine.serving — request-level serving subsystem.

    ServeEngine      submit/step/drain engine: continuous batching over a
                     slotted KV cache, fused prefill, hot-reload
    GenerationRequest / RequestHandle
                     the request/response surface (streaming callbacks)
    ContinuousBatchingScheduler
                     host-side slot admission/retirement policy
    HotReloader      checkpoint watcher -> versioned param swaps
    PagePool         host-side paged-KV allocator (refcounts, COW,
                     trash page 0)
    PrefixIndex      shared-prefix page registry (exact byte-chain keys,
                     LRU eviction)
    insert_rows / select_rows / slot_positions
                     the slotted-cache device primitives (paged
                     counterparts live in .slots too)
"""
from .engine import ServeEngine
from .reload import HotReloader
from .scheduler import (ContinuousBatchingScheduler, GenerationRequest,
                        PrefixIndex, PressureLadder, RequestHandle)
from .slots import PagePool, insert_rows, select_rows, slot_positions

__all__ = [
    "ServeEngine", "GenerationRequest", "RequestHandle",
    "ContinuousBatchingScheduler", "HotReloader", "PagePool", "PrefixIndex",
    "PressureLadder", "insert_rows", "select_rows", "slot_positions",
]
