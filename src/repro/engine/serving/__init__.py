"""repro.engine.serving — request-level serving subsystem.

    ServeEngine      submit/step/drain engine: continuous batching over a
                     slotted KV cache, fused prefill, hot-reload
    GenerationRequest / RequestHandle
                     the request/response surface (streaming callbacks)
    ContinuousBatchingScheduler
                     host-side slot admission/retirement policy
    HotReloader      checkpoint watcher -> versioned param swaps
    insert_rows / select_rows / slot_positions
                     the slotted-cache device primitives
"""
from .engine import ServeEngine
from .reload import HotReloader
from .scheduler import (ContinuousBatchingScheduler, GenerationRequest,
                        RequestHandle)
from .slots import insert_rows, select_rows, slot_positions

__all__ = [
    "ServeEngine", "GenerationRequest", "RequestHandle",
    "ContinuousBatchingScheduler", "HotReloader",
    "insert_rows", "select_rows", "slot_positions",
]
