"""Checkpoint hot-reload: swap serving weights mid-stream.

A `HotReloader` watches a checkpoint directory (written by a concurrent
TrainSession) and hands new params to the ServeEngine as versioned
weights: requests admitted after the swap decode with the new params
while in-flight requests finish on the version they started with — no
drain, no drop.

Safety comes from two existing mechanisms, reused rather than
reinvented:

  * atomic checkpoints — `latest_step()` only ever lists fully-renamed
    step directories, so a reader on its own manager can never observe a
    partial write;
  * AsyncCheckpointManager barriers — when the reloader SHARES the
    training run's async manager (same process, e.g. tests or a sidecar
    deployment), `latest_step()`/`restore_params()` first drain the
    in-flight background write, so the reloader sees the checkpoint the
    trainer just scheduled instead of racing it.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

from repro.checkpoint import CheckpointIntegrityError

PyTree = Any


class HotReloader:
    """Polls a CheckpointManager; restores the params subtree on change.

    A corrupt latest step (torn write, bit-flip — anything integrity
    validation rejects) is quarantined by the manager and the reloader
    falls back to the next-newest valid step instead of raising into
    the serve tick: the engine keeps serving, on older weights, and
    `fallbacks` counts how often that happened."""

    def __init__(self, manager, template: PyTree, *,
                 poll_every: int = 1, loaded_step: Optional[int] = None):
        """manager: any CheckpointManager (an AsyncCheckpointManager's
        barriers make shared-manager polling race-free). template: a
        params pytree (arrays or ShapeDtypeStructs) to restore into.
        poll_every: only hit the filesystem every N `poll()` calls.
        loaded_step: step already serving (skip re-loading it)."""
        self.manager = manager
        self.template = template
        self.poll_every = max(1, poll_every)
        self.loaded_step = loaded_step
        self.fallbacks = 0
        self._tick = 0

    def poll(self) -> Optional[Tuple[int, PyTree]]:
        """Returns (step, params) when a newer checkpoint landed, else
        None. Never raises on an empty directory."""
        self._tick += 1
        if (self._tick - 1) % self.poll_every:
            return None
        while True:
            latest = self.manager.latest_step()  # async manager: barrier
            if latest is None or latest == self.loaded_step:
                return None
            if self.loaded_step is not None and latest < self.loaded_step:
                return None                      # gc'd / rolled back dir
            try:
                params = self.manager.restore_params(self.template, latest)
            except CheckpointIntegrityError as e:
                # the manager quarantined the step (renamed *.bad), so
                # latest_step() moves past it next iteration — the loop
                # strictly descends and terminates
                self.fallbacks += 1
                print(f"[reload] skipping corrupt step {latest}: {e}")
                continue
            self.loaded_step = latest
            return latest, params
