"""Request-level scheduling for ServeEngine: continuous batching.

The scheduler is pure host-side bookkeeping (no jax) so its admission /
retirement policy is unit-testable without a model: a FIFO queue feeds a
fixed pool of `max_slots` decode slots; a request is admitted the moment
a slot frees up (not when the whole batch drains — that is the
"continuous" in continuous batching) and retired on EOS or on its token
budget. Slot count and cache capacity are fixed at engine build, so the
churn of the active set never changes any device-side shapes — no
recompilation as requests come and go.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

_REQ_IDS = itertools.count()


@dataclasses.dataclass
class GenerationRequest:
    """One decode job: a prompt, its sampling budget, and its policy.

    stream: optional per-token callback `fn(handle, token)` fired as each
    token is committed (including the one produced by the prefill).

    Sampling: `temperature=0` (the default) is greedy argmax — the
    tested-bitwise path. With `temperature>0` the engine samples, after
    optional `top_k` (0 = off) and nucleus `top_p` (1.0 = off)
    truncation. Decode stays reproducible: token t of a request is a
    pure function of (`seed`, t) — `seed` defaults to the request_id —
    independent of batch composition or admission timing."""
    prompt: np.ndarray                      # [T] int token ids
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    stream: Optional[Callable] = None
    temperature: float = 0.0                # 0 => greedy argmax
    top_k: int = 0                          # 0 => no top-k truncation
    top_p: float = 1.0                      # 1.0 => no nucleus truncation
    seed: Optional[int] = None              # None => request_id
    request_id: int = dataclasses.field(
        default_factory=lambda: next(_REQ_IDS))

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")

    @property
    def sampling_seed(self) -> int:
        return self.request_id if self.seed is None else self.seed


class RequestHandle:
    """Live view of a submitted request. The engine appends to `tokens`
    as decode ticks complete; `done` flips on retirement."""

    def __init__(self, request: GenerationRequest):
        self.request = request
        self.tokens: List[int] = []          # generated tokens (no prompt)
        self.status = "queued"               # queued | running | done
        self.slot: Optional[int] = None
        self.version: Optional[int] = None   # params version when admitted
        self.finish_reason: Optional[str] = None   # eos | length
        self.submitted_at = time.perf_counter()
        self.first_token_at: Optional[float] = None
        self.done_at: Optional[float] = None

    @property
    def done(self) -> bool:
        return self.status == "done"

    @property
    def output(self) -> np.ndarray:
        """prompt + generated tokens, the legacy `generate` row layout."""
        return np.concatenate(
            [self.request.prompt, np.asarray(self.tokens, np.int32)])

    def __repr__(self):
        return (f"RequestHandle(id={self.request.request_id}, "
                f"status={self.status}, slot={self.slot}, "
                f"tokens={len(self.tokens)})")


class ContinuousBatchingScheduler:
    """FIFO admission into a fixed slot pool; retire on EOS/budget."""

    def __init__(self, max_slots: int, max_len: int):
        assert max_slots >= 1 and max_len >= 2, (max_slots, max_len)
        self.max_slots = max_slots
        self.max_len = max_len
        self.queue: deque = deque()
        self.active: Dict[int, RequestHandle] = {}
        self._free: List[int] = list(range(max_slots))

    # ---------------------------------------------------------- lifecycle
    def submit(self, handle: RequestHandle):
        req = handle.request
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.request_id}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds the slot "
                f"capacity max_len={self.max_len}")
        self.queue.append(handle)

    def admit(self) -> List[Tuple[int, RequestHandle]]:
        """Move queued requests into free slots (FIFO). Returns the
        (slot, handle) pairs admitted this tick."""
        out = []
        while self._free and self.queue:
            slot = self._free.pop(0)
            handle = self.queue.popleft()
            handle.slot, handle.status = slot, "running"
            self.active[slot] = handle
            out.append((slot, handle))
        return out

    def should_retire(self, handle: RequestHandle, token: int) -> Optional[str]:
        req = handle.request
        if req.eos_id is not None and token == req.eos_id:
            return "eos"
        if len(handle.tokens) >= req.max_new_tokens:
            return "length"
        return None

    def retire(self, slot: int, reason: str):
        handle = self.active.pop(slot)
        handle.status, handle.finish_reason = "done", reason
        handle.done_at = time.perf_counter()
        handle.slot = None
        self._free.append(slot)

    # -------------------------------------------------------------- state
    @property
    def has_work(self) -> bool:
        return bool(self.active or self.queue)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def occupancy(self) -> float:
        return len(self.active) / self.max_slots
