"""Request-level scheduling for ServeEngine: continuous batching.

The scheduler is pure host-side bookkeeping (no jax) so its admission /
retirement policy is unit-testable without a model: a FIFO queue feeds a
fixed pool of `max_slots` decode slots; a request is admitted the moment
a slot frees up (not when the whole batch drains — that is the
"continuous" in continuous batching) and retired on EOS or on its token
budget. Slot count and cache capacity are fixed at engine build, so the
churn of the active set never changes any device-side shapes — no
recompilation as requests come and go.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

_REQ_IDS = itertools.count()


@dataclasses.dataclass
class GenerationRequest:
    """One decode job: a prompt, its sampling budget, and its policy.

    stream: optional per-token callback `fn(handle, token)` fired as each
    token is committed (including the one produced by the prefill).

    Sampling: `temperature=0` (the default) is greedy argmax — the
    tested-bitwise path. With `temperature>0` the engine samples, after
    optional `top_k` (0 = off) and nucleus `top_p` (1.0 = off)
    truncation. Decode stays reproducible: token t of a request is a
    pure function of (`seed`, t) — `seed` defaults to the request_id —
    independent of batch composition or admission timing.

    Resilience: `deadline_s` is a wall-clock budget from submission —
    an expired request fails terminally with finish_reason 'deadline'
    (it is never left hanging in the queue or a slot). `max_retries`
    bounds recompute preemptions: the (max_retries+1)-th preemption
    fails the request with finish_reason 'retries' instead of requeueing
    it. Both default to off (None) — the pre-resilience behavior."""
    prompt: np.ndarray                      # [T] int token ids
    max_new_tokens: int = 16
    eos_id: Optional[int] = None
    stream: Optional[Callable] = None
    temperature: float = 0.0                # 0 => greedy argmax
    top_k: int = 0                          # 0 => no top-k truncation
    top_p: float = 1.0                      # 1.0 => no nucleus truncation
    seed: Optional[int] = None              # None => request_id
    deadline_s: Optional[float] = None      # wall-clock budget (None = none)
    max_retries: Optional[int] = None       # preemption budget (None = inf)
    request_id: int = dataclasses.field(
        default_factory=lambda: next(_REQ_IDS))

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.prompt.size == 0:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        if self.temperature < 0:
            raise ValueError(f"temperature must be >= 0, "
                             f"got {self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")
        if not 0 < self.top_p <= 1.0:
            raise ValueError(f"top_p must be in (0, 1], got {self.top_p}")
        if self.deadline_s is not None and self.deadline_s <= 0:
            raise ValueError(f"deadline_s must be > 0, got "
                             f"{self.deadline_s}")
        if self.max_retries is not None and self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got "
                             f"{self.max_retries}")

    @property
    def sampling_seed(self) -> int:
        return self.request_id if self.seed is None else self.seed


class RequestHandle:
    """Live view of a submitted request. The engine appends to `tokens`
    as decode ticks complete; `done` flips on retirement."""

    def __init__(self, request: GenerationRequest):
        self.request = request
        self.tokens: List[int] = []          # generated tokens (no prompt)
        self.status = "queued"               # queued | running | done | failed
        self.slot: Optional[int] = None
        self.version: Optional[int] = None   # params version when admitted
        self.finish_reason: Optional[str] = None
        # eos | length | deadline | retries | drained
        self.submitted_at = time.perf_counter()
        self.first_token_at: Optional[float] = None
        self.done_at: Optional[float] = None
        self.retries = 0                     # recompute preemptions so far
        # speculative-decoding accounting (engine speculation ticks):
        # draft tokens proposed for / accepted by this request
        self.spec_proposed = 0
        self.spec_accepted = 0

    @property
    def done(self) -> bool:
        """Terminal — completed OR failed (deadline/retries/drained). A
        submitted request always becomes done; it is never left hanging."""
        return self.status in ("done", "failed")

    @property
    def failed(self) -> bool:
        return self.status == "failed"

    @property
    def deadline_at(self) -> Optional[float]:
        d = self.request.deadline_s
        return None if d is None else self.submitted_at + d

    def past_deadline(self, now: float) -> bool:
        da = self.deadline_at
        return da is not None and now > da

    @property
    def output(self) -> np.ndarray:
        """prompt + generated tokens, the legacy `generate` row layout."""
        return np.concatenate(
            [self.request.prompt, np.asarray(self.tokens, np.int32)])

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (submit -> first commit), seconds."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def tpot(self) -> Optional[float]:
        """Mean time per output token AFTER the first (the streaming
        cadence), seconds; None until 2+ tokens exist."""
        if self.done_at is None or len(self.tokens) < 2:
            return None
        return (self.done_at - self.first_token_at) / (len(self.tokens) - 1)

    def __repr__(self):
        return (f"RequestHandle(id={self.request.request_id}, "
                f"status={self.status}, slot={self.slot}, "
                f"tokens={len(self.tokens)})")


class ContinuousBatchingScheduler:
    """FIFO admission into a fixed slot pool; retire on EOS/budget."""

    def __init__(self, max_slots: int, max_len: int):
        assert max_slots >= 1 and max_len >= 2, (max_slots, max_len)
        self.max_slots = max_slots
        self.max_len = max_len
        self.queue: deque = deque()
        self.active: Dict[int, RequestHandle] = {}
        self._free: List[int] = list(range(max_slots))

    # ---------------------------------------------------------- lifecycle
    def submit(self, handle: RequestHandle):
        req = handle.request
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.request_id}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds the slot "
                f"capacity max_len={self.max_len}")
        self.queue.append(handle)

    def admit(self, accept: Optional[Callable] = None
              ) -> List[Tuple[int, RequestHandle]]:
        """Move queued requests into free slots (FIFO). Returns the
        (slot, handle) pairs admitted this tick.

        accept(handle) -> bool: optional admission gate (the paged
        engine declines when the page pool cannot cover the prompt).
        FIFO order is preserved — a declined head blocks the queue until
        pages free up, keeping admission starvation-free."""
        out = []
        while self._free and self.queue:
            if accept is not None and not accept(self.queue[0]):
                break
            slot = self._free.pop(0)
            handle = self.queue.popleft()
            handle.slot, handle.status = slot, "running"
            self.active[slot] = handle
            out.append((slot, handle))
        return out

    def preempt(self, slot: int) -> RequestHandle:
        """Evict a running request back to the FRONT of the queue
        (vLLM-style recompute preemption under page-pool pressure). Its
        generated tokens are kept; re-admission prefills prompt+generated
        and decode continues bitwise-identically.

        With a `max_retries` budget, the (budget+1)-th preemption fails
        the request terminally (finish_reason 'retries') instead of
        requeueing — the caller checks `handle.failed` on the return."""
        handle = self.active.pop(slot)
        handle.status, handle.slot = "queued", None
        self._free.append(slot)
        handle.retries += 1
        budget = handle.request.max_retries
        if budget is not None and handle.retries > budget:
            handle.status = "failed"
            handle.finish_reason = "retries"
            handle.done_at = time.perf_counter()
        else:
            self.queue.appendleft(handle)
        return handle

    def fail(self, handle: RequestHandle, reason: str) -> None:
        """Terminal failure (deadline expiry, drain, retry exhaustion):
        remove the handle from wherever it sits — queue or slot — and
        mark it failed. Idempotent on already-terminal handles. The
        caller releases any KV pages the slot held BEFORE calling."""
        if handle.done:
            return
        if handle.status == "running" and handle.slot is not None:
            self.active.pop(handle.slot, None)
            self._free.append(handle.slot)
            handle.slot = None
        elif handle.status == "queued":
            try:
                self.queue.remove(handle)
            except ValueError:
                pass
        handle.status = "failed"
        handle.finish_reason = reason
        handle.done_at = time.perf_counter()

    def expired(self, now: Optional[float] = None) -> List[RequestHandle]:
        """Queued + running handles past their deadline (host-side
        bookkeeping only; the engine releases pages then calls fail)."""
        now = time.perf_counter() if now is None else now
        return [h for h in list(self.queue) + list(self.active.values())
                if h.past_deadline(now)]

    def should_retire(self, handle: RequestHandle, token: int) -> Optional[str]:
        req = handle.request
        if req.eos_id is not None and token == req.eos_id:
            return "eos"
        if len(handle.tokens) >= req.max_new_tokens:
            return "length"
        return None

    def retire(self, slot: int, reason: str):
        handle = self.active.pop(slot)
        handle.status, handle.finish_reason = "done", reason
        handle.done_at = time.perf_counter()
        handle.slot = None
        self._free.append(slot)

    # -------------------------------------------------------------- state
    @property
    def has_work(self) -> bool:
        return bool(self.active or self.queue)

    @property
    def free_slots(self) -> int:
        return len(self._free)

    def occupancy(self) -> float:
        return len(self.active) / self.max_slots


class PrefixIndex:
    """Host-side registry of shared-prefix pages (vLLM-style prefix
    caching): maps page-aligned token prefixes to the physical pages
    holding their K/V, so a request whose prompt starts with an
    already-prefilled prefix (the common one-system-prompt-many-users
    serve shape) reuses those pages read-only and prefills only its
    unshared tail.

    Keys are the EXACT token bytes of the prefix up to each page
    boundary — no hash collisions, correctness by construction. Only
    FULL pages are ever registered, capped at (len(prompt) - 1) //
    page_size: the last prompt token always lands in the requester's own
    pages, so the extend-prefill has at least one tail token to compute
    logits from, and decode never writes into a registered page
    (registered pages are immutable). Entries are LRU-ordered; the pool
    evicts least-recently-matched entries first when it runs dry."""

    def __init__(self, page_size: int):
        assert page_size >= 1
        self.page_size = page_size
        self._pages: "OrderedDict[bytes, int]" = OrderedDict()  # key -> pid
        self._keys: Dict[int, bytes] = {}                       # pid -> key

    def _key(self, prompt: np.ndarray, n_pages: int) -> bytes:
        return np.ascontiguousarray(
            prompt[:n_pages * self.page_size], np.int32).tobytes()

    def max_shareable(self, prompt: np.ndarray) -> int:
        """Pages a prompt could share: full pages strictly before the
        final token."""
        return max(0, (len(prompt) - 1) // self.page_size)

    def match(self, prompt: np.ndarray) -> List[int]:
        """Physical pages of the longest registered prefix of `prompt`
        (in logical order). Does NOT take references — the caller owns
        refcounting via its PagePool. Marks matched entries
        most-recently-used."""
        pages: List[int] = []
        for i in range(1, self.max_shareable(prompt) + 1):
            pid = self._pages.get(self._key(prompt, i))
            if pid is None:
                break
            pages.append(pid)
        if pages:
            # bump deepest-first so shallow chain links end most recent:
            # LRU eviction then drops leaf pages before their prefix,
            # never orphaning a reachable chain suffix
            for i in range(len(pages), 0, -1):
                self._pages.move_to_end(self._key(prompt, i))
        return pages

    def register(self, prompt: np.ndarray, page_ids: List[int],
                 start: int = 0) -> List[int]:
        """Record pages `start..start+len(page_ids)` of `prompt`'s chain
        (the caller passes the pages it just prefilled). Returns the
        subset actually registered (new entries — the caller holds one
        pool reference per returned page on the index's behalf)."""
        newly = []
        limit = min(start + len(page_ids), self.max_shareable(prompt))
        for i in range(start, limit):
            key = self._key(prompt, i + 1)
            if key in self._pages:
                continue
            pid = page_ids[i - start]
            self._pages[key] = pid
            self._keys[pid] = key
            newly.append(pid)
        # deepest-first recency bump (see match): shallow links stay
        # most recent so LRU eviction trims chains leaf-first
        for i in range(limit, start, -1):
            key = self._key(prompt, i)
            if key in self._pages:
                self._pages.move_to_end(key)
        return newly

    def evict_lru(self, evictable: Optional[Callable] = None
                  ) -> Optional[int]:
        """Drop the least-recently-used entry whose page `evictable(pid)`
        (default: any); returns its page id (the caller releases its
        pool reference). None when nothing qualifies. The filter lets
        the engine skip pages other slots still reference — evicting
        those frees nothing and would only cold the cache."""
        for key, pid in self._pages.items():        # LRU order
            if evictable is None or evictable(pid):
                del self._pages[key]
                del self._keys[pid]
                return pid
        return None

    def forget(self, pid: int) -> None:
        """Remove a page from the index (external eviction)."""
        key = self._keys.pop(pid, None)
        if key is not None:
            del self._pages[key]

    def pages(self) -> List[int]:
        """Every physical page the index currently references (one pool
        reference each, held on the index's behalf)."""
        return list(self._keys)

    def __contains__(self, pid: int) -> bool:
        return pid in self._keys

    def __len__(self) -> int:
        return len(self._pages)


class PressureLadder:
    """Serve-side graceful-degradation state machine (pure host logic,
    unit-testable without a model). Levels, in escalation order:

        0 normal   — everything on
        1 no_spec  — speculation off (draft dispatches stop competing
                     with real decode work)
        2 no_admit — admissions paused while anything is active (new
                     requests wait; in-flight ones get the pool)
        3 preempt  — proactively preempt-by-recompute the youngest slot
                     when the pool is dry, so older slots can grow

    `update` maps (free page fraction, queue depth) to a level with
    hysteresis: a level is entered when free_frac drops below its
    `enter` threshold, and only decays once free_frac clears
    `exit_margin` x that threshold AND the queue is no longer hot — so
    the ladder never flaps across a boundary. Deep queues
    (>= queue_factor x max_slots) alone raise level 1: under a flood,
    draining real requests beats speculating on them."""

    LEVELS = ("normal", "no_spec", "no_admit", "preempt")

    def __init__(self, *, enter=(0.25, 0.10, 0.02), exit_margin: float = 1.5,
                 queue_factor: int = 4):
        assert enter[0] > enter[1] > enter[2] >= 0, enter
        assert exit_margin > 1.0, exit_margin
        self.enter = tuple(enter)
        self.exit_margin = exit_margin
        self.queue_factor = queue_factor
        self.level = 0
        self.changes = 0

    @property
    def name(self) -> str:
        return self.LEVELS[self.level]

    def update(self, *, free_frac: float, queue_len: int,
               max_slots: int) -> int:
        target = 0
        for i, thr in enumerate(self.enter):
            if free_frac < thr:
                target = i + 1
        queue_hot = queue_len >= self.queue_factor * max(1, max_slots)
        if queue_hot:
            target = max(target, 1)
        if target < self.level:
            clear = (free_frac >= min(1.0, self.enter[self.level - 1]
                                      * self.exit_margin)
                     and not queue_hot)
            if not clear:
                target = self.level        # hysteresis: hold the level
        if target != self.level:
            self.level = target
            self.changes += 1
        return self.level
