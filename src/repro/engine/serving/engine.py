"""ServeEngine — request-level serving over the EngineConfig surface.

    engine = ServeEngine.from_config(
        EngineConfig(arch="qwen3-32b", reduced=True, max_slots=8,
                     max_len=128))
    h = engine.submit(GenerationRequest(prompt, max_new_tokens=32))
    engine.drain()                       # or: while engine.step(): ...
    h.tokens                             # generated ids (streamed too)

Compared to the legacy `ServeSession.generate(prompts, gen_len)` batch
loop this is a different shape of API — requests, not batches:

  * **continuous batching** — a fixed pool of `max_slots` decode slots
    over ONE slotted KV cache (per-slot write positions / length masks);
    requests are admitted the moment a slot frees and retired on
    EOS/budget, with no recompilation as the active set churns;
  * **fused prefill** — the whole prompt runs through one
    `model.prefill_cache` forward (flash-attention path on TPU) instead
    of T sequential jitted `decode_step` dispatches; recurrent-state
    families (mamba/RWKV) use a fused `lax.scan` of decode steps —
    still one dispatch, bitwise-faithful to stepped decode;
  * **checkpoint hot-reload** — params are versioned; a `HotReloader`
    watching a (possibly shared, barrier-protected) CheckpointManager
    swaps in new weights for NEW admissions while in-flight slots keep
    decoding on the version they started with.

The engine is deliberately single-threaded and tick-driven (`step()` =
admit + one batched decode + retire): callers own the concurrency story,
and tests get determinism for free.

KV layout (PR 5): the default is **paged** — attention K/V lives in a
global page arena sized in `page_size`-token pages, slots address it
through int32 page tables, and a host-side `PagePool` allocates on
admission/growth, frees on retirement, copies-on-write shared pages and
evicts cold prefix pages under pressure. Short requests hold only the
pages their tokens occupy (not `max_len` capacity), page-aligned shared
prompt prefixes are mapped read-only onto the same physical pages with
prefill computing only the unshared tail, and when the arena is
undersized (`kv_pages`) the engine preempts the youngest request
vLLM-style (recompute on re-admission — bitwise-identical continuation,
though a preempted request restarts on the CURRENT param version).
Greedy tokens are bitwise-identical to `kv_layout='dense'`; page churn
never changes a device shape, so the no-retrace contract holds.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .reload import HotReloader
from .scheduler import (ContinuousBatchingScheduler, GenerationRequest,
                        PrefixIndex, PressureLadder, RequestHandle)
from .slots import (PagePool, cast_paged_like as _cast_paged, copy_pages,
                    dense_fallback_stats, dense_kv_bytes, gather_prefix,
                    insert_rows_at, paged_insert_rows, paged_kv_page_bytes,
                    select_rows, select_rows_paged, set_page_tables)

PyTree = Any

_PREFILL_MODES = ("auto", "parallel", "scan")


def effective_kv_layout(config, model_cfg):
    """The cache layout ServeEngine actually builds for (config, model):
    ('paged' | 'dense', fallback_reason). Recurrent-only families (rwkv)
    have no attention K/V to page, so `kv_layout='paged'` falls back to
    the dense slotted layout — this is THE place that decision lives;
    `__init__` warns on a non-empty reason and the retrace checker
    (`repro.analysis.retrace`) keys its transition enumeration off it."""
    if config.kv_layout != "paged":
        return "dense", ""
    if model_cfg.family == "ssm":
        return "dense", (f"{model_cfg.name} (family=ssm) has no attention "
                         f"K/V to page; serving the dense slotted layout")
    return "paged", ""


def resolve_prefill_mode(config, model) -> str:
    """'parallel' or 'scan' for (config, model), validating the request
    the same way ServeEngine does (shared with the retrace checker)."""
    mode = config.prefill_mode
    if mode not in _PREFILL_MODES:
        raise ValueError(f"prefill_mode={mode!r}; one of {_PREFILL_MODES}")
    if mode == "auto":
        mode = "parallel" if model.prefill_cache is not None else "scan"
    if mode == "parallel" and model.prefill_cache is None:
        raise ValueError(
            f"{model.cfg.name} ({model.cfg.family}) has no parallel "
            f"prefill (recurrent state); use prefill_mode='scan'")
    return mode


def _bucket(n: int, max_len: int) -> int:
    """Prompt padding bucket: next power of two (min 8), clipped to the
    cache capacity — bounds prefill recompilation at log2(max_len)."""
    b = 8
    while b < n:
        b *= 2
    return min(b, max_len)


def resolve_serve_parts(config, *, model=None, mesh=None, params=None,
                        checkpoint=None, attn_chunk: int = 64):
    """Shared ServeEngine/ServeSession bootstrap: local mesh, arch ->
    model (preset head padding), checkpoint manager from ckpt_dir, and
    params — freshly initialized, or the params-only restore of the
    latest checkpoint when one exists. Returns
    (model, mesh, params, checkpoint, loaded_step)."""
    from repro.checkpoint import CheckpointManager
    from repro.configs.base import get_config, get_reduced, pad_heads_for_tp
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model

    config.validate()
    if mesh is None:
        mesh = make_local_mesh(config.data_mesh or 1, config.model_mesh)
    if model is None:
        if not config.arch:
            raise ValueError("EngineConfig.arch is empty — pass a built "
                             "Model via from_config(model=...)")
        mcfg = (get_reduced(config.arch) if config.reduced
                else get_config(config.arch))
        if config.pad_heads:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            mcfg = pad_heads_for_tp(mcfg, sizes.get("model", 1))
        model = build_model(mcfg, attn_chunk=attn_chunk,
                            param_dtype=jnp.dtype(config.param_dtype))
    if checkpoint is None and config.ckpt_dir:
        checkpoint = CheckpointManager(config.ckpt_dir)
    loaded_step = None
    if params is None:
        if checkpoint is not None and checkpoint.latest_step() is not None:
            template = jax.eval_shape(model.init, jax.random.key(0))
            loaded_step = checkpoint.latest_step()
            params = checkpoint.restore_params(template, loaded_step)
        else:
            params = model.init(jax.random.key(0))
    return model, mesh, params, checkpoint, loaded_step


def derive_draft_config(target_cfg, spec: Optional[Dict[str, Any]] = None):
    """The draft ModelConfig a speculation-enabled engine builds.

    `spec` (EngineConfig.draft_config) forms:
      * None — auto-derived shrink of the target: quarter depth, MoE
        routing dropped (a draft exists to be cheap), same widths/vocab;
      * {'arch': preset-name[, 'reduced': bool, field overrides]} — a
        registry preset (the --draft-preset CLI path);
      * {field overrides} — dataclasses.replace over the target config.

    Invariants enforced for every form: the draft shares the target's
    vocab (proposals must live in the target's token space), is an
    attention-family decoder (its per-slot cache rolls back by a pos
    rewrite), and runs full attention (sliding_window forced to 0 so the
    dense draft cache masks by pos alone — no rolling wrap to heal)."""
    import dataclasses as _dc
    from repro.configs.base import get_config, get_reduced
    if spec and "arch" in spec:
        extra = {k: v for k, v in spec.items() if k not in ("arch",
                                                            "reduced")}
        dcfg = (get_reduced(spec["arch"]) if spec.get("reduced")
                else get_config(spec["arch"]))
        if extra:
            dcfg = _dc.replace(dcfg, **extra)
    elif spec:
        dcfg = _dc.replace(target_cfg, **spec)
    else:
        dcfg = _dc.replace(target_cfg,
                           name=f"{target_cfg.name}-draft",
                           n_layers=max(1, target_cfg.n_layers // 4),
                           n_experts=0, n_experts_per_tok=0,
                           n_shared_experts=0, first_dense_layers=0)
    if dcfg.sliding_window:
        dcfg = _dc.replace(dcfg, sliding_window=0)
    if dcfg.family in ("ssm", "hybrid") or dcfg.is_encoder_decoder:
        raise ValueError(
            f"draft model {dcfg.name} (family={dcfg.family}) cannot "
            f"draft for speculation: recurrent/enc-dec state has no "
            f"pos-rewrite rollback — pick an attention-family draft")
    if dcfg.vocab_size != target_cfg.vocab_size:
        raise ValueError(
            f"draft vocab_size={dcfg.vocab_size} != target vocab_size="
            f"{target_cfg.vocab_size} ({target_cfg.name}): draft "
            f"proposals must be target token ids")
    return dcfg


def _make_parallel_prefill(model, cap: int):
    """Returns the last-position logits [B, V] (not an argmax'd token):
    the engine applies the per-request sampling policy — greedy argmax
    by default, bitwise the old fused path."""
    def prefill(params, tokens, lengths):
        logits, cache = model.prefill_cache(params, tokens, lengths, cap)
        return logits[:, -1, :], cache
    return prefill


def _steady_cache_dtypes(model, params, batch: int, cap: int):
    """Fixed-point of decode_step's output dtypes: recurrent families
    (mamba conv history, RWKV token shifts) re-emit state in the compute
    dtype, so a freshly-initialized cache can change leaf dtypes after
    the first step. Serving needs the steady layout up front — the decode
    tick must never retrace and the prefill scan carry must be stable —
    and starting there is exact: the initial zeros are representable in
    either dtype. Runs entirely under eval_shape: nothing is allocated
    on device (a paged engine must not spike to the dense footprint it
    exists to avoid)."""
    cache = jax.eval_shape(
        lambda p: model.init_cache(p, batch, cap, per_slot=True), params)
    tok = jax.ShapeDtypeStruct((batch, 1), jnp.int32)
    for _ in range(3):
        new = jax.eval_shape(model.decode_step, params, tok, cache)[1]
        drift = jax.tree.leaves(jax.tree.map(
            lambda c, n: c.dtype != n.dtype, cache, new))
        if not any(drift):
            break
        cache = jax.tree.map(
            lambda c, n: jax.ShapeDtypeStruct(c.shape, n.dtype), cache, new)
    else:
        raise ValueError(f"{model.cfg.name}: decode cache dtypes do not "
                         f"reach a fixed point")
    return jax.tree.map(lambda c: c.dtype, cache)


def _make_scan_prefill(model, cap: int, dtypes):
    """Fused stepped prefill: a lax.scan of decode steps — ONE dispatch
    per prompt (vs T), bitwise-identical math to sequential decode. The
    fused path for recurrent-state families whose chunked training
    forward cannot surrender its state mid-sequence."""
    def prefill(params, tokens, lengths):
        B, P = tokens.shape
        cache0 = jax.tree.map(
            lambda c, dt: c.astype(dt),
            model.init_cache(params, B, cap, per_slot=True), dtypes)
        V = model.cfg.vocab_size
        last0 = jnp.zeros((B, V), jnp.float32)

        def body(carry, t):
            cache, last = carry
            tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
            logits, new_cache = model.decode_step(params, tok, cache)
            cache = select_rows(t < lengths, new_cache, cache)
            last = jnp.where((t == lengths - 1)[:, None],
                             logits[:, -1, :].astype(jnp.float32), last)
            return (cache, last), None

        (cache, last), _ = jax.lax.scan(body, (cache0, last0),
                                        jnp.arange(P))
        return last, cache
    return prefill


def abstract_serve_state(config, model) -> Dict[str, Any]:
    """Shape-level model of the engine's device state — every field is a
    ShapeDtypeStruct tree obtained under `jax.eval_shape` (nothing ever
    touches a device, not even PRNG key creation).

    Mirrors `ServeEngine.__init__`'s cache construction exactly: steady
    dtypes, paged-vs-dense layout (via `effective_kv_layout`), paged
    arena sizing, and the prefill row signatures the admission path
    scatters in. The retrace checker (`repro.analysis.retrace`) proves
    every slot-churn / page-table / hot-reload transition maps the cache
    signature onto itself, which is what makes the decode tick's
    no-retrace contract a static guarantee."""
    config.validate()
    cfg = model.cfg
    cap = config.serve_max_len()
    B = config.max_slots
    kshape = jax.eval_shape(lambda: jax.random.key(0))
    params = jax.eval_shape(model.init, kshape)
    dtypes = _steady_cache_dtypes(model, params, B, cap)
    layout, fallback_reason = effective_kv_layout(config, cfg)
    pages = None
    if layout == "paged":
        from repro.models.attention import paged_capacity
        ps = config.page_size
        pcap = paged_capacity(cfg, cap)
        if pcap % ps:
            raise ValueError(f"{cfg.name}: paged capacity {pcap} not a "
                             f"multiple of page_size={ps}")
        pages_per_slot = pcap // ps
        num_pages = config.kv_pages or (B * pages_per_slot + 1)
        pages = {"page_size": ps, "pages_per_slot": pages_per_slot,
                 "num_pages": num_pages}
        cache = jax.eval_shape(
            lambda p: _cast_paged(
                model.init_cache(p, B, cap, per_slot=True,
                                 paged=(ps, num_pages)), dtypes), params)
    else:
        cache = jax.eval_shape(
            lambda p: jax.tree.map(lambda c, dt: c.astype(dt),
                                   model.init_cache(p, B, cap,
                                                    per_slot=True), dtypes),
            params)
    mode = resolve_prefill_mode(config, model)
    prefill = (_make_parallel_prefill(model, cap) if mode == "parallel"
               else _make_scan_prefill(model, cap, dtypes))
    P = min(8, cap)
    rows = {}
    for n in sorted({1, B}):
        rows[n] = jax.eval_shape(
            prefill, params, jax.ShapeDtypeStruct((n, P), jnp.int32),
            jax.ShapeDtypeStruct((n,), jnp.int32))[1]
    fallback = (dense_fallback_stats(cache)
                if config.kv_layout == "paged" else (0, 0))
    speculation = None
    if config.speculation_k and model.verify_step is not None:
        from repro.models import build_model as _build_model
        dcfg = derive_draft_config(cfg, config.draft_config)
        dmodel = _build_model(dcfg,
                              param_dtype=jnp.dtype(config.param_dtype))
        dparams = jax.eval_shape(dmodel.init, kshape)
        ddtypes = _steady_cache_dtypes(dmodel, dparams, B, cap)
        dcache = jax.eval_shape(
            lambda p: jax.tree.map(lambda c, dt: c.astype(dt),
                                   dmodel.init_cache(p, B, cap,
                                                     per_slot=True),
                                   ddtypes), dparams)
        drows = {}
        dprefill = _make_parallel_prefill(dmodel, cap)
        for n in sorted({1, B}):
            drows[n] = jax.eval_shape(
                dprefill, dparams,
                jax.ShapeDtypeStruct((n, P), jnp.int32),
                jax.ShapeDtypeStruct((n,), jnp.int32))[1]
        speculation = {"k": config.speculation_k, "draft_model": dmodel,
                       "draft_params": dparams, "draft_cache": dcache,
                       "draft_rows": drows}
    return {"params": params, "cache": cache, "rows": rows,
            "layout": layout, "fallback_reason": fallback_reason,
            "dense_fallback": fallback, "prefill_mode": mode,
            "pages": pages, "max_slots": B, "capacity": cap,
            "speculation": speculation}


class ServeEngine:
    """Continuous-batching serving engine for one (model, mesh, config)."""

    def __init__(self, config, model, mesh, params: PyTree, *,
                 checkpoint=None, loaded_step: Optional[int] = None,
                 draft_params: Optional[PyTree] = None):
        cfg = model.cfg
        if cfg.is_encoder_decoder or cfg.frontend != "none":
            raise ValueError(
                f"ServeEngine serves decoder-only text models; "
                f"{cfg.name} (frontend={cfg.frontend}, "
                f"enc-dec={cfg.is_encoder_decoder}) still goes through "
                f"ServeSession.generate(stepped_prefill=True)")
        self.config = config
        self.model = model
        self.mesh = mesh
        self.max_slots = config.max_slots
        # max_len=0 => seq_len, rounded up to a page multiple when paged
        # (the old bare `max_len or seq_len` default now composes with
        # page_size instead of tripping the tiling assert)
        self.max_len = config.serve_max_len()
        self.scheduler = ContinuousBatchingScheduler(self.max_slots,
                                                     self.max_len)
        mode = self.prefill_mode = resolve_prefill_mode(config, model)

        # versioned params: in-flight slots pin the version they were
        # admitted with; hot-reload bumps _version for new admissions
        self._params: Dict[int, PyTree] = {0: params}
        self._version = 0
        self._loaded_step = loaded_step
        self.checkpoint = checkpoint
        self._reloader: Optional[HotReloader] = None
        if checkpoint is not None and config.hot_reload:
            template = jax.eval_shape(model.init, jax.random.key(0))
            self._reloader = HotReloader(checkpoint, template,
                                         loaded_step=loaded_step)

        # steady-state leaf dtypes: the decode tick never retraces and
        # the prefill paths land rows in exactly this layout (the DENSE
        # per-slot layout — also what every prefill path emits; the
        # paged arena borrows its dtypes leaf-for-leaf)
        self._cache_dtypes = _steady_cache_dtypes(model, params,
                                                  self.max_slots,
                                                  self.max_len)
        # paged KV arena (the default): recurrent-only families (rwkv)
        # have no KV to page and keep the dense slotted layout — loudly
        layout, fallback_reason = effective_kv_layout(config, cfg)
        self.paged = layout == "paged"
        if fallback_reason:
            import warnings
            from ..build import EngineWarning
            warnings.warn(fallback_reason, EngineWarning, stacklevel=3)
        if self.paged:
            from repro.models.attention import paged_capacity
            ps = config.page_size
            cap = paged_capacity(cfg, self.max_len)
            if cap % ps:
                raise ValueError(
                    f"{cfg.name}: paged cache capacity {cap} (sliding "
                    f"window {cfg.sliding_window}) is not a multiple of "
                    f"page_size={ps}; pick a page size dividing the "
                    f"window so paged rows tile pages exactly "
                    f"(kv_layout='dense' always works)")
            self._page_size = ps
            self._pages_per_slot = cap // ps
            # full provisioning: every slot at capacity + the trash page.
            # kv_pages can size the arena down (backpressure + preemption
            # kick in) or up (a larger warm prefix cache).
            full = self.max_slots * self._pages_per_slot + 1
            self._num_pages = config.kv_pages or full
            if self._num_pages < self._pages_per_slot + 1:
                raise ValueError(
                    f"kv_pages={self._num_pages} cannot hold even one "
                    f"full slot: capacity {cap} needs "
                    f"{self._pages_per_slot} pages of {ps} tokens plus "
                    f"the reserved trash page "
                    f"(>= {self._pages_per_slot + 1})")
            self._pool = PagePool(self._num_pages, ps)
            share = (config.prefix_sharing and self.prefill_mode == "parallel"
                     and not cfg.sliding_window)
            self._prefix = PrefixIndex(ps) if share else None
            self._tables = np.zeros((self.max_slots, self._pages_per_slot),
                                    np.int32)
            self._owned = np.zeros_like(self._tables, bool)
            self._shared = np.zeros_like(self._tables, bool)
            self._tables_dirty = False
            self._host_pos = np.zeros((self.max_slots,), np.int64)
            self._admit_seq = np.zeros((self.max_slots,), np.int64)
            self._seq = 0
            self.cache = _cast_paged(
                model.init_cache(params, self.max_slots, self.max_len,
                                 per_slot=True,
                                 paged=(ps, self._num_pages)),
                self._cache_dtypes)
            self._page_bytes = paged_kv_page_bytes(self.cache)
            self._kv_capacity_bytes = (self._num_pages - 1) * self._page_bytes
            self._paged_insert = jax.jit(paged_insert_rows)
            self._set_tables = jax.jit(set_page_tables)
            self._copy_pages = jax.jit(copy_pages)
            self._select_paged = jax.jit(select_rows_paged)
            self._gather_prefix = jax.jit(gather_prefix)
        else:
            self.cache = jax.tree.map(
                lambda c, dt: c.astype(dt),
                model.init_cache(params, self.max_slots, self.max_len,
                                 per_slot=True), self._cache_dtypes)
            self._page_bytes = 0
            self._kv_capacity_bytes = dense_kv_bytes(self.cache)
            self._pool = None
            self._prefix = None
        # paged-accounting honesty: per-slot state that stays dense even
        # though paging was requested (mamba recurrent state in hybrids;
        # the whole cache under the ssm fallback). Surfaced in kv_stats.
        self._dense_fallback_leaves = 0
        self._dense_fallback_bytes = 0
        if config.kv_layout == "paged":
            self._dense_fallback_leaves, self._dense_fallback_bytes = \
                dense_fallback_stats(self.cache)
            if self.paged and self._dense_fallback_leaves:
                import warnings
                from ..build import EngineWarning
                warnings.warn(
                    f"{cfg.name}: {self._dense_fallback_leaves} cache "
                    f"leaves ({self._dense_fallback_bytes} bytes) stay "
                    f"dense per-slot under kv_layout='paged' (recurrent "
                    f"state has no K/V rows to page); paged byte "
                    f"accounting excludes them — see "
                    f"kv_stats()['dense_fallback_leaves']",
                    EngineWarning, stacklevel=3)
        self._tokens = np.zeros((self.max_slots, 1), np.int32)
        # per-slot sampling policy rows (fixed [max_slots] shapes: policy
        # churn never retraces). Greedy slots (temperature 0) take the
        # bitwise argmax path; the all-greedy tick skips sampling math
        # entirely via the plain decode step.
        self._temp = np.zeros((self.max_slots,), np.float32)
        self._topk = np.zeros((self.max_slots,), np.int32)
        self._topp = np.ones((self.max_slots,), np.float32)
        self._keys = np.zeros((self.max_slots, 2), np.uint32)
        self._pos = np.zeros((self.max_slots,), np.int32)
        # NOTE: no buffer donation — hot-reload may decode the same cache
        # under two param versions in one tick
        from ..build import (make_batched_decode_step,
                             make_sampling_decode_step, sample_logits)
        self._decode = jax.jit(make_batched_decode_step(model))
        self._decode_sampled = jax.jit(make_sampling_decode_step(model))
        self._sample = jax.jit(sample_logits)
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
        self._insert = jax.jit(insert_rows_at)
        self._select = jax.jit(select_rows)
        self._prefill = jax.jit(
            _make_parallel_prefill(model, self.max_len) if mode == "parallel"
            else _make_scan_prefill(model, self.max_len,
                                    self._cache_dtypes))
        if self.paged and self._prefix is not None:
            # shared-prefix extend: one forward over the UNSHARED TAIL
            # only, attending to the gathered prefix pages. Compiles per
            # (tail bucket, prefix page count) pair — prefixes are few
            # (system prompts); the decode tick itself never retraces.
            def _ext(params, toks, lengths, pfx, prefix_len):
                logits, rows = model.prefill_cache(
                    params, toks, lengths, self.max_len,
                    prefix_kv=pfx, prefix_len=prefix_len)
                return logits[:, -1, :], rows
            self._prefill_ext = jax.jit(_ext,
                                        static_argnames=("prefix_len",))
        # ---- speculative decoding: draft propose -> one-forward verify
        self.spec_k = int(config.speculation_k or 0)
        self._draft_model = None
        if self.spec_k and model.verify_step is None:
            import warnings
            from ..build import EngineWarning
            warnings.warn(
                f"{cfg.name} (family={cfg.family}): recurrent state has "
                f"no pos-rewrite rollback — speculation disabled, every "
                f"tick runs plain decode", EngineWarning, stacklevel=3)
            self.spec_k = 0
        if self.spec_k:
            from repro.models import build_model as _build_model
            from repro.models.attention import paged_capacity
            from ..build import make_draft_propose, make_verify_step
            dcfg = derive_draft_config(cfg, config.draft_config)
            self._draft_model = _build_model(
                dcfg, attn_chunk=64,
                param_dtype=jnp.dtype(config.param_dtype))
            self._draft_params = (draft_params if draft_params is not None
                                  else self._draft_model.init(
                                      jax.random.key(1)))
            ddtypes = _steady_cache_dtypes(self._draft_model,
                                           self._draft_params,
                                           self.max_slots, self.max_len)
            # the draft cache is DENSE per-slot by design: drafts are
            # small, their rows are transient (rolled back by the next
            # propose's pos rewrite), and paging them would double the
            # host bookkeeping for no memory story
            self._draft_cache = jax.tree.map(
                lambda c, dt: c.astype(dt),
                self._draft_model.init_cache(self._draft_params,
                                             self.max_slots, self.max_len,
                                             per_slot=True), ddtypes)
            self._draft_prefill = jax.jit(
                _make_parallel_prefill(self._draft_model, self.max_len))
            self._propose = jax.jit(
                make_draft_propose(self._draft_model, self.spec_k))
            self._verify = jax.jit(make_verify_step(model))
            # spec-tick feasibility ceiling: pos + k must stay BELOW the
            # rolling capacity for every active slot, so verify writes
            # land at rows pos+t exactly (no wrap/clamp) and rollback is
            # a pure pos rewrite. SWA targets stop speculating once the
            # window fills; everyone stops within k of max_len.
            self._spec_cap = paged_capacity(cfg, self.max_len)
        self._ttft: List[float] = []
        self._tpot: List[float] = []
        # graceful degradation (opt-in): the pressure ladder watches
        # page-pool and queue pressure each tick and sheds load in
        # stages instead of thrashing on preemptions
        self._ladder = PressureLadder() if config.pressure_ladder else None
        self._draining = False
        self.stats = {"submitted": 0, "completed": 0, "generated_tokens": 0,
                      "prefill_calls": 0, "decode_steps": 0, "reloads": 0,
                      "kv_bytes_in_use": 0, "peak_kv_bytes_in_use": 0,
                      "kv_pages_used": 0, "kv_pages_free": (
                          self._pool.pages_free if self._pool else 0),
                      "prefix_hits": 0, "prefix_tokens_reused": 0,
                      "cow_copies": 0, "preemptions": 0,
                      "spec_ticks": 0, "spec_tokens_proposed": 0,
                      "spec_tokens_accepted": 0, "draft_prefills": 0,
                      "failed": 0, "deadline_kills": 0, "retries": 0,
                      "drained": 0, "restore_fallbacks": 0,
                      "degradation_level": 0, "degradation_changes": 0,
                      "ladder_preempts": 0,
                      "started_at": None}
        if not self.paged:
            # dense slots pay full capacity up front — that constant IS
            # the footprint (what paging exists to beat)
            self.stats["kv_bytes_in_use"] = self._kv_capacity_bytes
            self.stats["peak_kv_bytes_in_use"] = self._kv_capacity_bytes

    # ------------------------------------------------------- construction
    @classmethod
    def from_config(cls, config, *, model=None, mesh=None, params=None,
                    checkpoint=None, attn_chunk: int = 64,
                    draft_params=None) -> "ServeEngine":
        """Build model/mesh/params from the same EngineConfig surface as
        TrainSession; with `ckpt_dir` set, serves the *trained* weights
        via the params-only restore (and hot-reloads later saves when
        `hot_reload=True`). `draft_params`: trained weights for the
        speculation draft model (default: fresh init — correct but low
        acceptance; speculation pays off with a draft that agrees with
        the target)."""
        model, mesh, params, checkpoint, loaded_step = resolve_serve_parts(
            config, model=model, mesh=mesh, params=params,
            checkpoint=checkpoint, attn_chunk=attn_chunk)
        return cls(config, model, mesh, params, checkpoint=checkpoint,
                   loaded_step=loaded_step, draft_params=draft_params)

    # ------------------------------------------------------------- submit
    def submit(self, request: GenerationRequest) -> RequestHandle:
        """Enqueue a request; it is admitted to a slot by a later
        `step()`. Raises immediately if it can never fit a slot."""
        handle = RequestHandle(request)
        self.scheduler.submit(handle)
        self.stats["submitted"] += 1
        if self.stats["started_at"] is None:
            self.stats["started_at"] = time.perf_counter()
        return handle

    # ------------------------------------------------------------- params
    def swap_params(self, params: PyTree, step: Optional[int] = None):
        """Hot-swap: new admissions decode with `params`; slots already
        in flight finish on their admitted version."""
        self._version += 1
        self._params[self._version] = params
        self._loaded_step = step
        self.stats["reloads"] += 1
        # registered prefix pages hold K/V computed under the OLD
        # weights — flush them so new admissions re-prefill under the
        # new version (pages still referenced by in-flight old-version
        # slots survive until those slots retire)
        self.flush_prefix()

    def flush_prefix(self) -> int:
        """Release every prefix-index page reference; returns the number
        of pages flushed. Hot-reload calls this; the chaos soak uses it
        before asserting the zero-leaked-pages invariant."""
        n = 0
        if self._prefix is not None:
            while True:
                pid = self._prefix.evict_lru()
                if pid is None:
                    break
                self._pool.release([pid])
                n += 1
        return n

    def _gc_versions(self):
        live = {h.version for h in self.scheduler.active.values()}
        live.add(self._version)
        for v in [v for v in self._params if v not in live]:
            del self._params[v]

    @property
    def params(self) -> PyTree:
        """The params new admissions will see."""
        return self._params[self._version]

    @property
    def loaded_step(self) -> Optional[int]:
        return self._loaded_step

    # --------------------------------------------------------------- tick
    def step(self) -> bool:
        """One scheduler tick: deadline enforcement -> hot-reload poll
        -> pressure-ladder update -> admit (fused prefill; paged
        admission reserves pages, shared prefixes prefill only the
        unshared tail) -> one batched decode over the active slots (paged
        growth/COW first) -> retire finished. Returns True while queued
        or in-flight work remains."""
        self._enforce_deadlines()
        if self._reloader is not None:
            got = self._reloader.poll()
            if got is not None:
                self.swap_params(got[1], step=got[0])
            self.stats["restore_fallbacks"] = self._reloader.fallbacks
        level = 0
        if self._ladder is not None:
            free_frac = 1.0
            if self.paged:
                free_frac = (self._pool.pages_free
                             / max(1, self._num_pages - 1))
            level = self._ladder.update(
                free_frac=free_frac, queue_len=len(self.scheduler.queue),
                max_slots=self.max_slots)
            self.stats["degradation_level"] = level
            self.stats["degradation_changes"] = self._ladder.changes
        # admissions stop while draining, and at ladder level >= 2 while
        # anything is in flight (an empty active set must still admit —
        # pausing then would deadlock the queue against a full pool)
        blocked = self._draining or (level >= 2 and self.scheduler.active)
        if not blocked:
            admitted = self.scheduler.admit(
                self._reserve_pages if self.paged else None)
            if admitted:
                self._admit_batch(admitted)
        if (level >= 3 and self.paged and self._pool.pages_free == 0
                and len(self.scheduler.active) > 1):
            # preempt-by-recompute rung: free the youngest slot's pages
            # proactively so the older slots can keep growing
            if self._preempt_youngest(None):
                self.stats["ladder_preempts"] += 1
        if self.scheduler.active:
            self._decode_tick()
        if self._draining and not self.scheduler.active:
            # active set drained: queued requests end terminally (never
            # hung) with finish_reason 'drained'
            for h in list(self.scheduler.queue):
                self.scheduler.fail(h, "drained")
                self.stats["failed"] += 1
                self.stats["drained"] += 1
        self._gc_versions()
        if self.paged:
            used = self._pool.pages_used
            b = used * self._page_bytes
            self.stats["kv_bytes_in_use"] = b
            self.stats["peak_kv_bytes_in_use"] = max(
                self.stats["peak_kv_bytes_in_use"], b)
            self.stats["kv_pages_used"] = used
            self.stats["kv_pages_free"] = self._pool.pages_free
        return self.scheduler.has_work

    def drain(self) -> None:
        """Run ticks until every submitted request is terminal."""
        while self.step():
            pass

    # --------------------------------------------------------- resilience
    def _enforce_deadlines(self):
        """Fail every queued/running request past its deadline_s budget
        (terminal finish_reason 'deadline'; a running slot's pages are
        released first). Requests without a deadline are untouched."""
        now = time.perf_counter()
        for h in self.scheduler.expired(now):
            if h.slot is not None and self.paged:
                self._release_slot_pages(h.slot)
            self.scheduler.fail(h, "deadline")
            self.stats["deadline_kills"] += 1
            self.stats["failed"] += 1

    def request_drain(self):
        """Graceful-drain mode (SIGTERM): no new admissions; in-flight
        slots decode to completion; once the active set empties, queued
        requests fail terminally with finish_reason 'drained'. `drain()`
        then falls through — no request is ever left hanging."""
        self._draining = True

    @property
    def draining(self) -> bool:
        return self._draining

    def install_drain_handler(self):
        """SIGTERM => request_drain(): the serve-side analogue of the
        checkpoint preemption handler (train already exits through one).
        The process keeps running until the caller's drain loop ends."""
        import signal

        def handler(signum, frame):
            print("[serve] SIGTERM: draining (no new admissions; "
                  "in-flight requests finish)")
            self.request_drain()
        signal.signal(signal.SIGTERM, handler)

    def leaked_pages(self) -> int:
        """Pages the pool holds that no active slot and no prefix-index
        entry accounts for. After a drain (empty active set) and a
        `flush_prefix()`, this must be exactly `pages_used` == 0 — the
        zero-leak invariant the chaos soak asserts."""
        if not self.paged:
            return 0
        pids = set()
        for slot in self.scheduler.active:
            mask = self._owned[slot] | self._shared[slot]
            pids.update(int(p) for p in self._tables[slot][mask])
        if self._prefix is not None:
            pids.update(self._prefix.pages())
        return self._pool.pages_used - len(pids)

    # ------------------------------------------------------ paged plumbing
    def _full_prompt(self, handle) -> np.ndarray:
        """Prompt plus any already-generated tokens: preempted requests
        re-prefill their whole trajectory (recompute preemption), which
        continues decode bitwise-identically."""
        if not handle.tokens:
            return handle.request.prompt
        return np.concatenate([handle.request.prompt,
                               np.asarray(handle.tokens, np.int32)])

    def _prompt_pages(self, n_tokens: int) -> int:
        """Pages the prefill of an n-token prompt touches (rolling SWA
        prompts longer than the window only ever occupy the window)."""
        return min(-(-n_tokens // self._page_size), self._pages_per_slot)

    def _evict_until(self, n_free: int) -> bool:
        """Drop cold prefix-index entries (LRU, leaf pages first) until
        `n_free` pages are available. Only pages nothing else references
        are candidates — evicting an entry whose page an active (or
        reserving) slot still holds frees nothing and would just cold
        the cache."""
        while self._pool.pages_free < n_free:
            if self._prefix is None:
                return False
            pid = self._prefix.evict_lru(
                lambda p: self._pool.refcount(p) == 1)
            if pid is None:
                return False
            self._pool.release([pid])
        return True

    def _reserve_pages(self, handle) -> bool:
        """Admission gate + reservation: match the prompt against the
        prefix index (read-only reuse), then allocate pages for the
        unshared tail — evicting cold prefix pages if needed. Declines
        (request stays queued, FIFO) when the pool cannot cover it."""
        prompt = self._full_prompt(handle)
        shared: List[int] = []
        if self._prefix is not None:
            shared = self._prefix.match(prompt)[:self._pages_per_slot]
        # pin the matched pages FIRST: with this reference held, evicting
        # their index entries can never free them, so the allocation below
        # cannot hand a matched page back as this slot's own page
        # (aliasing a shared table entry with an owned one)
        self._pool.ref(shared)
        n_own = self._prompt_pages(len(prompt)) - len(shared)
        own = self._pool.alloc(n_own) if self._evict_until(n_own) else None
        if own is None:
            self._pool.release(shared)
            return False
        if self._prefix is not None:
            # register this prompt's own full pages NOW — at reservation,
            # not after prefill — so a SAME-TICK co-arrival with the same
            # page-aligned prefix matches them above and joins the
            # extend-prefill path (first-contact grouping: the leader
            # prefills the full prompt once, followers prefill only their
            # tails against the leader's pages). The index holds one pool
            # ref per newly registered page; admission-group ordering
            # guarantees the leader's prefill lands before any follower
            # gathers the prefix.
            newly = self._prefix.register(prompt, own, start=len(shared))
            self._pool.ref(newly)
        handle._admit_plan = (prompt, shared, own)
        return True

    def _release_slot_pages(self, slot: int):
        """Drop this slot's page references (owned AND shared); pages
        the prefix index still holds survive for future reuse."""
        mask = self._owned[slot] | self._shared[slot]
        if mask.any():
            self._pool.release(self._tables[slot][mask].tolist())
        self._tables[slot] = 0
        self._owned[slot] = False
        self._shared[slot] = False
        self._tables_dirty = True

    def _preempt_youngest(self, keep_slot: Optional[int]) -> bool:
        """Pool pressure: push the most recently admitted request (other
        than `keep_slot`; None keeps nothing) back to the queue front,
        freeing its pages. It re-prefills prompt+generated on
        re-admission — same tokens, but on the CURRENT param version. A
        request over its `max_retries` budget fails terminally instead
        of requeueing (finish_reason 'retries')."""
        others = [s for s in self.scheduler.active if s != keep_slot]
        if not others:
            return False
        victim = max(others, key=lambda s: self._admit_seq[s])
        handle = self.scheduler.active[victim]
        self._release_slot_pages(victim)
        self.scheduler.preempt(victim)
        self.stats["preemptions"] += 1
        if handle.failed:
            self.stats["failed"] += 1
        else:
            self.stats["retries"] += 1
        return True

    def _claim_page(self, slot: int, lp: int):
        """Make logical page `lp` of `slot` writable: allocate a fresh
        page (growth) or copy-on-write a shared one, evicting/preempting
        under pressure."""
        while not (self._evict_until(1) and self._pool.pages_free >= 1):
            if not self._preempt_youngest(slot):
                raise RuntimeError(
                    f"page pool exhausted growing slot {slot} "
                    f"(kv_pages={self._num_pages}): no evictable prefix "
                    f"pages and no other request to preempt")
        if self._shared[slot, lp]:
            old = int(self._tables[slot, lp])
            new = self._pool.cow(old)     # cannot fail: a page is free
            self.cache = self._copy_pages(self.cache,
                                          jnp.asarray([old]),
                                          jnp.asarray([new]))
            self._shared[slot, lp] = False
            self.stats["cow_copies"] += 1
        else:
            (new,) = self._pool.alloc(1)
        self._tables[slot, lp] = new
        self._owned[slot, lp] = True
        self._tables_dirty = True

    def _grow_active(self):
        """Before a decode tick: every active slot must own the page its
        next token writes into. Fresh pages for linear growth; COW when
        a (forced-)shared page would be written; preemption as the last
        resort. May shrink the active set."""
        cap = self._pages_per_slot * self._page_size
        for slot in sorted(self.scheduler.active):
            if slot not in self.scheduler.active:   # preempted meanwhile
                continue
            p = int(self._host_pos[slot])
            rolling = self.model.cfg.sliding_window > 0
            row = p % cap if rolling else min(p, cap - 1)
            lp = row // self._page_size
            if not self._owned[slot, lp]:
                self._claim_page(slot, lp)

    def _sync_tables(self):
        if self._tables_dirty:
            self.cache = self._set_tables(self.cache,
                                          jnp.asarray(self._tables))
            self._tables_dirty = False

    # ----------------------------------------------------------- internals
    def _admit_batch(self, admitted):
        """Fused prefill for this tick's admissions, grouped by prompt
        bucket (and, when paged, by shared-prefix chain): one prefill
        dispatch + one cache scatter per group (not per request) — the
        batched-arrival fast path. Shared-prefix groups gather the
        prefix K/V from its pages once and prefill ONLY the unshared
        tail."""
        groups: Dict[Any, list] = {}
        plans: Dict[int, Any] = {}
        for slot, handle in admitted:
            handle.version = self._version
            req = handle.request
            self._temp[slot] = req.temperature
            self._topk[slot] = req.top_k
            self._topp[slot] = req.top_p
            self._keys[slot] = np.asarray(
                jax.random.PRNGKey(req.sampling_seed), np.uint32)
            # sampling position continues across preemption: token t is
            # a pure function of (seed, t)
            self._pos[slot] = len(handle.tokens)
            if self.paged:
                prompt, shared, own = handle._admit_plan
                del handle._admit_plan
                plans[slot] = (prompt, shared, own)
                n_sh = len(shared)
                table = np.zeros((self._pages_per_slot,), np.int32)
                table[:n_sh] = shared
                table[n_sh:n_sh + len(own)] = own
                self._tables[slot] = table
                self._owned[slot] = False
                self._owned[slot, n_sh:n_sh + len(own)] = True
                self._shared[slot] = False
                self._shared[slot, :n_sh] = True
                # no dirty mark: paged_insert writes this slot's device
                # table row itself
                self._host_pos[slot] = len(prompt)
                self._admit_seq[slot] = self._seq = self._seq + 1
                if n_sh:
                    self.stats["prefix_hits"] += 1
                    self.stats["prefix_tokens_reused"] += (
                        n_sh * self._page_size)
                # (prefix registration happened in _reserve_pages, so
                # same-tick co-arrivals could already match these pages)
                tail = prompt[n_sh * self._page_size:]
                # bucket within the capacity left after the prefix: the
                # cache rows land at offset prefix_len
                key = (_bucket(len(tail),
                               self.max_len - n_sh * self._page_size),
                       tuple(shared))
            else:
                prompt = handle.request.prompt
                key = (_bucket(len(prompt), self.max_len), ())
            groups.setdefault(key, []).append((slot, handle))
        if self._draft_model is not None:
            # draft-cache lifecycle, admit: the draft prefills the FULL
            # prompt (plus generated tokens for preempted re-admissions
            # — i.e. prompt+accepted only, rejected drafts were never
            # committed) into its dense per-slot cache. No prefix
            # sharing: the draft has no page arena to share through.
            # Runs BEFORE the target groups commit their first token so
            # the draft lands at the same position the target is at.
            dgroups: Dict[int, list] = {}
            for slot, handle in admitted:
                fp = self._full_prompt(handle)
                dgroups.setdefault(_bucket(len(fp), self.max_len),
                                   []).append((slot, fp))
            for P, dgroup in dgroups.items():
                toks = np.zeros((len(dgroup), P), np.int32)
                lengths = np.zeros((len(dgroup),), np.int32)
                for i, (_, fp) in enumerate(dgroup):
                    toks[i, :len(fp)] = fp
                    lengths[i] = len(fp)
                _, rows = self._draft_prefill(self._draft_params,
                                              jnp.asarray(toks),
                                              jnp.asarray(lengths))
                self._draft_cache = self._insert(
                    self._draft_cache, rows,
                    jnp.asarray([s for s, _ in dgroup]))
                self.stats["draft_prefills"] += 1
        params = self._params[self._version]
        for (P, shared), group in groups.items():
            n = len(group)
            prefix_len = len(shared) * self._page_size if self.paged else 0
            toks = np.zeros((n, P), np.int32)
            lengths = np.zeros((n,), np.int32)
            for i, (slot, handle) in enumerate(group):
                prompt = (plans[slot][0][prefix_len:] if self.paged
                          else handle.request.prompt)
                toks[i, :len(prompt)] = prompt
                lengths[i] = len(prompt)
            if prefix_len:
                pfx = self._gather_prefix(self.cache,
                                          jnp.asarray(shared, jnp.int32))
                logits, rows = self._prefill_ext(params, jnp.asarray(toks),
                                                 jnp.asarray(lengths), pfx,
                                                 prefix_len)
            else:
                logits, rows = self._prefill(params, jnp.asarray(toks),
                                             jnp.asarray(lengths))
            slots = [slot for slot, _ in group]
            if self.paged:
                tables = self._tables[slots]
                write_tables = np.where(self._owned[slots], tables, 0)
                self.cache = self._paged_insert(
                    self.cache, rows, jnp.asarray(slots),
                    jnp.asarray(write_tables), jnp.asarray(tables))
            else:
                self.cache = self._insert(self.cache, rows,
                                          jnp.asarray(slots))
            self.stats["prefill_calls"] += 1
            # first generated token: the group's sampling policies (all-
            # greedy groups stay on the bitwise argmax path)
            if all(h.request.temperature <= 0 for _, h in group):
                nxt = np.asarray(self._argmax(logits))
            else:
                nxt = np.asarray(self._sample(
                    logits, jnp.asarray(self._keys[slots]),
                    jnp.asarray(self._pos[slots]),
                    jnp.asarray(self._temp[slots]),
                    jnp.asarray(self._topk[slots]),
                    jnp.asarray(self._topp[slots])))
            for i, (_, handle) in enumerate(group):
                self._commit(handle, int(nxt[i]))

    # ------------------------------------------------- speculative decoding
    def _can_speculate(self) -> bool:
        """Host-side spec-tick preconditions; any miss makes THIS tick
        run plain decode (never an error — speculation is opportunistic):
        single live param version (hot-reload transition ticks verify
        under one set of weights or not at all), all-greedy (sampled
        requests bypass speculation), and pos + k < capacity for every
        active slot — the no-wrap/no-clamp contract that makes verify
        rows exactly pos+t and rollback a pure pos rewrite. The first
        pressure-ladder rung also lands here: degraded mode sheds the
        draft's extra dispatches before touching admissions."""
        if self._ladder is not None and self._ladder.level >= 1:
            return False
        active = self.scheduler.active
        if not active:
            return False
        if len({h.version for h in active.values()}) != 1:
            return False
        k = self.spec_k
        for h in active.values():
            if h.request.temperature > 0:
                return False
            # rows in cache = prompt + generated - 1 (the last committed
            # token's K/V lands when it is fed); verify writes k+1 more
            rows = len(h.request.prompt) + len(h.tokens) - 1
            if rows + k >= self._spec_cap:
                return False
        return True

    def _grow_spec(self, k: int) -> Dict[int, list]:
        """Claim every page the verify forward may write (rows
        pos..pos+k per active slot; fresh page, or COW of a shared one,
        preempting under pool pressure like plain growth). Each claim
        records (logical page, previous table entry, was-shared) so
        `_rollback_spec` can return pages that ended up holding only
        rejected rows. Claims for a slot preempted by a LATER claim are
        already released with its other pages; its undo entries are
        simply never applied."""
        ps = self._page_size
        undo: Dict[int, list] = {}
        for slot in sorted(self.scheduler.active):
            if slot not in self.scheduler.active:   # preempted meanwhile
                continue
            p = int(self._host_pos[slot])
            for lp in range(p // ps, (p + k) // ps + 1):
                if not self._owned[slot, lp]:
                    undo.setdefault(slot, []).append(
                        (lp, int(self._tables[slot, lp]),
                         bool(self._shared[slot, lp])))
                    self._claim_page(slot, lp)
        return undo

    def _rollback_spec(self, entries, slot: int, last_row: int):
        """Undo this tick's page claims that hold ONLY rejected rows
        (logical pages strictly beyond `last_row`, the K/V row of the
        last committed token): release the page and restore the
        pre-claim table entry — trash for plain growth, the
        re-referenced read-only original for a COW'd shared page (the
        original was never written; the copy holds only rejected rows).
        Pages up to `last_row` keep their claims: they hold committed
        K/V. Device tables re-sync values-only on the next tick."""
        ps = self._page_size
        for lp, old_pid, old_shared in entries:
            if lp * ps > last_row:
                self._pool.release([int(self._tables[slot, lp])])
                self._tables[slot, lp] = old_pid
                self._owned[slot, lp] = False
                self._shared[slot, lp] = old_shared
                if old_shared:
                    self._pool.ref([old_pid])
                self._tables_dirty = True

    def _spec_tick(self) -> bool:
        """One speculation tick: draft proposes k tokens per slot (one
        scanned dispatch over its dense cache, healing last tick's
        overrun via the pos rewrite), the target scores all k+1
        positions in ONE verify dispatch, and each slot commits its
        longest draft prefix matching the target's greedy argmax plus
        the corrected token — 1..k+1 tokens for one target dispatch,
        bitwise what k+1 plain ticks would have produced. Returns False
        when preconditions fail (caller runs the plain tick)."""
        if not self._can_speculate():
            return False
        k = self.spec_k
        undo: Dict[int, list] = {}
        if self.paged:
            undo = self._grow_spec(k)       # may preempt under pressure
            self._sync_tables()
        active = dict(self.scheduler.active)
        if not active:                      # growth preempted everything
            return True
        p_vec = np.zeros((self.max_slots,), np.int32)
        for slot, h in active.items():
            # K/V rows currently in cache == the device pos (see
            # _can_speculate); equals _host_pos for the paged layout
            p_vec[slot] = len(h.request.prompt) + len(h.tokens) - 1
        version = next(iter({h.version for h in active.values()}))
        toks = jnp.asarray(self._tokens)
        drafts, self._draft_cache = self._propose(
            self._draft_params, toks, self._draft_cache,
            jnp.asarray(p_vec))
        spec_toks = jnp.concatenate([toks, drafts], axis=1)   # [B, k+1]
        nxt, g, acc, self.cache = self._verify(
            self._params[version], spec_toks, self.cache)
        del nxt   # == g[b, acc[b]]; _commit feeds _tokens from g anyway
        g = np.asarray(g)
        acc_np = np.asarray(acc)
        self.stats["decode_steps"] += 1     # ONE target dispatch
        self.stats["spec_ticks"] += 1
        for slot, handle in active.items():
            a = int(acc_np[slot])
            self.stats["spec_tokens_proposed"] += k
            self.stats["spec_tokens_accepted"] += a
            handle.spec_proposed += k
            handle.spec_accepted += a
            if self.paged:
                self._host_pos[slot] += a + 1
            for t in range(a + 1):
                self._commit(handle, int(g[slot, t]))
                if handle.done:
                    # EOS/budget inside the accepted run: later tokens
                    # are discarded; the slot's pages are already
                    # released wholesale, no rollback needed
                    break
            if self.paged and not handle.done:
                self._rollback_spec(undo.get(slot, ()), slot,
                                    int(p_vec[slot]) + a)
        return True

    def _decode_tick(self):
        if self.spec_k and self._spec_tick():
            return
        if self.paged:
            # every active slot must own its write page before the batch
            # advances (growth / COW; may preempt under pool pressure)
            self._grow_active()
            self._sync_tables()
        active = dict(self.scheduler.active)       # slot -> handle
        if not active:                             # all preempted
            return
        versions = sorted({h.version for h in active.values()})
        toks = jnp.asarray(self._tokens)
        # all-greedy ticks take the plain argmax decode (bitwise the
        # pre-sampling path, no wasted sort/gumbel work); any sampled
        # slot switches the tick to the sampling step, where greedy rows
        # still resolve to the identical argmax
        if any(h.request.temperature > 0 for h in active.values()):
            policy = (jnp.asarray(self._keys), jnp.asarray(self._pos),
                      jnp.asarray(self._temp), jnp.asarray(self._topk),
                      jnp.asarray(self._topp))
            decode = lambda params: self._decode_sampled(
                params, toks, self.cache, *policy)
        else:
            decode = lambda params: self._decode(params, toks, self.cache)
        if len(versions) == 1:
            nxt, self.cache = decode(self._params[versions[0]])
            nxt = np.asarray(nxt)
        else:
            # transition tick(s): decode once per live version, then keep
            # each slot's row from the version it is pinned to. Paged:
            # arena leaves merge by PHYSICAL page ownership (each slot
            # writes only its own pages; shared prefix pages are
            # read-only and identical under every version)
            outs = {v: decode(self._params[v]) for v in versions}
            merged = outs[versions[0]][1]
            nxt = np.asarray(outs[versions[0]][0]).copy()
            for v in versions[1:]:
                mask = np.zeros((self.max_slots,), bool)
                for slot, h in active.items():
                    if h.version == v:
                        mask[slot] = True
                if self.paged:
                    pmask = np.zeros((self._num_pages,), bool)
                    pmask[self._tables[mask][self._owned[mask]]] = True
                    pmask[PagePool.TRASH] = False
                    merged = self._select_paged(jnp.asarray(mask),
                                                jnp.asarray(pmask),
                                                outs[v][1], merged)
                else:
                    merged = self._select(jnp.asarray(mask), outs[v][1],
                                          merged)
                nxt[mask] = np.asarray(outs[v][0])[mask]
            self.cache = merged
        self.stats["decode_steps"] += 1
        if self.paged:
            for slot in active:
                self._host_pos[slot] += 1
        for slot, handle in active.items():
            self._commit(handle, int(nxt[slot, 0]))

    def _commit(self, handle: RequestHandle, token: int):
        """Record one generated token; stream it; retire if finished."""
        handle.tokens.append(token)
        slot = handle.slot
        self._tokens[slot, 0] = token
        # next sample position = #tokens generated so far: token t is a
        # pure function of (seed, t) regardless of batch composition
        self._pos[slot] = len(handle.tokens)
        self.stats["generated_tokens"] += 1
        if handle.first_token_at is None:
            handle.first_token_at = time.perf_counter()
        if handle.request.stream is not None:
            handle.request.stream(handle, token)
        reason = self.scheduler.should_retire(handle, token)
        if reason is not None:
            self.scheduler.retire(slot, reason)
            self.stats["completed"] += 1
            if handle.ttft is not None:
                self._ttft.append(handle.ttft)
            if handle.tpot is not None:
                self._tpot.append(handle.tpot)
            if self.paged:
                self._release_slot_pages(slot)

    # ---------------------------------------------------------- reporting
    def kv_stats(self) -> Dict[str, Any]:
        """KV-memory view of the engine: live/peak bytes, page counts,
        prefix-reuse and pressure counters. Dense layout reports its
        constant full-capacity footprint."""
        return {"kv_layout": "paged" if self.paged else "dense",
                "dense_fallback_leaves": self._dense_fallback_leaves,
                "dense_fallback_bytes": self._dense_fallback_bytes,
                "kv_bytes_in_use": self.stats["kv_bytes_in_use"],
                "peak_kv_bytes_in_use": self.stats["peak_kv_bytes_in_use"],
                "kv_capacity_bytes": self._kv_capacity_bytes,
                "kv_page_bytes": self._page_bytes,
                "kv_pages_used": self.stats["kv_pages_used"],
                "kv_pages_free": self.stats["kv_pages_free"],
                "prefix_hits": self.stats["prefix_hits"],
                "prefix_tokens_reused": self.stats["prefix_tokens_reused"],
                "cow_copies": self.stats["cow_copies"],
                "preemptions": self.stats["preemptions"],
                "spec_ticks": self.stats["spec_ticks"],
                "spec_tokens_proposed": self.stats["spec_tokens_proposed"],
                "spec_tokens_accepted": self.stats["spec_tokens_accepted"],
                "spec_acceptance_rate": (
                    self.stats["spec_tokens_accepted"]
                    / self.stats["spec_tokens_proposed"]
                    if self.stats["spec_tokens_proposed"] else 0.0)}

    def throughput(self) -> Dict[str, float]:
        """Completion/throughput fields (the serve CLI prints these):
        tok/s plus per-request latency — TTFT (submit -> first token)
        and TPOT (per-token cadence after the first), each mean/p50/p99
        over completed requests — and, under speculation, acceptance
        accounting and target dispatches per generated token."""
        started = self.stats["started_at"]
        wall = (time.perf_counter() - started) if started else 0.0
        toks = self.stats["generated_tokens"]
        out = {"completed": self.stats["completed"],
               "submitted": self.stats["submitted"],
               "generated_tokens": toks,
               "decode_steps": self.stats["decode_steps"],
               "prefill_calls": self.stats["prefill_calls"],
               "reloads": self.stats["reloads"],
               "wall_s": wall,
               "tok_s": toks / wall if wall > 0 else 0.0,
               "kv_bytes_in_use": self.stats["kv_bytes_in_use"],
               "peak_kv_bytes": self.stats["peak_kv_bytes_in_use"],
               "prefix_hits": self.stats["prefix_hits"],
               "prefix_tokens_reused": self.stats["prefix_tokens_reused"],
               # resilience counters: every submitted request ends in
               # completed or failed; failed splits into deadline kills,
               # retry-budget exhaustion, and drain-time shedding
               "failed": self.stats["failed"],
               "deadline_kills": self.stats["deadline_kills"],
               "retries": self.stats["retries"],
               "drained": self.stats["drained"],
               "restore_fallbacks": self.stats["restore_fallbacks"]}
        if self._ladder is not None:
            out["degradation_level"] = self.stats["degradation_level"]
            out["degradation_changes"] = self.stats["degradation_changes"]
            out["ladder_preempts"] = self.stats["ladder_preempts"]
        for name, samples in (("ttft", self._ttft), ("tpot", self._tpot)):
            if samples:
                # host wall-clock stats, not device pulls: `samples` are
                # time.perf_counter deltas recorded at retirement
                arr = np.asarray(samples, np.float64)
                out[f"{name}_mean_s"] = float(arr.mean())  # lint: allow(host-pull)
                out[f"{name}_p50_s"] = float(np.percentile(arr, 50))  # lint: allow(host-pull)
                out[f"{name}_p99_s"] = float(np.percentile(arr, 99))  # lint: allow(host-pull)
        if self.paged:
            out["kv_pages_used"] = self.stats["kv_pages_used"]
            out["kv_pages_free"] = self.stats["kv_pages_free"]
            out["preemptions"] = self.stats["preemptions"]
        if self.spec_k:
            proposed = self.stats["spec_tokens_proposed"]
            out["spec_ticks"] = self.stats["spec_ticks"]
            out["spec_tokens_proposed"] = proposed
            out["spec_tokens_accepted"] = self.stats["spec_tokens_accepted"]
            out["spec_acceptance_rate"] = (
                self.stats["spec_tokens_accepted"] / proposed
                if proposed else 0.0)
            out["draft_prefills"] = self.stats["draft_prefills"]
            # dispatches_per_token: target-model decode+verify dispatches
            # per generated token — the quantity speculation exists to
            # shrink (1.0 for plain decode; 1/(1 + acceptance*k) under
            # speculation)
            out["dispatches_per_token"] = (
                self.stats["decode_steps"] / toks if toks else 0.0)
        return out

    def close(self):
        if self.checkpoint is not None:
            close = getattr(self.checkpoint, "close", None)
            if close is not None:
                close()
