"""ServeEngine — request-level serving over the EngineConfig surface.

    engine = ServeEngine.from_config(
        EngineConfig(arch="qwen3-32b", reduced=True, max_slots=8,
                     max_len=128))
    h = engine.submit(GenerationRequest(prompt, max_new_tokens=32))
    engine.drain()                       # or: while engine.step(): ...
    h.tokens                             # generated ids (streamed too)

Compared to the legacy `ServeSession.generate(prompts, gen_len)` batch
loop this is a different shape of API — requests, not batches:

  * **continuous batching** — a fixed pool of `max_slots` decode slots
    over ONE slotted KV cache (per-slot write positions / length masks);
    requests are admitted the moment a slot frees and retired on
    EOS/budget, with no recompilation as the active set churns;
  * **fused prefill** — the whole prompt runs through one
    `model.prefill_cache` forward (flash-attention path on TPU) instead
    of T sequential jitted `decode_step` dispatches; recurrent-state
    families (mamba/RWKV) use a fused `lax.scan` of decode steps —
    still one dispatch, bitwise-faithful to stepped decode;
  * **checkpoint hot-reload** — params are versioned; a `HotReloader`
    watching a (possibly shared, barrier-protected) CheckpointManager
    swaps in new weights for NEW admissions while in-flight slots keep
    decoding on the version they started with.

The engine is deliberately single-threaded and tick-driven (`step()` =
admit + one batched decode + retire): callers own the concurrency story,
and tests get determinism for free.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .reload import HotReloader
from .scheduler import (ContinuousBatchingScheduler, GenerationRequest,
                        RequestHandle)
from .slots import insert_rows_at, select_rows

PyTree = Any

_PREFILL_MODES = ("auto", "parallel", "scan")


def _bucket(n: int, max_len: int) -> int:
    """Prompt padding bucket: next power of two (min 8), clipped to the
    cache capacity — bounds prefill recompilation at log2(max_len)."""
    b = 8
    while b < n:
        b *= 2
    return min(b, max_len)


def resolve_serve_parts(config, *, model=None, mesh=None, params=None,
                        checkpoint=None, attn_chunk: int = 64):
    """Shared ServeEngine/ServeSession bootstrap: local mesh, arch ->
    model (preset head padding), checkpoint manager from ckpt_dir, and
    params — freshly initialized, or the params-only restore of the
    latest checkpoint when one exists. Returns
    (model, mesh, params, checkpoint, loaded_step)."""
    from repro.checkpoint import CheckpointManager
    from repro.configs.base import get_config, get_reduced, pad_heads_for_tp
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model

    config.validate()
    if mesh is None:
        mesh = make_local_mesh(config.data_mesh or 1, config.model_mesh)
    if model is None:
        if not config.arch:
            raise ValueError("EngineConfig.arch is empty — pass a built "
                             "Model via from_config(model=...)")
        mcfg = (get_reduced(config.arch) if config.reduced
                else get_config(config.arch))
        if config.pad_heads:
            sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
            mcfg = pad_heads_for_tp(mcfg, sizes.get("model", 1))
        model = build_model(mcfg, attn_chunk=attn_chunk,
                            param_dtype=jnp.dtype(config.param_dtype))
    if checkpoint is None and config.ckpt_dir:
        checkpoint = CheckpointManager(config.ckpt_dir)
    loaded_step = None
    if params is None:
        if checkpoint is not None and checkpoint.latest_step() is not None:
            template = jax.eval_shape(model.init, jax.random.key(0))
            loaded_step = checkpoint.latest_step()
            params = checkpoint.restore_params(template, loaded_step)
        else:
            params = model.init(jax.random.key(0))
    return model, mesh, params, checkpoint, loaded_step


def _make_parallel_prefill(model, cap: int):
    """Returns the last-position logits [B, V] (not an argmax'd token):
    the engine applies the per-request sampling policy — greedy argmax
    by default, bitwise the old fused path."""
    def prefill(params, tokens, lengths):
        logits, cache = model.prefill_cache(params, tokens, lengths, cap)
        return logits[:, -1, :], cache
    return prefill


def _steady_cache_dtypes(model, params, batch: int, cap: int):
    """Fixed-point of decode_step's output dtypes: recurrent families
    (mamba conv history, RWKV token shifts) re-emit state in the compute
    dtype, so a freshly-initialized cache can change leaf dtypes after
    the first step. Serving needs the steady layout up front — the decode
    tick must never retrace and the prefill scan carry must be stable —
    and starting there is exact: the initial zeros are representable in
    either dtype."""
    cache = model.init_cache(params, batch, cap, per_slot=True)
    tok = jnp.zeros((batch, 1), jnp.int32)
    for _ in range(3):
        new = jax.eval_shape(model.decode_step, params, tok, cache)[1]
        drift = jax.tree.leaves(jax.tree.map(
            lambda c, n: c.dtype != n.dtype, cache, new))
        if not any(drift):
            break
        cache = jax.tree.map(lambda c, n: jnp.zeros(c.shape, n.dtype),
                             cache, new)
    else:
        raise ValueError(f"{model.cfg.name}: decode cache dtypes do not "
                         f"reach a fixed point")
    return jax.tree.map(lambda c: c.dtype, cache)


def _make_scan_prefill(model, cap: int, dtypes):
    """Fused stepped prefill: a lax.scan of decode steps — ONE dispatch
    per prompt (vs T), bitwise-identical math to sequential decode. The
    fused path for recurrent-state families whose chunked training
    forward cannot surrender its state mid-sequence."""
    def prefill(params, tokens, lengths):
        B, P = tokens.shape
        cache0 = jax.tree.map(
            lambda c, dt: c.astype(dt),
            model.init_cache(params, B, cap, per_slot=True), dtypes)
        V = model.cfg.vocab_size
        last0 = jnp.zeros((B, V), jnp.float32)

        def body(carry, t):
            cache, last = carry
            tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, axis=1)
            logits, new_cache = model.decode_step(params, tok, cache)
            cache = select_rows(t < lengths, new_cache, cache)
            last = jnp.where((t == lengths - 1)[:, None],
                             logits[:, -1, :].astype(jnp.float32), last)
            return (cache, last), None

        (cache, last), _ = jax.lax.scan(body, (cache0, last0),
                                        jnp.arange(P))
        return last, cache
    return prefill


class ServeEngine:
    """Continuous-batching serving engine for one (model, mesh, config)."""

    def __init__(self, config, model, mesh, params: PyTree, *,
                 checkpoint=None, loaded_step: Optional[int] = None):
        cfg = model.cfg
        if cfg.is_encoder_decoder or cfg.frontend != "none":
            raise ValueError(
                f"ServeEngine serves decoder-only text models; "
                f"{cfg.name} (frontend={cfg.frontend}, "
                f"enc-dec={cfg.is_encoder_decoder}) still goes through "
                f"ServeSession.generate(stepped_prefill=True)")
        self.config = config
        self.model = model
        self.mesh = mesh
        self.max_slots = config.max_slots
        self.max_len = config.max_len or config.seq_len
        self.scheduler = ContinuousBatchingScheduler(self.max_slots,
                                                     self.max_len)
        mode = config.prefill_mode
        if mode not in _PREFILL_MODES:
            raise ValueError(f"prefill_mode={mode!r}; one of {_PREFILL_MODES}")
        if mode == "auto":
            mode = "parallel" if model.prefill_cache is not None else "scan"
        if mode == "parallel" and model.prefill_cache is None:
            raise ValueError(
                f"{cfg.name} ({cfg.family}) has no parallel prefill "
                f"(recurrent state); use prefill_mode='scan'")
        self.prefill_mode = mode

        # versioned params: in-flight slots pin the version they were
        # admitted with; hot-reload bumps _version for new admissions
        self._params: Dict[int, PyTree] = {0: params}
        self._version = 0
        self._loaded_step = loaded_step
        self.checkpoint = checkpoint
        self._reloader: Optional[HotReloader] = None
        if checkpoint is not None and config.hot_reload:
            template = jax.eval_shape(model.init, jax.random.key(0))
            self._reloader = HotReloader(checkpoint, template,
                                         loaded_step=loaded_step)

        # steady-state leaf dtypes: the decode tick never retraces and
        # the prefill paths land rows in exactly this layout
        self._cache_dtypes = _steady_cache_dtypes(model, params,
                                                  self.max_slots,
                                                  self.max_len)
        self.cache = jax.tree.map(
            lambda c, dt: c.astype(dt),
            model.init_cache(params, self.max_slots, self.max_len,
                             per_slot=True), self._cache_dtypes)
        self._tokens = np.zeros((self.max_slots, 1), np.int32)
        # per-slot sampling policy rows (fixed [max_slots] shapes: policy
        # churn never retraces). Greedy slots (temperature 0) take the
        # bitwise argmax path; the all-greedy tick skips sampling math
        # entirely via the plain decode step.
        self._temp = np.zeros((self.max_slots,), np.float32)
        self._topk = np.zeros((self.max_slots,), np.int32)
        self._topp = np.ones((self.max_slots,), np.float32)
        self._keys = np.zeros((self.max_slots, 2), np.uint32)
        self._pos = np.zeros((self.max_slots,), np.int32)
        # NOTE: no buffer donation — hot-reload may decode the same cache
        # under two param versions in one tick
        from ..build import (make_batched_decode_step,
                             make_sampling_decode_step, sample_logits)
        self._decode = jax.jit(make_batched_decode_step(model))
        self._decode_sampled = jax.jit(make_sampling_decode_step(model))
        self._sample = jax.jit(sample_logits)
        self._argmax = jax.jit(
            lambda lg: jnp.argmax(lg, axis=-1).astype(jnp.int32))
        self._insert = jax.jit(insert_rows_at)
        self._select = jax.jit(select_rows)
        self._prefill = jax.jit(
            _make_parallel_prefill(model, self.max_len) if mode == "parallel"
            else _make_scan_prefill(model, self.max_len,
                                    self._cache_dtypes))
        self.stats = {"submitted": 0, "completed": 0, "generated_tokens": 0,
                      "prefill_calls": 0, "decode_steps": 0, "reloads": 0,
                      "started_at": None}

    # ------------------------------------------------------- construction
    @classmethod
    def from_config(cls, config, *, model=None, mesh=None, params=None,
                    checkpoint=None, attn_chunk: int = 64) -> "ServeEngine":
        """Build model/mesh/params from the same EngineConfig surface as
        TrainSession; with `ckpt_dir` set, serves the *trained* weights
        via the params-only restore (and hot-reloads later saves when
        `hot_reload=True`)."""
        model, mesh, params, checkpoint, loaded_step = resolve_serve_parts(
            config, model=model, mesh=mesh, params=params,
            checkpoint=checkpoint, attn_chunk=attn_chunk)
        return cls(config, model, mesh, params, checkpoint=checkpoint,
                   loaded_step=loaded_step)

    # ------------------------------------------------------------- submit
    def submit(self, request: GenerationRequest) -> RequestHandle:
        """Enqueue a request; it is admitted to a slot by a later
        `step()`. Raises immediately if it can never fit a slot."""
        handle = RequestHandle(request)
        self.scheduler.submit(handle)
        self.stats["submitted"] += 1
        if self.stats["started_at"] is None:
            self.stats["started_at"] = time.perf_counter()
        return handle

    # ------------------------------------------------------------- params
    def swap_params(self, params: PyTree, step: Optional[int] = None):
        """Hot-swap: new admissions decode with `params`; slots already
        in flight finish on their admitted version."""
        self._version += 1
        self._params[self._version] = params
        self._loaded_step = step
        self.stats["reloads"] += 1

    def _gc_versions(self):
        live = {h.version for h in self.scheduler.active.values()}
        live.add(self._version)
        for v in [v for v in self._params if v not in live]:
            del self._params[v]

    @property
    def params(self) -> PyTree:
        """The params new admissions will see."""
        return self._params[self._version]

    @property
    def loaded_step(self) -> Optional[int]:
        return self._loaded_step

    # --------------------------------------------------------------- tick
    def step(self) -> bool:
        """One scheduler tick: hot-reload poll -> admit (fused prefill)
        -> one batched decode over the active slots -> retire finished.
        Returns True while queued or in-flight work remains."""
        if self._reloader is not None:
            got = self._reloader.poll()
            if got is not None:
                self.swap_params(got[1], step=got[0])
        admitted = self.scheduler.admit()
        if admitted:
            self._admit_batch(admitted)
        if self.scheduler.active:
            self._decode_tick()
        self._gc_versions()
        return self.scheduler.has_work

    def drain(self) -> None:
        """Run ticks until every submitted request has completed."""
        while self.step():
            pass

    # ----------------------------------------------------------- internals
    def _admit_batch(self, admitted):
        """Fused prefill for this tick's admissions, grouped by prompt
        bucket: one prefill dispatch + one cache scatter per group (not
        per request) — the batched-arrival fast path."""
        groups: Dict[int, list] = {}
        for slot, handle in admitted:
            handle.version = self._version
            req = handle.request
            self._temp[slot] = req.temperature
            self._topk[slot] = req.top_k
            self._topp[slot] = req.top_p
            self._keys[slot] = np.asarray(
                jax.random.PRNGKey(req.sampling_seed), np.uint32)
            self._pos[slot] = 0
            P = _bucket(len(req.prompt), self.max_len)
            groups.setdefault(P, []).append((slot, handle))
        params = self._params[self._version]
        for P, group in groups.items():
            n = len(group)
            toks = np.zeros((n, P), np.int32)
            lengths = np.zeros((n,), np.int32)
            for i, (_, handle) in enumerate(group):
                prompt = handle.request.prompt
                toks[i, :len(prompt)] = prompt
                lengths[i] = len(prompt)
            logits, rows = self._prefill(params, jnp.asarray(toks),
                                         jnp.asarray(lengths))
            slots = [slot for slot, _ in group]
            self.cache = self._insert(self.cache, rows, jnp.asarray(slots))
            self.stats["prefill_calls"] += 1
            # first generated token: the group's sampling policies at
            # pos 0 (all-greedy groups stay on the bitwise argmax path)
            if all(h.request.temperature <= 0 for _, h in group):
                nxt = np.asarray(self._argmax(logits))
            else:
                nxt = np.asarray(self._sample(
                    logits, jnp.asarray(self._keys[slots]),
                    jnp.asarray(self._pos[slots]),
                    jnp.asarray(self._temp[slots]),
                    jnp.asarray(self._topk[slots]),
                    jnp.asarray(self._topp[slots])))
            for i, (_, handle) in enumerate(group):
                self._commit(handle, int(nxt[i]))

    def _decode_tick(self):
        active = dict(self.scheduler.active)       # slot -> handle
        versions = sorted({h.version for h in active.values()})
        toks = jnp.asarray(self._tokens)
        # all-greedy ticks take the plain argmax decode (bitwise the
        # pre-sampling path, no wasted sort/gumbel work); any sampled
        # slot switches the tick to the sampling step, where greedy rows
        # still resolve to the identical argmax
        if any(h.request.temperature > 0 for h in active.values()):
            policy = (jnp.asarray(self._keys), jnp.asarray(self._pos),
                      jnp.asarray(self._temp), jnp.asarray(self._topk),
                      jnp.asarray(self._topp))
            decode = lambda params: self._decode_sampled(
                params, toks, self.cache, *policy)
        else:
            decode = lambda params: self._decode(params, toks, self.cache)
        if len(versions) == 1:
            nxt, self.cache = decode(self._params[versions[0]])
            nxt = np.asarray(nxt)
        else:
            # transition tick(s): decode once per live version, then keep
            # each slot's row from the version it is pinned to
            outs = {v: decode(self._params[v]) for v in versions}
            merged = outs[versions[0]][1]
            nxt = np.asarray(outs[versions[0]][0]).copy()
            for v in versions[1:]:
                mask = np.zeros((self.max_slots,), bool)
                for slot, h in active.items():
                    if h.version == v:
                        mask[slot] = True
                merged = self._select(jnp.asarray(mask), outs[v][1], merged)
                nxt[mask] = np.asarray(outs[v][0])[mask]
            self.cache = merged
        self.stats["decode_steps"] += 1
        for slot, handle in active.items():
            self._commit(handle, int(nxt[slot, 0]))

    def _commit(self, handle: RequestHandle, token: int):
        """Record one generated token; stream it; retire if finished."""
        handle.tokens.append(token)
        self._tokens[handle.slot, 0] = token
        # next sample position = #tokens generated so far: token t is a
        # pure function of (seed, t) regardless of batch composition
        self._pos[handle.slot] = len(handle.tokens)
        self.stats["generated_tokens"] += 1
        if handle.first_token_at is None:
            handle.first_token_at = time.perf_counter()
        if handle.request.stream is not None:
            handle.request.stream(handle, token)
        reason = self.scheduler.should_retire(handle, token)
        if reason is not None:
            self.scheduler.retire(handle.slot, reason)
            self.stats["completed"] += 1

    # ---------------------------------------------------------- reporting
    def throughput(self) -> Dict[str, float]:
        """Completion/throughput fields (the serve CLI prints these)."""
        started = self.stats["started_at"]
        wall = (time.perf_counter() - started) if started else 0.0
        toks = self.stats["generated_tokens"]
        return {"completed": self.stats["completed"],
                "submitted": self.stats["submitted"],
                "generated_tokens": toks,
                "decode_steps": self.stats["decode_steps"],
                "prefill_calls": self.stats["prefill_calls"],
                "reloads": self.stats["reloads"],
                "wall_s": wall,
                "tok_s": toks / wall if wall > 0 else 0.0}

    def close(self):
        if self.checkpoint is not None:
            close = getattr(self.checkpoint, "close", None)
            if close is not None:
                close()
