"""String-keyed combiner registry — the engine's pluggable dispatch.

Replaces the if/elif chain that used to live in
`repro.core.combine.build_combiner`. Every combiner is a *factory*

    factory(cfg: CombineConfig, *, mesh, dp_axes, leaf_specs) -> combine

where `combine(stacked_grads) -> combined_grads` operates on a stacked
pytree (leading lane axis of length `cfg.span`). Built-in entries:

    sum            plain sum over lanes (synchronous-SGD baseline)
    mean           arithmetic mean over lanes
    adasum-gspmd   recursive tree on the lane axis; GSPMD picks collectives
    adasum-rvh     ADASUMRVH (paper Algorithm 1) via shard_map; needs
                   one lane per DP rank (mesh + dp_axes required)
    adasum-linear  ring-order recursion (paper §3.4) — ablation variant
    adascale       AdaScale SGD gain-ratio scaling (Johnson et al.) —
                   the first third-party-style entry

Extension point: register a new combiner without touching core dispatch —

    from repro.engine import register_combiner

    @register_combiner("dasgd")
    def _dasgd(cfg, *, mesh=None, dp_axes=(), leaf_specs=None):
        def combine(stacked):
            ...  # e.g. delayed averaging (Zhou et al., DaSGD)
        return combine

and select it with `EngineConfig(combine="dasgd")` (anything that is
not a built-in op name is looked up here verbatim).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Sequence

from repro.core import adasum as A
from repro.core import rvh as R
from repro.core.combine import (CombineConfig, _level_triple,
                                build_fused_combiner, stack_stats,
                                tree_combine_per_layer, tree_combine_whole)

PyTree = Any
Combiner = Callable[[PyTree], PyTree]
CombinerFactory = Callable[..., Combiner]

_COMBINERS: Dict[str, CombinerFactory] = {}


def register_combiner(name: str, *, overwrite: bool = False):
    """Decorator: register `factory` under `name` (e.g. 'adasum-rvh')."""
    def deco(factory: CombinerFactory) -> CombinerFactory:
        if name in _COMBINERS and not overwrite:
            raise KeyError(f"combiner {name!r} already registered "
                           f"(pass overwrite=True to replace)")
        _COMBINERS[name] = factory
        return factory
    return deco


def available_combiners() -> tuple:
    return tuple(sorted(_COMBINERS))


def get_combiner_factory(name: str) -> CombinerFactory:
    try:
        return _COMBINERS[name]
    except KeyError:
        raise KeyError(f"unknown combiner {name!r}; registered: "
                       f"{available_combiners()}") from None


def registry_key(op: str, backend: str = "") -> str:
    """Map (CombineConfig.op, CombineConfig.backend) to a registry name."""
    if op in ("sum", "mean"):
        return op
    if op == "adasum":
        return {"gspmd_tree": "adasum-gspmd", "rvh": "adasum-rvh",
                "fused": "adasum-fused",
                "linear": "adasum-linear", "": "adasum-gspmd"}.get(backend,
                                                                   backend)
    return op   # custom registry entries are addressed by op name directly


def make_combiner(cfg: CombineConfig, *, mesh=None,
                  dp_axes: Sequence[str] = (),
                  leaf_specs: Optional[PyTree] = None,
                  with_stats: bool = False) -> Combiner:
    """Registry-dispatched replacement for core.combine.build_combiner.

    Every returned combiner carries a `combine_path` attribute naming
    the implementation that will actually run (e.g. 'gspmd-fused' vs
    'gspmd-reference') — the run-metadata hook benchmarks record, since
    the registry key alone can hide a fallback.

    with_stats=True returns a combiner whose calls yield
    (combined, CombineStats) — see `stats_combiner`."""
    if with_stats:
        return stats_combiner(cfg, mesh=mesh, dp_axes=tuple(dp_axes),
                              leaf_specs=leaf_specs)
    key = registry_key(cfg.op, cfg.backend)
    factory = get_combiner_factory(key)
    combiner = factory(cfg, mesh=mesh, dp_axes=tuple(dp_axes),
                       leaf_specs=leaf_specs)
    if not hasattr(combiner, "combine_path"):
        try:
            combiner.combine_path = key
        except AttributeError:      # exotic callables (partial, C ext)
            pass
    return combiner


def probe_stats(stacked: PyTree, acc_dtype) -> dict:
    """Level-0 CombineStats geometry probe for combiners that don't
    natively surface dot triples (sum/mean/adascale/rvh/custom): pair
    adjacent lanes once and total [dot, ‖a‖², ‖b‖²] over all leaves.
    Level 0 pairs lanes that saw independent batches, which is all the
    gradient-noise estimator needs; GSPMD picks the reduction
    collectives. Returns {'levels': f32 [1, 3]} ([0, 3] at span 1)."""
    import jax
    leaves = jax.tree.leaves(stacked)
    if not leaves or leaves[0].shape[0] < 2:
        return stack_stats([])
    return stack_stats([_level_triple(leaves, acc_dtype)])


def stats_combiner(cfg: CombineConfig, *, mesh=None,
                   dp_axes: Sequence[str] = (),
                   leaf_specs: Optional[PyTree] = None) -> Combiner:
    """A combiner returning (combined, CombineStats).

    The adasum gspmd/fused paths surface their own per-level triples —
    piggybacked on the per-bucket psums the combine already issues
    (zero extra collectives on the fused path); every other combiner is
    wrapped with the level-0 `probe_stats`. The combined output is the
    SAME program as the plain combiner — stats only read existing
    intermediates, never reorder the combine math."""
    key = registry_key(cfg.op, cfg.backend)
    if key in ("adasum-gspmd", "adasum-fused"):
        if cfg.fused:
            fused = build_fused_combiner(cfg, mesh=mesh, dp_axes=dp_axes,
                                         leaf_specs=leaf_specs,
                                         with_stats=True)
            if fused is not None:
                fused.combine_path = "gspmd-fused"
                return fused
            if key == "adasum-fused":
                raise ValueError(
                    "adasum-fused: the lane axis is device-sharded (one "
                    "lane per DP rank); use backend='rvh' or "
                    "backend='gspmd_tree' there")
        fn = tree_combine_per_layer if cfg.per_layer else tree_combine_whole

        def ref(stacked):
            collect: list = []
            out = fn(stacked, cfg.acc, collect=collect)
            return out, stack_stats(collect)

        ref.combine_path = "gspmd-reference"
        return ref

    base = make_combiner(cfg, mesh=mesh, dp_axes=dp_axes,
                         leaf_specs=leaf_specs)

    def probed(stacked):
        return base(stacked), probe_stats(stacked, cfg.acc)

    probed.combine_path = getattr(base, "combine_path", key)
    return probed


# --------------------------------------------------------------- built-ins

@register_combiner("sum")
def _sum(cfg, *, mesh=None, dp_axes=(), leaf_specs=None):
    return lambda stacked: A.sum_reduce(stacked, mean=False)


@register_combiner("mean")
def _mean(cfg, *, mesh=None, dp_axes=(), leaf_specs=None):
    return lambda stacked: A.sum_reduce(stacked, mean=True)


@register_combiner("adasum-gspmd")
def _adasum_gspmd(cfg, *, mesh=None, dp_axes=(), leaf_specs=None):
    """Default backend: bucketed single-pass fused combine (cfg.fused,
    default on), falling back to the per-leaf reference tree when fusion
    cannot apply (lane axis device-sharded: span == dp — warned, like
    the rvh fallback) or is opted out (cfg.fused=False /
    EngineConfig.fused_combine=False)."""
    if cfg.fused:
        fused = build_fused_combiner(cfg, mesh=mesh, dp_axes=dp_axes,
                                     leaf_specs=leaf_specs)
        if fused is not None:
            fused.combine_path = "gspmd-fused"
            return fused
        import warnings
        from repro.engine.build import EngineWarning
        warnings.warn(
            "fused combine requested but span == dp: the lane axis is "
            "device-sharded (RVH layout), so local adjacent-lane pairing "
            "would cross devices — running the per-leaf reference tree "
            "instead. Use backend='rvh' (paper Algorithm 1) for the "
            "bandwidth-optimal one-lane-per-rank path, or span < dp for "
            "the fused hierarchical path.", EngineWarning, stacklevel=3)
    fn = tree_combine_per_layer if cfg.per_layer else tree_combine_whole
    ref = lambda stacked: fn(stacked, cfg.acc)
    ref.combine_path = "gspmd-reference"
    return ref


@register_combiner("adasum-fused")
def _adasum_fused(cfg, *, mesh=None, dp_axes=(), leaf_specs=None):
    """The fused bucketed combine, explicitly — no reference fallback.
    Selected via backend='fused' (or combine='adasum-fused'); errors
    loudly where adasum-gspmd would silently degrade."""
    fused = build_fused_combiner(cfg, mesh=mesh, dp_axes=dp_axes,
                                 leaf_specs=leaf_specs)
    if fused is None:
        raise ValueError(
            "adasum-fused: the lane axis is device-sharded (one lane per "
            "DP rank); use backend='rvh' (paper Algorithm 1) or "
            "backend='gspmd_tree' there")
    fused.combine_path = "gspmd-fused"
    return fused


@register_combiner("adasum-linear")
def _adasum_linear(cfg, *, mesh=None, dp_axes=(), leaf_specs=None):
    import jax

    def lin(stacked):
        n = jax.tree.leaves(stacked)[0].shape[0]
        lanes = [jax.tree.map(lambda x, i=i: x[i], stacked)
                 for i in range(n)]
        return A.adasum_linear_reduce(lanes, per_layer=cfg.per_layer,
                                      acc_dtype=cfg.acc)
    return lin


@register_combiner("adascale")
def _adascale(cfg, *, mesh=None, dp_axes=(), leaf_specs=None):
    """AdaScale SGD (Johnson et al., 2020) as a combiner — the first
    'third-party' registry entry the ROADMAP asked for.

    AdaScale scales the averaged gradient by the gain ratio

        r = (sigma^2 + mu^2) / (sigma^2 / S + mu^2)      in [1, S]

    (sigma^2: per-lane gradient variance, mu^2: squared mean norm,
    estimated from the S lanes as in the paper's Algorithm 1). r -> 1
    when lanes agree (combined == mean: no extra signal to harvest) and
    r -> S when lanes are orthogonal (combined == sum: full batch-size
    gain) — the same two endpoints Adasum interpolates geometrically.
    `cfg.per_layer` picks per-leaf vs whole-model gain; `cfg.acc` is the
    moment-accumulation dtype (paper §4.4.1 analogue).
    """
    import jax
    import jax.numpy as jnp

    eps = 1e-20

    def gain(var, mu2, S):
        r = (var + mu2) / (var / S + mu2 + eps)
        return jnp.clip(r, 1.0, S)

    def combine(stacked):
        S = jax.tree.leaves(stacked)[0].shape[0]

        def moments(x):
            xa = x.astype(cfg.acc)
            m = jnp.mean(xa, axis=0)
            var = jnp.sum(jnp.square(xa - m)) / max(S - 1, 1)
            msq = jnp.sum(jnp.square(m))
            return m, var, msq

        if cfg.per_layer:
            def per_leaf(x):
                m, var, msq = moments(x)
                mu2 = jnp.maximum(msq - var / S, 0.0)
                return (gain(var, mu2, S) * m).astype(x.dtype)
            return jax.tree.map(per_leaf, stacked)

        leaves, treedef = jax.tree.flatten(stacked)
        mo = [moments(x) for x in leaves]
        var = sum(v for _, v, _ in mo)
        mu2 = jnp.maximum(sum(m2 for _, _, m2 in mo) - var / S, 0.0)
        r = gain(var, mu2, S)
        return jax.tree.unflatten(
            treedef, [(r * m).astype(x.dtype)
                      for (m, _, _), x in zip(mo, leaves)])

    return combine


@register_combiner("adasum-rvh")
def _adasum_rvh(cfg, *, mesh=None, dp_axes=(), leaf_specs=None):
    assert mesh is not None and dp_axes, "rvh backend needs mesh + dp_axes"
    return lambda stacked: R.adasum_rvh_pytree(
        stacked, mesh, tuple(dp_axes), leaf_specs=leaf_specs,
        per_layer=cfg.per_layer, acc_dtype=cfg.acc,
        use_pallas=cfg.use_pallas, compress=cfg.compress,
        bucket_bytes=cfg.fusion_bytes)
