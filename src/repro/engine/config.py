"""One round-trippable config for the whole engine.

`EngineConfig` unifies what used to be four uncoordinated layers —
`RunPolicy` (parallelism / policy), `CombineConfig` (combiner knobs),
`DataConfig` (stream), and the optimizer / checkpoint settings that each
launcher re-declared by hand. One instance fully describes a run:

    cfg = EngineConfig(arch="hymba-1p5b", combine="adasum")
    cfg == EngineConfig.from_dict(cfg.to_dict())        # always True

Per-arch presets (the old `parallel.policy._POLICIES` table) live here;
`repro.parallel.get_policy` now derives its RunPolicy from them.
"""
from __future__ import annotations

import argparse
import dataclasses
from typing import Any, Dict, Optional

from repro.data.pipeline import DataConfig
from repro.parallel.policy import RunPolicy

_COMBINE_OPS = ("adasum", "sum", "mean")
_BACKENDS = ("", "rvh", "gspmd_tree", "fused", "linear")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    # ---- model ----
    arch: str = ""              # registry id ("hymba-1p5b", ...); "" => the
                                # caller passes a built Model to the session
    reduced: bool = False       # CPU-scale reduced variant of `arch`

    # ---- combiner ----
    combine: str = "adasum"     # 'adasum' | 'sum' | 'mean' | registry entry
    backend: str = ""           # '' => auto: rvh when span==dp else gspmd
    span: int = 0               # #Adasum lanes; 0 => one per DP rank
    combine_point: str = "auto" # 'pre' | 'post' | 'auto' (by optimizer kind)
    per_layer: bool = True      # paper §3.6 per-layer Adasum
    acc_dtype: str = "float32"  # dot-product accumulation dtype (§4.4.1)
    use_pallas: bool = False    # Pallas kernels for the RVH dots/combine
    compress: str = "none"      # 'int8': quantized RVH wire payloads
    fused_combine: bool = True  # bucketed single-pass combine for the
                                # gspmd_tree backend (opt out to get the
                                # per-leaf reference tree.map)
    fusion_threshold_mb: int = 64   # Horovod-style packing bucket budget

    # ---- parallelism ----
    data_mesh: int = 0          # 0 => all devices not used by model_mesh
    model_mesh: int = 1
    fsdp: bool = False          # ZeRO-3 params over `data`
    scatter_grads: bool = False # ZeRO-2 lane grads over `data`
    pad_heads: bool = False     # TP head alignment (exact math)
    attn_chunk: int = 512

    # ---- optimizer / training ----
    optimizer: str = "adam"
    lr: float = 1e-3
    local_steps: int = 1        # paper §5.2 local-SGD steps per sync
    combine_delay: int = 0      # 0 = synchronous combine (bitwise today's
                                # behavior); 1 = DaSGD-style delayed mode:
                                # round i-1's delta exchange overlaps round
                                # i's compute, correction lands at i+1
    accum_steps: int = 1        # microbatch gradient accumulation (§2.2)
    accum_dtype: str = "float32"
    opt_state_dtype: str = "float32"
    param_dtype: str = "float32"

    # ---- data ----
    seq_len: int = 256
    global_batch: int = 16
    data_kind: str = "synthetic"    # synthetic | memmap
    data_path: str = ""
    data_seed: int = 0

    # ---- run control ----
    steps: int = 100
    ckpt_dir: str = ""
    ckpt_every: int = 50
    log_every: int = 10
    strict: bool = False        # hard-error instead of warn+degrade (e.g.
                                # rvh backend silently falling back)

    # ---- pipelined runtime (engine/pipeline.py) ----
    prefetch: bool = True       # double-buffered host->device batch stage
    prefetch_depth: int = 1     # speculative batches in flight (1 =
                                # double-buffered; >1 = deeper pipeline)
    device_stage: bool = False  # prefetch thread also jax.device_put()s
                                # the batch onto the mesh (DP-sharded
                                # dim 0), not just onto the host heap
    async_checkpoint: bool = True   # off-thread checkpoint writes
    elastic: bool = False       # consume straggler flags: checkpoint +
                                # halve-DP restart (needs ckpt_dir)

    # ---- adaptive batch/span controller (repro.control) ----
    combine_stats: bool = True  # surface CombineStats (grad-noise scale,
                                # lane-orthogonality angle, adascale gain)
                                # in per-step metrics + run_metadata(); on
                                # the fused path the triples ride the
                                # psums the combine already issues (zero
                                # extra collectives)
    adaptive_batch: bool = False # gradient-noise-adaptive controller:
                                # grow global_batch (and span) when the
                                # EMA noise scale exceeds the band, via
                                # save -> rebuild -> resume (needs
                                # ckpt_dir; driven by fit_adaptive)
    grow_factor: int = 2        # batch multiplier per resize (AdaBatch
                                # doubling; power of two when grow_span)
    grow_threshold: float = 2.0 # resize while ema_noise > threshold *
                                # global_batch (hysteresis: reset below
                                # threshold/2)
    grow_patience: int = 8      # consecutive in-band steps before a resize
    grow_cooldown: int = 16     # steps after a resize before re-arming
    max_global_batch: int = 0   # controller hard cap (0 = uncapped)
    grow_span: bool = True      # grow Adasum span with the batch (kept a
                                # power-of-two divisor of dp)
    lr_rescale: str = "adascale" # LR rule at a resize: 'adascale' gain |
                                # 'linear' | 'none'
    noise_ema: float = 0.9      # noise-scale EMA decay
    shrink_threshold: float = 0.0 # shrink while ema_noise < this *
                                # global_batch (0 = shrink direction off;
                                # must stay below grow_threshold); LR is
                                # divided by the gain growth multiplied by
    min_global_batch: int = 0   # controller shrink floor (0 = span floor)

    # ---- serving (engine/serving.ServeEngine) ----
    max_slots: int = 8          # continuous-batching decode slot pool
    max_len: int = 0            # per-slot cache capacity; 0 => seq_len
                                # (rounded up to a page multiple when
                                # kv_layout='paged' — see serve_max_len())
    hot_reload: bool = False    # poll ckpt_dir mid-stream; new requests
                                # see new weights, in-flight finish on old
    prefill_mode: str = "auto"  # 'parallel' (one fused forward) | 'scan'
                                # (fused decode scan) | 'auto' (by family)
    kv_layout: str = "paged"    # 'paged' (page-pool arena, the default)
                                # | 'dense' (per-slot max_len buffers)
    page_size: int = 16         # tokens per KV page (paged layout)
    kv_pages: int = 0           # physical pages in the arena (incl. the
                                # reserved trash page); 0 => enough for
                                # every slot at full capacity
    prefix_sharing: bool = True # map page-aligned shared prompt prefixes
                                # onto the same read-only pages; prefill
                                # computes only the unshared tail
    speculation_k: int = 0      # draft-model speculative decoding: draft
                                # tokens proposed + verified per tick
                                # (0 = off). Greedy-only — sampled slots
                                # make the tick fall back to plain decode
    draft_config: Optional[Dict[str, Any]] = None
                                # draft model spec: {'arch': preset-name
                                # [, 'reduced': bool, field overrides]}
                                # or plain ModelConfig field overrides
                                # applied to the target config; None =>
                                # auto-derived shrunken target (quarter
                                # depth). Must share the target's vocab
    pressure_ladder: bool = False # serve graceful degradation under
                                # kv/queue pressure: disable speculation
                                # -> stop admissions -> preempt-by-
                                # recompute (opt-in; off keeps the
                                # aggressive-admission default behavior)

    # ------------------------------------------------------------ validation
    def validate(self, dp_total: Optional[int] = None) -> "EngineConfig":
        """Cross-field checks that used to live ad hoc in launch/train.py.
        Pass `dp_total` (the mesh's DP degree) for mesh-dependent checks.
        Returns self so it chains."""
        if self.combine in _COMBINE_OPS and self.backend not in _BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r}; "
                             f"expected one of {_BACKENDS[1:]}")
        if self.combine not in _COMBINE_OPS:
            from .registry import available_combiners
            if self.combine not in available_combiners():
                raise ValueError(
                    f"unknown combine op {self.combine!r}; built-ins "
                    f"{_COMBINE_OPS}, registry {available_combiners()}")
        if self.span < 0:
            raise ValueError(f"span must be >= 0, got {self.span}")
        if self.fusion_threshold_mb < 1:
            raise ValueError(f"fusion_threshold_mb must be >= 1, got "
                             f"{self.fusion_threshold_mb}")
        if self.prefetch_depth < 1:
            raise ValueError(f"prefetch_depth must be >= 1, got "
                             f"{self.prefetch_depth}")
        if not self.prefetch and (self.prefetch_depth > 1
                                  or self.device_stage):
            raise ValueError(
                "prefetch_depth > 1 / device_stage require prefetch=True "
                "(they configure the prefetch stage; with prefetch off "
                "they would be silently ignored)")
        if self.local_steps < 1 or self.accum_steps < 1:
            raise ValueError("local_steps/accum_steps must be >= 1")
        if self.local_steps > 1 and self.accum_steps > 1:
            raise ValueError("local_steps and accum_steps are mutually "
                             "exclusive (both reshape the lane batch)")
        if self.combine_delay not in (0, 1):
            raise ValueError(
                f"combine_delay must be 0 (synchronous) or 1 (DaSGD-style "
                f"one-round delayed exchange), got {self.combine_delay}")
        if self.combine_delay and self.accum_steps > 1:
            raise ValueError(
                "combine_delay and accum_steps are mutually exclusive: "
                "the delayed path combines per-lane optimizer-step deltas "
                "(local_steps semantics), not accumulated raw gradients — "
                "use local_steps to amortize syncs instead")
        if self.data_kind == "memmap" and not self.data_path:
            raise ValueError("data_kind='memmap' needs data_path")
        if self.grow_factor < 2:
            raise ValueError(f"grow_factor must be >= 2 (AdaBatch-style "
                             f"multiplicative growth), got {self.grow_factor}")
        if self.grow_span and self.grow_factor & (self.grow_factor - 1):
            raise ValueError(
                f"grow_factor={self.grow_factor} must be a power of two "
                f"when grow_span=True (the span must stay a power-of-two "
                f"divisor of dp); set grow_span=False for other factors")
        if self.grow_threshold <= 0:
            raise ValueError(f"grow_threshold must be > 0, got "
                             f"{self.grow_threshold}")
        if self.grow_patience < 1 or self.grow_cooldown < 0:
            raise ValueError("grow_patience must be >= 1 and grow_cooldown "
                             ">= 0")
        if self.max_global_batch < 0:
            raise ValueError(f"max_global_batch must be >= 0 (0 = "
                             f"uncapped), got {self.max_global_batch}")
        if not 0.0 <= self.noise_ema < 1.0:
            raise ValueError(f"noise_ema must be in [0, 1), got "
                             f"{self.noise_ema}")
        if self.shrink_threshold < 0:
            raise ValueError(f"shrink_threshold must be >= 0 (0 = shrink "
                             f"off), got {self.shrink_threshold}")
        if self.shrink_threshold and (self.shrink_threshold
                                      >= self.grow_threshold):
            raise ValueError(
                f"shrink_threshold={self.shrink_threshold} must stay "
                f"below grow_threshold={self.grow_threshold} (the bands "
                f"must not overlap or the controller oscillates)")
        if self.min_global_batch < 0:
            raise ValueError(f"min_global_batch must be >= 0, got "
                             f"{self.min_global_batch}")
        if self.lr_rescale not in ("adascale", "linear", "none"):
            raise ValueError(f"lr_rescale={self.lr_rescale!r}; expected "
                             f"adascale | linear | none")
        if self.adaptive_batch:
            if not self.ckpt_dir:
                raise ValueError("adaptive_batch=True needs ckpt_dir (a "
                                 "resize resumes from the checkpoint "
                                 "manifest)")
            if not self.combine_stats:
                raise ValueError("adaptive_batch=True needs "
                                 "combine_stats=True (the controller is "
                                 "driven by the combiner's noise signal)")
            if self.combine_delay:
                raise ValueError(
                    "adaptive_batch and combine_delay are mutually "
                    "exclusive: CombineStats are collected on the "
                    "synchronous combine paths only (the delayed carry's "
                    "dots describe the previous round)")
            if self.elastic:
                raise ValueError(
                    "adaptive_batch and elastic are mutually exclusive "
                    "drivers (fit_adaptive vs fit_elastic) — straggler "
                    "shrink + noise growth composition is not supported "
                    "yet")
        if self.elastic and not self.ckpt_dir:
            raise ValueError("elastic=True needs ckpt_dir (restarts "
                             "resume from the checkpoint manifest)")
        if self.max_slots < 1:
            raise ValueError(f"max_slots must be >= 1, got {self.max_slots}")
        if self.max_len < 0:
            raise ValueError(f"max_len must be >= 0, got {self.max_len}")
        if self.hot_reload and not self.ckpt_dir:
            raise ValueError("hot_reload=True needs ckpt_dir (the serve "
                             "engine watches it for new checkpoints)")
        if self.prefill_mode not in ("auto", "parallel", "scan"):
            raise ValueError(f"prefill_mode={self.prefill_mode!r}; "
                             f"expected auto | parallel | scan")
        if self.kv_layout not in ("paged", "dense"):
            raise ValueError(f"kv_layout={self.kv_layout!r}; "
                             f"expected paged | dense")
        if self.kv_layout == "paged":
            if self.page_size < 1:
                raise ValueError(
                    f"page_size must be >= 1 for kv_layout='paged', got "
                    f"{self.page_size} (each KV page holds page_size "
                    f"token rows)")
            if self.kv_pages < 0:
                raise ValueError(f"kv_pages must be >= 0 (0 = full "
                                 f"provisioning), got {self.kv_pages}")
            if self.kv_pages == 1:
                raise ValueError(
                    f"kv_pages=1 is only the reserved trash page; the "
                    f"engine needs at least one allocatable page (the "
                    f"model-aware one-full-slot minimum — sliding "
                    f"windows cap it below max_len — is checked at "
                    f"ServeEngine build)")
        if self.speculation_k < 0:
            raise ValueError(
                f"speculation_k must be >= 0 (draft tokens per tick; 0 "
                f"disables speculation), got {self.speculation_k}")
        if self.draft_config is not None:
            if not self.speculation_k:
                raise ValueError(
                    "draft_config is set but speculation_k=0; speculation "
                    "is off without draft tokens — set speculation_k >= 1 "
                    "or drop draft_config")
            if not isinstance(self.draft_config, dict) or not self.draft_config:
                raise ValueError(
                    f"draft_config must be a non-empty dict ({{'arch': "
                    f"preset[, 'reduced': bool]}} or ModelConfig field "
                    f"overrides), got {self.draft_config!r}")
        if dp_total is not None:
            span = self.span or dp_total
            if span > dp_total or dp_total % span:
                raise ValueError(
                    f"span={span} must divide dp={dp_total}")
            if self.backend == "rvh" and span != dp_total and self.strict:
                raise ValueError(
                    f"backend='rvh' requires span == dp "
                    f"(span={span}, dp={dp_total}); drop strict=True to "
                    f"fall back to 'gspmd_tree' with a warning")
            rows = self.global_batch
            if rows % span:
                raise ValueError(
                    f"global_batch={rows} not divisible by span={span}")
            lane_rows = rows // span
            if self.local_steps > 1 and lane_rows % self.local_steps:
                raise ValueError(
                    f"local_steps={self.local_steps} needs lane batch "
                    f"({lane_rows}) divisible by it")
            if self.accum_steps > 1 and lane_rows % self.accum_steps:
                raise ValueError(
                    f"accum_steps={self.accum_steps} needs lane batch "
                    f"({lane_rows}) divisible by it")
        return self

    def serve_max_len(self) -> int:
        """The per-slot cache capacity the serve engine actually builds:
        `max_len` (0 => seq_len — the old default now composes with
        paging), rounded UP to a page multiple under kv_layout='paged'
        so logical rows tile pages exactly. Rounding only ever loosens
        the request-capacity check."""
        n = self.max_len or self.seq_len
        if self.kv_layout == "paged" and self.page_size > 0:
            n = -(-n // self.page_size) * self.page_size
        return n

    # ------------------------------------------------------------ round-trip
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "EngineConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown EngineConfig keys: {sorted(unknown)}")
        return cls(**d)

    # ---------------------------------------------------------------- presets
    @classmethod
    def preset(cls, arch: str, **overrides) -> "EngineConfig":
        """Per-arch preset (the old `_POLICIES` table) + overrides."""
        from repro.configs.base import canonical
        base = dict(_PRESETS.get(canonical(arch), {}))
        base["arch"] = arch
        base.update(overrides)
        return cls(**base)

    # ----------------------------------------------------------- conversions
    def run_policy(self) -> RunPolicy:
        """Project onto the legacy RunPolicy consumed by the step builder."""
        return RunPolicy(
            span=self.span, fsdp=self.fsdp, scatter_grads=self.scatter_grads,
            # "" passes through: the builder resolves auto to rvh when
            # span == dp (only known once the mesh exists), gspmd otherwise
            backend=self.backend,
            optimizer=self.optimizer,
            param_dtype=self.param_dtype, local_steps=self.local_steps,
            combine_delay=self.combine_delay,
            combine_op=self.combine, attn_chunk=self.attn_chunk,
            accum_steps=self.accum_steps, accum_dtype=self.accum_dtype,
            opt_state_dtype=self.opt_state_dtype, pad_heads=self.pad_heads,
            combine_point=self.combine_point, per_layer=self.per_layer,
            acc_dtype=self.acc_dtype, use_pallas=self.use_pallas,
            compress=self.compress, fused_combine=self.fused_combine,
            fusion_threshold_mb=self.fusion_threshold_mb,
            combine_stats=self.combine_stats)

    def data_config(self, vocab_size: int) -> DataConfig:
        return DataConfig(seq_len=self.seq_len,
                          global_batch=self.global_batch,
                          vocab_size=vocab_size, seed=self.data_seed,
                          kind=self.data_kind, path=self.data_path or None)

    # ------------------------------------------------------------------- CLI
    @classmethod
    def from_cli(cls, argv=None, **defaults) -> "EngineConfig":
        """Parse the train CLI into a config. Flags override the per-arch
        preset, which overrides the dataclass defaults."""
        ap = argparse.ArgumentParser(description="repro.engine train CLI")
        ap.add_argument("--arch", required="arch" not in defaults)
        ap.add_argument("--reduced", action="store_true", default=None,
                        help="use the reduced config (CPU-scale)")
        ap.add_argument("--steps", type=int, default=None)
        ap.add_argument("--seq", type=int, default=None, dest="seq_len")
        ap.add_argument("--batch", type=int, default=None,
                        dest="global_batch")
        ap.add_argument("--lr", type=float, default=None)
        ap.add_argument("--optimizer", default=None)
        ap.add_argument("--combine", default=None,
                        help="adasum | sum | mean | any registry entry")
        ap.add_argument("--backend", default=None,
                        choices=["rvh", "gspmd_tree", "fused", "linear"])
        ap.add_argument("--no-fused-combine", action="store_true",
                        help="per-leaf reference tree.map instead of the "
                        "bucketed single-pass gspmd_tree combine")
        ap.add_argument("--fusion-threshold-mb", type=int, default=None,
                        dest="fusion_threshold_mb",
                        help="packing bucket budget for the fused combine "
                        "(Horovod fusion threshold analogue)")
        ap.add_argument("--span", type=int, default=None)
        ap.add_argument("--local-steps", type=int, default=None,
                        dest="local_steps")
        ap.add_argument("--combine-delay", type=int, default=None,
                        dest="combine_delay", choices=[0, 1],
                        help="1 = DaSGD-style delayed combine: the Adasum "
                        "exchange for the previous round's deltas overlaps "
                        "this round's compute (slow-interconnect mode)")
        ap.add_argument("--accum-steps", type=int, default=None,
                        dest="accum_steps")
        ap.add_argument("--no-per-layer", action="store_true",
                        help="whole-model Adasum granularity (§3.6 ablation)")
        ap.add_argument("--acc-dtype", default=None, dest="acc_dtype")
        ap.add_argument("--use-pallas", action="store_true", default=None,
                        dest="use_pallas")
        ap.add_argument("--strict", action="store_true", default=None,
                        help="error (not warn) on degraded fallbacks")
        ap.add_argument("--data-mesh", type=int, default=None,
                        dest="data_mesh")
        ap.add_argument("--model-mesh", type=int, default=None,
                        dest="model_mesh")
        ap.add_argument("--ckpt-dir", default=None, dest="ckpt_dir")
        ap.add_argument("--ckpt-every", type=int, default=None,
                        dest="ckpt_every")
        ap.add_argument("--log-every", type=int, default=None,
                        dest="log_every")
        ap.add_argument("--data-seed", type=int, default=None,
                        dest="data_seed")
        ap.add_argument("--no-prefetch", action="store_true",
                        help="synchronous batch pulls (disable the "
                        "double-buffered prefetch stage)")
        ap.add_argument("--prefetch-depth", type=int, default=None,
                        dest="prefetch_depth",
                        help="speculative batches in flight (1 = "
                        "double-buffered)")
        ap.add_argument("--device-stage", action="store_true", default=None,
                        dest="device_stage",
                        help="prefetch thread device_put()s batches onto "
                        "the mesh (DP-sharded) instead of host staging")
        ap.add_argument("--sync-checkpoint", action="store_true",
                        help="block the step loop on checkpoint writes")
        ap.add_argument("--elastic", action="store_true", default=None,
                        help="straggler flag => checkpoint + halve-DP "
                        "restart (needs --ckpt-dir)")
        ap.add_argument("--no-combine-stats", action="store_true",
                        help="drop the CombineStats per-step metrics "
                        "(grad-noise scale / lane orthogonality / gain)")
        ap.add_argument("--adaptive-batch", action="store_true",
                        default=None, dest="adaptive_batch",
                        help="noise-adaptive controller: grow batch/span "
                        "when measured gradient noise exceeds the band "
                        "(needs --ckpt-dir)")
        ap.add_argument("--grow-factor", type=int, default=None,
                        dest="grow_factor",
                        help="batch multiplier per adaptive resize")
        ap.add_argument("--grow-threshold", type=float, default=None,
                        dest="grow_threshold",
                        help="resize while ema noise_scale > threshold * "
                        "global_batch")
        ap.add_argument("--grow-patience", type=int, default=None,
                        dest="grow_patience",
                        help="consecutive in-band steps before a resize")
        ap.add_argument("--grow-cooldown", type=int, default=None,
                        dest="grow_cooldown",
                        help="steps after a resize before re-arming")
        ap.add_argument("--max-global-batch", type=int, default=None,
                        dest="max_global_batch",
                        help="adaptive controller batch cap (0 = uncapped)")
        ap.add_argument("--no-grow-span", action="store_true",
                        help="adaptive resizes grow only the batch, "
                        "never the Adasum span")
        ap.add_argument("--shrink-threshold", type=float, default=None,
                        dest="shrink_threshold",
                        help="adaptive shrink band: halve while ema "
                        "noise_scale < threshold * global_batch (0 = off)")
        ap.add_argument("--min-global-batch", type=int, default=None,
                        dest="min_global_batch",
                        help="adaptive controller shrink floor (0 = span "
                        "floor only)")
        ap.add_argument("--lr-rescale", default=None, dest="lr_rescale",
                        choices=["adascale", "linear", "none"],
                        help="LR rule at an adaptive resize")
        ap.add_argument("--noise-ema", type=float, default=None,
                        dest="noise_ema",
                        help="noise-scale EMA decay in [0, 1)")
        ap.add_argument("--max-slots", type=int, default=None,
                        dest="max_slots",
                        help="serving: continuous-batching slot pool size")
        ap.add_argument("--max-len", type=int, default=None, dest="max_len",
                        help="serving: per-slot cache capacity (0 => seq)")
        ap.add_argument("--hot-reload", action="store_true", default=None,
                        dest="hot_reload",
                        help="serving: pick up new checkpoints mid-stream")
        ap.add_argument("--prefill-mode", default=None, dest="prefill_mode",
                        choices=["auto", "parallel", "scan"])
        ap.add_argument("--kv-layout", default=None, dest="kv_layout",
                        choices=["paged", "dense"],
                        help="serving: paged KV arena (default) or dense "
                        "per-slot buffers")
        ap.add_argument("--page-size", type=int, default=None,
                        dest="page_size",
                        help="serving: token rows per KV page")
        ap.add_argument("--kv-pages", type=int, default=None,
                        dest="kv_pages",
                        help="serving: physical pages in the KV arena "
                        "(0 = enough for every slot at full capacity)")
        ap.add_argument("--no-prefix-sharing", action="store_true",
                        help="serving: disable shared-prefix page reuse")
        ap.add_argument("--speculation-k", type=int, default=None,
                        dest="speculation_k",
                        help="serving: draft tokens proposed + verified "
                        "per tick (0 = plain decode)")
        ap.add_argument("--draft-preset", default=None, dest="draft_preset",
                        help="serving: draft model arch preset for "
                        "speculation (default: auto-derived shrunken "
                        "target); honors --reduced")
        ap.add_argument("--pressure-ladder", action="store_true",
                        default=None, dest="pressure_ladder",
                        help="serving: graceful degradation under "
                        "kv/queue pressure (no-spec -> no-admit -> "
                        "preempt)")
        args, extra = ap.parse_known_args(argv)
        if extra:
            raise SystemExit(f"unknown arguments: {extra}")

        cfg = cls.preset(args.arch or defaults.get("arch", ""))
        over: Dict[str, Any] = dict(defaults)
        for f in dataclasses.fields(cls):
            v = getattr(args, f.name, None)
            if v is not None:
                over[f.name] = v
        if args.no_per_layer:
            over["per_layer"] = False
        if args.no_fused_combine:
            over["fused_combine"] = False
        if args.no_prefetch:
            over["prefetch"] = False
        if args.sync_checkpoint:
            over["async_checkpoint"] = False
        if args.no_prefix_sharing:
            over["prefix_sharing"] = False
        if args.no_combine_stats:
            over["combine_stats"] = False
        if args.no_grow_span:
            over["grow_span"] = False
        if getattr(args, "draft_preset", None):
            over["draft_config"] = {"arch": args.draft_preset,
                                    "reduced": cfg.reduced
                                    or bool(over.get("reduced"))}
        # Local CLI runs ride small host meshes: FSDP/ZeRO-2 presets from
        # the pod-scale table are switched off (as launch/train.py always
        # did) unless explicitly re-enabled via defaults.
        over.setdefault("fsdp", False)
        over.setdefault("scatter_grads", False)
        return dataclasses.replace(cfg, **over).validate()


# Per-arch presets — absorbed from parallel/policy._POLICIES. Derived from
# the 16 GB/chip v5e budget (DESIGN.md §4): small/medium archs run
# paper-pure RVH (one lane per DP rank); the huge ones run hierarchical
# (§4.2.2): sum inside a lane group, Adasum across `span` groups.
_PRESETS: Dict[str, Dict[str, Any]] = {
    "hymba_1p5b":            dict(backend="rvh", pad_heads=True),
    "moonshot_v1_16b_a3b":   dict(span=4, fsdp=True, scatter_grads=True,
                                  backend="gspmd_tree"),
    "mixtral_8x22b":         dict(span=2, fsdp=True, scatter_grads=True,
                                  backend="gspmd_tree",
                                  param_dtype="bfloat16", attn_chunk=256,
                                  accum_steps=8, accum_dtype="bfloat16",
                                  opt_state_dtype="bfloat16",
                                  pad_heads=True),
    "llava_next_34b":        dict(span=4, fsdp=True, scatter_grads=True,
                                  backend="gspmd_tree", accum_steps=4,
                                  pad_heads=True),
    "gemma_7b":              dict(backend="rvh"),
    "minitron_4b":           dict(backend="rvh", pad_heads=True),
    "minicpm3_4b":           dict(backend="rvh"),
    "qwen3_32b":             dict(span=4, fsdp=True, scatter_grads=True,
                                  backend="gspmd_tree", accum_steps=4,
                                  pad_heads=True),
    "seamless_m4t_large_v2": dict(backend="rvh"),
    "rwkv6_7b":              dict(backend="rvh"),
}


def preset_policy(arch: str) -> RunPolicy:
    """RunPolicy view of the preset table (compat for get_policy)."""
    return EngineConfig.preset(arch).run_policy()
