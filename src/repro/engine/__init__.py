"""repro.engine — the single public API of this reproduction.

    from repro.engine import EngineConfig, TrainSession
    session = TrainSession.from_config(
        EngineConfig(arch="hymba-1p5b", reduced=True, combine="adasum"))
    session.fit(100)

Layers:
  config    EngineConfig — one round-trippable config (policy + combiner
            + data + optimizer + checkpointing + pipeline + serving
            knobs) with per-arch presets
  registry  string-keyed combiner registry (@register_combiner)
  build     build_runtime — model + mesh + policy -> step functions
  session   TrainSession / ServeSession + callback hooks
  pipeline  StepPipeline (prefetch + async-checkpoint overlapped loop)
            and fit_elastic (straggler flag -> halve-DP restart driver)
  serving   ServeEngine — request-level serving: continuous batching
            over a slotted KV cache, fused prefill, checkpoint
            hot-reload (GenerationRequest / RequestHandle surface)
"""
from .config import EngineConfig
from .registry import (available_combiners, get_combiner_factory,
                       make_combiner, register_combiner, registry_key)
from .build import (EngineWarning, Runtime, build_runtime,
                    make_batched_decode_step, make_serve_step)
from .session import (Callback, CheckpointCallback, FailureInjectionCallback,
                      LoggingCallback, ServeSession, StragglerCallback,
                      TrainSession, default_callbacks)
from .pipeline import StepPipeline, fit_elastic
from .serving import (GenerationRequest, HotReloader, PressureLadder,
                      RequestHandle, ServeEngine)

__all__ = [
    "EngineConfig", "TrainSession", "ServeSession",
    "ServeEngine", "GenerationRequest", "RequestHandle", "HotReloader",
    "PressureLadder",
    "register_combiner", "make_combiner", "available_combiners",
    "get_combiner_factory", "registry_key",
    "build_runtime", "make_serve_step", "make_batched_decode_step",
    "Runtime", "EngineWarning",
    "Callback", "LoggingCallback", "CheckpointCallback",
    "StragglerCallback", "FailureInjectionCallback", "default_callbacks",
    "StepPipeline", "fit_elastic",
]
