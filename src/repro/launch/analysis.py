"""Roofline analysis from compiled dry-run artifacts (no real hardware).

Three terms per (arch x shape x mesh), all PER-DEVICE quantities (the
compiled module is the per-device SPMD program):

    compute_s    = device_FLOPs / peak_FLOPs            (197 TFLOP/s bf16)
    memory_s     = device_HBM_bytes / HBM_bw            (819 GB/s)
    collective_s = device_collective_bytes / link_bw    (~50 GB/s/link)

collective_bytes comes from parsing the optimized HLO: the sum of operand
sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops (start/done fusions included).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional

# v5e-class hardware constants (from the brief)
PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
LINK_BW = 50e9               # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per collective kind: op count + operand bytes summed."""
    stats: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        # operand shapes: everything after the op's opening paren
        tail = line[m.end():]
        opnd = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(tail))
        if opnd == 0:   # fall back to output shape(s) before the '='
            head = line[:m.start()]
            opnd = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))
        st = stats.setdefault(kind, {"count": 0, "bytes": 0.0})
        st["count"] += 1
        st["bytes"] += opnd
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float                  # per-device
    hbm_bytes: float              # per-device
    collective_bytes: float       # per-device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    collectives: Dict[str, Dict[str, float]]
    model_flops_global: float = 0.0
    useful_ratio: float = 0.0     # MODEL_FLOPS / (device_FLOPs * chips)
    xla_flops_once: float = 0.0   # raw cost_analysis (loop bodies once)

    def to_json(self):
        return dataclasses.asdict(self)


def analyze(compiled, hlo_text: str, *, n_chips: int,
            model_flops_global: float = 0.0) -> Roofline:
    # Trip-count-aware HLO analysis (XLA's cost_analysis counts while
    # bodies once — see hlo_cost.py). xla_flops is kept for reference.
    from . import hlo_cost
    cost = compiled.cost_analysis()
    if isinstance(cost, list):   # older API returned [dict]
        cost = cost[0]
    hc = hlo_cost.analyze_text(hlo_text)
    flops = hc.flops
    hbm = hc.bytes
    colls = hc.colls
    # wire-byte convention: what actually crosses links per rank (the
    # operand-size sum is kept alongside in `collectives`)
    cbytes = hc.coll_wire_bytes
    terms = {
        "compute": flops / PEAK_FLOPS,
        "memory": hbm / HBM_BW,
        "collective": cbytes / LINK_BW,
    }
    dominant = max(terms, key=terms.get)
    useful = (model_flops_global / (flops * n_chips)
              if flops > 0 and model_flops_global else 0.0)
    r = Roofline(flops, hbm, cbytes, terms["compute"], terms["memory"],
                 terms["collective"], dominant, colls,
                 model_flops_global, useful)
    r.xla_flops_once = float(cost.get("flops", 0.0))
    return r


def memory_summary(compiled) -> Dict[str, float]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:          # pragma: no cover
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    out["total_hbm_bytes"] = (out.get("argument_size_in_bytes", 0)
                              + out.get("output_size_in_bytes", 0)
                              + out.get("temp_size_in_bytes", 0)
                              - out.get("alias_size_in_bytes", 0))
    return out


def model_flops(cfg, cell) -> float:
    """MODEL_FLOPS: 6·N·D for training (N=params — active for MoE), 2·N·D
    for prefill, 2·N per token for decode."""
    from repro.models.api import count_params
    n = count_params(cfg, active_only=bool(cfg.n_experts))
    if cfg.is_encoder_decoder or cfg.frontend == "vision":
        tokens = cell.global_batch * cell.seq_len   # budget across enc+dec
    else:
        tokens = cell.global_batch * cell.seq_len
    if cell.kind == "train":
        return 6.0 * n * tokens
    if cell.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * cell.global_batch      # one token per sequence
