"""Batched serving driver: prefill a batch of prompts, then greedy-decode.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.models import build_model
from repro.parallel import make_serve_step
from repro.launch.mesh import make_local_mesh


def generate(model, params, prompts, gen_len: int, max_len: int,
             frontend_embeds=None):
    """prompts: [B, T] int32. Returns [B, T+gen_len]."""
    B, T = prompts.shape
    cfg = model.cfg
    if cfg.is_encoder_decoder:
        cache = model.init_cache(params, B, max_len,
                                 frontend_embeds=frontend_embeds)
    else:
        cache = model.init_cache(params, B, max_len)
    step = jax.jit(make_serve_step(model), donate_argnums=(2,))
    # prefill by stepping tokens (cache-exact; a fused prefill is the
    # prefill_32k dry-run path)
    tok = prompts[:, :1]
    out = [prompts]
    for t in range(T):
        nxt, cache = step(params, prompts[:, t:t + 1], cache)
    cur = nxt
    gen = []
    for _ in range(gen_len):
        gen.append(cur)
        cur, cache = step(params, cur, cache)
    return jnp.concatenate([prompts] + gen, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--data-mesh", type=int, default=0)
    ap.add_argument("--model-mesh", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg, attn_chunk=64)
    mesh = make_local_mesh(args.data_mesh or 1, args.model_mesh)
    params = model.init(jax.random.key(0))
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    fe = None
    if cfg.frontend != "none":
        ft = cfg.frontend_tokens or args.prompt_len
        fe = jnp.zeros((args.batch, ft, cfg.frontend_dim), jnp.float32)
    t0 = time.perf_counter()
    out = generate(model, params, prompts,
                   args.gen, args.prompt_len + args.gen + 1,
                   frontend_embeds=fe)
    dt = time.perf_counter() - t0
    toks = args.batch * args.gen
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. prefill+compile)")
    print(out[:, args.prompt_len:])
    return out


if __name__ == "__main__":
    main()
