"""Batched serving driver — a thin CLI over `repro.engine.ServeSession`.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-7b --reduced \
        --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.engine import EngineConfig, ServeSession


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--data-mesh", type=int, default=0)
    ap.add_argument("--model-mesh", type=int, default=1)
    args = ap.parse_args(argv)

    cfg = EngineConfig(arch=args.arch, reduced=args.reduced,
                       data_mesh=args.data_mesh, model_mesh=args.model_mesh)
    session = ServeSession.from_config(cfg)
    mcfg = session.model.cfg
    prompts = jax.random.randint(jax.random.key(1),
                                 (args.batch, args.prompt_len), 0,
                                 mcfg.vocab_size)
    fe = None
    if mcfg.frontend != "none":
        ft = mcfg.frontend_tokens or args.prompt_len
        fe = jnp.zeros((args.batch, ft, mcfg.frontend_dim), jnp.float32)
    t0 = time.perf_counter()
    out = session.generate(prompts, args.gen,
                           max_len=args.prompt_len + args.gen + 1,
                           frontend_embeds=fe)
    dt = time.perf_counter() - t0
    toks = args.batch * args.gen
    print(f"[serve] generated {out.shape} in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s incl. prefill+compile)")
    print(out[:, args.prompt_len:])
    return out


if __name__ == "__main__":
    main()
