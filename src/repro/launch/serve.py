"""Request-level serving driver — a thin CLI over `ServeEngine`.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-32b --reduced \
        --requests 3 --prompt-len 32 --gen 16 --max-slots 2 --stagger 2

Submits `--requests` synthetic prompts (lengths jittered around
--prompt-len, arrivals staggered by --stagger decode ticks), drives the
continuous-batching engine to completion, and prints the throughput
fields (`completed=`, `tok_s=`, ...). With --ckpt-dir it serves the
trained weights from the latest checkpoint; add --hot-reload to pick up
new checkpoints mid-stream. `--legacy` runs the old batch-synchronous
`ServeSession.generate` stepped loop instead (same workload) for
comparison.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np
import jax.numpy as jnp

from repro.engine import (EngineConfig, GenerationRequest, ServeEngine,
                          ServeSession)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--max-slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=0,
                    help="slot capacity (0 => prompt+gen+1)")
    ap.add_argument("--stagger", type=int, default=1,
                    help="decode ticks between request arrivals")
    ap.add_argument("--prefill-mode", default="auto",
                    choices=["auto", "parallel", "scan"])
    ap.add_argument("--kv-layout", default="paged",
                    choices=["paged", "dense"],
                    help="paged KV arena (default) or dense per-slot "
                    "buffers")
    ap.add_argument("--page-size", type=int, default=16, dest="page_size",
                    help="token rows per KV page")
    ap.add_argument("--kv-pages", type=int, default=0, dest="kv_pages",
                    help="physical pages in the KV arena (0 = enough for "
                    "every slot at full capacity)")
    ap.add_argument("--no-prefix-sharing", action="store_true",
                    help="disable shared-prefix page reuse")
    ap.add_argument("--system-prompt", type=int, default=0,
                    dest="system_prompt",
                    help="prepend this many shared system-prompt tokens "
                    "to every request (exercises prefix sharing)")
    ap.add_argument("--speculation-k", type=int, default=0,
                    dest="speculation_k",
                    help="draft tokens per speculation tick (0 = off); "
                    "greedy requests only")
    ap.add_argument("--draft-preset", default="", dest="draft_preset",
                    help="registry arch for the draft model (default: "
                    "auto-shrunk target)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy argmax (default); >0 samples")
    ap.add_argument("--top-k", type=int, default=0, dest="top_k",
                    help="top-k truncation (0 = off)")
    ap.add_argument("--top-p", type=float, default=1.0, dest="top_p",
                    help="nucleus truncation (1.0 = off)")
    ap.add_argument("--sample-seed", type=int, default=None,
                    dest="sample_seed",
                    help="base sampling seed (default: per request_id)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    dest="deadline_s",
                    help="per-request wall-clock deadline in seconds "
                    "(0 = none); past it the request fails terminally")
    ap.add_argument("--max-retries", type=int, default=None,
                    dest="max_retries",
                    help="preemption retry budget per request (default: "
                    "unlimited)")
    ap.add_argument("--pressure-ladder", action="store_true",
                    dest="pressure_ladder",
                    help="graceful degradation under kv/queue pressure: "
                    "shed speculation, pause admissions, preempt")
    ap.add_argument("--ckpt-dir", default="", dest="ckpt_dir")
    ap.add_argument("--hot-reload", action="store_true", dest="hot_reload")
    ap.add_argument("--legacy", action="store_true",
                    help="old ServeSession.generate stepped loop")
    ap.add_argument("--data-mesh", type=int, default=0)
    ap.add_argument("--model-mesh", type=int, default=1)
    args = ap.parse_args(argv)

    max_len = args.max_len or (args.system_prompt + args.prompt_len
                               + args.gen + 1)
    if max_len <= args.gen + args.system_prompt:
        ap.error(f"--max-len {max_len} leaves no room for a prompt "
                 f"beyond --system-prompt {args.system_prompt} + --gen "
                 f"{args.gen} tokens")
    draft_config = None
    if args.draft_preset:
        draft_config = {"arch": args.draft_preset, "reduced": args.reduced}
    cfg = EngineConfig(arch=args.arch, reduced=args.reduced,
                       data_mesh=args.data_mesh, model_mesh=args.model_mesh,
                       max_slots=args.max_slots, max_len=max_len,
                       prefill_mode=args.prefill_mode,
                       kv_layout=args.kv_layout, page_size=args.page_size,
                       kv_pages=args.kv_pages,
                       prefix_sharing=not args.no_prefix_sharing,
                       speculation_k=args.speculation_k,
                       draft_config=draft_config,
                       pressure_ladder=args.pressure_ladder,
                       ckpt_dir=args.ckpt_dir,
                       hot_reload=args.hot_reload).validate()
    rng = np.random.RandomState(1)

    from repro.configs.base import get_config, get_reduced
    mcfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    stepped_only = mcfg.is_encoder_decoder or mcfg.frontend != "none"
    if args.legacy or stepped_only:
        if stepped_only and not args.legacy:
            print(f"[serve] {mcfg.name}: frontend/enc-dec archs serve "
                  f"through the stepped batch path")
        session = ServeSession.from_config(cfg)
        mcfg = session.model.cfg
        V = mcfg.vocab_size
        prompts = rng.randint(0, V, (args.requests, args.prompt_len))
        fe = None
        if mcfg.frontend != "none":
            ft = mcfg.frontend_tokens or args.prompt_len
            fe = jnp.zeros((args.requests, ft, mcfg.frontend_dim),
                           jnp.float32)
        t0 = time.perf_counter()
        out = session.generate(jnp.asarray(prompts), args.gen,
                               max_len=max_len, frontend_embeds=fe,
                               stepped_prefill=True)
        wall = time.perf_counter() - t0
        toks = args.requests * args.gen
        print(f"[serve] legacy completed={args.requests} "
              f"generated_tokens={toks} wall_s={wall:.2f} "
              f"tok_s={toks / wall:.1f}")
        print(np.asarray(out)[:, args.prompt_len:])
        return out

    engine = ServeEngine.from_config(cfg)
    V = engine.model.cfg.vocab_size
    if engine.loaded_step is not None:
        print(f"[serve] serving checkpoint step {engine.loaded_step} "
              f"from {cfg.ckpt_dir}")

    def stream(handle, token):
        if len(handle.tokens) == 1:
            dt = handle.first_token_at - handle.submitted_at
            print(f"[serve] req {handle.request.request_id} first token "
                  f"after {dt * 1e3:.0f}ms (slot {handle.slot})")

    system = rng.randint(0, V, args.system_prompt)
    handles = []
    for i in range(args.requests):
        # staggered arrivals at jittered prompt lengths: the continuous-
        # batching case (admit into a running batch, retire independently)
        plen = max(1, min(args.prompt_len + int(rng.randint(-4, 5)),
                          max_len - args.gen - args.system_prompt))
        prompt = np.concatenate([system, rng.randint(0, V, plen)])
        seed = None if args.sample_seed is None else args.sample_seed + i
        handles.append(engine.submit(GenerationRequest(
            prompt=prompt, max_new_tokens=args.gen,
            temperature=args.temperature, top_k=args.top_k,
            top_p=args.top_p, seed=seed, stream=stream,
            deadline_s=args.deadline_s or None,
            max_retries=args.max_retries)))
        for _ in range(args.stagger):
            engine.step()
    engine.drain()

    tp = engine.throughput()
    lat = {k: tp.pop(k) for k in list(tp)
           if k.startswith(("ttft_", "tpot_"))}
    res = {k: tp.pop(k) for k in
           ("failed", "deadline_kills", "retries", "drained",
            "restore_fallbacks", "degradation_level",
            "degradation_changes", "ladder_preempts") if k in tp}
    fields = " ".join(
        f"{k}={v:.2f}" if isinstance(v, float) else f"{k}={v}"
        for k, v in tp.items())
    print(f"[serve] {fields}")
    print("[serve] resilience " + " ".join(
        f"{k}={v}" for k, v in res.items()))
    if lat:
        print("[serve] latency " + " ".join(
            f"{k[:-2]}_ms={v * 1e3:.1f}" for k, v in lat.items()))
    if args.speculation_k:
        kv = engine.kv_stats()
        print(f"[serve] spec k={args.speculation_k} "
              f"ticks={tp.get('spec_ticks', 0)} "
              f"proposed={tp.get('spec_tokens_proposed', 0)} "
              f"accepted={tp.get('spec_tokens_accepted', 0)} "
              f"acceptance={kv.get('spec_acceptance_rate', 0.0):.3f} "
              f"dispatches_per_token="
              f"{tp.get('dispatches_per_token', 0.0):.3f}")
    kv = engine.kv_stats()
    print(f"[serve] kv layout={kv['kv_layout']} "
          f"in_use={kv['kv_bytes_in_use']} peak={kv['peak_kv_bytes_in_use']} "
          f"capacity={kv['kv_capacity_bytes']} "
          f"pages={kv['kv_pages_used']}/{kv['kv_pages_used'] + kv['kv_pages_free']} "
          f"prefix_hits={kv['prefix_hits']} "
          f"prefix_tokens_reused={kv['prefix_tokens_reused']} "
          f"cow={kv['cow_copies']} preemptions={kv['preemptions']}")
    for h in handles:
        print(f"[serve] req {h.request.request_id} "
              f"({h.finish_reason}): {h.tokens}")
    # every submitted request must be terminal (completed or, with
    # deadlines/retry budgets in force, failed) — never hung
    terminal = tp["completed"] + res.get("failed", 0)
    if terminal != args.requests:
        print(f"[serve] ERROR: {terminal}/{args.requests} terminal",
              file=sys.stderr)
        sys.exit(1)
    if tp["completed"] != args.requests and not (
            args.deadline_s or args.max_retries is not None):
        print(f"[serve] ERROR: {tp['completed']}/{args.requests} completed",
              file=sys.stderr)
        sys.exit(1)
    return handles


if __name__ == "__main__":
    main()
