"""Assigned input-shape cells and their ShapeDtypeStruct stand-ins.

Shapes (per the assignment):
    train_4k     seq_len=4096   global_batch=256   (training step)
    prefill_32k  seq_len=32768  global_batch=32    (inference prefill)
    decode_32k   seq_len=32768  global_batch=128   (one-token decode w/ cache)
    long_500k    seq_len=524288 global_batch=1     (long-context decode)

long_500k requires sub-quadratic attention: it RUNS for rwkv6 (attn-free),
hymba (SWA+SSM) and mixtral (SWA); it's a SKIP cell for the pure
full-attention archs (see DESIGN.md §Arch-applicability).

VLM/audio cells: the modality frontend is a stub — specs deliver
precomputed patch/frame embeddings. For the enc-dec arch the sequence
budget is split half encoder frames / half decoder tokens.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.models.api import Model

S = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524_288, 1),
}


def cell_supported(cfg: ModelConfig, cell: ShapeCell) -> Tuple[bool, str]:
    if cell.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention (SKIP per DESIGN.md)"
    return True, ""


def train_batch_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    B, T = cell.global_batch, cell.seq_len
    if cfg.is_encoder_decoder:
        half = T // 2
        return {
            "frontend_embeds": S((B, half, cfg.frontend_dim), jnp.bfloat16),
            "tokens": S((B, half), jnp.int32),
            "labels": S((B, half), jnp.int32),
        }
    if cfg.frontend == "vision":
        t_text = T - cfg.frontend_tokens
        return {
            "frontend_embeds": S((B, cfg.frontend_tokens, cfg.frontend_dim),
                                 jnp.bfloat16),
            "tokens": S((B, t_text), jnp.int32),
            "labels": S((B, t_text), jnp.int32),
        }
    return {"tokens": S((B, T), jnp.int32), "labels": S((B, T), jnp.int32)}


def prefill_batch_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    specs = train_batch_specs(cfg, cell)
    specs.pop("labels", None)
    return specs


def decode_input_specs(model: Model, cfg: ModelConfig, cell: ShapeCell
                       ) -> Tuple[Any, Any, Any]:
    """(params_shapes, tokens_spec, cache_shapes) for a one-token decode
    step against a cache of cell.seq_len."""
    B = cell.global_batch
    params = jax.eval_shape(model.init, jax.random.key(0))
    tokens = S((B, 1), jnp.int32)
    if cfg.is_encoder_decoder:
        enc_len = cell.seq_len // 2
        enc_out = S((B, enc_len, cfg.d_model), jnp.bfloat16)
        cache = jax.eval_shape(
            lambda p, e: model.init_cache(p, B, cell.seq_len // 2, enc_out=e),
            params, enc_out)
    else:
        cache = jax.eval_shape(
            lambda p: model.init_cache(p, B, cell.seq_len), params)
    return params, tokens, cache


def input_specs(arch: str, shape: str, model: Optional[Model] = None):
    """Public entry: ShapeDtypeStruct stand-ins for every model input of the
    (arch x shape) cell."""
    from repro.models import build_model
    cfg = get_config(arch)
    cell = SHAPES[shape]
    model = model or build_model(cfg)
    if cell.kind == "train":
        return train_batch_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_batch_specs(cfg, cell)
    return decode_input_specs(model, cfg, cell)
