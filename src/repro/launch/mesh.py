"""Production meshes. Functions (not module constants) so importing never
touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16,16) = (data, model).
    Multi-pod: 2 pods x 256 chips (2,16,16) = (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Dev/test mesh over whatever devices exist."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
