"""Production meshes. Functions (not module constants) so importing never
touches jax device state."""
from __future__ import annotations

import jax


def make_mesh_compat(shape, axes):
    """jax.make_mesh across jax versions: `axis_types` (and
    jax.sharding.AxisType itself) only exist on newer jax; older releases
    behave as Auto on every axis, which is what we want everywhere."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(axis_type.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 256 chips (16,16) = (data, model).
    Multi-pod: 2 pods x 256 chips (2,16,16) = (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Dev/test mesh over whatever devices exist."""
    n = len(jax.devices())
    if data * model > n:
        data, model = n, 1
    return make_mesh_compat((data, model), ("data", "model"))
