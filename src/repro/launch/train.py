"""End-to-end training driver — a thin CLI over `repro.engine`.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b \
        --steps 200 --seq 256 --batch 16 --data-mesh 4 --model-mesh 2 \
        --combine adasum --optimizer adam --ckpt-dir runs/q3

Runs on whatever devices exist (use XLA_FLAGS host-device-count for local
multi-device runs). Fault-tolerant: periodic atomic checkpoints (written
off-thread; --sync-checkpoint to block), SIGTERM save, resume from
latest, prefetched batches (--no-prefetch for the serial loop),
straggler monitor, optional injected failures for drills, --elastic
for the checkpoint + halve-DP restart driver, and --adaptive-batch for
the gradient-noise-adaptive batch/span grow driver (repro.control). All of that lives in
`repro.engine` (TrainSession + pipeline); this module only parses flags
and forwards.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

from repro.engine import (EngineConfig, TrainSession, default_callbacks,
                          fit_elastic)


def main(argv=None):
    # the two driver-only flags ride in front of the EngineConfig CLI
    ap = argparse.ArgumentParser(add_help=False)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (recovery drill)")
    ap.add_argument("--metrics-out", default=None)
    args, engine_argv = ap.parse_known_args(argv)

    cfg = EngineConfig.from_cli(engine_argv)
    callbacks = default_callbacks(cfg, fail_at=args.fail_at)
    if cfg.adaptive_batch:
        from repro.control import fit_adaptive
        history, session = fit_adaptive(cfg, cfg.steps, callbacks=callbacks)
    elif cfg.elastic:
        history, session = fit_elastic(cfg, cfg.steps, callbacks=callbacks)
    else:
        session = TrainSession.from_config(cfg, callbacks=callbacks)
        history = session.fit(cfg.steps)
    session.close()
    if history:
        print(f"[train] done: final loss {history[-1]['loss']:.4f}")
    else:
        print(f"[train] nothing to do: run already at step {cfg.steps}")
    res = session.run_metadata().get("resilience", {})
    if any(res.get(k) for k in ("restore_fallbacks", "quarantined_steps",
                                "restarts", "grow_backs")):
        print("[train] resilience " + " ".join(
            f"{k}={len(v) if isinstance(v, list) else v}"
            for k, v in res.items()))
    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps(history))
    return history


if __name__ == "__main__":
    main()
