"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b \
        --steps 200 --seq 256 --batch 16 --data-mesh 4 --model-mesh 2 \
        --combine adasum --optimizer adam --ckpt-dir runs/q3

Runs on whatever devices exist (use XLA_FLAGS host-device-count for local
multi-device runs). Fault-tolerant: periodic atomic checkpoints, SIGTERM
save, resume from latest, straggler monitor, optional injected failures
for drills.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import get_config, get_reduced
from repro.models import build_model
from repro.parallel import make_runtime, get_policy
from repro.parallel.policy import RunPolicy
from repro.data import DataConfig, make_source
from repro.checkpoint import CheckpointManager
from repro.runtime import StepMonitor, FailureInjector
from repro.launch.mesh import make_local_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", default=None)
    ap.add_argument("--combine", default="adasum",
                    choices=["adasum", "sum", "mean"])
    ap.add_argument("--backend", default=None)
    ap.add_argument("--span", type=int, default=None)
    ap.add_argument("--local-steps", type=int, default=1)
    ap.add_argument("--data-mesh", type=int, default=0)
    ap.add_argument("--model-mesh", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--fail-at", type=int, nargs="*", default=[],
                    help="inject failures at these steps (recovery drill)")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    model = build_model(cfg, attn_chunk=min(512, args.seq))

    data_size = args.data_mesh or max(1, len(jax.devices())
                                      // args.model_mesh)
    mesh = make_local_mesh(data_size, args.model_mesh)

    rpol = get_policy(args.arch)
    rpol = dataclasses.replace(
        rpol,
        combine_op=args.combine,
        span=args.span if args.span is not None else rpol.span,
        local_steps=args.local_steps,
        optimizer=args.optimizer or rpol.optimizer,
        backend=args.backend or rpol.backend,
        fsdp=False, scatter_grads=False)
    # local meshes are small; span can't exceed dp
    dp = int(np.prod([s for a, s in zip(mesh.axis_names, mesh.devices.shape)
                      if a != "model"]))
    if rpol.span > dp or rpol.span == 0:
        rpol = dataclasses.replace(rpol, span=0)
    if args.batch % max(rpol.span or dp, 1):
        raise SystemExit(f"batch {args.batch} not divisible by span")

    rt = make_runtime(model, mesh, rpol, lr=args.lr)
    state = rt.init_state(jax.random.key(0))

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start_step = 0
    if ckpt and ckpt.latest_step() is not None:
        state = ckpt.restore(state)
        start_step = int(jax.device_get(state["step"]))
        print(f"[train] resumed from step {start_step}")

    dcfg = DataConfig(seq_len=args.seq, global_batch=args.batch,
                      vocab_size=cfg.vocab_size)
    source = make_source(dcfg, cfg)
    step_fn = jax.jit(rt.train_step, donate_argnums=(0,))
    monitor = StepMonitor()
    injector = FailureInjector(args.fail_at)
    if ckpt:
        ckpt.install_preemption_handler(
            lambda: ckpt.save(int(jax.device_get(state["step"])), state))

    history = []
    for step in range(start_step, args.steps):
        batch = {k: jnp.asarray(v) for k, v in source.batch(step).items()}
        injector.check(step)
        monitor.start()
        state, metrics = step_fn(state, batch)
        loss = float(jax.device_get(metrics["loss"]))
        dt = monitor.stop()
        history.append({"step": step, "loss": loss, "s": dt})
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {loss:.4f} {dt*1e3:.0f}ms "
                  f"span={rt.span} combine={rpol.combine_op}")
        if ckpt and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, state)
    if ckpt:
        ckpt.save(args.steps, state)
    print(f"[train] done: final loss {history[-1]['loss']:.4f} "
          f"monitor={monitor.summary()}")
    if args.metrics_out:
        Path(args.metrics_out).write_text(json.dumps(history))
    return history


if __name__ == "__main__":
    main()
