import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell on
the production meshes with ShapeDtypeStruct inputs (no allocation), print
memory_analysis + cost_analysis, and dump the roofline terms to JSON.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape train_4k [--multi-pod] [--out results/dryrun]
    PYTHONPATH=src python -m repro.launch.dryrun --all

Exit code != 0 on any failed cell — failures (sharding mismatch, OOM at
compile, unsupported collective) are bugs in the system.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ARCH_IDS, get_config, canonical, \
    pad_heads_for_tp
from repro.models import build_model
from repro.engine import build_runtime, make_serve_step
from repro.parallel import get_policy
from repro.parallel.sharding import batch_specs, cache_specs, param_specs, \
    ShardingPolicy
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as SP
from repro.launch import analysis as AN


def _shardings(mesh, spec_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def lower_cell(arch: str, shape: str, mesh, *, rpol=None, attn_chunk=None):
    """Lower one cell; returns (lowered, aux_info)."""
    cfg = get_config(arch)
    cell = SP.SHAPES[shape]
    ok, why = SP.cell_supported(cfg, cell)
    if not ok:
        return None, {"status": "SKIP", "reason": why}
    rpol = rpol or get_policy(arch)
    if attn_chunk:
        rpol = dataclasses.replace(rpol, attn_chunk=attn_chunk)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if rpol.pad_heads:
        cfg = pad_heads_for_tp(cfg, sizes.get("model", 1))
    model = build_model(cfg, attn_chunk=rpol.attn_chunk,
                        param_dtype=jnp.dtype(rpol.param_dtype),
                        moe_shards=sizes.get("data", 1))
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")
    dp_total = int(np.prod([sizes[a] for a in dp_axes]))

    if cell.kind == "train":
        rt = build_runtime(model, mesh, rpol)
        bspecs = SP.train_batch_specs(cfg, cell)
        bshard = batch_specs(bspecs, dp_axes)
        state_sh = _shardings(mesh, rt.state_specs)
        fn = jax.jit(rt.train_step,
                     in_shardings=(state_sh, _shardings(mesh, bshard)),
                     donate_argnums=(0,))
        lowered = fn.lower(rt.state_shapes, bspecs)
        return lowered, {"status": "OK", "kind": "train", "span": rt.span}

    spol = ShardingPolicy("model", "data" if rpol.fsdp else None,
                          sizes.get("model", 1), sizes.get("data", 1))
    pshapes = jax.eval_shape(model.init, jax.random.key(0))
    pspecs = param_specs(cfg, pshapes, spol)
    psh = _shardings(mesh, pspecs)

    if cell.kind == "prefill":
        bspecs = SP.prefill_batch_specs(cfg, cell)
        bshard = _shardings(mesh, batch_specs(bspecs, dp_axes))
        fn = jax.jit(model.prefill, in_shardings=(psh, bshard))
        lowered = fn.lower(pshapes, bspecs)
        return lowered, {"status": "OK", "kind": "prefill"}

    # decode
    pshapes2, tok_spec, cshapes = SP.decode_input_specs(model, cfg, cell)
    csh = _shardings(mesh, cache_specs(cshapes, cfg, spol, dp_axes,
                                       cell.global_batch, dp_total))
    tsh = NamedSharding(mesh, P(dp_axes if cell.global_batch % dp_total == 0
                                else None, None))
    serve = make_serve_step(model)
    fn = jax.jit(serve, in_shardings=(psh, tsh, csh), donate_argnums=(2,))
    lowered = fn.lower(pshapes2, tok_spec, cshapes)
    return lowered, {"status": "OK", "kind": "decode"}


def run_cell(arch: str, shape: str, *, multi_pod: bool, out_dir: Path,
             keep_hlo: bool = False) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    tag = f"{canonical(arch)}__{shape}__{mesh_name}"
    res = {"arch": arch, "shape": shape, "mesh": mesh_name}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_chips = int(np.prod(mesh.devices.shape))
        lowered, info = lower_cell(arch, shape, mesh)
        res.update(info)
        if info["status"] == "SKIP":
            print(f"[dryrun] {tag}: SKIP ({info['reason']})")
            return res
        compiled = lowered.compile()
        res["compile_s"] = time.time() - t0
        res["memory"] = AN.memory_summary(compiled)
        hlo = compiled.as_text()
        cfg = get_config(arch)
        cell = SP.SHAPES[shape]
        roof = AN.analyze(compiled, hlo, n_chips=n_chips,
                          model_flops_global=AN.model_flops(cfg, cell))
        res["roofline"] = roof.to_json()
        if keep_hlo:
            (out_dir / f"{tag}.hlo.txt").write_text(hlo)
        print(f"[dryrun] {tag}: OK compile={res['compile_s']:.1f}s "
              f"hbm/dev={res['memory'].get('total_hbm_bytes', 0)/2**30:.2f}GiB "
              f"flops/dev={roof.flops:.3e} coll/dev={roof.collective_bytes:.3e}B "
              f"dominant={roof.dominant}")
    except Exception as e:
        res["status"] = "FAIL"
        res["error"] = f"{type(e).__name__}: {e}"
        res["traceback"] = traceback.format_exc()[-4000:]
        print(f"[dryrun] {tag}: FAIL {res['error']}")
    finally:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / f"{tag}.json").write_text(json.dumps(res, indent=1))
    return res


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--keep-hlo", action="store_true")
    ap.add_argument("--skip-done", action="store_true")
    args = ap.parse_args()
    out = Path(args.out)

    archs = ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(SP.SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) else \
        [args.multi_pod]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                f = out / f"{canonical(arch)}__{shape}__{mesh_name}.json"
                if args.skip_done and f.exists():
                    prev = json.loads(f.read_text())
                    if prev.get("status") in ("OK", "SKIP"):
                        print(f"[dryrun] {f.stem}: cached {prev['status']}")
                        continue
                r = run_cell(arch, shape, multi_pod=mp, out_dir=out,
                             keep_hlo=args.keep_hlo)
                failures += r["status"] == "FAIL"
    print(f"[dryrun] done, failures={failures}")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
