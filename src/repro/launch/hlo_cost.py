"""Mini HLO cost analyzer — trip-count-aware FLOPs / HBM bytes /
collective bytes from optimized HLO text.

Why: XLA's `compiled.cost_analysis()` counts a while-loop body ONCE, so a
scan-over-layers train step under-reports FLOPs by ~n_layers and misses
all in-loop collective traffic. This analyzer parses the partitioned HLO
module, recovers loop trip counts from the loop-condition compare
constants (JAX scans always run 0..N step 1), and recursively weights
while bodies by their trips.

Costs per instruction:
  * dot: exact — 2 x |output| x |contracted dims| (from operand shapes +
    lhs_contracting_dims),
  * convolution: 2 x |output| x |kernel| (unused by our models),
  * fusions / elementwise / reduce: approx 1 flop per output element,
  * HBM bytes: operands + output for compute ops (fusion internals are
    on-chip traffic and deliberately excluded),
  * collectives: operand bytes (summed separately per kind).

All quantities are PER-DEVICE (the module is the SPMD per-device program).
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "f8e4m3": 1, "f8e5m2fnuz": 1, "f8e4m3fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_TOKEN = re.compile(
    r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPNDS = re.compile(r"%([\w.\-]+)")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
# ops whose operands/outputs we do NOT count as HBM traffic.
# NOTE "convert": XLA:CPU promotes bf16 compute to f32, inserting
# whole-tensor converts that DO NOT EXIST on the bf16-native TPU target —
# counting them would inflate the memory term ~2-5x (validated on the
# mixtral/llava cells). Real dtype conversions on TPU fuse into their
# consumers.
_FREE_OPS = {"get-tuple-element", "tuple", "bitcast", "parameter",
             "constant", "partition-id", "replica-id", "after-all",
             "copy-start", "copy-done", "convert", "copy"}


def _shape_info(typestr: str) -> Tuple[int, int]:
    """(total elements, total bytes) over all shape tokens in a type
    string (handles tuple types)."""
    elems = 0
    bts = 0
    for dt, dims in _SHAPE_TOKEN.findall(typestr):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dt]
    return elems, bts


def _first_shape_dims(typestr: str) -> Optional[List[int]]:
    m = _SHAPE_TOKEN.search(typestr)
    if not m:
        return None
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0       # operand-size convention (the brief)
    coll_wire_bytes: float = 0.0  # bytes actually crossing links per rank
    colls: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        self.coll_wire_bytes += other.coll_wire_bytes * mult
        for k, v in other.colls.items():
            st = self.colls.setdefault(k, {"count": 0.0, "bytes": 0.0,
                                           "wire_bytes": 0.0})
            st["count"] += v["count"] * mult
            st["bytes"] += v["bytes"] * mult
            st["wire_bytes"] += v.get("wire_bytes", 0.0) * mult


_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _group_size(rest: str) -> int:
    m = _GROUPS_IOTA.search(rest)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST.search(rest)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


def _wire_bytes(kind: str, operand: float, output: float, n: int) -> float:
    """Per-rank bytes crossing links for a bandwidth-optimal algorithm."""
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-reduce":
        return 2.0 * operand * f
    if kind == "all-gather":
        return max(output, operand) * f
    if kind == "reduce-scatter":
        return operand * f
    if kind == "all-to-all":
        return operand * f
    return operand        # collective-permute: exact


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[Tuple[str, str]]] = {}
        self.entry: Optional[str] = None
        self._parse(text)
        self._memo: Dict[str, Cost] = {}

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            stripped = line.strip()
            m = re.match(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->.*{", line)
            if m and not line.startswith(" "):
                cur = m.group(2)
                self.computations[cur] = []
                if m.group(1):
                    self.entry = cur
                continue
            if stripped == "}":
                continue
            if cur is None:
                continue
            im = _INSTR.match(line)
            if im:
                self.computations[cur].append((im.group(1), im.group(2)))

    # ------------------------------------------------------------- helpers
    def _types_in(self, comp: str) -> Dict[str, str]:
        table = {}
        for name, rest in self.computations.get(comp, []):
            table[name] = rest.split(" ")[0] if rest else ""
            # the type is everything before the op name; safer: first
            # shape-ish prefix — store full rest, _shape_info scans tokens
            table[name] = rest
        return table

    def _out_type(self, rest: str) -> str:
        """The output type part of an instruction body (before op name)."""
        # e.g. "f32[4,64]{1,0} dot(%a, %b), ..." or "(f32[..], f32[..]) while(...)"
        m = re.match(r"^(\([^)]*\)|\S+)\s", rest)
        return m.group(1) if m else rest

    def _trip_count(self, cond_comp: str) -> float:
        """Loop bound from the condition's compare constant (JAX scans
        iterate 0..N-1)."""
        best = 1.0
        for name, rest in self.computations.get(cond_comp, []):
            for c in re.findall(r"constant\((\d+)\)", rest):
                best = max(best, float(c))
        # the cond may call a wrapped fusion computation
        for name, rest in self.computations.get(cond_comp, []):
            cm = re.search(r"calls=%([\w.\-]+)", rest)
            if cm:
                for _, r2 in self.computations.get(cm.group(1), []):
                    for c in re.findall(r"constant\((\d+)\)", r2):
                        best = max(best, float(c))
        return best

    # ------------------------------------------------------------ costing
    def cost_of(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total      # guards cycles
        types = {}
        for name, rest in self.computations.get(comp, []):
            types[name] = self._out_type(rest)
        for name, rest in self.computations.get(comp, []):
            out_type = self._out_type(rest)
            body = rest[len(out_type):].lstrip()
            op = body.split("(")[0].strip()
            out_elems, out_bytes = _shape_info(out_type)

            if op == "while":
                bm = re.search(r"body=%([\w.\-]+)", rest)
                cm = re.search(r"condition=%([\w.\-]+)", rest)
                if bm:
                    trip = self._trip_count(cm.group(1)) if cm else 1.0
                    sub = Cost()
                    sub.add(self.cost_of(bm.group(1)))
                    if cm:
                        sub.add(self.cost_of(cm.group(1)))
                    total.add(sub, trip)
                continue
            if op in ("conditional", "call", "async-start"):
                for cn in re.findall(r"(?:calls|branch_computations)=\{?%?"
                                     r"([\w.\-]+)", rest):
                    total.add(self.cost_of(cn))
                continue

            base = op.replace("-start", "").replace("-done", "")
            opnd_names = _OPNDS.findall(body[body.find("("):]) if "(" in body \
                else []
            opnd_bytes = 0
            opnd_types = []
            for o in opnd_names:
                t = types.get(o)
                if t is None:
                    continue
                ot = self._out_type(t)
                opnd_types.append((o, ot))
                opnd_bytes += _shape_info(ot)[1]

            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue   # counted at -start
                cb = opnd_bytes or out_bytes
                wire = _wire_bytes(base, cb, out_bytes, _group_size(rest))
                st = total.colls.setdefault(
                    base, {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0})
                st["count"] += 1
                st["bytes"] += cb
                st["wire_bytes"] += wire
                total.coll_bytes += cb
                total.coll_wire_bytes += wire
                total.bytes += opnd_bytes + out_bytes
                continue

            if op in _FREE_OPS:
                continue

            if op == "dot":
                lhs_dims = None
                if opnd_types:
                    lhs_dims = _first_shape_dims(opnd_types[0][1])
                contract = 1
                cm2 = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rest)
                if cm2 and lhs_dims:
                    for d in cm2.group(1).split(","):
                        if d:
                            contract *= lhs_dims[int(d)]
                total.flops += 2.0 * out_elems * contract
                total.bytes += opnd_bytes + out_bytes
                continue
            if op == "convolution":
                kernel = _first_shape_dims(opnd_types[1][1]) \
                    if len(opnd_types) > 1 else [1]
                total.flops += 2.0 * out_elems * \
                    (math.prod(kernel[:-1]) if kernel else 1)
                total.bytes += opnd_bytes + out_bytes
                continue
            # fusions / elementwise / reduce / scatter / gather ...
            if "calls=%wrapped_convert" in rest or \
                    "calls=%wrapped_copy" in rest:
                continue       # CPU bf16-promotion artifact (see _FREE_OPS)
            total.flops += out_elems
            total.bytes += opnd_bytes + out_bytes
        return total

    def total(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.cost_of(self.entry)


def analyze_text(hlo_text: str) -> Cost:
    return HloModule(hlo_text).total()
