"""rwkv6-7b "Finch" [ssm, attention-free] (arXiv:2404.05892). 32L
d_model=4096 d_ff=14336 vocab=65536, data-dependent per-channel decay,
head size 64 (64 heads). Constant-memory decode state -> runs the
long_500k shape natively."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=0, n_kv_heads=0, d_ff=14336,
    vocab_size=65_536, attn_type="none",
    rwkv_head_dim=64, rwkv_decay_lora=64,
    max_seq_len=524_288,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-reduced", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=192,
        vocab_size=257, attn_type="none",
        rwkv_head_dim=16, rwkv_decay_lora=8,
    )
