"""minicpm3-4b [dense, MLA] (hf:openbmb/MiniCPM3-4B). 62L d_model=2560
40H (kv=40 in the assignment; MLA shares a latent KV) d_ff=6400
vocab=73448. MLA dims from the HF config: q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v_head=64. Decode uses the absorbed-latent path
(compressed cache)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense",
    n_layers=62, d_model=2560, n_heads=40, n_kv_heads=40, d_ff=6400,
    vocab_size=73_448, head_dim=64,
    attn_type="mla", q_lora_rank=768, kv_lora_rank=256,
    qk_rope_head_dim=32, qk_nope_head_dim=64, v_head_dim=64,
    tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minicpm3-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=257, head_dim=16,
        attn_type="mla", q_lora_rank=32, kv_lora_rank=16,
        qk_rope_head_dim=8, qk_nope_head_dim=16, v_head_dim=16,
        tie_embeddings=True,
    )
