"""mixtral-8x22b [moe] (arXiv:2401.04088). 56L d_model=6144 48H (GQA kv=8)
per-expert d_ff=16384 vocab=32768, 8 experts top-2, sliding-window
attention (window 4096 as in the Mistral lineage). Experts are
TP-partitioned on the hidden dim (8 experts don't divide a 16-way model
axis)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32_768, head_dim=128,
    sliding_window=4096,
    n_experts=8, n_experts_per_tok=2, moe_d_ff=16384,
    expert_partition="hidden",
    max_seq_len=524_288,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="mixtral-reduced", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=257, head_dim=16, sliding_window=32,
        n_experts=4, n_experts_per_tok=2, moe_d_ff=128,
        expert_partition="hidden",
    )
