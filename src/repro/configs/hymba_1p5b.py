"""hymba-1.5b [hybrid] — parallel attention + mamba heads per layer
(arXiv:2411.13676). 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
ssm_state=16. Most Hymba layers use sliding-window attention (global attn on
a few layers in the paper); we model the SWA regime (window 1024), which is
what makes the arch sub-quadratic for long_500k."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, head_dim=64,
    sliding_window=1024, ssm_state=16, ssm_heads=25, ssm_conv=4,
    max_seq_len=524_288,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="hymba-reduced", family="hybrid",
        n_layers=2, d_model=80, n_heads=5, n_kv_heads=1, d_ff=160,
        vocab_size=257, head_dim=16,
        sliding_window=32, ssm_state=8, ssm_heads=5, ssm_conv=4,
    )
