"""Assigned architecture configs (+ registry)."""
from .base import ModelConfig, ARCH_IDS, get_config, get_reduced, canonical, all_configs
