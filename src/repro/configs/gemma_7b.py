"""gemma-7b [dense] (arXiv:2403.08295). 28L d_model=3072 16H (kv=16, i.e.
MHA at 7B; the 2B sibling uses MQA) d_ff=24576 GeGLU, head_dim=256,
vocab=256000, tied embeddings."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16, d_ff=24576,
    vocab_size=256_000, head_dim=256,
    mlp_type="geglu", tie_embeddings=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="gemma-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
        vocab_size=257, head_dim=32,
        mlp_type="geglu", tie_embeddings=True,
    )
