"""moonshot-v1-16b-a3b [moe] — Kimi/Moonlight 16B-A3B
(hf:moonshotai/Moonlight-16B-A3B, DeepSeek-V3-style MoE). 48L d_model=2048
16H (GQA kv=16) per-expert d_ff=1408 vocab=163840, 64 routed experts top-6
+ 2 shared experts, first layer dense (per the HF config)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab_size=163_840, head_dim=128,
    n_experts=64, n_experts_per_tok=6, n_shared_experts=2,
    first_dense_layers=1, moe_d_ff=1408, expert_partition="expert",
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="moonshot-reduced", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=96,
        vocab_size=257, head_dim=16,
        n_experts=8, n_experts_per_tok=2, n_shared_experts=1,
        first_dense_layers=1, moe_d_ff=96, expert_partition="expert",
    )
