"""qwen3-32b [dense] (hf:Qwen/Qwen3-32B). 64L d_model=5120 64H (GQA kv=8)
d_ff=25600 vocab=151936, qk-norm, head_dim=128 (q-dim 8192 != d_model)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=64, n_kv_heads=8, d_ff=25600,
    vocab_size=151_936, head_dim=128,
    qk_norm=True,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="qwen3-reduced", family="dense",
        n_layers=2, d_model=64, n_heads=8, n_kv_heads=2, d_ff=192,
        vocab_size=257, head_dim=16,
        qk_norm=True,
    )
