"""Architecture config schema + registry.

One frozen dataclass describes every LM-family architecture in the pool
(dense / MoE / SSM / hybrid / VLM-backbone / audio enc-dec). Each assigned
architecture lives in its own module (`src/repro/configs/<id>.py`) exporting
`CONFIG` (the exact published shape) and `reduced()` (a tiny same-family
variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0             # 0 => d_model // n_heads

    # ---- attention variants ----
    attn_type: str = "gqa"        # gqa | mla | none
    qk_norm: bool = False         # qwen3
    sliding_window: int = 0       # 0 = full attention
    rope_theta: float = 10_000.0
    # MLA (minicpm3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_rope_head_dim: int = 0
    qk_nope_head_dim: int = 0
    v_head_dim: int = 0

    # ---- MLP ----
    mlp_type: str = "swiglu"      # swiglu | geglu

    # ---- MoE ----
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    moe_d_ff: int = 0             # per-expert hidden (defaults to d_ff)
    capacity_factor: float = 1.25
    expert_partition: str = "expert"   # expert | hidden (TP axis placement)

    # ---- SSM / hybrid ----
    ssm_state: int = 0            # mamba N (hymba) / rwkv head size
    ssm_heads: int = 0            # 0 => derived
    ssm_conv: int = 4             # conv window (mamba)
    # rwkv6
    rwkv_head_dim: int = 64
    rwkv_decay_lora: int = 64
    rwkv_mix_lora: int = 32

    # ---- encoder-decoder (seamless) ----
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0

    # ---- modality frontend stubs ----
    frontend: str = "none"        # none | vision | audio
    frontend_dim: int = 0         # embedding dim delivered by the stub
    frontend_tokens: int = 0      # #frontend positions in train seq

    # ---- misc ----
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    max_seq_len: int = 32_768
    orig_heads: int = 0     # >0 => q heads beyond this are TP padding
                            # (their wo rows are zero-init: exact math)

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.attn_type == "none"

    @property
    def subquadratic(self) -> bool:
        """Can this arch run long-context (500k) decode? True for
        attention-free, hybrid-with-SWA and SWA archs."""
        return self.attention_free or self.family in ("ssm", "hybrid") or \
            self.sliding_window > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS=6·N·D)."""
        from repro.models import api
        return api.count_params(self)

    def active_param_count(self) -> int:
        from repro.models import api
        return api.count_params(self, active_only=True)


def pad_heads_for_tp(cfg: ModelConfig, tp: int) -> ModelConfig:
    """TP head alignment (the Megatron trick, exact math — see §Perf):

    * kv heads are block-DUPLICATED by the minimal integer factor making
      kv % tp == 0 (duplicated keys/values attend identically: the GQA
      q->kv mapping is preserved exactly under block repetition);
    * q heads are PADDED up to the next multiple of tp that the new kv
      count divides; padded heads get zero wo rows, contributing exactly
      nothing.

    Without this, archs whose head counts don't divide the model axis
    fall back to contraction sharding: every kv projection psums a full
    [tokens, d] fp32 activation per layer (the dominant collective on the
    mixtral/llava baselines)."""
    import math as _m
    if tp <= 1 or cfg.attention_free or cfg.attn_type == "mla":
        return cfg
    h, kv = cfg.n_heads, cfg.n_kv_heads
    f = tp // _m.gcd(kv, tp)
    kv2 = kv * f
    h2 = h
    while h2 % tp or h2 % kv2:
        h2 += 1
    if (h2, kv2) == (h, kv):
        return cfg
    return dataclasses.replace(cfg, n_heads=h2, n_kv_heads=kv2,
                               orig_heads=cfg.orig_heads or h)


ARCH_IDS: Tuple[str, ...] = (
    "hymba_1p5b", "moonshot_v1_16b_a3b", "mixtral_8x22b", "llava_next_34b",
    "gemma_7b", "minitron_4b", "minicpm3_4b", "qwen3_32b",
    "seamless_m4t_large_v2", "rwkv6_7b",
)

# canonical external ids (with dashes) -> module names
_ALIASES = {
    "hymba-1.5b": "hymba_1p5b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "mixtral-8x22b": "mixtral_8x22b",
    "llava-next-34b": "llava_next_34b",
    "gemma-7b": "gemma_7b",
    "minitron-4b": "minitron_4b",
    "minicpm3-4b": "minicpm3_4b",
    "qwen3-32b": "qwen3_32b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "rwkv6-7b": "rwkv6_7b",
}


def canonical(arch: str) -> str:
    return _ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.CONFIG


def get_reduced(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canonical(arch)}")
    return mod.reduced()


def all_configs():
    return {a: get_config(a) for a in ARCH_IDS}
