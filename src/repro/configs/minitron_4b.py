"""minitron-4b [dense] — pruned Nemotron (arXiv:2407.14679). 32L
d_model=3072 24H (GQA kv=8) d_ff=9216 vocab=256000."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=9216,
    vocab_size=256_000, head_dim=128,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="minitron-reduced", family="dense",
        n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=288,
        vocab_size=257, head_dim=16,
    )
