"""llava-next-34b [vlm] — Nous-Hermes-2-Yi-34B backbone
(hf:llava-hf/llava-v1.6-34b-hf). 60L d_model=7168 56H (GQA kv=8)
d_ff=20480 vocab=64000. The vision tower (anyres tiling) is a STUB:
input_specs() delivers precomputed patch embeddings [B, 576, 1024]
projected by the standard 2-layer MLP connector."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab_size=64_000, head_dim=128,
    frontend="vision", frontend_dim=1024, frontend_tokens=576,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="llava-reduced", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
        vocab_size=257, head_dim=16,
        frontend="vision", frontend_dim=32, frontend_tokens=8,
    )
