"""seamless-m4t-large-v2 [audio, enc-dec] (arXiv:2308.11596). 24L encoder +
24L decoder, d_model=1024 16H (kv=16) d_ff=8192 vocab=256206. The speech
frontend (w2v-BERT conformer stack) is a STUB per the brief: input_specs()
delivers precomputed frame embeddings [B, frames, 1024]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=256_206, head_dim=64,
    is_encoder_decoder=True, n_encoder_layers=24,
    frontend="audio", frontend_dim=1024,
)


def reduced() -> ModelConfig:
    return ModelConfig(
        name="seamless-reduced", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab_size=257, head_dim=16,
        is_encoder_decoder=True, n_encoder_layers=2,
        frontend="audio", frontend_dim=64,
    )
