"""Adasum: the paper's adaptive gradient combiner (Section 3).

Pairwise op, reference recursive-tree reduction, and per-layer pytree
application. These are the *reference* (non-distributed) forms; the
distributed AdasumRVH lives in :mod:`repro.core.rvh`.

All dot products / norms accumulate in a configurable high precision
(paper 4.4.1 uses double on CPU/GPU; on TPU fp32 is the idiomatic
equivalent — see DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

# Guard against division by zero for all-zero gradients (e.g. untouched
# MoE experts). With EPS in the denominator the combiner degrades to a
# plain sum, which is the correct limit: a zero gradient is orthogonal
# to everything.
EPS = 1e-30

PyTree = Any


def _flat_dot(a: jnp.ndarray, b: jnp.ndarray, acc_dtype: jnp.dtype) -> jnp.ndarray:
    """Dot product of two equally-shaped arrays, accumulated in acc_dtype."""
    a = a.astype(acc_dtype).reshape(-1)
    b = b.astype(acc_dtype).reshape(-1)
    return jnp.dot(a, b)


def adasum_scalars(dot: jnp.ndarray, n1sq: jnp.ndarray, n2sq: jnp.ndarray):
    """The two Adasum coefficients given dot = g1·g2, n1sq = ‖g1‖², n2sq = ‖g2‖².

    Returns (s1, s2) with  Adasum(g1,g2) = s1*g1 + s2*g2:
        s1 = 1 - dot / (2‖g1‖²),   s2 = 1 - dot / (2‖g2‖²).
    """
    s1 = 1.0 - dot / (2.0 * n1sq + EPS)
    s2 = 1.0 - dot / (2.0 * n2sq + EPS)
    return s1, s2


def adasum_segment_scalars(v: jnp.ndarray):
    """`adasum_scalars` over stacked per-segment dot triples.

    v: [..., 3] with the last axis holding [g1·g2, ‖g1‖², ‖g2‖²] (the
    layout `block_dots` / `segment_dots` emit). Returns (s1, s2) of shape
    [...]. All-zero rows (padding segments, untouched MoE experts) yield
    s1 = s2 = 1 — the plain-sum limit, so zero padding survives a fused
    combine unchanged."""
    return adasum_scalars(v[..., 0], v[..., 1], v[..., 2])


def adasum_pair(g1: jnp.ndarray, g2: jnp.ndarray, *, acc_dtype=jnp.float32) -> jnp.ndarray:
    """Adasum of two gradient arrays (whole-tensor granularity)."""
    dot = _flat_dot(g1, g2, acc_dtype)
    n1 = _flat_dot(g1, g1, acc_dtype)
    n2 = _flat_dot(g2, g2, acc_dtype)
    s1, s2 = adasum_scalars(dot, n1, n2)
    out = s1.astype(g1.dtype) * g1 + s2.astype(g2.dtype) * g2
    return out


def adasum_pair_pytree(t1: PyTree, t2: PyTree, *, per_layer: bool = True,
                       acc_dtype=jnp.float32) -> PyTree:
    """Adasum of two gradient pytrees.

    per_layer=True (paper §3.6): each leaf (parameter tensor) gets its own
    dot/norms — this is the per-layer variant the paper found superior.
    per_layer=False: a single dot/norm over the concatenation of all leaves
    (whole-model granularity), matching the "apply to the whole gradient"
    baseline discussed in §3.6.
    """
    if per_layer:
        return jax.tree.map(
            functools.partial(adasum_pair, acc_dtype=acc_dtype), t1, t2)
    l1, treedef = jax.tree.flatten(t1)
    l2 = treedef.flatten_up_to(t2)
    dot = sum(_flat_dot(a, b, acc_dtype) for a, b in zip(l1, l2))
    n1 = sum(_flat_dot(a, a, acc_dtype) for a in l1)
    n2 = sum(_flat_dot(b, b, acc_dtype) for b in l2)
    s1, s2 = adasum_scalars(dot, n1, n2)
    out = [s1.astype(a.dtype) * a + s2.astype(b.dtype) * b for a, b in zip(l1, l2)]
    return jax.tree.unflatten(treedef, out)


def adasum_tree_reduce(grads: Sequence[PyTree] | PyTree, *, per_layer: bool = True,
                       acc_dtype=jnp.float32) -> PyTree:
    """Reference recursive binary-tree Adasum over N gradients (§3.4).

    `grads` is either a list of pytrees or a single pytree whose leaves have
    a leading axis of (power-of-two) length N. The recursion
    Adasum(g[0,n]) = Adasum(Adasum(g[0,n/2)), Adasum(g[n/2,n])) pairs
    *adjacent* leaves at the bottom of the tree — the same tree shape
    ADASUMRVH (Algorithm 1) builds with its distance-1-first exchanges.
    """
    if not isinstance(grads, (list, tuple)):
        n = jax.tree.leaves(grads)[0].shape[0]
        grads = [jax.tree.map(lambda x, i=i: x[i], grads) for i in range(n)]
    grads = list(grads)
    n = len(grads)
    assert n & (n - 1) == 0, f"Adasum tree reduce needs power-of-two inputs, got {n}"
    while len(grads) > 1:
        grads = [
            adasum_pair_pytree(grads[2 * i], grads[2 * i + 1],
                               per_layer=per_layer, acc_dtype=acc_dtype)
            for i in range(len(grads) // 2)
        ]
    return grads[0]


def adasum_linear_reduce(grads: Sequence[PyTree], *, per_layer: bool = True,
                         acc_dtype=jnp.float32) -> PyTree:
    """Linear (ring-order) recursive application (§3.4 first recursion):
    Adasum(g[0,n+1]) = Adasum(Adasum(g[0,n]), g[n+1]).

    Implemented for the ablation against the tree order; the paper found the
    tree ("recursive halving") form faster and uses it in ADASUMRVH.
    """
    acc = grads[0]
    for g in grads[1:]:
        acc = adasum_pair_pytree(acc, g, per_layer=per_layer, acc_dtype=acc_dtype)
    return acc


def sum_reduce(grads: Sequence[PyTree] | PyTree, mean: bool = False) -> PyTree:
    """Baseline synchronous-SGD combiner (Horovod Sum/Average)."""
    if not isinstance(grads, (list, tuple)):
        n = jax.tree.leaves(grads)[0].shape[0]
        op = (lambda x: jnp.mean(x, axis=0)) if mean else (lambda x: jnp.sum(x, axis=0))
        return jax.tree.map(op, grads)
    acc = jax.tree.map(lambda *xs: sum(xs), *grads)
    if mean:
        acc = jax.tree.map(lambda x: x / len(grads), acc)
    return acc
