"""Core Adasum library (the paper's primary contribution).

- adasum:        the pairwise combiner + reference tree/linear reductions
- rvh:           ADASUMRVH (Algorithm 1) over TPU mesh axes via shard_map
- fusion:        tensor fusion with per-layer boundary bookkeeping (§4.4.3)
- orthogonality: the per-layer orthogonality metric (§3.6, Fig. 1)
- combine:       CombineConfig + gradient-combination dispatch
- dist_opt:      DistributedOptimizer (pre/post-optimizer Adasum, ZeRO-1)
"""
from .adasum import (adasum_pair, adasum_pair_pytree, adasum_tree_reduce,
                     adasum_linear_reduce, adasum_scalars, sum_reduce, EPS)
from .orthogonality import per_layer_orthogonality
from . import fusion, rvh
