"""DistributedOptimizer — the Horovod integration point (paper §4.1).

    opt = hvd.DistributedOptimizer(opt, op=hvd.Adasum)

becomes

    dopt = DistributedOptimizer(opt, combine_cfg, combiner)

Semantics (paper §4.1 + Fig. 3):
  * pre-optimizer  ('pre'):  combined = Combine(per-lane gradients);
        then ONE optimizer step with the combined gradient. This is the
        mode for SGD/Momentum (and the Sum baseline for everything).
  * post-optimizer ('post'): each lane steps its OWN optimizer on its
        local gradient; the *effective gradients* (deltas) are combined
        and applied to the shared parameters. Required for adaptive
        optimizers (Adam/LAMB) because Adasum must not inflate the
        minibatch the optimizer logic sees. Per-lane optimizer states
        stay consistent because every lane sees its own gradient stream
        (as in Horovod, where each node owns its optimizer state).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .combine import CombineConfig
from ..optim.optimizers import Optimizer

PyTree = Any


@dataclasses.dataclass(frozen=True)
class DistributedOptimizer:
    opt: Optimizer
    cfg: CombineConfig
    combiner: Callable[[PyTree], PyTree]
    span: int = 1
    # optional sharding pins (GSPMD can otherwise replicate the full-model
    # per-lane deltas — catastrophic at MoE scale): applied to the stacked
    # per-lane deltas and to the combined delta respectively.
    lane_constraint: Optional[Callable[[PyTree], PyTree]] = None
    delta_constraint: Optional[Callable[[PyTree], PyTree]] = None

    @property
    def point(self) -> str:
        if self.cfg.op in ("sum", "mean"):
            return "pre"   # classic synchronous SGD: reduce, then step
        if self.cfg.point == "auto":
            return self.opt.default_combine_point
        return self.cfg.point

    def init(self, params: PyTree) -> Dict[str, PyTree]:
        if self.point == "post" and self.span > 1:
            # one optimizer state per lane (Horovod: per-node state)
            inner = self.opt.init(params)
            state = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.span,) + x.shape), inner)
        else:
            state = self.opt.init(params)
        return {"inner": state, "step": jnp.zeros((), jnp.int32)}

    def update(self, stacked_grads: PyTree, state: Dict[str, PyTree],
               params: PyTree) -> Tuple[PyTree, Dict[str, PyTree]]:
        """stacked_grads: leaves [span, *shape]. Returns (delta, new_state)."""
        delta, new_state, _ = self._update(stacked_grads, state, params,
                                           self.combiner)
        return delta, new_state

    def update_stats(self, stacked_grads: PyTree, state: Dict[str, PyTree],
                     params: PyTree, stats_combiner: Callable
                     ) -> Tuple[PyTree, Dict[str, PyTree], Optional[PyTree]]:
        """`update` routed through a stats-enabled combiner (from
        `make_combiner(..., with_stats=True)`): returns (delta,
        new_state, CombineStats). The combine math is the same program —
        stats only read intermediates the combine already computes.
        At span 1 no combine runs and stats is None."""
        return self._update(stacked_grads, state, params, stats_combiner)

    def _update(self, stacked_grads: PyTree, state: Dict[str, PyTree],
                params: PyTree, combiner: Callable
                ) -> Tuple[PyTree, Dict[str, PyTree], Optional[PyTree]]:
        stats = None

        def combine(tree):
            nonlocal stats
            out = combiner(tree)
            if isinstance(out, tuple):
                out, stats = out
            return out

        step = state["step"]
        if self.point == "pre":
            combined = combine(stacked_grads)
            delta, inner = self.opt.update(combined, state["inner"], params, step)
        else:
            if self.span > 1:
                def lane_update(g, s):
                    return self.opt.update(g, s, params, step)
                deltas, inner = jax.vmap(lane_update)(stacked_grads,
                                                      state["inner"])
                if self.lane_constraint is not None:
                    deltas = self.lane_constraint(deltas)
                delta = combine(deltas)
            else:
                g = jax.tree.map(lambda x: x[0], stacked_grads)
                delta, inner = self.opt.update(g, state["inner"], params, step)
        if self.delta_constraint is not None:
            delta = self.delta_constraint(delta)
        return delta, {"inner": inner, "step": step + 1}, stats

    def apply(self, params: PyTree, delta: PyTree) -> PyTree:
        return jax.tree.map(
            lambda p, d: (p.astype(jnp.float32) + d).astype(p.dtype),
            params, delta)
