"""Tensor fusion with per-layer boundary bookkeeping (paper §4.4.3).

Horovod fuses many small tensors into one buffer before an allreduce, and
Adasum additionally tracks the per-tensor boundaries inside the fused buffer
so per-layer dot products (§3.6) survive fusion. On TPU the fusion layout is
*static* (chosen at trace time — XLA compiles a fixed schedule), which plays
the role of HOROVOD_FUSION_THRESHOLD bookkeeping.

The layout is identical on every device because local (post-sharding) leaf
shapes are identical per SPMD semantics; boundaries are therefore consistent
across all data-parallel ranks, which is requirement (1)+(2) of §4.4.3.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class FusionLayout:
    """Static layout of the fused flat buffer.

    Attributes:
      shapes:    local leaf shapes in flatten order.
      dtypes:    leaf dtypes.
      offsets:   start offset of each leaf in the fused buffer.
      sizes:     element count of each leaf.
      padded_len: total buffer length, padded to a multiple of `align`.
      num_segments: number of real segments (== number of leaves); the
        padding tail is segment `num_segments` (a dummy layer).
      treedef:   pytree structure for unpacking.
    """
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    padded_len: int
    num_segments: int
    treedef: Any

    def segment_ids(self) -> np.ndarray:
        """int32 [padded_len] mapping each element to its layer index."""
        seg = np.full((self.padded_len,), self.num_segments, dtype=np.int32)
        for i, (off, sz) in enumerate(zip(self.offsets, self.sizes)):
            seg[off:off + sz] = i
        return seg


def make_layout(tree: PyTree, *, align: int = 1, leaf_align: int = 1
                ) -> FusionLayout:
    """Builds a FusionLayout for a pytree of (local) arrays or ShapeDtypeStructs.

    `align`: pad the buffer total to a multiple of this (RVH needs
    2**rounds · leaf_align so every halving slice stays aligned).
    `leaf_align`: start every leaf at a multiple of this (the Pallas
    kernel contract: one layer per kernel block)."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes, dtypes, offsets, sizes = [], [], [], []
    off = 0
    for leaf in leaves:
        if leaf_align > 1:
            off = ((off + leaf_align - 1) // leaf_align) * leaf_align
        shapes.append(tuple(leaf.shape))
        dtypes.append(leaf.dtype)
        sz = int(np.prod(leaf.shape)) if leaf.shape else 1
        offsets.append(off)
        sizes.append(sz)
        off += sz
    align = max(align, 1) * max(leaf_align, 1)
    padded = ((off + align - 1) // align) * align
    padded = max(padded, align)
    return FusionLayout(tuple(shapes), tuple(dtypes), tuple(offsets),
                        tuple(sizes), padded, len(leaves), treedef)


def layout_bytes(layout: FusionLayout) -> int:
    """Raw (unpadded) payload bytes of one lane of the fused buffer."""
    return sum(sz * np.dtype(dt).itemsize
               for sz, dt in zip(layout.sizes, layout.dtypes))


def pack(tree: PyTree, layout: FusionLayout, dtype=None) -> jnp.ndarray:
    """Flattens leaves into the fused buffer (zero padded, including
    alignment gaps between leaves). Writes each leaf into a zeroed
    buffer via dynamic_update_slice — XLA:CPU lowers a many-operand
    concatenate orders of magnitude slower (measured 65 ms vs 2 ms for a
    64-leaf fp32 pack), and on TPU the updates fuse identically."""
    leaves = layout.treedef.flatten_up_to(tree)
    dtype = dtype or jnp.result_type(*layout.dtypes)
    buf = jnp.zeros((layout.padded_len,), dtype)
    for leaf, off in zip(leaves, layout.offsets):
        buf = jax.lax.dynamic_update_slice(
            buf, leaf.astype(dtype).reshape(-1), (off,))
    return buf


def unpack(buf: jnp.ndarray, layout: FusionLayout) -> PyTree:
    """Splits the fused buffer back into the original pytree."""
    leaves = []
    for shape, dtype, off, sz in zip(layout.shapes, layout.dtypes,
                                     layout.offsets, layout.sizes):
        leaves.append(jax.lax.dynamic_slice_in_dim(buf, off, sz, 0)
                      .reshape(shape).astype(dtype))
    return jax.tree.unflatten(layout.treedef, leaves)


def bucketize_sizes(sizes_bytes: Sequence[int], bucket_bytes: int
                    ) -> List[Tuple[int, int]]:
    """Splits a run of per-leaf byte sizes into contiguous buckets of
    ~bucket_bytes, never splitting a leaf across buckets (Horovod's
    fusion threshold). Returns (leaf_start, leaf_end) index ranges."""
    buckets: List[Tuple[int, int]] = []
    start, acc = 0, 0
    for i, nbytes in enumerate(sizes_bytes):
        if acc > 0 and acc + nbytes > bucket_bytes:
            buckets.append((start, i))
            start, acc = i, 0
        acc += nbytes
    buckets.append((start, len(sizes_bytes)))
    return buckets


def bucketize(layout: FusionLayout, bucket_bytes: int, itemsize: int = 4
              ) -> List[Tuple[int, int]]:
    """`bucketize_sizes` over a layout's leaves at a uniform itemsize."""
    return bucketize_sizes([sz * itemsize for sz in layout.sizes],
                           bucket_bytes)


def select_block_elems(sizes: Sequence[int], *, unit: int = 1024,
                       max_block: int = 8192, max_waste: float = 0.25
                       ) -> int:
    """Pick a kernel block size for a bucket of leaf element counts: the
    largest power-of-two multiple of `unit` (<= max_block) whose
    leaf-alignment padding wastes at most `max_waste` of the raw payload.
    Big-matrix buckets get the full 8192-element blocks; buckets of tiny
    leaves (norms, biases) degrade to the 1024 granule so per-leaf
    padding stays bounded."""
    raw = max(sum(int(s) for s in sizes), 1)
    b = max(max_block, unit)
    while b > unit:
        padded = sum((int(s) + b - 1) // b * b for s in sizes)
        if padded - raw <= max_waste * raw:
            return b
        b //= 2
    return unit


def pack_stacked(leaves: Sequence[jnp.ndarray], layout: FusionLayout,
                 dtype=None) -> jnp.ndarray:
    """Like `pack`, but every leaf carries a leading stack (lane) axis:
    [k, *shape] leaves -> [k, padded_len] fused buffer (alignment gaps +
    tail zero-padded). The layout describes the *payload* shapes (no
    stack axis)."""
    dtype = dtype or jnp.result_type(*layout.dtypes)
    k = leaves[0].shape[0]
    # dynamic_update_slice writes, not concatenate — see pack()
    buf = jnp.zeros((k, layout.padded_len), dtype)
    for leaf, off in zip(leaves, layout.offsets):
        buf = jax.lax.dynamic_update_slice(
            buf, leaf.astype(dtype).reshape(k, -1), (0, off))
    return buf
