"""Gradient-combination primitives: Sum / Mean / Adasum over DP lanes.

Dispatch lives in the string-keyed registry (`repro.engine.registry`,
`@register_combiner`); `build_combiner` below is a thin compat wrapper
over it. This module keeps `CombineConfig` and the reference tree
implementations the registry entries are built from.

All combiners operate on a *stacked* gradient pytree — leaves have a
leading lane axis of length `span` (one lane per Adasum leaf). Backends:

  gspmd_tree : the recursive tree expressed as array ops on the lane axis;
               XLA/GSPMD chooses the collectives. Baseline + works for any
               lane sharding (incl. scattered ZeRO-2 grads).
  rvh        : ADASUMRVH (Algorithm 1) via shard_map — paper-faithful,
               bandwidth-optimal; requires one lane per DP rank.
  linear     : ring-order recursion (§3.4 first form) — the variant the
               paper implemented and found slower; kept for the ablation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from . import adasum as A

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CombineConfig:
    op: str = "adasum"            # 'sum' | 'mean' | 'adasum'
    point: str = "auto"           # 'pre' | 'post' | 'auto'
    backend: str = "gspmd_tree"   # 'gspmd_tree' | 'rvh' | 'linear'
    span: int = 0                 # #lanes; 0 => one lane per DP rank
    per_layer: bool = True        # paper §3.6
    acc_dtype: str = "float32"    # paper §4.4.1 (fp64 there; fp32 on TPU)
    use_pallas: bool = False      # Pallas kernels for dots/combine
    hierarchical: bool = False    # sum inside pod, Adasum across pods (§4.2.2)
    compress: str = "none"        # 'int8': quantized RVH wire payloads

    @property
    def acc(self):
        return jnp.dtype(self.acc_dtype)


def _split_lanes(x: jnp.ndarray):
    """[n, *shape] -> a, b = even/odd lanes [n//2, *shape]. IMPORTANT: only
    the lane axis is reshaped — flattening the payload axes would destroy
    their TP/FSDP sharding and replicate multi-GiB leaves (observed on
    mixtral: 336 GiB/device buffers before this formulation)."""
    n = x.shape[0]
    y = x.reshape((n // 2, 2) + x.shape[1:])
    return y[:, 0], y[:, 1]


def _pair_dots(a: jnp.ndarray, b: jnp.ndarray, acc_dtype):
    axes = tuple(range(1, a.ndim))
    af = a.astype(acc_dtype)
    bf = b.astype(acc_dtype)
    return (jnp.sum(af * bf, axes), jnp.sum(af * af, axes),
            jnp.sum(bf * bf, axes))


def _bcast(s: jnp.ndarray, ndim: int):
    return s.reshape(s.shape + (1,) * (ndim - 1))


def _pair_combine_stacked(x: jnp.ndarray, acc_dtype) -> jnp.ndarray:
    """One tree level on a stacked leaf [n, *shape] -> [n//2, *shape],
    pairing adjacent lanes (the RVH tree shape). Per-leaf (=per-layer) dots."""
    a, b = _split_lanes(x)
    dot, na, nb = _pair_dots(a, b, acc_dtype)
    s1, s2 = A.adasum_scalars(dot, na, nb)
    return (_bcast(s1, a.ndim).astype(x.dtype) * a
            + _bcast(s2, b.ndim).astype(x.dtype) * b)


def tree_combine_per_layer(stacked: PyTree, acc_dtype) -> PyTree:
    n = jax.tree.leaves(stacked)[0].shape[0]
    while n > 1:
        stacked = jax.tree.map(
            lambda x: _pair_combine_stacked(x, acc_dtype), stacked)
        n //= 2
    return jax.tree.map(lambda x: x[0], stacked)


def tree_combine_whole(stacked: PyTree, acc_dtype) -> PyTree:
    """Whole-model granularity: dots accumulated across all leaves."""
    n = jax.tree.leaves(stacked)[0].shape[0]
    while n > 1:
        leaves, treedef = jax.tree.flatten(stacked)
        pairs = [_split_lanes(l) for l in leaves]
        dots = [_pair_dots(a, b, acc_dtype) for a, b in pairs]
        dot = sum(d[0] for d in dots)
        na = sum(d[1] for d in dots)
        nb = sum(d[2] for d in dots)
        s1, s2 = A.adasum_scalars(dot, na, nb)
        out = [(_bcast(s1, a.ndim).astype(l.dtype) * a
                + _bcast(s2, b.ndim).astype(l.dtype) * b)
               for (a, b), l in zip(pairs, leaves)]
        stacked = jax.tree.unflatten(treedef, out)
        n //= 2
    return jax.tree.map(lambda x: x[0], stacked)


def build_combiner(cfg: CombineConfig, *, mesh=None, dp_axes: Sequence[str] = (),
                   leaf_specs: Optional[PyTree] = None
                   ) -> Callable[[PyTree], PyTree]:
    """Returns combine(stacked_grads) -> combined_grads (no lane axis).

    Dispatch lives in the string-keyed registry
    (`repro.engine.registry`); this wrapper is kept so core callers and
    older code keep working unchanged. The lazy import avoids a
    core <-> engine import cycle."""
    from repro.engine.registry import make_combiner
    return make_combiner(cfg, mesh=mesh, dp_axes=dp_axes,
                         leaf_specs=leaf_specs)
