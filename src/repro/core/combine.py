"""Gradient-combination primitives: Sum / Mean / Adasum over DP lanes.

Dispatch lives in the string-keyed registry (`repro.engine.registry`,
`@register_combiner`); `build_combiner` below is a thin compat wrapper
over it. This module keeps `CombineConfig`, the reference tree
implementations the registry entries are built from, and the fused
bucketed fast path (`build_fused_combiner`).

All combiners operate on a *stacked* gradient pytree — leaves have a
leading lane axis of length `span` (one lane per Adasum leaf). Backends:

  gspmd_tree : the recursive tree expressed as array ops on the lane axis;
               XLA/GSPMD chooses the collectives. Works for any lane
               sharding (incl. scattered ZeRO-2 grads). With cfg.fused
               (default) the hot loop runs the bucketed single-pass
               combine below; cfg.fused=False keeps the per-leaf
               reference tree.map.
  rvh        : ADASUMRVH (Algorithm 1) via shard_map — paper-faithful,
               bandwidth-optimal; requires one lane per DP rank.
  linear     : ring-order recursion (§3.4 first form) — the variant the
               paper implemented and found slower; kept for the ablation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from . import adasum as A
from . import fusion

PyTree = Any


@dataclasses.dataclass(frozen=True)
class CombineConfig:
    op: str = "adasum"            # 'sum' | 'mean' | 'adasum'
    point: str = "auto"           # 'pre' | 'post' | 'auto'
    backend: str = "gspmd_tree"   # 'gspmd_tree' | 'rvh' | 'fused' | 'linear'
    span: int = 0                 # #lanes; 0 => one lane per DP rank
    per_layer: bool = True        # paper §3.6
    acc_dtype: str = "float32"    # paper §4.4.1 (fp64 there; fp32 on TPU)
    use_pallas: bool = False      # Pallas kernels for dots/combine
    hierarchical: bool = False    # sum inside pod, Adasum across pods (§4.2.2)
    compress: str = "none"        # 'int8': quantized RVH wire payloads
    fused: bool = True            # bucketed single-pass gspmd_tree hot path
    fusion_threshold_mb: int = 64 # Horovod-style per-bucket packing budget

    @property
    def acc(self):
        return jnp.dtype(self.acc_dtype)

    @property
    def fusion_bytes(self) -> int:
        # fractional MB budgets are honored (floor 1 KiB) so the bucket
        # split is exercisable at reduced-model scale; integer configs
        # behave exactly as before
        return max(int(self.fusion_threshold_mb * (1 << 20)), 1 << 10)


def _split_lanes(x: jnp.ndarray):
    """[n, *shape] -> a, b = even/odd lanes [n//2, *shape]. IMPORTANT: only
    the lane axis is reshaped — flattening the payload axes would destroy
    their TP/FSDP sharding and replicate multi-GiB leaves (observed on
    mixtral: 336 GiB/device buffers before this formulation)."""
    n = x.shape[0]
    y = x.reshape((n // 2, 2) + x.shape[1:])
    return y[:, 0], y[:, 1]


def _pair_dots(a: jnp.ndarray, b: jnp.ndarray, acc_dtype):
    axes = tuple(range(1, a.ndim))
    af = a.astype(acc_dtype)
    bf = b.astype(acc_dtype)
    return (jnp.sum(af * bf, axes), jnp.sum(af * af, axes),
            jnp.sum(bf * bf, axes))


def _bcast(s: jnp.ndarray, ndim: int):
    return s.reshape(s.shape + (1,) * (ndim - 1))


def _pair_combine_stacked(x: jnp.ndarray, acc_dtype) -> jnp.ndarray:
    """One tree level on a stacked leaf [n, *shape] -> [n//2, *shape],
    pairing adjacent lanes (the RVH tree shape). Per-leaf (=per-layer) dots."""
    a, b = _split_lanes(x)
    dot, na, nb = _pair_dots(a, b, acc_dtype)
    s1, s2 = A.adasum_scalars(dot, na, nb)
    return (_bcast(s1, a.ndim).astype(x.dtype) * a
            + _bcast(s2, b.ndim).astype(x.dtype) * b)


def _level_triple(leaves, acc_dtype) -> jnp.ndarray:
    """Total [dot, ‖a‖², ‖b‖²] of one tree level, summed over every leaf
    and lane pair — the CombineStats payload. Recomputes the same dots
    the combine itself takes (XLA CSEs the shared subgraph), so enabling
    collection never perturbs the combined output."""
    tot = jnp.zeros((3,), acc_dtype)
    for l in leaves:
        a, b = _split_lanes(l)
        dot, na, nb = _pair_dots(a, b, acc_dtype)
        tot = tot + jnp.stack([dot.sum(), na.sum(), nb.sum()]).astype(acc_dtype)
    return tot


def tree_combine_per_layer(stacked: PyTree, acc_dtype,
                           collect: Optional[list] = None) -> PyTree:
    n = jax.tree.leaves(stacked)[0].shape[0]
    while n > 1:
        if collect is not None:
            collect.append(_level_triple(jax.tree.leaves(stacked), acc_dtype))
        stacked = jax.tree.map(
            lambda x: _pair_combine_stacked(x, acc_dtype), stacked)
        n //= 2
    return jax.tree.map(lambda x: x[0], stacked)


def tree_combine_whole(stacked: PyTree, acc_dtype,
                       collect: Optional[list] = None) -> PyTree:
    """Whole-model granularity: dots accumulated across all leaves."""
    n = jax.tree.leaves(stacked)[0].shape[0]
    while n > 1:
        leaves, treedef = jax.tree.flatten(stacked)
        pairs = [_split_lanes(l) for l in leaves]
        dots = [_pair_dots(a, b, acc_dtype) for a, b in pairs]
        dot = sum(d[0] for d in dots)
        na = sum(d[1] for d in dots)
        nb = sum(d[2] for d in dots)
        if collect is not None:
            collect.append(jnp.stack([dot.sum(), na.sum(), nb.sum()]))
        s1, s2 = A.adasum_scalars(dot, na, nb)
        out = [(_bcast(s1, a.ndim).astype(l.dtype) * a
                + _bcast(s2, b.ndim).astype(l.dtype) * b)
               for (a, b), l in zip(pairs, leaves)]
        stacked = jax.tree.unflatten(treedef, out)
        n //= 2
    return jax.tree.map(lambda x: x[0], stacked)


def stack_stats(collect: list) -> dict:
    """CombineStats pytree from collected per-level triples: {'levels':
    f32 [num_levels, 3]} with rows [Σ dot, Σ ‖a‖², Σ ‖b‖²] summed over
    every leaf/bucket and lane pair of that tree level. Level 0 pairs
    lanes that saw independent batches — its triple IS the gradient-
    noise-scale estimate `repro.control.noise` consumes. Empty collect
    (span == 1: no pairing happens) yields a [0, 3] array."""
    if not collect:
        return {"levels": jnp.zeros((0, 3), jnp.float32)}
    return {"levels": jnp.stack(collect).astype(jnp.float32)}


# --------------------------------------------------------------- fused path
#
# The paper's efficiency claim (§4.4.2 + §4.4.3) is earned by reading the
# gradient buffers ONCE per tree level: tensors fused into flat buffers
# with static per-layer boundaries, all three dot products in a single
# pass, one FMA write. The reference gspmd_tree above instead issues
# O(leaves) tiny reductions + FMAs per level. The fused path below closes
# that gap for the default backend:
#
#   * leaves are grouped by (sharding-axes, dtype) and packed into
#     Horovod-style buckets of `fusion_threshold_mb` — packing never
#     materializes a multi-GiB buffer;
#   * packing happens on the LOCAL shards inside shard_map (manual over
#     the whole mesh), so TP/FSDP-sharded leaves are never flattened
#     globally — the replication failure mode `_split_lanes` documents;
#   * per tree level, each bucket runs one `block_dots` pass (both lane
#     halves read once -> per-block [a·b, a·a, b·b] partials), a tiny
#     block->segment reduction + one psum over exactly the bucket's
#     sharding axes for the §3.6 per-layer coefficients, and one
#     `block_combine` FMA write. O(buckets) ops per level, not O(leaves).


def _payload_axes(spec) -> Tuple[str, ...]:
    from repro.parallel.sharding import spec_axes
    return spec_axes(spec)


def fused_plan(leaves, specs, cfg: CombineConfig, psum: bool):
    """Static bucketing of (local) stacked leaves: group by (sharding
    axes, dtype), split groups at the fusion threshold, pick a kernel
    block + layout per bucket. Returns [(leaf_idxs, layout, block_elems,
    psum_axes)] — all host-side, resolved once at trace time.

    Public: the comms-plan checker (`repro.analysis.comms`) recomputes
    this plan from abstract leaves and asserts the traced jaxpr emits
    exactly one psum per sharded bucket per tree level."""
    groups = {}
    for i, (leaf, spec) in enumerate(zip(leaves, specs)):
        axes = _payload_axes(spec) if psum else ()
        groups.setdefault((axes, jnp.dtype(leaf.dtype).name), []).append(i)
    plan = []
    # block granule: the Pallas kernels need the fp32 tile (8x128); the
    # jnp reference ops have no tile constraint, and a finer granule
    # keeps tiny-leaf buckets (norms/biases) from drowning in per-leaf
    # alignment padding
    unit = 1024 if cfg.use_pallas else 256
    for (axes, _dt), idxs in sorted(groups.items()):
        payload = [jax.ShapeDtypeStruct(leaves[i].shape[1:], leaves[i].dtype)
                   for i in idxs]
        sizes = [int(np.prod(p.shape)) if p.shape else 1 for p in payload]
        nbytes = [s * p.dtype.itemsize for s, p in zip(sizes, payload)]
        for s, e in fusion.bucketize_sizes(nbytes, cfg.fusion_bytes):
            block = fusion.select_block_elems(sizes[s:e], unit=unit)
            layout = fusion.make_layout(tuple(payload[s:e]),
                                        leaf_align=block)
            plan.append((tuple(idxs[s:e]), layout, block, axes))
    return plan


_fused_plan = fused_plan   # pre-analysis name, kept for callers


def plan_summary(plan) -> List[dict]:
    """Host-readable description of a fused plan, one dict per bucket —
    the payload of the comms-plan report."""
    return [{
        "leaves": len(idxs),
        "axes": list(axes),
        "dtype": np.dtype(layout.dtypes[0]).name,
        "block_elems": int(block),
        "padded_elems": int(layout.padded_len),
        "payload_bytes": int(fusion.layout_bytes(layout)),
    } for idxs, layout, block, axes in plan]


def _bucket_dots(a, b, ids, num, block, acc_dtype, use_pallas):
    """Single-pass per-(pair, segment) dot triples for one bucket level:
    flat lane halves -> [num, 3] via per-block partials + a tiny segment
    reduction (valid because FusionLayout block-aligns every layer)."""
    if use_pallas:
        from repro.kernels.adasum_dots import block_dots
        blocks = block_dots(a, b, block_elems=block).astype(acc_dtype)
    else:
        from repro.kernels.ref import block_dots_ref
        blocks = block_dots_ref(a, b, block, acc_dtype)
    return jax.ops.segment_sum(blocks, ids, num_segments=num)


def _bucket_combine(a, b, s1b, s2b, block, use_pallas):
    if use_pallas:
        from repro.kernels.adasum_combine import block_combine
        return block_combine(a, b, s1b, s2b, block_elems=block)
    from repro.kernels.ref import combine_ref
    return combine_ref(a, b, s1b, s2b, block)


def _pack_buckets(leaves, plan):
    """Pack (local) stacked leaves into the plan's fusion buffers, once;
    every tree level then reads each buffer exactly once. Returns
    (packed [n, padded_len] buffers, per-bucket metas)."""
    packed, metas = [], []
    for idxs, layout, block, axes in plan:
        buf = fusion.pack_stacked([leaves[i] for i in idxs], layout)
        block_seg = jnp.asarray(layout.segment_ids()[::block])
        packed.append(buf)
        metas.append((layout, block, axes, block_seg))
    return packed, metas


def _bucket_level_dots(buf, meta, cfg):
    """One tree level's single-pass dot triples for one bucket buffer
    [n, L]: both lane halves read once -> per-(pair, segment) [p, nseg1,
    3], finished by one psum over exactly the bucket's sharding axes —
    a single collective per bucket per level, which is the invariant the
    comms-plan checker pins."""
    layout, block, axes, block_seg = meta
    p = buf.shape[0] // 2
    L = buf.shape[1]
    y = buf.reshape(p, 2, L)
    a = y[:, 0].reshape(p * L)
    b = y[:, 1].reshape(p * L)
    nseg1 = layout.num_segments + 1     # + the padding segment
    nblk = L // block
    ids = (jnp.tile(block_seg, p)
           + nseg1 * jnp.repeat(jnp.arange(p, dtype=jnp.int32), nblk))
    v = _bucket_dots(a, b, ids, p * nseg1, block, cfg.acc,
                     cfg.use_pallas).reshape(p, nseg1, 3)
    if axes:
        v = jax.lax.psum(v, axes)
    return (a, b, ids, nblk), v


def _bucket_chain(buf, meta, cfg, collect: Optional[list] = None):
    """Full per-layer tree reduction of ONE bucket [n, L] -> [1, L]: a
    self-contained chain of level ops (dots -> psum -> scalars -> FMA)
    with no cross-bucket data dependency. The chains are what the
    delayed-combine mode hands XLA as a restartable stream: each
    bucket's psum chain is free to run concurrently with unrelated
    compute — including the next step's forward/backward, since the
    carry it consumes was produced a step earlier.

    `collect`, when given, is a per-level accumulator list (one [3]
    entry per tree level, shared across buckets): the already-psummed
    dot triples `v` are reduced into it, so stats collection adds ZERO
    extra collectives on this path."""
    n = buf.shape[0]
    block = meta[1]
    level = 0
    while n > 1:
        (a, b, ids, _nblk), v = _bucket_level_dots(buf, meta, cfg)
        if collect is not None:
            collect[level] = collect[level] + v.sum(axis=(0, 1))
        s1, s2 = A.adasum_segment_scalars(v)     # [p, nseg1]
        s1b = s1.reshape(-1)[ids]
        s2b = s2.reshape(-1)[ids]
        out = _bucket_combine(a, b, s1b, s2b, block, cfg.use_pallas)
        n //= 2
        level += 1
        buf = out.reshape(n, -1)
    return buf


def _whole_model_levels(packed, metas, cfg, collect: Optional[list] = None):
    """Level-major reduction at whole-model granularity (§3.6 off):
    every level's dot triples are summed across ALL buckets before the
    scalars form, so bucket chains cannot run independently — the
    synchronization price of whole-model coefficients."""
    n = packed[0].shape[0]
    while n > 1:
        p = n // 2
        halves, dots = [], []
        for buf, meta in zip(packed, metas):
            h, v = _bucket_level_dots(buf, meta, cfg)
            halves.append(h)
            dots.append(v)
        # one dot triple per pair, summed over every bucket (padding
        # segments contribute zeros)
        level_v = sum(v.sum(axis=1) for v in dots)        # [p, 3]
        if collect is not None:
            collect.append(level_v.sum(axis=0))
        s1w, s2w = A.adasum_segment_scalars(level_v)
        new = []
        for (a, b, ids, nblk), meta in zip(halves, metas):
            block = meta[1]
            s1b = jnp.repeat(s1w, nblk)
            s2b = jnp.repeat(s2w, nblk)
            out = _bucket_combine(a, b, s1b, s2b, block, cfg.use_pallas)
            new.append(out.reshape(p, -1))
        packed = new
        n = p
    return packed


def _unpack_buffers(bufs, plan, leaves, treedef):
    out_leaves: List[Any] = [None] * len(leaves)
    for buf, (idxs, layout, _b, _a) in zip(bufs, plan):
        res = fusion.unpack(buf.reshape(-1), layout)
        for i, r in zip(idxs, res):
            out_leaves[i] = r
    return jax.tree.unflatten(treedef, out_leaves)


def fused_combine_tree(stacked: PyTree, cfg: CombineConfig,
                       leaf_specs_flat: Optional[List] = None,
                       psum: bool = False,
                       collect: Optional[list] = None) -> PyTree:
    """Bucketed single-pass Adasum tree reduction on (local) stacked
    leaves [n, *shape] -> [*shape]. With `psum=True` it must run inside
    shard_map manual over the mesh; each bucket's dots are finished by
    one psum over exactly the axes its leaves are sharded over. With
    per-layer granularity each bucket reduces as an independent chain
    (`_bucket_chain`).

    `collect`, when given, receives one [3] dot triple per tree level
    (summed over buckets and pairs) — built from the SAME psummed `v`
    every level already computes, so stats cost no extra collective."""
    leaves, treedef = jax.tree.flatten(stacked)
    if not leaves:
        return stacked
    n = leaves[0].shape[0]
    if n == 1:
        return jax.tree.map(lambda x: x[0], stacked)
    assert n & (n - 1) == 0, \
        f"fused combine needs a power-of-two lane count, got {n}"
    specs = leaf_specs_flat or [P()] * len(leaves)
    plan = fused_plan(leaves, specs, cfg, psum)
    packed, metas = _pack_buckets(leaves, plan)
    if cfg.per_layer:
        if collect is not None:
            levels = n.bit_length() - 1
            acc = [jnp.zeros((3,), cfg.acc) for _ in range(levels)]
            packed = [_bucket_chain(buf, meta, cfg, collect=acc)
                      for buf, meta in zip(packed, metas)]
            collect.extend(acc)
        else:
            packed = [_bucket_chain(buf, meta, cfg)
                      for buf, meta in zip(packed, metas)]
    else:
        packed = _whole_model_levels(packed, metas, cfg, collect=collect)
    return _unpack_buffers(packed, plan, leaves, treedef)


def fused_correction_tree(stacked: PyTree, cfg: CombineConfig,
                          leaf_specs_flat: Optional[List] = None,
                          psum: bool = False) -> PyTree:
    """Delayed-combine correction on the pending-delta carry:

        correction = Adasum(deltas) - lane_mean(deltas)

    `lane_mean` is the local estimate `delayed_local_step` already
    applied when the deltas were produced; folding the correction in
    later completes the exchange without double-counting. One packing
    feeds both consumers (each pending buffer is read once); the tree
    side emits exactly the psums `fused_combine_tree` does — one per
    sharded bucket per level — and the lane mean is lane-axis
    arithmetic, local under shard_map, no collective."""
    leaves, treedef = jax.tree.flatten(stacked)
    if not leaves:
        return stacked
    n = leaves[0].shape[0]
    if n == 1:
        # a single lane combines to itself: zero remote correction
        return jax.tree.map(
            lambda x: jnp.zeros(x.shape[1:], x.dtype), stacked)
    assert n & (n - 1) == 0, \
        f"fused correction needs a power-of-two lane count, got {n}"
    specs = leaf_specs_flat or [P()] * len(leaves)
    plan = fused_plan(leaves, specs, cfg, psum)
    packed, metas = _pack_buckets(leaves, plan)
    means = [buf.astype(cfg.acc).mean(axis=0).astype(buf.dtype)
             for buf in packed]
    if cfg.per_layer:
        combined = [_bucket_chain(buf, meta, cfg)
                    for buf, meta in zip(packed, metas)]
    else:
        combined = _whole_model_levels(packed, metas, cfg)
    diffs = [c.reshape(-1) - m for c, m in zip(combined, means)]
    return _unpack_buffers(diffs, plan, leaves, treedef)


def _build_fused(cfg: CombineConfig, mesh, dp_axes, leaf_specs, tree_fn,
                 with_stats: bool = False
                 ) -> Optional[Callable[[PyTree], PyTree]]:
    dp_total = 1
    if mesh is not None and dp_axes:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        dp_total = int(np.prod([sizes[a] for a in dp_axes]))
    if dp_total > 1 and cfg.span in (0, dp_total):
        return None
    # shard_map (pack local shards, explicit psums) only pays off — and is
    # only safe to pin — when the caller described the payload sharding;
    # otherwise run with global semantics and let GSPMD partition.
    use_shard_map = mesh is not None and leaf_specs is not None

    def run(stacked: PyTree) -> PyTree:
        leaves, treedef = jax.tree.flatten(stacked)
        if not leaves:
            return (stacked, stack_stats([])) if with_stats else stacked
        if leaf_specs is not None:
            specs = [s or P() for s in treedef.flatten_up_to(leaf_specs)]
        else:
            specs = [P()] * len(leaves)
        if not use_shard_map:
            if with_stats:
                collect: list = []
                out = tree_fn(stacked, cfg, specs, psum=False,
                              collect=collect)
                return out, stack_stats(collect)
            return tree_fn(stacked, cfg, specs, psum=False)
        from .rvh import _shard_map_compat
        in_specs = jax.tree.unflatten(
            treedef, [P(None, *tuple(s)) for s in specs])
        out_specs = jax.tree.unflatten(
            treedef, [P(*tuple(s)) for s in specs])

        if with_stats:
            # the stats triples are psummed inside the body (sharded
            # buckets) or computed from replicated payloads, so every
            # device holds the same value — P() (replicated) is exact
            def body_stats(tree):
                collect: list = []
                out = tree_fn(tree, cfg, specs, psum=True, collect=collect)
                return out, stack_stats(collect)

            return _shard_map_compat(
                body_stats, mesh, (in_specs,),
                (out_specs, {"levels": P()}))(stacked)

        def body(tree):
            return tree_fn(tree, cfg, specs, psum=True)

        return _shard_map_compat(body, mesh, (in_specs,), out_specs)(stacked)

    return run


def build_fused_combiner(cfg: CombineConfig, *, mesh=None,
                         dp_axes: Sequence[str] = (),
                         leaf_specs: Optional[PyTree] = None,
                         with_stats: bool = False
                         ) -> Optional[Callable[[PyTree], PyTree]]:
    """Sharding-aware fused bucketed combine for the gspmd_tree backend.

    Returns None when the fused path cannot apply: with one lane per DP
    rank (span == dp) the lane axis itself is device-sharded in the
    runtime's RVH layout, so local adjacent-lane pairing would cross
    devices — that regime belongs to the rvh backend (or the per-leaf
    reference tree, which lets GSPMD pick the collectives).

    with_stats=True: the combiner returns (combined, CombineStats) —
    the per-level dot triples read out of the psums the combine already
    issues, so the traced program has the SAME collective multiset as
    the plain combiner (the comms pass pins this).
    """
    return _build_fused(cfg, mesh, dp_axes, leaf_specs, fused_combine_tree,
                        with_stats=with_stats)


def build_fused_correction(cfg: CombineConfig, *, mesh=None,
                           dp_axes: Sequence[str] = (),
                           leaf_specs: Optional[PyTree] = None
                           ) -> Optional[Callable[[PyTree], PyTree]]:
    """`build_fused_combiner`'s delayed-mode sibling: the same bucketed
    shard_map program shape, but computing `fused_correction_tree`
    (combined minus lane mean) from one packing of the pending carry.
    None under the same span == dp condition."""
    return _build_fused(cfg, mesh, dp_axes, leaf_specs,
                        fused_correction_tree)


def lane_mean(stacked: PyTree, acc_dtype=jnp.float32) -> PyTree:
    """Mean over the leading lane axis — the delayed mode's immediate
    local estimate. Must compute exactly the mean the correction
    subtracts (same acc dtype), or the exchange would drift."""
    return jax.tree.map(
        lambda x: jnp.mean(x.astype(acc_dtype), axis=0).astype(x.dtype),
        stacked)


def build_delayed_correction(cfg: CombineConfig, *, mesh=None,
                             dp_axes: Sequence[str] = (),
                             leaf_specs: Optional[PyTree] = None
                             ) -> Callable[[PyTree], PyTree]:
    """The delayed-combine exchange: correction(pending) =
    combine(pending) - lane_mean(pending). Takes the fused bucketed path
    whenever `build_fused_combiner` would (same plan, same psums), else
    wraps whichever combiner the registry resolves for the config —
    correctness never depends on fusion."""
    if (cfg.op == "adasum" and cfg.fused
            and cfg.backend in ("", "gspmd_tree", "fused")):
        fused = build_fused_correction(cfg, mesh=mesh, dp_axes=dp_axes,
                                       leaf_specs=leaf_specs)
        if fused is not None:
            return fused
    from repro.engine.registry import make_combiner
    combiner = make_combiner(cfg, mesh=mesh, dp_axes=dp_axes,
                             leaf_specs=leaf_specs)

    def correction(pending: PyTree) -> PyTree:
        combined = combiner(pending)
        local = lane_mean(pending, cfg.acc)
        return jax.tree.map(lambda c, l: c - l, combined, local)

    return correction


def build_combiner(cfg: CombineConfig, *, mesh=None, dp_axes: Sequence[str] = (),
                   leaf_specs: Optional[PyTree] = None
                   ) -> Callable[[PyTree], PyTree]:
    """Returns combine(stacked_grads) -> combined_grads (no lane axis).

    Dispatch lives in the string-keyed registry
    (`repro.engine.registry`); this wrapper is kept so core callers and
    older code keep working unchanged. The lazy import avoids a
    core <-> engine import cycle."""
    from repro.engine.registry import make_combiner
    return make_combiner(cfg, mesh=mesh, dp_axes=dp_axes,
                         leaf_specs=leaf_specs)
