"""ADASUMRVH — the paper's Algorithm 1 (recursive vector halving with
Adasum) mapped onto TPU ICI via shard_map.

Mapping from the MPI formulation (DESIGN.md §2):
  * SEND/RECV of buffer halves with the neighbor at distance d
        -> `lax.ppermute` with the XOR-pairing permutation,
  * ALLREDUCE of partial dots over the 2d-sized rank group (line 17)
        -> `lax.psum` with `axis_index_groups`,
  * per-layer dot products on the fused buffer (paper §3.6 + §4.4.3)
        -> segment reduction over the static FusionLayout segment ids,
  * fp64 dot accumulation (§4.4.1)
        -> configurable acc_dtype (fp32 default on TPU, fp64 for CPU tests).

Multi-axis trees: `dp_axes` lists (axis_name, size) innermost-first, e.g.
[('data',16), ('pod',2)] — rounds 0..3 pair data-neighbors inside a pod,
round 4 pairs across pods, which is exactly the paper's hierarchical
NVLink-inside / IB-across layout transposed to ICI-inside / DCI-across.

Tensor-parallel shards: each layer may be sharded over `model`-like axes;
full-layer dots are finished by an extra psum over those axes, with a
static per-segment replication-correction for layers that are *not*
sharded over a given axis (so replicas are not double counted).
"""
from __future__ import annotations

import functools
import math
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .adasum import adasum_segment_scalars
from . import fusion

PyTree = Any


def segment_dots(a: jnp.ndarray, b: jnp.ndarray, seg: jnp.ndarray,
                 num_segments: int, acc_dtype=jnp.float32,
                 use_pallas: bool = False) -> jnp.ndarray:
    """Fused per-segment [a·b, a·a, b·b] -> [num_segments, 3] in acc_dtype.

    The single-pass three-dot reduction is the compute hot loop the paper
    hand-vectorizes (§4.4.2); `use_pallas` switches to the Pallas TPU kernel.
    """
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.adasum_segment_dots(a, b, seg, num_segments,
                                        acc_dtype=acc_dtype)
    af = a.astype(acc_dtype)
    bf = b.astype(acc_dtype)
    prods = jnp.stack([af * bf, af * af, bf * bf], axis=-1)  # [n, 3]
    return jax.ops.segment_sum(prods, seg, num_segments=num_segments)


def combine_halves(a: jnp.ndarray, b: jnp.ndarray, v: jnp.ndarray,
                   seg: jnp.ndarray, use_pallas: bool = False) -> jnp.ndarray:
    """x' = s1·a + s2·b with per-segment scalars from the dot triples
    (Algorithm 1 line 18, per-layer per §3.6)."""
    s1, s2 = adasum_segment_scalars(v)
    if use_pallas:
        from repro.kernels import ops as kops
        return kops.adasum_combine(a, b, s1, s2, seg)
    return (s1[seg].astype(a.dtype) * a + s2[seg].astype(b.dtype) * b)


def _xor_perm(size: int, d: int) -> List[Tuple[int, int]]:
    return [(r, r ^ d) for r in range(size)]


# --------------------------------------------------- wire compression (int8)
# Beyond-paper (the paper cites 1-bit SGD / PowerSGD as the orthogonal
# communication-reduction axis, §6): the RVH half-exchanges can carry
# int8 payloads with per-128-block absmax scales (4.25 bits of mantissa
# on the wire per fp32 value => ~3.7x fewer wire bytes). Dots/combine
# still run on dequantized fp32 values, so Adasum's precision guarantees
# (§4.4.1) apply to the combination itself.
_QBLOCK = 128


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    n = x.shape[0]
    assert n % _QBLOCK == 0, n
    xb = x.reshape(n // _QBLOCK, _QBLOCK).astype(jnp.float32)
    scale = jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0 + 1e-30
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(n), scale[:, 0]


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray, dtype) -> jnp.ndarray:
    n = q.shape[0]
    xb = q.reshape(n // _QBLOCK, _QBLOCK).astype(jnp.float32) * scale[:, None]
    return xb.reshape(n).astype(dtype)


def _exchange(send: jnp.ndarray, ax: str, perm, compress: str):
    if compress == "int8":
        q, s = _quantize(send)
        q = jax.lax.ppermute(q, ax, perm)
        s = jax.lax.ppermute(s, ax, perm)
        return _dequantize(q, s, send.dtype)
    return jax.lax.ppermute(send, ax, perm)


def _round_schedule(dp_axes: Sequence[Tuple[str, int]]):
    """Yields (axis, local_distance, done_axes, group_block) per tree round."""
    done: List[str] = []
    for ax, size in dp_axes:
        n = int(math.log2(size))
        assert 2 ** n == size, f"dp axis {ax} size {size} not a power of two"
        for j in range(n):
            yield ax, size, 2 ** j, tuple(done), 2 ** (j + 1)
        done.append(ax)


def _groups(size: int, block: int) -> List[List[int]]:
    return [list(range(s, s + block)) for s in range(0, size, block)]


def adasum_rvh_local(buf: jnp.ndarray, seg: jnp.ndarray,
                     dp_axes: Sequence[Tuple[str, int]],
                     num_segments: int,
                     seg_scale: Optional[jnp.ndarray] = None,
                     model_axes: Sequence[str] = (),
                     acc_dtype=jnp.float32,
                     use_pallas: bool = False,
                     allgather_result: bool = True,
                     compress: str = "none") -> jnp.ndarray:
    """Algorithm 1 body. Must run inside shard_map manual over dp_axes (and
    model_axes if any layer is TP-sharded).

    buf:  local fused gradient buffer [padded_len] (padding zeroed);
          padded_len must be divisible by prod(dp sizes).
    seg:  int32 [padded_len] segment (layer) ids; padding -> num_segments.
    seg_scale: [num_segments+1] static per-segment dot correction
          1/replication_factor over model_axes (see module docstring).
    allgather_result: run lines 22-24; if False, returns the owned
          1/N slice (fused into ZeRO-1 — the allgather phase is elided
          and replaced by the parameter allgather downstream).
    """
    total = 1
    for _, s in dp_axes:
        total *= s
    if total == 1:
        return buf
    assert buf.shape[0] % total == 0, (buf.shape, total)

    trace: List[Tuple[str, int, int]] = []
    # ---- reduce-scatter + combine phase (lines 2-21) ----
    for ax, size, d, done_axes, block in _round_schedule(dp_axes):
        mid = buf.shape[0] // 2
        idx = jax.lax.axis_index(ax)
        is_left = (idx // d) % 2 == 0
        lo, hi = buf[:mid], buf[mid:]
        slo, shi = seg[:mid], seg[mid:]
        keep = jnp.where(is_left, lo, hi)
        send = jnp.where(is_left, hi, lo)
        seg = jnp.where(is_left, slo, shi)
        recv = _exchange(send, ax, _xor_perm(size, d),
                         compress if buf.shape[0] % (2 * _QBLOCK) == 0
                         else "none")
        a = jnp.where(is_left, keep, recv)   # lower-rank contribution
        b = jnp.where(is_left, recv, keep)   # higher-rank contribution
        v = segment_dots(a, b, seg, num_segments + 1, acc_dtype, use_pallas)
        if seg_scale is not None:
            v = v * seg_scale[:, None].astype(v.dtype)
        # finish the dots (line 17): full psum over already-scattered axes,
        # grouped psum over the current axis, full psum over TP axes.
        for dax in done_axes:
            v = jax.lax.psum(v, dax)
        if block < size:
            v = jax.lax.psum(v, ax, axis_index_groups=_groups(size, block))
        else:
            v = jax.lax.psum(v, ax)
        for max_ in model_axes:
            v = jax.lax.psum(v, max_)
        buf = combine_halves(a, b, v, seg, use_pallas)
        trace.append((ax, size, d))

    if not allgather_result:
        return buf

    # ---- allgather phase (lines 22-24) ----
    for ax, size, d in reversed(trace):
        idx = jax.lax.axis_index(ax)
        is_left = (idx // d) % 2 == 0
        other = _exchange(buf, ax, _xor_perm(size, d),
                          compress if buf.shape[0] % _QBLOCK == 0
                          else "none")
        buf = jnp.where(is_left,
                        jnp.concatenate([buf, other]),
                        jnp.concatenate([other, buf]))
    return buf


def _leaf_replication_factors(leaf_specs, mesh_axis_sizes, model_axes):
    """Per-leaf dot correction: 1/(product of model-axis sizes the leaf is
    NOT sharded over). Sharded leaves contribute disjoint slices (correct
    under psum); replicated leaves would be counted size(axis) times."""
    factors = []
    for spec in leaf_specs:
        used = set()
        for entry in (spec or ()):  # PartitionSpec entries
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                used.add(ax)
        f = 1
        for ax in model_axes:
            if ax not in used:
                f *= mesh_axis_sizes[ax]
        factors.append(1.0 / f)
    return factors


def adasum_rvh_pytree(stacked: PyTree, mesh: jax.sharding.Mesh,
                      dp_axes: Sequence[str],
                      leaf_specs: Optional[PyTree] = None,
                      *, per_layer: bool = True, acc_dtype=jnp.float32,
                      use_pallas: bool = False,
                      compress: str = "none",
                      bucket_bytes: Optional[int] = None) -> PyTree:
    """Applies ADASUMRVH to a stacked gradient pytree.

    stacked: pytree with leaves [n_lanes, *shape]; the lane axis is sharded
      over `dp_axes` (innermost-first order, e.g. ('data','pod')) with one
      lane per DP rank.
    leaf_specs: optional pytree of PartitionSpecs describing how *shape is
      sharded over the TP axes (without the lane dim). None => replicated.
    bucket_bytes: split the fused buffer into buckets of ~this size (never
      splitting a leaf) and run one independent RVH chain per bucket —
      the chains have no data dependence, so XLA overlaps bucket k+1's
      half-exchanges with bucket k's dots/combine (communication/compute
      pipelining). None (or per_layer=False, which needs whole-model
      dots) keeps the single fused buffer.
    Returns the combined pytree [*shape] (no lane dim), replicated over dp.
    """
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp_sizes = [(ax, sizes[ax]) for ax in dp_axes]
    n_lanes = 1
    for _, s in dp_sizes:
        n_lanes *= s
    leaves, treedef = jax.tree.flatten(stacked)
    assert all(l.shape[0] == n_lanes for l in leaves), (
        f"lane dim must equal prod(dp axes)={n_lanes}")

    if leaf_specs is None:
        specs = [P() for _ in leaves]
    else:
        specs = treedef.flatten_up_to(leaf_specs)
    model_axes = [ax for ax in mesh.axis_names if ax not in dp_axes]
    # Only psum dots over model axes actually used by some leaf.
    used_model_axes = []
    for spec in specs:
        for entry in (spec or ()):
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                if ax in model_axes and ax not in used_model_axes:
                    used_model_axes.append(ax)
    factors = _leaf_replication_factors(specs, sizes, used_model_axes)

    lane_spec = tuple(reversed(dp_axes))  # outermost axis major in the index
    in_specs = jax.tree.unflatten(
        treedef, [P(lane_spec, *(s or ())) for s in specs])
    out_specs = jax.tree.unflatten(treedef, [P(*(s or ())) for s in specs])

    def body(tree):
        tree = jax.tree.map(lambda x: x.reshape(x.shape[1:]), tree)  # drop lane
        # Pallas kernel contract: leaves block-aligned so each kernel block
        # maps to exactly one layer; alignment survives every RVH halving
        # because the total stays a multiple of n_lanes * leaf_align.
        leaf_align = 1
        if use_pallas:
            from repro.kernels import ops as kops
            leaf_align = kops.BLOCK_ELEMS
        body_leaves, body_treedef = jax.tree.flatten(tree)
        if per_layer and bucket_bytes and len(body_leaves) > 1:
            # one independent RVH chain per bucket: XLA pipelines bucket
            # k+1's exchanges against bucket k's dots/combine
            nbytes = [
                (int(np.prod(l.shape)) if l.shape else 1) * l.dtype.itemsize
                for l in body_leaves]
            ranges = fusion.bucketize_sizes(nbytes, bucket_bytes)
        else:
            ranges = [(0, len(body_leaves))]
        out_leaves: List = [None] * len(body_leaves)
        for lo, hi in ranges:
            sub = tuple(body_leaves[lo:hi])
            layout = fusion.make_layout(sub, align=n_lanes,
                                        leaf_align=leaf_align)
            if not per_layer:
                # whole-model granularity: one segment for everything.
                # With TP axes this needs a uniform replication factor
                # (heterogeneous factors cannot be corrected on a single
                # collapsed dot).
                assert len(set(factors)) <= 1, (
                    "per_layer=False requires uniform TP sharding "
                    "across leaves")
                seg_np = np.zeros((layout.padded_len,), np.int32)
                tail = layout.padded_len - sum(layout.sizes)
                if tail:
                    seg_np[-tail:] = 1
                seg = jnp.asarray(seg_np)
                nseg = 1
                scale = (jnp.asarray([factors[0], 1.0]).astype(acc_dtype)
                         if used_model_axes else None)
            else:
                seg = jnp.asarray(layout.segment_ids())
                nseg = layout.num_segments
                scale = (jnp.asarray(factors[lo:hi] + [1.0]).astype(acc_dtype)
                         if used_model_axes else None)
            buf = fusion.pack(sub, layout,
                              dtype=jnp.result_type(*layout.dtypes))
            out = adasum_rvh_local(buf, seg, dp_sizes, nseg, seg_scale=scale,
                                   model_axes=used_model_axes,
                                   acc_dtype=acc_dtype, use_pallas=use_pallas,
                                   compress=compress)
            out_leaves[lo:hi] = list(fusion.unpack(out, layout))
        return jax.tree.unflatten(body_treedef, out_leaves)

    fn = _shard_map_compat(body, mesh, (in_specs,), out_specs)
    return fn(stacked)


def _shard_map_compat(body, mesh, in_specs, out_specs):
    """shard_map across jax versions: `jax.shard_map(..., check_vma=)` on
    current jax, `jax.experimental.shard_map.shard_map(..., check_rep=)`
    on the 0.4.x line."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)
