"""Per-layer gradient orthogonality metric (paper §3.6, Fig. 1).

orthogonality(g_1..g_n) = ‖Adasum(g_[1,n])‖² / Σ_i ‖g_i‖²

Value 1 ⇒ gradients mutually orthogonal (Adasum sums them);
value 1/n ⇒ gradients parallel with equal norm (Adasum averages).
"""
from __future__ import annotations

from typing import Any, Dict, Sequence

import jax
import jax.numpy as jnp

from .adasum import adasum_tree_reduce, EPS

PyTree = Any


def per_layer_orthogonality(grads: Sequence[PyTree] | PyTree,
                            acc_dtype=jnp.float32) -> Dict[str, jnp.ndarray]:
    """Returns {layer_path: orthogonality scalar} plus '__mean__' (Fig. 1 red line).

    `grads` as in adasum_tree_reduce: list of pytrees or stacked leading axis.
    """
    if not isinstance(grads, (list, tuple)):
        n = jax.tree.leaves(grads)[0].shape[0]
        grads = [jax.tree.map(lambda x, i=i: x[i], grads) for i in range(n)]
    combined = adasum_tree_reduce(grads, per_layer=True, acc_dtype=acc_dtype)

    flat_c = jax.tree_util.tree_flatten_with_path(combined)[0]
    flat_gs = [jax.tree.leaves(g) for g in grads]

    out: Dict[str, jnp.ndarray] = {}
    vals = []
    for i, (path, c) in enumerate(flat_c):
        num = jnp.sum(c.astype(acc_dtype) ** 2)
        den = sum(jnp.sum(g[i].astype(acc_dtype) ** 2) for g in flat_gs)
        o = num / (den + EPS)
        key = jax.tree_util.keystr(path)
        out[key] = o
        vals.append(o)
    out["__mean__"] = jnp.mean(jnp.stack(vals))
    return out
