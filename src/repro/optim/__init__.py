"""Pure-JAX optimizers used in the paper (Momentum-SGD, Adam, LAMB) plus
learning-rate schedules and dynamic loss scaling."""
from .optimizers import (Optimizer, sgd, momentum, adam, lamb, get_optimizer)
from .schedules import (constant, linear_warmup_decay, cosine_warmup,
                        get_schedule)
from .scaling import DynamicLossScaler
