"""Optimizers as pure (init, update) pairs over pytrees.

The three the paper scales with Adasum: Momentum-SGD (§5.1), Adam (§5.3),
LAMB (§5.3). `update` returns the *delta* to add to params — this is the
quantity the post-optimizer Adasum mode combines (paper Fig. 3:
effective_gradient = current - start == delta).

No optax in this environment; these match the standard formulations
(Adam: Kingma&Ba; LAMB: You et al. with bias-corrected Adam core and
per-layer trust ratio).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """init(params) -> state;  update(grads, state, params, step) ->
    (delta, new_state). `delta` is the signed parameter update
    (params_new = params + delta)."""
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree, jnp.ndarray], Tuple[PyTree, PyTree]]
    name: str = "opt"
    # Paper §4.1: stateless/linear optimizers combine gradients BEFORE the
    # optimizer ("pre"); adaptive ones combine deltas AFTER ("post").
    default_combine_point: str = "pre"


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def sgd(lr) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return ()

    def update(grads, state, params, step):
        a = sched(step)
        delta = jax.tree.map(lambda g: (-a * g.astype(jnp.float32)), grads)
        return delta, state

    return Optimizer(init, update, "sgd", "pre")


def momentum(lr, beta: float = 0.9, nesterov: bool = False,
             weight_decay: float = 0.0) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)}

    def update(grads, state, params, step):
        a = sched(step)

        def upd(g, m, p):
            g = g.astype(jnp.float32)
            if weight_decay:
                g = g + weight_decay * p.astype(jnp.float32)
            m_new = beta * m + g
            d = (g + beta * m_new) if nesterov else m_new
            return -a * d, m_new

        flat = jax.tree.map(upd, grads, state["m"], params)
        delta = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
        m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
        return delta, {"m": m}

    return Optimizer(init, update, "momentum", "pre")


def _adam_core(g, m, v, step, b1, b2, eps):
    g = g.astype(jnp.float32)
    m_new = b1 * m + (1.0 - b1) * g
    v_new = b2 * v + (1.0 - b2) * (g * g)
    t = step.astype(jnp.float32) + 1.0
    mhat = m_new / (1.0 - b1 ** t)
    vhat = v_new / (1.0 - b2 ** t)
    return mhat / (jnp.sqrt(vhat) + eps), m_new, v_new


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    """state_dtype=bf16 halves optimizer HBM (production memory trick;
    the update math still runs in fp32 — only storage is compressed)."""
    sched = _as_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        a = sched(step)

        def upd(g, m, v, p):
            u, m_new, v_new = _adam_core(g, m.astype(jnp.float32),
                                         v.astype(jnp.float32), step,
                                         b1, b2, eps)
            m_new = m_new.astype(state_dtype)
            v_new = v_new.astype(state_dtype)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return -a * u, m_new, v_new

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2)}

    return Optimizer(init, update, "adam", "post")


def lamb(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-6,
         weight_decay: float = 0.01, min_trust: float = 0.0,
         max_trust: float = 10.0, state_dtype=jnp.float32) -> Optimizer:
    """LAMB (You et al. 2019): Adam core + per-layer trust ratio
    ‖p‖/‖u‖ scaling. The state-of-the-art large-batch optimizer the paper
    combines with Adasum for BERT-Large (Table 3)."""
    sched = _as_schedule(lr)

    def init(params):
        z = lambda p: jnp.zeros(p.shape, state_dtype)
        return {"m": jax.tree.map(z, params), "v": jax.tree.map(z, params)}

    def update(grads, state, params, step):
        a = sched(step)

        def upd(g, m, v, p):
            u, m_new, v_new = _adam_core(g, m.astype(jnp.float32),
                                         v.astype(jnp.float32), step,
                                         b1, b2, eps)
            m_new = m_new.astype(state_dtype)
            v_new = v_new.astype(state_dtype)
            p32 = p.astype(jnp.float32)
            if weight_decay:
                u = u + weight_decay * p32
            pn = jnp.linalg.norm(p32.reshape(-1))
            un = jnp.linalg.norm(u.reshape(-1))
            trust = jnp.where((pn > 0) & (un > 0),
                              jnp.clip(pn / (un + 1e-12), min_trust, max_trust),
                              1.0)
            return -a * trust * u, m_new, v_new

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"m": pick(1), "v": pick(2)}

    return Optimizer(init, update, "lamb", "post")


_REGISTRY = {"sgd": sgd, "momentum": momentum, "adam": adam, "lamb": lamb}


def get_optimizer(name: str, lr, **kwargs) -> Optimizer:
    if name not in _REGISTRY:
        raise KeyError(f"unknown optimizer {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](lr, **kwargs)
