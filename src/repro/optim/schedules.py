"""Learning-rate schedules. The paper's LeNet-5 study (§5.4) uses linear
warmup-then-decay "from zero to zero"; ResNet/BERT use the benchmark
defaults (step decay / linear decay with warmup)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(base_lr: float):
    return lambda step: jnp.asarray(base_lr, jnp.float32)


def linear_warmup_decay(base_lr: float, warmup_steps: int, total_steps: int):
    """Linear 0 -> base_lr over warmup, then linear base_lr -> 0 (§5.4)."""
    def sched(step):
        step = step.astype(jnp.float32)
        warm = step / jnp.maximum(warmup_steps, 1)
        frac = (total_steps - step) / jnp.maximum(total_steps - warmup_steps, 1)
        return base_lr * jnp.clip(jnp.minimum(warm, frac), 0.0, 1.0)
    return sched


def cosine_warmup(base_lr: float, warmup_steps: int, total_steps: int,
                  min_frac: float = 0.0):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = jnp.clip(step / jnp.maximum(warmup_steps, 1), 0.0, 1.0)
        prog = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * jnp.minimum(warm, cos)
    return sched


def step_decay(base_lr: float, boundaries, factors):
    """MLPerf-ResNet-style piecewise schedule (the Fig. 1 orthogonality
    drops happen exactly at these boundaries)."""
    def sched(step):
        lr = jnp.asarray(base_lr, jnp.float32)
        for b, f in zip(boundaries, factors):
            lr = jnp.where(step >= b, base_lr * f, lr)
        return lr
    return sched


_REGISTRY = {"constant": constant, "linear_warmup_decay": linear_warmup_decay,
             "cosine_warmup": cosine_warmup, "step_decay": step_decay}


def get_schedule(name: str, **kwargs):
    return _REGISTRY[name](**kwargs)
