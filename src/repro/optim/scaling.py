"""Dynamic loss/gradient scaling (paper §4.4.1, Micikevicius et al.).

On GPU the paper trains in fp16 and dynamically rescales tensors it
introduces (e.g. the effective_gradient) to stay inside fp16 range. On
TPU the native low-precision type is bf16 whose exponent range matches
fp32, so scaling is unnecessary — we keep the scaler for fp16 paths and
paper fidelity (DESIGN.md §2)."""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class ScalerState(NamedTuple):
    scale: jnp.ndarray        # current loss scale
    good_steps: jnp.ndarray   # consecutive finite steps


class DynamicLossScaler:
    """scale *= 2 after `growth_interval` finite steps; scale /= 2 on any
    non-finite gradient (and the step is skipped by the caller)."""

    def __init__(self, init_scale: float = 2.0 ** 15, growth_interval: int = 2000,
                 factor: float = 2.0, min_scale: float = 1.0,
                 max_scale: float = 2.0 ** 24):
        self.init_scale = init_scale
        self.growth_interval = growth_interval
        self.factor = factor
        self.min_scale = min_scale
        self.max_scale = max_scale

    def init(self) -> ScalerState:
        return ScalerState(jnp.asarray(self.init_scale, jnp.float32),
                           jnp.zeros((), jnp.int32))

    def scale_loss(self, loss: jnp.ndarray, state: ScalerState) -> jnp.ndarray:
        return loss * state.scale.astype(loss.dtype)

    def unscale(self, grads: PyTree, state: ScalerState) -> PyTree:
        inv = 1.0 / state.scale
        return jax.tree.map(lambda g: g.astype(jnp.float32) * inv, grads)

    def check_finite(self, grads: PyTree) -> jnp.ndarray:
        leaves = jax.tree.leaves(grads)
        finite = jnp.asarray(True)
        for l in leaves:
            finite &= jnp.all(jnp.isfinite(l))
        return finite

    def update(self, state: ScalerState, finite: jnp.ndarray) -> ScalerState:
        grew = state.good_steps + 1 >= self.growth_interval
        new_scale = jnp.where(
            finite,
            jnp.where(grew, jnp.minimum(state.scale * self.factor, self.max_scale),
                      state.scale),
            jnp.maximum(state.scale / self.factor, self.min_scale))
        new_good = jnp.where(finite & ~grew, state.good_steps + 1, 0)
        return ScalerState(new_scale, new_good)
