"""Step-time / straggler monitoring + elastic-restart decisions.

At pod scale, synchronous SGD stalls on the slowest participant. The
mitigation ladder implemented here (DESIGN.md §6):
  1. gradient accumulation / local steps (paper §5.2) — fewer syncs,
     configured via RunPolicy.local_steps;
  2. detection: robust z-score of step wall-times; persistent outliers
     are flagged;
  3. elastic drop: on a flagged failure the runner checkpoints, halves
     the DP degree (power-of-two mesh), and restarts from the manifest —
     Adasum's no-hyperparameter property (paper §5.4) means the restart
     needs no LR retuning.

The FailureInjector simulates node loss for the recovery tests.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Deque, List, Optional


@dataclasses.dataclass
class StragglerConfig:
    window: int = 50
    z_threshold: float = 4.0
    min_steps: int = 10
    patience: int = 3            # consecutive outliers before flagging


class StepMonitor:
    def __init__(self, cfg: StragglerConfig = StragglerConfig()):
        self.cfg = cfg
        self.times: Deque[float] = deque(maxlen=cfg.window)
        self._consecutive = 0
        self._last: Optional[float] = None
        self.flagged = False

    def start(self):
        self._last = time.perf_counter()

    def stop(self) -> float:
        assert self._last is not None
        dt = time.perf_counter() - self._last
        self.observe(dt)
        return dt

    def observe(self, dt: float):
        import numpy as np
        if len(self.times) >= self.cfg.min_steps:
            med = float(np.median(self.times))
            mad = float(np.median([abs(t - med) for t in self.times])) + 1e-9
            z = 0.6745 * (dt - med) / mad
            if z > self.cfg.z_threshold:
                self._consecutive += 1
                if self._consecutive >= self.cfg.patience:
                    self.flagged = True
            else:
                self._consecutive = 0
        self.times.append(dt)

    def summary(self):
        import numpy as np
        if not self.times:
            return {}
        a = np.asarray(self.times)
        return {"mean_s": float(a.mean()), "p50_s": float(np.median(a)),
                "max_s": float(a.max()), "flagged": self.flagged}


class NodeLossError(RuntimeError):
    """A participant is gone (real or injected). The elastic driver
    catches exactly this — a RuntimeError subclass so legacy callers
    expecting RuntimeError keep working."""


class FailureInjector:
    """Deterministic failure schedule for recovery tests: raises at the
    configured steps (simulating a lost node / preemption)."""

    def __init__(self, fail_at_steps: List[int]):
        self.fail_at = set(fail_at_steps)

    def check(self, step: int):
        if step in self.fail_at:
            self.fail_at.discard(step)
            raise NodeLossError(f"injected node failure at step {step}")


def next_power_of_two_below(n: int) -> int:
    p = 1
    while p * 2 < n:
        p *= 2
    return p
