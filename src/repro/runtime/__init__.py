from .monitor import (StepMonitor, StragglerConfig, FailureInjector,
                      NodeLossError, next_power_of_two_below)
from .prefetch import DelayedSource, Prefetcher
from .elastic import (ElasticPlan, ResizePlan, ResizeSignal, RestartSignal,
                      plan_grow, plan_shrink)
from .delayed import DelayedCombineStream
