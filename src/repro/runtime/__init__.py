from .monitor import (StepMonitor, StragglerConfig, FailureInjector,
                      next_power_of_two_below)
