from .monitor import (StepMonitor, StragglerConfig, FailureInjector,
                      NodeLossError, next_power_of_two_below)
from .prefetch import DelayedSource, Prefetcher
from .elastic import (ElasticPlan, GrowBackSignal, ResizePlan, ResizeSignal,
                      RestartSignal, plan_grow, plan_grow_back, plan_shrink,
                      plan_shrink_batch)
from .delayed import DelayedCombineStream
