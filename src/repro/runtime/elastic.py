"""Elastic restart decisions (paper §5.4).

Adasum's scale-invariance is what makes shrinking the job safe: when a
node is lost (or a persistent straggler is evicted) the run restarts at
a smaller power-of-two DP degree *with no hyperparameter change* — the
combined update stays well-conditioned at any span. This module holds the
pure decision logic; the driver that rebuilds mesh/session lives in
`repro.engine.pipeline` (it needs the engine layer).

Signals:
  * `RestartSignal` — raised inside the step loop when the StepMonitor
    flags a persistent straggler and the run is elastic;
  * `NodeLossError` (monitor.py) — a participant is gone, real or
    injected by `FailureInjector`; treated identically by the driver.
"""
from __future__ import annotations

import dataclasses

from .monitor import next_power_of_two_below


class RestartSignal(Exception):
    """A flagged straggler requests an elastic restart at `step`."""

    def __init__(self, step: int, reason: str = "straggler"):
        super().__init__(f"elastic restart requested at step {step} "
                         f"({reason})")
        self.step = step
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """One shrink decision: the DP degree to restart at."""
    old_dp: int
    new_dp: int

    @property
    def shrunk(self) -> bool:
        return self.new_dp < self.old_dp


def plan_shrink(dp_total: int) -> ElasticPlan:
    """Halve the DP degree to the next power of two below (monitor.py's
    mitigation ladder step 3). At dp=1 there is nothing left to drop —
    the plan keeps dp=1 and the driver gives up restarting."""
    if dp_total <= 1:
        return ElasticPlan(dp_total, dp_total)
    return ElasticPlan(dp_total, next_power_of_two_below(dp_total))
