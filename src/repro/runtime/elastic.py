"""Elastic restart decisions (paper §5.4).

Adasum's scale-invariance is what makes shrinking the job safe: when a
node is lost (or a persistent straggler is evicted) the run restarts at
a smaller power-of-two DP degree *with no hyperparameter change* — the
combined update stays well-conditioned at any span. This module holds the
pure decision logic; the driver that rebuilds mesh/session lives in
`repro.engine.pipeline` (it needs the engine layer).

Signals:
  * `RestartSignal` — raised inside the step loop when the StepMonitor
    flags a persistent straggler and the run is elastic;
  * `NodeLossError` (monitor.py) — a participant is gone, real or
    injected by `FailureInjector`; treated identically by the driver.
"""
from __future__ import annotations

import dataclasses

from .monitor import next_power_of_two_below


class RestartSignal(Exception):
    """A flagged straggler requests an elastic restart at `step`."""

    def __init__(self, step: int, reason: str = "straggler"):
        super().__init__(f"elastic restart requested at step {step} "
                         f"({reason})")
        self.step = step
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """One shrink decision: the DP degree to restart at."""
    old_dp: int
    new_dp: int

    @property
    def shrunk(self) -> bool:
        return self.new_dp < self.old_dp


def plan_shrink(dp_total: int) -> ElasticPlan:
    """Halve the DP degree to the next power of two below (monitor.py's
    mitigation ladder step 3). At dp=1 there is nothing left to drop —
    the plan keeps dp=1 and the driver gives up restarting."""
    if dp_total <= 1:
        return ElasticPlan(dp_total, dp_total)
    return ElasticPlan(dp_total, next_power_of_two_below(dp_total))


# ------------------------------------------------------- planned resize
#
# The failure-shrink path above, generalized: the noise-adaptive batch
# controller (repro.control) *plans* a growth — larger global batch
# and/or Adasum span, LR rescaled — and the driver executes it through
# the same save -> rebuild-from-config -> resume machinery a shrink
# uses. Adasum's scale invariance is again what makes the mid-run
# change safe: the combined update stays well-conditioned at any span.


class ResizeSignal(Exception):
    """The batch controller requests a planned resize at `step`."""

    def __init__(self, step: int, plan: "ResizePlan"):
        super().__init__(f"adaptive resize requested at step {step} "
                         f"({plan.describe()})")
        self.step = step
        self.plan = plan


@dataclasses.dataclass(frozen=True)
class ResizePlan:
    """One controller growth decision, fully resolved: the batch/span/LR
    to rebuild the session with."""
    old_batch: int
    new_batch: int
    old_span: int
    new_span: int
    old_lr: float
    new_lr: float
    reason: str = "noise"

    @property
    def grew(self) -> bool:
        return (self.new_batch > self.old_batch
                or self.new_span > self.old_span)

    def describe(self) -> str:
        return (f"batch {self.old_batch}->{self.new_batch}, "
                f"span {self.old_span}->{self.new_span}, "
                f"lr {self.old_lr:g}->{self.new_lr:g}, {self.reason}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def plan_grow(global_batch: int, span: int, dp_total: int, lr: float, *,
              factor: int = 2, grow_span: bool = True,
              max_global_batch: int = 0, lr_scale: float = 1.0,
              reason: str = "noise") -> ResizePlan:
    """Resolve an AdaBatch-style growth by `factor` into a concrete
    ResizePlan. Pure sizing logic:

      * new batch = factor x old, capped at `max_global_batch` (0 = no
        cap); if the cap already binds, the plan is a no-grow no-op
        (`plan.grew` False) and the driver stops resizing;
      * span grows with the batch when `grow_span`, but never past
        dp_total and always to a power-of-two divisor of it (the fused
        combine / RVH lane-count contract);
      * new lr = lr * lr_scale — the caller computes lr_scale (AdaScale
        gain for the factor, linear, or 1.0).
    """
    assert factor >= 2, factor
    new_batch = global_batch * factor
    if max_global_batch and new_batch > max_global_batch:
        new_batch = max(max_global_batch, global_batch)
    new_span = span
    if grow_span and new_batch > global_batch:
        cand = span * factor
        while cand > dp_total or (dp_total % cand) or (cand & (cand - 1)):
            cand //= 2
            if cand <= span:
                cand = span
                break
        # a lane must still hold at least one batch row
        if cand > span and new_batch % cand == 0:
            new_span = cand
    if new_batch == global_batch:
        return ResizePlan(global_batch, global_batch, span, span, lr, lr,
                          reason="capped")
    return ResizePlan(global_batch, new_batch, span, new_span, lr,
                      float(lr * lr_scale), reason=reason)
