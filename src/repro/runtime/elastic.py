"""Elastic restart decisions (paper §5.4).

Adasum's scale-invariance is what makes shrinking the job safe: when a
node is lost (or a persistent straggler is evicted) the run restarts at
a smaller power-of-two DP degree *with no hyperparameter change* — the
combined update stays well-conditioned at any span. This module holds the
pure decision logic; the driver that rebuilds mesh/session lives in
`repro.engine.pipeline` (it needs the engine layer).

Signals:
  * `RestartSignal` — raised inside the step loop when the StepMonitor
    flags a persistent straggler and the run is elastic;
  * `NodeLossError` (monitor.py) — a participant is gone, real or
    injected by `FailureInjector`; treated identically by the driver.
"""
from __future__ import annotations

import dataclasses

from .monitor import next_power_of_two_below


class RestartSignal(Exception):
    """A flagged straggler requests an elastic restart at `step`."""

    def __init__(self, step: int, reason: str = "straggler"):
        super().__init__(f"elastic restart requested at step {step} "
                         f"({reason})")
        self.step = step
        self.reason = reason


class GrowBackSignal(Exception):
    """Capacity returned: a callback asks the elastic driver to re-expand
    the DP degree at `step` (caught by `fit_elastic`, which saves,
    rebuilds at the target DP, and resumes — LR rescaled by the AdaScale
    gain of the growth factor, per §5.4 no other hyperparameter moves)."""

    def __init__(self, step: int, target_dp: int = 0,
                 reason: str = "capacity returned"):
        super().__init__(f"elastic grow-back requested at step {step} "
                         f"({reason})")
        self.step = step
        self.target_dp = target_dp   # 0 => the run's original DP degree
        self.reason = reason


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """One elastic decision: the DP degree to restart at (and, for a
    grow-back, the LR to restart with)."""
    old_dp: int
    new_dp: int
    old_lr: float = 0.0
    new_lr: float = 0.0

    @property
    def shrunk(self) -> bool:
        return self.new_dp < self.old_dp

    @property
    def grew(self) -> bool:
        return self.new_dp > self.old_dp


def plan_shrink(dp_total: int) -> ElasticPlan:
    """Halve the DP degree to the next power of two below (monitor.py's
    mitigation ladder step 3). At dp=1 there is nothing left to drop —
    the plan keeps dp=1 and the driver gives up restarting."""
    if dp_total <= 1:
        return ElasticPlan(dp_total, dp_total)
    return ElasticPlan(dp_total, next_power_of_two_below(dp_total))


def plan_grow_back(dp_total: int, target_dp: int, lr: float, *,
                   lr_scale: float = 1.0) -> ElasticPlan:
    """The reverse of `plan_shrink`, for when capacity returns: re-expand
    DP to the largest power of two <= `target_dp`. New LR = lr *
    lr_scale, where the caller computes lr_scale as the AdaScale gain of
    the growth factor from live CombineStats (1.0 with no stats — per
    §5.4 the run stays safe either way, the gain just recovers the
    larger batch's efficiency). A target at or below the current degree
    yields a no-op plan (`plan.grew` False)."""
    new_dp = 1
    while new_dp * 2 <= max(target_dp, 1):
        new_dp *= 2
    if new_dp <= dp_total:
        return ElasticPlan(dp_total, dp_total, lr, lr)
    return ElasticPlan(dp_total, new_dp, lr, float(lr * lr_scale))


# ------------------------------------------------------- planned resize
#
# The failure-shrink path above, generalized: the noise-adaptive batch
# controller (repro.control) *plans* a growth — larger global batch
# and/or Adasum span, LR rescaled — and the driver executes it through
# the same save -> rebuild-from-config -> resume machinery a shrink
# uses. Adasum's scale invariance is again what makes the mid-run
# change safe: the combined update stays well-conditioned at any span.


class ResizeSignal(Exception):
    """The batch controller requests a planned resize at `step`."""

    def __init__(self, step: int, plan: "ResizePlan"):
        super().__init__(f"adaptive resize requested at step {step} "
                         f"({plan.describe()})")
        self.step = step
        self.plan = plan


@dataclasses.dataclass(frozen=True)
class ResizePlan:
    """One controller resize decision (growth or shrink), fully
    resolved: the batch/span/LR to rebuild the session with."""
    old_batch: int
    new_batch: int
    old_span: int
    new_span: int
    old_lr: float
    new_lr: float
    reason: str = "noise"

    @property
    def grew(self) -> bool:
        return (self.new_batch > self.old_batch
                or self.new_span > self.old_span)

    @property
    def shrank(self) -> bool:
        return self.new_batch < self.old_batch

    @property
    def changed(self) -> bool:
        return (self.new_batch != self.old_batch
                or self.new_span != self.old_span)

    def describe(self) -> str:
        return (f"batch {self.old_batch}->{self.new_batch}, "
                f"span {self.old_span}->{self.new_span}, "
                f"lr {self.old_lr:g}->{self.new_lr:g}, {self.reason}")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


def plan_grow(global_batch: int, span: int, dp_total: int, lr: float, *,
              factor: int = 2, grow_span: bool = True,
              max_global_batch: int = 0, lr_scale: float = 1.0,
              reason: str = "noise") -> ResizePlan:
    """Resolve an AdaBatch-style growth by `factor` into a concrete
    ResizePlan. Pure sizing logic:

      * new batch = factor x old, capped at `max_global_batch` (0 = no
        cap); if the cap already binds, the plan is a no-grow no-op
        (`plan.grew` False) and the driver stops resizing;
      * span grows with the batch when `grow_span`, but never past
        dp_total and always to a power-of-two divisor of it (the fused
        combine / RVH lane-count contract);
      * new lr = lr * lr_scale — the caller computes lr_scale (AdaScale
        gain for the factor, linear, or 1.0).
    """
    assert factor >= 2, factor
    new_batch = global_batch * factor
    if max_global_batch and new_batch > max_global_batch:
        new_batch = max(max_global_batch, global_batch)
    new_span = span
    if grow_span and new_batch > global_batch:
        cand = span * factor
        while cand > dp_total or (dp_total % cand) or (cand & (cand - 1)):
            cand //= 2
            if cand <= span:
                cand = span
                break
        # a lane must still hold at least one batch row
        if cand > span and new_batch % cand == 0:
            new_span = cand
    if new_batch == global_batch:
        return ResizePlan(global_batch, global_batch, span, span, lr, lr,
                          reason="capped")
    return ResizePlan(global_batch, new_batch, span, new_span, lr,
                      float(lr * lr_scale), reason=reason)


def plan_shrink_batch(global_batch: int, span: int, dp_total: int,
                      lr: float, *, factor: int = 2,
                      shrink_span: bool = True, min_global_batch: int = 0,
                      lr_scale: float = 1.0,
                      reason: str = "noise-low") -> ResizePlan:
    """`plan_grow` in reverse — the controller's shrink direction when
    the noise scale falls BELOW the hysteresis band (the batch is larger
    than the gradient noise justifies, so smaller batches buy the same
    progress per sample):

      * new batch = old // factor, floored at max(min_global_batch, 1);
      * span shrinks with it when `shrink_span` (floor 1), and the new
        batch must stay divisible by the new span (lane rows stay
        integral);
      * new lr = lr * lr_scale — the caller computes lr_scale (1/gain
        for adascale, 1/factor linear, 1.0 none).

    When the floor binds the plan is a no-change no-op (`plan.changed`
    False) and the controller stops planning shrinks.
    """
    assert factor >= 2, factor
    new_batch = global_batch // factor
    new_span = span
    if shrink_span and span > 1:
        new_span = max(1, span // factor)
    floor = max(min_global_batch, 1)
    if new_batch < floor or new_batch < new_span or new_batch % new_span:
        return ResizePlan(global_batch, global_batch, span, span, lr, lr,
                          reason="floored")
    return ResizePlan(global_batch, new_batch, span, new_span, lr,
                      float(lr * lr_scale), reason=reason)
