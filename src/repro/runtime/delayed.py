"""Host-level delayed-combine executor (combine_delay = 1).

The single-program `delayed_local_step` already lets XLA overlap the
pending-delta exchange with compute *inside* one dispatch. This module
is the split-execution variant: the exchange runs as its own dispatch on
a background thread while the main thread runs the local step, which

  * makes the overlap observable — per-step accounting splits
    `combine_wait_s` (time blocked on the exchange after compute
    finished) from `compute_s` (the local step itself);
  * lets a benchmark inject interconnect latency into the exchange leg
    only (`comm_delay`), emulating the paper's §5.2 slow-interconnect
    regime on a fast host.

Bitwise contract: `stream.step(state, batch)` produces exactly the same
state as the fused single-program step — same sub-computations
(`correction_fn`, `local_fn`, `fold_fn` from the Runtime), same apply
order (local mean first, remote correction second). The stream jits are
non-donating: the background thread holds a reference to the pending
carry while the main thread's local step runs, so donating either input
would be a use-after-free hazard.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, Optional, Tuple

import jax

PyTree = Any


class DelayedCombineStream:
    """Runs a Runtime's delayed-combine round as two overlapped legs.

    Usage (TrainSession wires this up via `use_delayed_stream`):

        stream = DelayedCombineStream(runtime, comm_delay=0.05)
        state, metrics = stream.step(state, batch)   # == train_step(...)

    `metrics` gains two host-side floats: `compute_s` (local-step wall
    time) and `combine_wait_s` (extra wait for the exchange after the
    local step finished — ~0 when the overlap hides it).
    """

    def __init__(self, runtime, *, comm_delay: float = 0.0):
        if runtime.correction_fn is None or runtime.local_fn is None:
            raise ValueError(
                "DelayedCombineStream needs a delayed-mode Runtime "
                "(EngineConfig.combine_delay=1): correction_fn/local_fn "
                "are only built then")
        self.runtime = runtime
        self.comm_delay = float(comm_delay)
        self._corr = jax.jit(runtime.correction_fn)
        self._local = jax.jit(runtime.local_fn)
        self._fold = jax.jit(runtime.fold_fn)
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-delayed-combine")
        self.last_compute_s = 0.0
        self.last_combine_wait_s = 0.0

    # ------------------------------------------------------------- exchange
    def _exchange(self, pending: PyTree) -> PyTree:
        """The background leg: injected interconnect latency + the
        correction dispatch, blocked to completion so `combine_wait_s`
        measures real readiness, not async-dispatch queueing."""
        if self.comm_delay > 0:
            time.sleep(self.comm_delay)
        corr = self._corr(pending)
        jax.block_until_ready(corr)
        return corr

    def combine_time(self, pending: PyTree) -> float:
        """Standalone wall time (s) of one exchange — the quantity the
        overlap is supposed to hide (benchmark baseline)."""
        t0 = time.perf_counter()
        self._exchange(pending)
        return time.perf_counter() - t0

    # ----------------------------------------------------------------- step
    def step(self, state: PyTree, batch: Dict[str, Any]
             ) -> Tuple[PyTree, Dict[str, Any]]:
        t0 = time.perf_counter()
        fut = self._pool.submit(self._exchange, state["pending"])
        new_state, metrics = self._local(state, batch)
        jax.block_until_ready(new_state)
        t1 = time.perf_counter()
        corr = fut.result()
        t2 = time.perf_counter()
        new_state = dict(new_state)
        new_state["params"] = self._fold(new_state["params"], corr)
        self.last_compute_s = t1 - t0
        self.last_combine_wait_s = t2 - t1
        metrics = dict(metrics)
        metrics["compute_s"] = self.last_compute_s
        metrics["combine_wait_s"] = self.last_combine_wait_s
        return new_state, metrics

    def serial_step(self, state: PyTree, batch: Dict[str, Any]
                    ) -> Tuple[PyTree, Dict[str, Any]]:
        """The same round with the exchange run inline BEFORE the local
        step (no background thread) — the no-overlap baseline the
        benchmark compares against. Bitwise-identical output."""
        t0 = time.perf_counter()
        corr = self._exchange(state["pending"])
        t1 = time.perf_counter()
        new_state, metrics = self._local(state, batch)
        jax.block_until_ready(new_state)
        t2 = time.perf_counter()
        new_state = dict(new_state)
        new_state["params"] = self._fold(new_state["params"], corr)
        metrics = dict(metrics)
        metrics["compute_s"] = t2 - t1
        metrics["combine_wait_s"] = t1 - t0
        return new_state, metrics

    def close(self):
        self._pool.shutdown(wait=True)
