"""Double-buffered host->device batch prefetching (DaSGD-style overlap).

The deterministic sources in `repro.data.pipeline` make every batch a
pure function of (seed, step); synchronous `fit` nevertheless *serializes*
host-side batch generation (a Python/numpy Markov walk) with the device
step. The `Prefetcher` moves that host work onto a background thread and
stages the next batch onto the device while step `i` runs, so the step
loop only ever blocks when the host is genuinely slower than the device.

Restart contract: because batches are addressed BY STEP (never by queue
position), prefetching cannot change the stream — `get(step)` returns
bitwise the same arrays the synchronous path would have produced, and a
save/restore/resume (or an elastic mesh rebuild) simply starts asking for
a different step. Stale speculative work is dropped, never consumed.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Any, Callable, Dict, Optional

PyTree = Any


def _default_stage(batch: Dict[str, Any]) -> Dict[str, Any]:
    import jax.numpy as jnp
    return {k: jnp.asarray(v) for k, v in batch.items()}


class Prefetcher:
    """Wraps a deterministic `source` (anything with `.batch(step)`).

    `get(step)` returns the staged batch for `step` and schedules the
    next `depth` steps on the background thread (double-buffered at the
    default depth=1). Completed-but-unclaimed futures for other steps are
    discarded on seek, preserving the pure-(seed, step) contract.
    """

    def __init__(self, source, *, depth: int = 1,
                 limit: Optional[int] = None,
                 stage: Optional[Callable[[Dict], Dict]] = None):
        assert depth >= 1, depth
        self.source = source
        self.depth = depth
        self.limit = limit      # first step NOT to produce (end of run)
        self._stage = stage or _default_stage
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="repro-prefetch")
        self._pending: Dict[int, Future] = {}
        self._lock = threading.Lock()
        self._closed = False
        # observability: how often the loop found its batch ready vs had
        # to fall back to a synchronous pull (miss == no overlap won)
        self.hits = 0
        self.misses = 0

    # ----------------------------------------------------------- internals
    def _produce(self, step: int):
        return self._stage(self.source.batch(step))

    def _schedule(self, step: int):
        if self.limit is not None and step >= self.limit:
            return      # never speculate past the end of the run
        if step not in self._pending:
            self._pending[step] = self._pool.submit(self._produce, step)

    # ------------------------------------------------------------- public
    def schedule(self, step: int):
        """Hint: start producing `step` in the background."""
        with self._lock:
            if not self._closed:
                self._schedule(step)

    def get(self, step: int) -> Dict[str, Any]:
        """The batch for `step` — bitwise identical to
        `source.batch(step)` post-staging, regardless of what was
        speculatively produced before."""
        with self._lock:
            if self._closed:
                return self._produce(step)
            fut = self._pending.pop(step, None)
            # a seek (restart/resume) invalidates speculation for other
            # steps; drop it so memory stays at O(depth) batches
            stale = [s for s in self._pending
                     if s < step or s > step + self.depth]
            for s in stale:
                self._pending.pop(s)
            for i in range(1, self.depth + 1):
                self._schedule(step + i)
        if fut is None:
            self.misses += 1
            return self._produce(step)
        self.hits += 1
        return fut.result()

    def close(self):
        with self._lock:
            self._closed = True
            self._pending.clear()
        self._pool.shutdown(wait=False)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class DelayedSource:
    """Injects a fixed host-side latency in front of a deterministic
    source — the workload model for the prefetch-overlap benchmark and
    tests (a slow tokenizer / storage read / augmentation stage)."""

    def __init__(self, source, delay_s: float):
        self.source = source
        self.delay_s = delay_s

    def batch(self, step: int):
        import time
        time.sleep(self.delay_s)
        return self.source.batch(step)

    def __getattr__(self, name):
        return getattr(self.source, name)
