"""Jit'd wrappers exposing the Pallas kernels at the granularity the core
library consumes (per-SEGMENT dots / per-element combine with per-segment
scalars), built on the block kernels + FusionLayout alignment.

`interpret` resolution lives in `kernels.backend`: interpreted off-TPU
(CPU validation per the brief), compiled on real TPU backends. The block
kernels now resolve it themselves, so these wrappers pass nothing.

`block_elems=None` auto-selects a valid block from the buffer length
(see `adasum_dots.auto_block_elems`); callers relying on auto must have
built their FusionLayout with `leaf_align` a multiple of the resolved
block so segment boundaries never cross a kernel block.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .adasum_dots import auto_block_elems, block_dots
from .adasum_combine import block_combine

# Alignment contract with repro.core.fusion: every layer starts at a
# multiple of BLOCK_ELEMS in the fused buffer, so each kernel block maps
# to exactly one layer (paper §4.4.3 boundary bookkeeping, made static).
BLOCK_ELEMS = 8192


def adasum_segment_dots(a: jnp.ndarray, b: jnp.ndarray, seg: jnp.ndarray,
                        num_segments: int, acc_dtype=jnp.float32,
                        block_elems: Optional[int] = BLOCK_ELEMS
                        ) -> jnp.ndarray:
    """[n] x2 + seg[n] -> [num_segments, 3] per-segment [a·b,a·a,b·b].

    Requires the FusionLayout block-alignment contract (each block is a
    single segment)."""
    if block_elems is None:
        block_elems = auto_block_elems(a.shape[0])
    blocks = block_dots(a, b, block_elems=block_elems)
    block_seg = seg[::block_elems]
    out = jax.ops.segment_sum(blocks, block_seg, num_segments=num_segments)
    return out.astype(acc_dtype)


def adasum_combine(a: jnp.ndarray, b: jnp.ndarray, s1: jnp.ndarray,
                   s2: jnp.ndarray, seg: jnp.ndarray,
                   block_elems: Optional[int] = BLOCK_ELEMS) -> jnp.ndarray:
    """x' = s1[seg]·a + s2[seg]·b via the fused combine kernel."""
    if block_elems is None:
        block_elems = auto_block_elems(a.shape[0])
    block_seg = seg[::block_elems]
    s1b = s1[block_seg]
    s2b = s2[block_seg]
    return block_combine(a, b, s1b, s2b, block_elems=block_elems)
