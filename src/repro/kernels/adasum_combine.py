"""Pallas TPU kernel: fused Adasum combine x' = s1·a + s2·b with
per-block (per-layer) scalars — Algorithm 1 line 18.

One pass over both buffers, one FMA each — the write-side counterpart of
the fused dot kernel. Scalars arrive as per-block arrays (one layer per
block by FusionLayout alignment), staged through SMEM-sized [1] blocks.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .adasum_dots import LANES, SUBLANES, auto_block_elems
from .backend import resolve_interpret


def _combine_kernel(s1_ref, s2_ref, a_ref, b_ref, o_ref):
    s1 = s1_ref[0].astype(jnp.float32)
    s2 = s2_ref[0].astype(jnp.float32)
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] = (s1 * a + s2 * b).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_elems", "interpret"))
def block_combine(a: jnp.ndarray, b: jnp.ndarray, s1b: jnp.ndarray,
                  s2b: jnp.ndarray, *, block_elems: Optional[int] = 8192,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """(n,), (n,), (nblk,), (nblk,) -> (n,) fused scale-add.
    block_elems=None derives the block from the scalar count (n // nblk)
    so callers that auto-selected their dots block stay consistent.
    interpret=None: compiled on TPU, interpreted elsewhere."""
    interpret = resolve_interpret(interpret)
    n = a.shape[0]
    if block_elems is None:
        block_elems = n // max(s1b.shape[0], 1)
        auto_block_elems(block_elems)   # validates the granule contract
    assert n % block_elems == 0, (n, block_elems)
    assert block_elems % (SUBLANES * LANES) == 0, block_elems
    rows = block_elems // LANES
    nblk = n // block_elems
    a2 = a.reshape(nblk * rows, LANES)
    b2 = b.reshape(nblk * rows, LANES)
    out = pl.pallas_call(
        _combine_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((1,), lambda i: (i,)),
                  pl.BlockSpec((1,), lambda i: (i,)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk * rows, LANES), a.dtype),
        interpret=interpret,
    )(s1b.astype(jnp.float32), s2b.astype(jnp.float32), a2, b2)
    return out.reshape(n)
