"""Pallas backend detection — one place to decide interpret vs compiled.

Every kernel entry point used to default `interpret=True`, which
validated on CPU but meant `use_pallas=True` on a real TPU silently ran
the (orders-of-magnitude slower) interpreter unless every call site
remembered to flip the flag. Kernels now default `interpret=None` and
resolve it here: compiled on TPU, interpreted everywhere else. An
explicit True/False always wins (tests pin interpret=True; TPU
microbenchmarks pin False to fail loudly off-TPU).
"""
from __future__ import annotations

from typing import Optional

import jax


def interpret_default() -> bool:
    """True (interpret) off-TPU, False (compile) on TPU."""
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: Optional[bool]) -> bool:
    return interpret_default() if interpret is None else bool(interpret)


def backend_summary() -> dict:
    """Environment stamp for analysis reports: which platform the trace
    ran on and how Pallas kernels would resolve there. Recorded in the
    comms-plan report's meta block (excluded from baseline diffs — the
    plan itself is platform-independent, the stamp is provenance)."""
    return {
        "platform": jax.default_backend(),
        "pallas_interpret_default": interpret_default(),
        "device_count": jax.device_count(),
    }
