"""Pallas TPU flash attention (inference/prefill path).

Beyond-paper optimization (§Perf iteration 3): the llava-next prefill_32k
cell is memory-bound on the quadratic [T, S] score matrix traffic
(chunked-but-materialized attention reads/writes ~6 TB/layer/device at
32k). Flash attention keeps the running-softmax state in VMEM so score
tiles never reach HBM: traffic drops to O(T·d + S·d).

Forward-only (no custom VJP) — training keeps the rematerialized chunked
path; serving/prefill uses this kernel.

Layout: grid over (batch·kv_heads·q_groups, q_blocks); each step streams
K/V tiles with an online-softmax accumulator. Causal + sliding-window
masks supported via position blocks.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale: float,
                  block_k: int, causal: bool, window: int):
    # q_ref: [1, block_q, dh]; k_ref/v_ref: [1, S, dh]
    _, block_q, dh = q_ref.shape
    S = k_ref.shape[1]
    qi = pl.program_id(1)
    # slice-style ref indexing (int indices break 0.4.x interpret mode)
    q = q_ref[...][0].astype(jnp.float32) * sm_scale
    q_positions = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(0, 1),
                            pl.dslice(j * block_k, block_k), slice(None))
                    )[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1),
                            pl.dslice(j * block_k, block_k), slice(None))
                    )[0].astype(jnp.float32)
        s = q @ k.T                                   # [bq, bk]
        k_positions = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_positions[None, :] <= q_positions[:, None]
        if window > 0:
            mask &= k_positions[None, :] > q_positions[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    n_k = S // block_k
    if causal:
        # only stream K tiles up to the causal frontier of this q block
        n_k_eff = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k,
                              n_k)
    else:
        n_k_eff = n_k
    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_k_eff, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]
                  ).astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, block_q: int = 512,
                    block_k: int = 512, interpret: Optional[bool] = None
                    ) -> jnp.ndarray:
    """q: [B,T,H,Dh]; k/v: [B,S,KV,Dh] (RoPE already applied) -> [B,T,H,Dh].

    H must be a multiple of KV. T % block_q == 0, S % block_k == 0.
    interpret=None: compiled on TPU, interpreted elsewhere."""
    interpret = resolve_interpret(interpret)
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    assert T % block_q == 0 and S % block_k == 0, (T, S, block_q, block_k)
    sm_scale = 1.0 / math.sqrt(Dh)

    # fold (B, KV, G) into one grid axis; per-(b,kv) K/V are shared by G
    qr = q.reshape(B, T, KV, G, Dh).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KV * G, T, Dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, S, Dh)
    kr = jnp.repeat(kr, G, axis=0)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, S, Dh)
    vr = jnp.repeat(vr, G, axis=0)

    kernel = functools.partial(_flash_kernel, sm_scale=sm_scale,
                               block_k=block_k, causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV * G, T // block_q),
        in_specs=[pl.BlockSpec((1, block_q, Dh), lambda h, i: (h, i, 0)),
                  pl.BlockSpec((1, S, Dh), lambda h, i: (h, 0, 0)),
                  pl.BlockSpec((1, S, Dh), lambda h, i: (h, 0, 0))],
        out_specs=pl.BlockSpec((1, block_q, Dh), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * G, T, Dh), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KV, G, T, Dh).transpose(0, 3, 1, 2, 4) \
        .reshape(B, T, H, Dh)
