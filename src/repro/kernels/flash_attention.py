"""Pallas TPU flash attention (inference/prefill path) and the
paged-gather decode kernel.

Beyond-paper optimization (§Perf iteration 3): the llava-next prefill_32k
cell is memory-bound on the quadratic [T, S] score matrix traffic
(chunked-but-materialized attention reads/writes ~6 TB/layer/device at
32k). Flash attention keeps the running-softmax state in VMEM so score
tiles never reach HBM: traffic drops to O(T·d + S·d).

Forward-only (no custom VJP) — training keeps the rematerialized chunked
path; serving/prefill uses this kernel.

Layout: grid over (batch·kv_heads·q_groups, q_blocks); each step streams
K/V tiles with an online-softmax accumulator. Causal + sliding-window
masks supported via position blocks.

`paged_decode_attention` is the serving-decode counterpart for the paged
KV arena (engine/serving paged layout): the page table rides in as a
scalar-prefetch operand, so each grid step DMAs exactly one physical
page's K/V tile — the kernel never materialises the gathered [B, cap]
K/V that the ref path builds in HBM — and an online-softmax accumulator
carries across the page axis of the grid. Validated in interpret mode
(PR-4 precedent); compiled on real TPU.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .backend import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, sm_scale: float,
                  block_k: int, causal: bool, window: int):
    # q_ref: [1, block_q, dh]; k_ref/v_ref: [1, S, dh]
    _, block_q, dh = q_ref.shape
    S = k_ref.shape[1]
    qi = pl.program_id(1)
    # slice-style ref indexing (int indices break 0.4.x interpret mode)
    q = q_ref[...][0].astype(jnp.float32) * sm_scale
    q_positions = qi * block_q + jax.lax.iota(jnp.int32, block_q)

    def body(j, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(0, 1),
                            pl.dslice(j * block_k, block_k), slice(None))
                    )[0].astype(jnp.float32)
        v = pl.load(v_ref, (pl.dslice(0, 1),
                            pl.dslice(j * block_k, block_k), slice(None))
                    )[0].astype(jnp.float32)
        s = q @ k.T                                   # [bq, bk]
        k_positions = j * block_k + jax.lax.iota(jnp.int32, block_k)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask &= k_positions[None, :] <= q_positions[:, None]
        if window > 0:
            mask &= k_positions[None, :] > q_positions[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    n_k = S // block_k
    if causal:
        # only stream K tiles up to the causal frontier of this q block
        n_k_eff = jnp.minimum(((qi + 1) * block_q + block_k - 1) // block_k,
                              n_k)
    else:
        n_k_eff = n_k
    acc0 = jnp.zeros((block_q, dh), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_k_eff, body, (acc0, m0, l0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]
                  ).astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, block_q: int = 512,
                    block_k: int = 512, interpret: Optional[bool] = None
                    ) -> jnp.ndarray:
    """q: [B,T,H,Dh]; k/v: [B,S,KV,Dh] (RoPE already applied) -> [B,T,H,Dh].

    H must be a multiple of KV. T % block_q == 0, S % block_k == 0.
    interpret=None: compiled on TPU, interpreted elsewhere."""
    interpret = resolve_interpret(interpret)
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    G = H // KV
    assert T % block_q == 0 and S % block_k == 0, (T, S, block_q, block_k)
    sm_scale = 1.0 / math.sqrt(Dh)

    # fold (B, KV, G) into one grid axis; per-(b,kv) K/V are shared by G
    qr = q.reshape(B, T, KV, G, Dh).transpose(0, 2, 3, 1, 4) \
        .reshape(B * KV * G, T, Dh)
    kr = k.transpose(0, 2, 1, 3).reshape(B * KV, S, Dh)
    kr = jnp.repeat(kr, G, axis=0)
    vr = v.transpose(0, 2, 1, 3).reshape(B * KV, S, Dh)
    vr = jnp.repeat(vr, G, axis=0)

    kernel = functools.partial(_flash_kernel, sm_scale=sm_scale,
                               block_k=block_k, causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B * KV * G, T // block_q),
        in_specs=[pl.BlockSpec((1, block_q, Dh), lambda h, i: (h, i, 0)),
                  pl.BlockSpec((1, S, Dh), lambda h, i: (h, 0, 0)),
                  pl.BlockSpec((1, S, Dh), lambda h, i: (h, 0, 0))],
        out_specs=pl.BlockSpec((1, block_q, Dh), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV * G, T, Dh), q.dtype),
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, KV, G, T, Dh).transpose(0, 3, 1, 2, 4) \
        .reshape(B, T, H, Dh)


# ------------------------------------------------------ paged decode kernel
def _paged_decode_kernel(pt_ref, pos_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, page_size: int,
                         n_pages: int, rolling: bool, scale: float):
    # grid (B, KV, logical page i); k_ref/v_ref hold ONE physical page's
    # tile [1, ps, 1, Dh] — the page table routed it here via the
    # scalar-prefetch index map, so the gather never touches HBM-wide
    # buffers. Online softmax carries across i in VMEM scratch.
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...][0, 0].astype(jnp.float32) * scale         # [G, Dh]
    k = k_ref[...][0, :, 0].astype(jnp.float32)              # [ps, Dh]
    v = v_ref[...][0, :, 0].astype(jnp.float32)
    s = q @ k.T                                              # [G, ps]

    p = pos_ref[b]
    cap = n_pages * page_size
    rows = i * page_size + jax.lax.broadcasted_iota(
        jnp.int32, (1, page_size), 1)                        # [1, ps]
    if rolling:
        slot_pos = p - ((p - rows) % cap)    # latest pos with pos%cap==row
    else:
        slot_pos = rows
    valid = (slot_pos >= 0) & (slot_pos <= p)
    s = jnp.where(valid, s, NEG_INF)

    m_prev, l_prev = m_ref[...], l_ref[...]                  # [G, 1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    pexp = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_prev * alpha + jnp.sum(pexp, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + pexp @ v

    @pl.when(i == pl.num_programs(2) - 1)
    def _():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = out.astype(o_ref.dtype)[None, None]


@functools.partial(jax.jit, static_argnames=("rolling", "interpret"))
def paged_decode_attention(q: jnp.ndarray, k_pages: jnp.ndarray,
                           v_pages: jnp.ndarray, page_table: jnp.ndarray,
                           pos: jnp.ndarray, *, rolling: bool = False,
                           interpret: Optional[bool] = None) -> jnp.ndarray:
    """One-token GQA decode over a paged KV arena.

    q: [B, H, Dh] (current token's queries, RoPE'd); k_pages/v_pages:
    [num_pages, page_size, KV, Dh] arenas (current token already
    written); page_table: int32 [B, pages_per_slot]; pos: int32 [B]
    tokens seen per slot BEFORE this step (rows at slot positions
    0..pos are attended — the write at pos included).

    rolling: sliding-window layout — logical row r holds the latest
    position p with p % cap == r (cap = pages_per_slot * page_size, a
    multiple of page_size by construction); masking reproduces the ref
    gather path exactly.

    Head h = kv * (H // KV) + g, matching the dense decode's grouping.
    Returns [B, H, Dh] in q.dtype."""
    interpret = resolve_interpret(interpret)
    B, H, Dh = q.shape
    NP, ps, KV, _ = k_pages.shape
    P = page_table.shape[1]
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)
    qr = q.reshape(B, KV, G, Dh)

    kernel = functools.partial(_paged_decode_kernel, page_size=ps,
                               n_pages=P, rolling=rolling, scale=scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, P),
        in_specs=[
            pl.BlockSpec((1, 1, G, Dh),
                         lambda b, kv, i, pt, ps_: (b, kv, 0, 0)),
            pl.BlockSpec((1, ps, 1, Dh),
                         lambda b, kv, i, pt, ps_: (pt[b, i], 0, kv, 0)),
            pl.BlockSpec((1, ps, 1, Dh),
                         lambda b, kv, i, pt, ps_: (pt[b, i], 0, kv, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, Dh),
                               lambda b, kv, i, pt, ps_: (b, kv, 0, 0)),
        scratch_shapes=[pltpu.VMEM((G, Dh), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32),
                        pltpu.VMEM((G, 1), jnp.float32)],
    )
    out = pl.pallas_call(
        kernel, grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, Dh), q.dtype),
        interpret=interpret,
    )(page_table, pos, qr, k_pages, v_pages)
    return out.reshape(B, H, Dh)
