"""Pallas TPU kernel: fused single-pass per-block [a·b, a·a, b·b].

This is the compute hot-spot the paper hand-vectorizes on CPU/GPU
(§4.4.2): Adasum needs three reductions over the same two gradient
buffers, and reading the buffers once (instead of three times) makes the
operation bandwidth-optimal. Higher-precision accumulation (§4.4.1) is
float32 here (TPU-idiomatic; the paper uses double on CPU — see
DESIGN.md §2).

TPU adaptation: the fused buffer is viewed as (rows, 128) — the VPU lane
width — and the grid walks row-blocks. Each grid step reduces one block
to a [1,3] partial in fp32; per-layer (segment) dots are recovered
outside by a tiny segment-sum over blocks, which is valid because the
FusionLayout aligns every layer to a block multiple (segment boundaries
never cross a block).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .backend import resolve_interpret

LANES = 128      # TPU VPU lane width
SUBLANES = 8     # fp32 sublane tile
UNIT = SUBLANES * LANES   # minimum block granule (fp32 tile)


def auto_block_elems(n: int, max_elems: int = 8192) -> int:
    """Largest multiple of UNIT (=1024) that divides `n`, capped at
    `max_elems`. This is the `block_elems=None` resolution rule for the
    block kernels: any buffer padded by FusionLayout (leaf_align >= UNIT)
    always has a valid block, so odd-sized buckets never trip the shape
    asserts."""
    if n <= 0 or n % UNIT:
        raise ValueError(
            f"buffer length {n} is not a positive multiple of {UNIT}; pad "
            f"it via fusion.make_layout(leaf_align={UNIT}) (or larger)")
    b = min(max_elems - max_elems % UNIT, n) or UNIT
    while b > UNIT and n % b:
        b -= UNIT
    return b


def _dots_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[0, 0] = jnp.sum(a * b)
    o_ref[0, 1] = jnp.sum(a * a)
    o_ref[0, 2] = jnp.sum(b * b)


@functools.partial(jax.jit, static_argnames=("block_elems", "interpret"))
def block_dots(a: jnp.ndarray, b: jnp.ndarray, *,
               block_elems: Optional[int] = 8192,
               interpret: Optional[bool] = None) -> jnp.ndarray:
    """(n,) x2 -> (n//block_elems, 3) fp32 partial dots.

    n must be a multiple of block_elems; block_elems a multiple of
    SUBLANES*LANES (=1024) — or None to auto-select the largest valid
    block from the buffer length (auto_block_elems). interpret=None:
    compiled on TPU, interpreted elsewhere (kernels.backend)."""
    interpret = resolve_interpret(interpret)
    n = a.shape[0]
    if block_elems is None:
        block_elems = auto_block_elems(n)
    assert n % block_elems == 0, (n, block_elems)
    assert block_elems % (SUBLANES * LANES) == 0, block_elems
    rows = block_elems // LANES
    nblk = n // block_elems
    a2 = a.reshape(nblk * rows, LANES)
    b2 = b.reshape(nblk * rows, LANES)
    return pl.pallas_call(
        _dots_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((rows, LANES), lambda i: (i, 0)),
                  pl.BlockSpec((rows, LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 3), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nblk, 3), jnp.float32),
        interpret=interpret,
    )(a2, b2)
