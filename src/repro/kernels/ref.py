"""Pure-jnp oracles for the Pallas kernels (the reference the kernels are
allclose-validated against)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_dots_ref(a: jnp.ndarray, b: jnp.ndarray, block_elems: int,
                   acc_dtype=jnp.float32) -> jnp.ndarray:
    """Per-block [a·b, a·a, b·b]: (n,) x2 -> (n//block_elems, 3)."""
    n = a.shape[0]
    assert n % block_elems == 0, (n, block_elems)
    af = a.astype(acc_dtype).reshape(n // block_elems, block_elems)
    bf = b.astype(acc_dtype).reshape(n // block_elems, block_elems)
    return jnp.stack([jnp.sum(af * bf, -1), jnp.sum(af * af, -1),
                      jnp.sum(bf * bf, -1)], axis=-1)


def combine_ref(a: jnp.ndarray, b: jnp.ndarray, s1b: jnp.ndarray,
                s2b: jnp.ndarray, block_elems: int) -> jnp.ndarray:
    """x' = s1[blk]*a + s2[blk]*b with per-block scalars: (n,) -> (n,)."""
    n = a.shape[0]
    nb = n // block_elems
    a2 = a.reshape(nb, block_elems)
    b2 = b.reshape(nb, block_elems)
    out = (s1b[:, None].astype(a.dtype) * a2
           + s2b[:, None].astype(b.dtype) * b2)
    return out.reshape(n)


def segment_dots_ref(a, b, seg, num_segments, acc_dtype=jnp.float32):
    """Direct per-segment dots (oracle for ops.adasum_segment_dots)."""
    af = a.astype(acc_dtype)
    bf = b.astype(acc_dtype)
    prods = jnp.stack([af * bf, af * af, bf * bf], axis=-1)
    return jax.ops.segment_sum(prods, seg, num_segments=num_segments)


def block_segment_dots_ref(a, b, block_seg, num_segments, block_elems,
                           acc_dtype=jnp.float32):
    """Per-segment dots via per-block partials + a tiny block-level
    segment reduction — the non-Pallas arm of the fused bucketed combine
    (same structure as block_dots + segment_sum, pure jnp). Valid under
    the FusionLayout alignment contract (no segment crosses a block)."""
    blocks = block_dots_ref(a, b, block_elems, acc_dtype)
    return jax.ops.segment_sum(blocks, block_seg,
                               num_segments=num_segments).astype(acc_dtype)
