"""Pallas TPU kernels for the Adasum compute hot-spots (paper §4.4.2):
fused per-block three-dot reduction and fused scale-combine."""
from . import ops, ref
from .adasum_dots import block_dots
from .adasum_combine import block_combine
