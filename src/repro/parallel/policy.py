"""Per-architecture runtime policy: Adasum span, FSDP, optimizer, backend.

`span` = number of Adasum leaves (paper: one per node/pod-group). For
small/medium archs one lane per DP rank (paper-pure tree over all ranks,
RVH backend). For the huge archs the paper's hierarchical mode (§4.2.2 +
§4.3) applies: plain sum-reduce inside a lane group (GSPMD reduce-scatter,
overlapped with backward) and Adasum across `span` lane groups, with
optimizer state ZeRO-partitioned. Derived from the 16 GB/chip v5e budget —
see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class RunPolicy:
    span: int = 0               # 0 => one lane per DP rank
    fsdp: bool = False          # ZeRO-3 params over `data`
    scatter_grads: bool = False # ZeRO-2: constrain lane grads over `data`
    backend: str = "rvh"        # combine backend when span==dp
    optimizer: str = "adam"
    param_dtype: str = "float32"
    local_steps: int = 1        # paper §5.2: local SGD steps per allreduce
    combine_op: str = "adasum"
    attn_chunk: int = 512
    accum_steps: int = 1        # microbatch gradient accumulation (§2.2):
                                # bounds saved-activation memory by 1/A
    accum_dtype: str = "float32"      # gradient-accumulator storage
    opt_state_dtype: str = "float32"  # Adam/LAMB m,v storage
    pad_heads: bool = False           # TP head alignment (exact; see
                                      # configs.base.pad_heads_for_tp)


_POLICIES = {
    # arch id (canonical)      span  fsdp   scatter backend
    "hymba_1p5b":            RunPolicy(0, False, False, "rvh", pad_heads=True),
    "moonshot_v1_16b_a3b":   RunPolicy(4, True, True, "gspmd_tree"),
    "mixtral_8x22b":         RunPolicy(2, True, True, "gspmd_tree",
                                       param_dtype="bfloat16",
                                       attn_chunk=256, accum_steps=8,
                                       accum_dtype="bfloat16",
                                       opt_state_dtype="bfloat16",
                                       pad_heads=True),
    "llava_next_34b":        RunPolicy(4, True, True, "gspmd_tree",
                                       accum_steps=4, pad_heads=True),
    "gemma_7b":              RunPolicy(0, False, False, "rvh"),
    "minitron_4b":           RunPolicy(0, False, False, "rvh", pad_heads=True),
    "minicpm3_4b":           RunPolicy(0, False, False, "rvh"),
    "qwen3_32b":             RunPolicy(4, True, True, "gspmd_tree",
                                       accum_steps=4, pad_heads=True),
    "seamless_m4t_large_v2": RunPolicy(0, False, False, "rvh"),
    "rwkv6_7b":              RunPolicy(0, False, False, "rvh"),
}


def get_policy(arch: str) -> RunPolicy:
    from repro.configs.base import canonical
    return _POLICIES.get(canonical(arch), RunPolicy())
