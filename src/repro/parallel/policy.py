"""Per-architecture runtime policy: Adasum span, FSDP, optimizer, backend.

`span` = number of Adasum leaves (paper: one per node/pod-group). For
small/medium archs one lane per DP rank (paper-pure tree over all ranks,
RVH backend). For the huge archs the paper's hierarchical mode (§4.2.2 +
§4.3) applies: plain sum-reduce inside a lane group (GSPMD reduce-scatter,
overlapped with backward) and Adasum across `span` lane groups, with
optimizer state ZeRO-partitioned. Derived from the 16 GB/chip v5e budget —
see DESIGN.md §4.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class RunPolicy:
    span: int = 0               # 0 => one lane per DP rank
    fsdp: bool = False          # ZeRO-3 params over `data`
    scatter_grads: bool = False # ZeRO-2: constrain lane grads over `data`
    backend: str = "rvh"        # combine backend when span==dp
    optimizer: str = "adam"
    param_dtype: str = "float32"
    local_steps: int = 1        # paper §5.2: local SGD steps per allreduce
    combine_delay: int = 0      # DaSGD-style delayed combine: the Adasum
                                # exchange for round i-1's deltas overlaps
                                # round i's compute (0 = synchronous)
    combine_op: str = "adasum"
    attn_chunk: int = 512
    accum_steps: int = 1        # microbatch gradient accumulation (§2.2):
                                # bounds saved-activation memory by 1/A
    accum_dtype: str = "float32"      # gradient-accumulator storage
    opt_state_dtype: str = "float32"  # Adam/LAMB m,v storage
    pad_heads: bool = False           # TP head alignment (exact; see
                                      # configs.base.pad_heads_for_tp)
    # combiner knobs, plumbed through to CombineConfig by the step builder
    # (previously silently dropped — paper §3.6 ablation was unreachable)
    combine_point: str = "auto"       # 'pre' | 'post' | 'auto'
    per_layer: bool = True            # per-layer Adasum granularity (§3.6)
    acc_dtype: str = "float32"        # dot accumulation dtype (§4.4.1)
    use_pallas: bool = False          # Pallas kernels for dots/combine
    compress: str = "none"            # 'int8' RVH wire compression
    fused_combine: bool = True        # bucketed single-pass gspmd_tree path
    fusion_threshold_mb: int = 64     # Horovod-style bucket budget (§4.4.3)
    combine_stats: bool = True        # surface CombineStats (grad-noise /
                                      # lane-orthogonality / gain metrics)
                                      # from the combiner's own dot products


def get_policy(arch: str) -> RunPolicy:
    """Per-arch policy. The preset table moved to
    `repro.engine.config._PRESETS`; this is the RunPolicy projection of
    it (lazy import: engine sits above this package)."""
    from repro.engine.config import preset_policy
    return preset_policy(arch)
