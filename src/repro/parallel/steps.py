"""DEPRECATED compat shim — the step builders moved to `repro.engine`.

`make_runtime` predates the unified engine API; new code should use

    from repro.engine import EngineConfig, TrainSession   # training loops
    from repro.engine import build_runtime                # custom loops

This module re-exports `Runtime` / `make_serve_step` and keeps
`make_runtime` working (with a DeprecationWarning) so pre-engine callers
and tests keep passing.
"""
from __future__ import annotations

import warnings

from repro.engine.build import (Runtime, build_runtime,   # noqa: F401
                                make_serve_step)


def make_runtime(model, mesh, rpol, **kwargs) -> Runtime:
    """Deprecated alias for `repro.engine.build_runtime`."""
    warnings.warn(
        "repro.parallel.make_runtime is deprecated; use "
        "repro.engine.TrainSession.from_config (or "
        "repro.engine.build_runtime for custom loops)",
        DeprecationWarning, stacklevel=2)
    return build_runtime(model, mesh, rpol, **kwargs)
