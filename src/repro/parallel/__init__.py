"""Distribution layer: sharding rules, runtime policies, step builders.

The step-builder symbols (`Runtime`, `make_runtime`, `make_serve_step`)
are loaded lazily: they now live in `repro.engine.build` (steps.py is a
deprecated shim), and an eager import here would cycle with the engine
package importing our sharding/policy modules.
"""
from .sharding import ShardingPolicy, param_specs, batch_specs, cache_specs
from .policy import RunPolicy, get_policy

_LAZY = ("Runtime", "make_runtime", "make_serve_step")


def __getattr__(name):
    if name in _LAZY:
        from . import steps
        return getattr(steps, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(list(globals()) + list(_LAZY))
