"""Distribution layer: sharding rules, runtime policies, step builders."""
from .sharding import ShardingPolicy, param_specs, batch_specs, cache_specs
from .policy import RunPolicy, get_policy
from .steps import Runtime, make_runtime, make_serve_step
