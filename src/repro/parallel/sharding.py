"""Sharding rules: parameter-tree paths -> PartitionSpecs.

Policy:
  * TP over the `model` axis: head-projection outputs (when head counts
    divide the axis), MLP hidden dims, expert dims (EP) or expert hidden
    (TP-in-expert), vocab (when divisible, else d_model).
  * When a head count does NOT divide the model axis (hymba 25H,
    minitron 24H, llava 56H, and all kv<16 GQA configs), the projection
    falls back to *contraction sharding* (input-dim over `model`) — memory
    still sharded, attention core replicated; see DESIGN.md + §Perf for
    the head-padding optimization.
  * FSDP over the `data` axis (optional): the non-TP dim of every large
    matrix additionally sharded over `data` (ZeRO-3; gathered per-layer
    inside the scan).
  * Optimizer state: ZeRO-1 — same specs as params (plus the lane axis in
    post-optimizer mode).
All rules respect divisibility: an axis that does not divide the dim is
dropped from the spec.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    tp_axis: str = "model"
    fsdp_axis: Optional[str] = None      # e.g. "data" for ZeRO-3
    tp_size: int = 1
    fsdp_size: int = 1


def _fits(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0


def spec_axes(spec: Optional[P]) -> Tuple[str, ...]:
    """The sorted set of mesh axes a PartitionSpec shards over — the
    grouping/psum key for sharding-aware fused combines (leaves sharded
    over the same axes can share one fused buffer: their local shards
    are disjoint slices, so one psum over exactly these axes finishes
    every dot without replication corrections)."""
    axes = set()
    for entry in (spec or ()):
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            axes.add(ax)
    return tuple(sorted(axes))


def _spec2(shape, pol: ShardingPolicy, tp_dim: int, lead: int = 0):
    """Spec for a matrix whose dim `tp_dim` gets TP and the other big dim
    gets FSDP. `lead` leading dims (layer-stack) stay unsharded."""
    entries = [None] * len(shape)
    if _fits(shape[tp_dim], pol.tp_size):
        entries[tp_dim] = pol.tp_axis
    if pol.fsdp_axis:
        for d in range(lead, len(shape)):
            if d != tp_dim and entries[d] is None and \
                    _fits(shape[d], pol.fsdp_size):
                entries[d] = pol.fsdp_axis
                break
    return P(*entries)


def _contraction_spec(shape, pol: ShardingPolicy, in_dim: int, lead: int = 0):
    """Fallback: shard the contraction (input) dim over TP."""
    entries = [None] * len(shape)
    if _fits(shape[in_dim], pol.tp_size):
        entries[in_dim] = pol.tp_axis
    if pol.fsdp_axis:
        for d in range(lead, len(shape)):
            if d != in_dim and entries[d] is None and \
                    _fits(shape[d], pol.fsdp_size):
                entries[d] = pol.fsdp_axis
                break
    return P(*entries)


def param_specs(cfg: ModelConfig, shapes: PyTree, pol: ShardingPolicy
                ) -> PyTree:
    """PartitionSpec pytree matching the param pytree (of ShapeDtypeStructs
    or arrays)."""
    tp = pol.tp_size
    heads_ok = _fits(cfg.n_heads, tp) or cfg.n_heads == 0
    kv_ok = _fits(cfg.n_kv_heads, tp)
    rwkv_heads_ok = cfg.family == "ssm" and \
        _fits(cfg.d_model // max(cfg.rwkv_head_dim, 1), tp)
    ssm_ok = _fits(cfg.ssm_heads or cfg.n_heads, tp)

    def rule(path, leaf) -> P:
        name = jax.tree_util.keystr(path)
        shape = leaf.shape
        lead = 1 if (".blocks" in name or "dense_blocks" in name
                     or "enc_blocks" in name or "dec_blocks" in name) else 0
        nd = len(shape)
        last2 = (nd - 2, nd - 1)

        def out_spec():   # (in, out_headed): TP on output
            return _spec2(shape, pol, last2[1], lead)

        def in_spec():    # (headed, out): TP on input
            return _spec2(shape, pol, last2[0], lead)

        def contraction():
            return _contraction_spec(shape, pol, last2[0], lead)

        if nd - lead < 1:
            return P(*([None] * nd))
        # ---- embeddings / heads ----
        if "embed" in name and "table" in name:
            if _fits(cfg.vocab_size, tp):
                return _spec2(shape, pol, 0)
            return _spec2(shape, pol, 1)
        if "lm_head" in name:
            if _fits(cfg.vocab_size, tp):
                return out_spec()
            return contraction()
        # ---- MoE ----
        if re.search(r"moe'?\]?\[?'?(w_gate|w_up)", name):
            tp_dim = 0 + lead if cfg.expert_partition == "expert" else nd - 1
            return _spec2(shape, pol, tp_dim, lead)
        if re.search(r"moe'?\]?\[?'?w_down", name):
            tp_dim = 0 + lead if cfg.expert_partition == "expert" else nd - 2
            return _spec2(shape, pol, tp_dim, lead)
        if "router" in name:
            return P(*([None] * nd))
        # ---- rwkv ----
        if "'time'" in name or "time." in name:
            if "w_o" in name:
                return in_spec() if rwkv_heads_ok else contraction()
            if re.search(r"w_[rkvg]", name):
                return out_spec() if rwkv_heads_ok else contraction()
            if "decay_B" in name and rwkv_heads_ok:
                return out_spec()
            if "bonus" in name and rwkv_heads_ok:
                return _spec2(shape, pol, lead)
            return P(*([None] * nd))
        if "'chan'" in name or "chan." in name:
            if "w_k" in name:
                return out_spec()
            if "w_v" in name:
                return in_spec()
            return P(*([None] * nd))
        # ---- mamba (hybrid mixer) ----
        if "mamba" in name:
            if re.search(r"w_[xz]", name) or "conv_w" in name:
                return out_spec() if ssm_ok else P(*([None] * nd))
            if "w_out" in name:
                return in_spec() if ssm_ok else contraction()
            return P(*([None] * nd))
        # ---- attention ----
        if re.search(r"w[q]\b|'wq'", name):
            return out_spec() if heads_ok else contraction()
        if re.search(r"'w[kv]'", name):
            # cross-attention (enc-dec) uses full heads; GQA uses kv heads
            ok = heads_ok if "xattn" in name else kv_ok
            return out_spec() if ok else contraction()
        if "'wo'" in name:
            return in_spec() if heads_ok else out_spec()
        if "q_up" in name or "kv_up" in name:
            return out_spec() if heads_ok else contraction()
        if "q_down" in name:
            return out_spec() if _fits(cfg.q_lora_rank, tp) else contraction()
        if "kv_down" in name:
            return contraction()
        # ---- MLP ----
        if re.search(r"w_gate|w_up", name):
            return out_spec()
        if "w_down" in name:
            return in_spec()
        # ---- frontends ----
        if "projector" in name or "frontend_proj" in name:
            if "w1" in name or "frontend_proj" in name:
                return out_spec()
            return in_spec()
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(rule, shapes)


def local_shape(shape: Sequence[int], spec: Optional[P],
                axis_sizes: Dict[str, int]) -> Tuple[int, ...]:
    """The per-device shard shape of a global `shape` under `spec` on a
    mesh with the given axis sizes (what shard_map bodies see)."""
    out = list(shape)
    for d, entry in enumerate(spec or ()):
        if entry is None:
            continue
        for ax in (entry if isinstance(entry, tuple) else (entry,)):
            out[d] //= axis_sizes[ax]
    return tuple(out)


def spec_violations(specs: PyTree, shapes: PyTree,
                    axis_sizes: Dict[str, int]) -> list:
    """Static validity check of a PartitionSpec tree against declared
    mesh axis sizes — no mesh or devices needed. Flags: a spec naming an
    axis the mesh doesn't have, a sharded dim the axis sizes don't
    divide, and one mesh axis used on two dims of the same leaf.
    Returns [(path, problem)] strings; the sharding linter
    (`repro.analysis.shardlint`) fails on any."""
    out = []

    def check(path, spec, leaf):
        if spec is None or leaf is None:
            return  # replicated entry / empty cache slot
        name = jax.tree_util.keystr(path)
        shape = tuple(leaf.shape)
        seen: set = set()
        for d, entry in enumerate(spec or ()):
            if entry is None:
                continue
            if d >= len(shape):
                out.append((name, f"spec {spec} longer than shape {shape}"))
                return
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                if ax not in axis_sizes:
                    out.append((name, f"dim {d}: unknown mesh axis {ax!r} "
                                f"(mesh has {sorted(axis_sizes)})"))
                    continue
                if ax in seen:
                    out.append((name, f"mesh axis {ax!r} used on more than "
                                f"one dim of {spec}"))
                seen.add(ax)
                if shape[d] % axis_sizes[ax] != 0:
                    out.append((name, f"dim {d} ({shape[d]}) not divisible "
                                f"by axis {ax!r}={axis_sizes[ax]}"))

    jax.tree_util.tree_map_with_path(
        check, specs, shapes,
        is_leaf=lambda x: isinstance(x, P) or x is None)
    return out


def batch_specs(batch_shapes: PyTree, dp_axes: Sequence[str]) -> PyTree:
    """Batch leaves sharded over the DP axes on dim 0."""
    dp = tuple(dp_axes)
    return jax.tree.map(
        lambda x: P(dp, *([None] * (len(x.shape) - 1))), batch_shapes)


def lane_batch_specs(batch_shapes: PyTree, dp_axes: Sequence[str],
                     span: int, dp_total: int) -> PyTree:
    """Specs for batches reshaped to (span, B//span, ...). When span ==
    dp_total the lane dim carries the DP axes; otherwise the lane dim is
    replicated and the inner batch is DP-sharded."""
    dp = tuple(dp_axes)

    def spec(x):
        tail = [None] * (len(x.shape) - 2)
        if span == dp_total:
            return P(dp, None, *tail)
        return P(None, dp, *tail)

    return jax.tree.map(spec, batch_shapes)


def cache_specs(cache_shapes: PyTree, cfg: ModelConfig, pol: ShardingPolicy,
                dp_axes: Sequence[str], batch: int, dp_total: int) -> PyTree:
    """KV-cache / state sharding for serving: batch dim over DP when it
    divides; sequence (capacity) dim over TP; falls back along each leaf."""
    dp = tuple(dp_axes)

    def spec(path, leaf):
        shape = leaf.shape
        entries = [None] * len(shape)
        # [L, B, S, ...] for kv caches; [L, B, H, ...] for states
        if len(shape) >= 2 and _fits(batch, dp_total) and shape[1] == batch:
            entries[1] = dp
        if len(shape) >= 3:
            # prefer TP on the capacity/seq dim (dim 2) when divisible
            if _fits(shape[2], pol.tp_size):
                entries[2] = pol.tp_axis
            elif len(shape) >= 4 and _fits(shape[3], pol.tp_size):
                entries[3] = pol.tp_axis
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec, cache_shapes)
