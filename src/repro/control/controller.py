"""The gradient-noise-adaptive batch/span controller (AdaBatch x Adasum).

Host-side decision logic only — no jax, no engine imports. The
controller watches the per-step `noise_scale` metric (the critical-
batch estimate `repro.control.noise` derives from Adasum's free dot
products), EMA-smooths it, and decides *when* to grow through a
hysteresis band:

    grow band   : ema_noise > grow_threshold * global_batch
    reset band  : ema_noise < grow_threshold * global_batch / 2

A resize fires only after `patience` consecutive in-band steps (noise
estimates are heavy-tailed; one spike must not double the batch), then
`cooldown` steps must pass before the next decision can even start
counting — the restarted run needs time to re-equilibrate its EMA at
the new batch. Growth itself is AdaBatch-style doubling
(`grow_factor`), span riding along when it keeps a power-of-two
divisor of dp, and the LR rescaled by the AdaScale gain of the factor
(`lr_rescale='adascale'`; 'linear' and 'none' are the ablations).

The controller only *plans* (`ResizePlan`); `repro.control.resize`
executes plans through the elastic save -> rebuild -> resume machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.runtime.elastic import ResizePlan, plan_grow, plan_shrink_batch

from .noise import NoiseEMA, gain_for_factor


@dataclasses.dataclass(frozen=True)
class ControllerConfig:
    grow_factor: int = 2         # batch multiplier per resize (AdaBatch)
    grow_threshold: float = 2.0  # grow while ema_noise > threshold * batch
    patience: int = 8            # consecutive in-band steps before a resize
    cooldown: int = 16           # steps after a resize before counting again
    warmup: int = 4              # steps before the EMA is trusted at all
    max_global_batch: int = 0    # hard cap (0 = uncapped)
    grow_span: bool = True       # grow Adasum span with the batch
    lr_rescale: str = "adascale" # 'adascale' | 'linear' | 'none'
    ema: float = 0.9             # noise-EMA decay
    shrink_threshold: float = 0.0  # shrink while ema_noise < this * batch
                                 # (0 = shrink direction off); LR divided
                                 # by the same gain the growth multiplied by
    min_global_batch: int = 0    # shrink floor (0 = span/1 floor only)

    @classmethod
    def from_engine(cls, cfg) -> "ControllerConfig":
        """Projection of the EngineConfig controller knobs."""
        return cls(grow_factor=cfg.grow_factor,
                   grow_threshold=cfg.grow_threshold,
                   patience=cfg.grow_patience, cooldown=cfg.grow_cooldown,
                   max_global_batch=cfg.max_global_batch,
                   grow_span=cfg.grow_span, lr_rescale=cfg.lr_rescale,
                   ema=cfg.noise_ema,
                   shrink_threshold=cfg.shrink_threshold,
                   min_global_batch=cfg.min_global_batch)


class BatchController:
    """Observes per-step metrics, emits ResizePlans (see module doc)."""

    def __init__(self, cfg: ControllerConfig, *, global_batch: int,
                 span: int, dp_total: int, lr: float):
        assert cfg.grow_factor >= 2
        assert cfg.lr_rescale in ("adascale", "linear", "none")
        assert cfg.shrink_threshold >= 0.0
        if cfg.shrink_threshold:
            # the bands must not overlap (2x reset margins either side)
            assert cfg.shrink_threshold < cfg.grow_threshold, cfg
        self.cfg = cfg
        self.global_batch = int(global_batch)
        self.span = int(span)
        self.dp_total = int(dp_total)
        self.lr = float(lr)
        self.noise = NoiseEMA(cfg.ema)
        self.var = NoiseEMA(cfg.ema)
        self.mu2 = NoiseEMA(cfg.ema)
        self._above = 0
        self._below = 0
        self._cool = 0
        self._exhausted = False         # growth capped
        self._shrink_stopped = False    # shrink floored
        self.decisions: List[ResizePlan] = []

    # ------------------------------------------------------------- observe
    def observe(self, step: int, metrics: Dict[str, float]
                ) -> Optional[ResizePlan]:
        """Feed one step's metrics; returns a ResizePlan when the
        hysteresis schedule decides to grow — or, with a shrink band
        configured (`shrink_threshold` > 0), to shrink when the noise
        scale falls below it. Metrics without a noise_scale key (stats
        off / span 1) are ignored."""
        ns = metrics.get("noise_scale")
        shrink_on = self.cfg.shrink_threshold > 0 and not self._shrink_stopped
        if ns is None or (self._exhausted and not shrink_on):
            return None
        ema = self.noise.update(ns)
        self.var.update(metrics.get("grad_var"))
        self.mu2.update(metrics.get("grad_mu2"))
        if self._cool > 0:
            self._cool -= 1
            return None
        if ema is None or self.noise.count < self.cfg.warmup:
            return None
        hi = self.cfg.grow_threshold * self.global_batch
        if ema > hi:
            self._above += 1
        elif ema < hi / 2.0:
            self._above = 0          # firmly out of band: reset patience
        lo = self.cfg.shrink_threshold * self.global_batch
        if shrink_on and ema < lo:
            self._below += 1
        elif ema > 2.0 * lo:
            self._below = 0          # firmly above the shrink band
        if self._above >= self.cfg.patience and not self._exhausted:
            plan = self._plan()
            self._above = 0
            if plan is None or not plan.grew:
                # cap reached: stop asking (the run continues at this batch)
                self._exhausted = True
                return None
            return plan
        if shrink_on and self._below >= self.cfg.patience:
            plan = self._plan_shrink()
            self._below = 0
            if plan is None or not plan.changed:
                # floor reached: stop planning shrinks
                self._shrink_stopped = True
                return None
            return plan
        return None

    # ---------------------------------------------------------------- plan
    def _lr_scale(self, factor: int) -> float:
        if self.cfg.lr_rescale == "linear":
            return float(factor)
        if self.cfg.lr_rescale == "none":
            return 1.0
        var = self.var.value or 0.0
        mu2 = self.mu2.value or 0.0
        if var <= 0.0 and mu2 <= 0.0:
            return 1.0
        return gain_for_factor(var, mu2, float(factor))

    def _plan(self) -> Optional[ResizePlan]:
        plan = plan_grow(self.global_batch, self.span, self.dp_total,
                         self.lr, factor=self.cfg.grow_factor,
                         grow_span=self.cfg.grow_span,
                         max_global_batch=self.cfg.max_global_batch,
                         lr_scale=self._lr_scale(self.cfg.grow_factor),
                         reason=f"ema_noise={self.noise.value:.1f}"
                                f">{self.cfg.grow_threshold:g}x"
                                f"{self.global_batch}")
        return plan

    def _plan_shrink(self) -> Optional[ResizePlan]:
        # the LR comes back down by the same gain the growth multiplied
        # by: 1/gain (adascale), 1/factor (linear), 1 (none)
        inv = 1.0 / max(self._lr_scale(self.cfg.grow_factor), 1e-12)
        plan = plan_shrink_batch(
            self.global_batch, self.span, self.dp_total, self.lr,
            factor=self.cfg.grow_factor, shrink_span=self.cfg.grow_span,
            min_global_batch=self.cfg.min_global_batch, lr_scale=inv,
            reason=f"ema_noise={self.noise.value:.1f}"
                   f"<{self.cfg.shrink_threshold:g}x{self.global_batch}")
        return plan

    # ------------------------------------------------------------- resized
    def notify_resized(self, plan: ResizePlan):
        """The driver executed `plan`: adopt the new operating point and
        start the cooldown. The noise EMA is kept (it re-equilibrates
        during cooldown — a fresh EMA would hit the warmup gate
        instead)."""
        self.decisions.append(plan)
        self.global_batch = plan.new_batch
        self.span = plan.new_span
        self.lr = plan.new_lr
        self._above = 0
        self._below = 0
        self._cool = self.cfg.cooldown
        if plan.shrank:
            self._exhausted = False   # headroom above the cap again
        if plan.grew:
            self._shrink_stopped = False
