"""Run fingerprinting for benchmark/controller telemetry.

Every `BENCH_history.jsonl` row (and `session.run_metadata()`) carries
the git SHA of the working tree and a stable hash of the resolved
EngineConfig, so a recorded number can always be traced back to the
exact code + config that produced it — including across the mid-run
config mutations the adaptive controller performs.
"""
from __future__ import annotations

import hashlib
import json
import os
import subprocess
from typing import Any, Dict, Optional


def git_sha(root: Optional[str] = None, short: bool = True) -> str:
    """The working tree's HEAD SHA ('' when not a git checkout / git
    unavailable — telemetry must never fail a run)."""
    try:
        args = ["git", "rev-parse", "--short" if short else "--verify",
                "HEAD"]
        out = subprocess.run(
            args, cwd=root or os.path.dirname(os.path.abspath(__file__)),
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else ""
    except Exception:
        return ""


def config_hash(cfg: Any) -> str:
    """Stable 12-hex digest of an EngineConfig (or any to_dict-able /
    plain dict): canonical-JSON sha1. Two configs hash equal iff every
    field matches — the adaptive controller's batch/span/lr mutations
    produce a new hash each resize."""
    if hasattr(cfg, "to_dict"):
        d = cfg.to_dict()
    elif isinstance(cfg, dict):
        d = cfg
    else:
        d = dict(vars(cfg))
    blob = json.dumps(d, sort_keys=True, default=str)
    return hashlib.sha1(blob.encode()).hexdigest()[:12]


def run_fingerprint(cfg: Any = None) -> Dict[str, str]:
    """{'git_sha': ..., 'config_hash': ...} (config_hash omitted when no
    config given) — the fields append_history stamps on every row."""
    fp = {"git_sha": git_sha()}
    if cfg is not None:
        fp["config_hash"] = config_hash(cfg)
    return fp
