"""Planned resize execution: the controller's decisions, made real.

`runtime/elastic.py`'s failure-shrink path generalizes into a *planned*
`resize()`: save a synchronous checkpoint, mutate the EngineConfig
(global batch, Adasum span, LR), rebuild mesh/runtime/combiner from it,
and resume from the manifest. The restore path re-places every leaf on
the live shardings (the PR-7 bitwise fix) and `reshard_lanes` folds or
splits the lane axis of per-lane optimizer state across a span change,
so resumed steps stay bitwise with an uninterrupted run at the new
operating point. Batches are pure (seed, step) functions, so the data
stream stays aligned across the resize — step N+1's batch is the same
whether or not a resize happened at N (at the new batch size, no
skipped or replayed steps).

`fit_adaptive` is the driver (`fit_elastic`'s sibling);
`ControllerCallback` raises the `ResizeSignal`; `log_effective`
validates + logs the settings actually in force after ANY rebuild.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

from repro.runtime.elastic import ResizePlan, ResizeSignal

from .controller import BatchController, ControllerConfig


def log_effective(session, label: str = "effective") -> Dict[str, Any]:
    """Validate and log the *effective* global batch / span / LR a
    session will actually run — called after every elastic rebuild
    (shrink or controller resize), because the config a driver *asked*
    for can be silently adjusted (span clamped to dp, preset
    overrides). Raises if the effective combination is inconsistent."""
    cfg, rt = session.config, session.runtime
    cfg.validate(rt.dp_total)
    if cfg.global_batch % rt.span:
        raise ValueError(f"effective global_batch={cfg.global_batch} not "
                         f"divisible by effective span={rt.span}")
    eff = {"global_batch": cfg.global_batch, "span": rt.span,
           "lane_rows": cfg.global_batch // rt.span, "lr": cfg.lr,
           "dp": rt.dp_total, "combine_path": rt.combine_path}
    print(f"[control] {label}: batch={eff['global_batch']} "
          f"span={eff['span']} lane_rows={eff['lane_rows']} "
          f"lr={eff['lr']:g} dp={eff['dp']} "
          f"combine_path={eff['combine_path']}")
    return eff


def apply_resize(config, plan: ResizePlan):
    """The config mutation a ResizePlan prescribes, validated. Span is
    written explicitly (not 0/auto) so the rebuilt runtime can't
    re-resolve it differently."""
    return dataclasses.replace(
        config, global_batch=plan.new_batch, span=plan.new_span,
        lr=plan.new_lr).validate()


class ControllerCallback:
    """Feeds per-step metrics to the BatchController; raises ResizeSignal
    when it decides to grow. Duck-typed Callback (no engine import —
    control sits below engine)."""

    def __init__(self, controller: BatchController):
        self.controller = controller

    def on_fit_start(self, session, start_step: int): ...

    def on_step_start(self, session, step: int): ...

    def on_fit_end(self, session, history): ...

    def on_step_end(self, session, step: int, metrics: Dict[str, float],
                    dt: float):
        plan = self.controller.observe(step, metrics)
        if plan is not None:
            raise ResizeSignal(step + 1, plan)


def fit_adaptive(config, steps: Optional[int] = None, *,
                 callbacks: Optional[List] = None, max_resizes: int = 8,
                 controller: Optional[BatchController] = None,
                 model=None, mesh=None,
                 ) -> Tuple[List[Dict[str, float]], Any]:
    """Noise-adaptive training driver: run `fit` with a BatchController
    watching the CombineStats metrics; on a ResizeSignal checkpoint,
    apply the plan to the config, rebuild the session (same mesh — dp
    does not change), and resume from the manifest. Returns (combined
    history, final session); the executed plans are on
    `session.resize_log` (and `controller.decisions`).

    The sibling of `engine.pipeline.fit_elastic` — same
    save -> rebuild -> resume skeleton, but the rebuild is *planned*
    (a growth the controller chose) instead of reactive (a failure)."""
    from repro.engine.session import TrainSession, default_callbacks

    if not config.ckpt_dir:
        raise ValueError("fit_adaptive needs EngineConfig.ckpt_dir (the "
                         "resize resumes from the manifest)")
    if not config.adaptive_batch:
        config = dataclasses.replace(config, adaptive_batch=True)
    config.validate()
    cbs = (default_callbacks(config) if callbacks is None
           else list(callbacks))
    history: List[Dict[str, float]] = []
    resize_log: List[Dict[str, Any]] = []
    ctrl = controller
    while True:
        session = TrainSession.from_config(config, model=model, mesh=mesh,
                                           callbacks=cbs)
        if ctrl is None:
            ctrl = BatchController(
                ControllerConfig.from_engine(config),
                global_batch=config.global_batch,
                span=session.runtime.span,
                dp_total=session.runtime.dp_total, lr=config.lr)
        if len(ctrl.decisions) < max_resizes:
            session.callbacks = list(session.callbacks) \
                + [ControllerCallback(ctrl)]
        log_effective(session, label="resize" if resize_log else "start")
        session.resize_log = resize_log
        try:
            history += session.fit(steps)
            return history, session
        except ResizeSignal as e:
            history += getattr(e, "history", [])
            # the flagged step completed (the signal fires from
            # on_step_end, carrying step+1): checkpoint it, barrier
            session.save_sync()
            resize_log.append({"step": e.step, **e.plan.to_dict()})
            print(f"[control] resize at step {e.step}: "
                  f"{e.plan.describe()}")
            mesh = session.mesh          # dp unchanged: keep the mesh
            session.close()
            config = apply_resize(config, e.plan)
            ctrl.notify_resized(e.plan)
