"""repro.control — gradient-noise-adaptive batch/span control.

Adasum's combiner already computes the pairwise gradient dot products
that measure lane orthogonality; this package turns that free signal
into a controller that grows global batch / Adasum span as measured
noise rises (AdaBatch x AdaScale x Adasum), executing each growth
through the elastic save -> rebuild -> resume machinery.

    noise.py      CombineStats -> noise-scale / gain metrics (pure math)
    controller.py EMA + hysteresis schedule -> ResizePlan decisions
    resize.py     plan execution: fit_adaptive / ControllerCallback /
                  apply_resize / log_effective
    telemetry.py  git SHA + config-hash run fingerprinting

Import layering: noise/controller/telemetry sit below the engine
(importable from repro.engine.build); resize drives the engine and is
loaded lazily here so `import repro.control` never recurses into a
partially-initialized engine package.
"""
from .noise import STAT_KEYS, NoiseEMA, gain_for_factor, summarize_stats
from .controller import BatchController, ControllerConfig
from .telemetry import config_hash, git_sha, run_fingerprint

_LAZY = ("ControllerCallback", "apply_resize", "fit_adaptive",
         "log_effective")

__all__ = ["STAT_KEYS", "NoiseEMA", "gain_for_factor", "summarize_stats",
           "BatchController", "ControllerConfig", "config_hash", "git_sha",
           "run_fingerprint", *_LAZY]


def __getattr__(name):
    if name in _LAZY:
        from . import resize
        return getattr(resize, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
