"""Gradient-noise-scale estimation from Adasum's free dot-product signal.

Adasum's combiner materializes, at every tree level, the pairwise
gradient dot products and squared norms (paper §3) — `CombineStats`
({'levels': f32 [L, 3]}, rows [Σ dot, Σ ‖a‖², Σ ‖b‖²]) surfaces them.
Level 0 pairs lanes that computed gradients on *independent* batch
shards, which makes its triple a two-sample gradient-noise estimate
(McCandlish et al., "An Empirical Model of Large-Batch Training"):

    E[g_a · g_b]            = ‖μ‖²                   (independent lanes)
    E[(‖g_a‖² + ‖g_b‖²)/2]  = ‖μ‖² + tr(Σ)/b_lane    (b_lane rows/lane)

so   mu2_hat = mean pair dot,   var_hat = mean lane sq − mu2_hat
estimate the squared mean-gradient norm and the per-lane gradient
variance, and

    noise_scale  B_noise ≈ b_lane · var_hat / mu2_hat

estimates the *critical batch size*: below it, batch rows add nearly
linear speedup; far above it, they are wasted. AdaScale's gain ratio
(Johnson et al.)

    gain(S) = (var + mu2) / (var / S + mu2)   in [1, S]

is the same quantity seen as the effective speedup of S lanes: → S when
lanes are orthogonal (pure noise, sum regime), → 1 when they agree
(mean regime). The controller grows global batch while
noise_scale >> global_batch; this module is pure math (jnp in-trace,
floats host-side) with no engine dependencies.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax.numpy as jnp

EPS = 1e-20

# the per-step metric keys summarize_stats emits (and session
# run_metadata / benchmark history record)
STAT_KEYS = ("grad_dot", "grad_sq", "lane_cos", "grad_var", "grad_mu2",
             "gain_ratio", "noise_scale")


def summarize_stats(stats: Dict[str, Any], span: int, lane_rows: int
                    ) -> Dict[str, jnp.ndarray]:
    """Scalar per-step metrics from a CombineStats pytree.

    stats: {'levels': [L, 3]} (traced or concrete); span, lane_rows are
    static Python ints. All outputs are 0-d f32 arrays (TrainSession
    floats them). With L == 0 (span 1: nothing was paired) every metric
    is 0 except gain_ratio = 1 — the single-lane limits.
    """
    levels = stats["levels"]
    if levels.shape[0] == 0 or span < 2:
        z = jnp.zeros((), jnp.float32)
        return {"grad_dot": z, "grad_sq": z, "lane_cos": z, "grad_var": z,
                "grad_mu2": z, "gain_ratio": jnp.ones((), jnp.float32),
                "noise_scale": z}
    pairs = span // 2
    dot_s, na_s, nb_s = levels[0, 0], levels[0, 1], levels[0, 2]
    grad_dot = dot_s / pairs                       # mean pair dot
    grad_sq = (na_s + nb_s) / (2 * pairs)          # mean per-lane ‖g‖²
    lane_cos = dot_s / (jnp.sqrt(na_s * nb_s) + EPS)
    mu2 = jnp.maximum(grad_dot, 0.0)
    var = jnp.maximum(grad_sq - grad_dot, 0.0)
    gain = jnp.clip((var + mu2) / (var / span + mu2 + EPS), 1.0, span)
    noise = lane_rows * var / (mu2 + EPS)
    f32 = lambda x: jnp.asarray(x, jnp.float32)
    return {"grad_dot": f32(grad_dot), "grad_sq": f32(grad_sq),
            "lane_cos": f32(lane_cos), "grad_var": f32(var),
            "grad_mu2": f32(mu2), "gain_ratio": f32(gain),
            "noise_scale": f32(noise)}


def gain_for_factor(var: float, mu2: float, factor: float) -> float:
    """AdaScale gain of growing the lane count / batch by `factor`,
    given the current per-lane variance and squared-mean estimates —
    the LR rescale the controller applies at a resize (host floats)."""
    if factor <= 1.0:
        return 1.0
    g = (var + mu2) / (var / factor + mu2 + EPS)
    return float(min(max(g, 1.0), factor))


class NoiseEMA:
    """Debiased exponential moving average over a host-side scalar
    stream, NaN/inf-guarded (a divergent step must not poison the
    controller): `update(x)` returns the current debiased mean."""

    def __init__(self, decay: float = 0.9):
        assert 0.0 <= decay < 1.0, decay
        self.decay = decay
        self._acc = 0.0
        self._w = 0.0
        self.count = 0

    def update(self, x: float) -> Optional[float]:
        import math
        if x is None or not math.isfinite(x):
            return self.value
        self._acc = self.decay * self._acc + (1.0 - self.decay) * float(x)
        self._w = self.decay * self._w + (1.0 - self.decay)
        self.count += 1
        return self.value

    @property
    def value(self) -> Optional[float]:
        if self._w <= 0.0:
            return None
        return self._acc / self._w
