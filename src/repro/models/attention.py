"""Attention variants: GQA/MQA/MHA (+qk-norm, sliding window), and MLA
(multi-head latent attention, minicpm3) with absorbed-latent decode.

Memory strategy: training/prefill attention is *query-chunked* — each
chunk materialises scores of shape [B, H, chunk, S] only (exact softmax,
no online rescaling needed since the full key axis is present per chunk).
For sliding-window attention the key axis is additionally sliced to
[window + chunk], keeping FLOPs O(T·window) instead of O(T²).

KV caches are fixed-capacity; sliding-window caches are rolling buffers
(slot = position mod window) with RoPE applied at write time.

Serving (engine/serving) uses *slotted* caches: `pos` is a vector [B] —
one write position per batch row — so a continuous-batching scheduler can
run rows at unequal sequence lengths in one decode call. The decode steps
dispatch on `cache.pos.ndim`; `per_slot=True` at init selects the layout.

Paged layout (the ServeEngine default): instead of a dense
`[B, cap, ...]` buffer per slot, K/V rows live in a global page arena
`[num_pages, page_size, ...]` shared by every slot, addressed through a
per-slot `page_table` [B, pages_per_slot] of int32 physical page ids.
Logical row r of slot b is `arena[page_table[b, r // ps], r % ps]`, so
the gather `arena[page_table[b]]` reconstructs exactly the dense layout
— the paged decode steps run the *identical* masked-attention math on it
and greedy tokens stay bitwise-equal to the dense cache. Physical page 0
is reserved as the trash page: free slots and unallocated table entries
point at it, so their garbage writes never corrupt live data. The page
tables are plain int32 leaves; the allocator (engine/serving/slots.
PagePool) rewrites them without ever changing a shape — admission,
growth, copy-on-write and eviction churn never retrace the decode step.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from repro.configs.base import ModelConfig

PyTree = Any
NEG_INF = -1e30


# ------------------------------------------------------------------ params
def gqa_init(key, cfg: ModelConfig, dtype) -> PyTree:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ks = L.split_keys(key, 4)
    wo = L.dense_init(ks[3], (h * dh, d), dtype)
    if cfg.orig_heads and cfg.orig_heads < h:
        # TP head padding (pad_heads_for_tp): padded q heads contribute
        # exactly nothing — zero their wo rows.
        mask = (jnp.arange(h) < cfg.orig_heads).astype(dtype)
        wo = wo * jnp.repeat(mask, dh)[:, None]
    p = {
        "wq": L.dense_init(ks[0], (d, h * dh), dtype),
        "wk": L.dense_init(ks[1], (d, kv * dh), dtype),
        "wv": L.dense_init(ks[2], (d, kv * dh), dtype),
        "wo": wo,
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def mla_init(key, cfg: ModelConfig, dtype) -> PyTree:
    d, h = cfg.d_model, cfg.n_heads
    qk_n, qk_r, vh = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    ks = L.split_keys(key, 6)
    return {
        "q_down": L.dense_init(ks[0], (d, cfg.q_lora_rank), dtype),
        "q_up": L.dense_init(ks[1], (cfg.q_lora_rank, h * (qk_n + qk_r)), dtype),
        "kv_down": L.dense_init(ks[2], (d, cfg.kv_lora_rank + qk_r), dtype),
        "kv_up": L.dense_init(ks[3], (cfg.kv_lora_rank, h * (qk_n + vh)), dtype),
        "wo": L.dense_init(ks[4], (h * vh, d), dtype),
        "q_norm": L.rmsnorm_init(cfg.q_lora_rank, dtype),
        "kv_norm": L.rmsnorm_init(cfg.kv_lora_rank, dtype),
    }


def convert_gqa_params(p: PyTree, cfg: ModelConfig, cfg_pad: ModelConfig,
                       dtype=jnp.float32) -> PyTree:
    """Exact weight conversion for pad_heads_for_tp: kv heads are
    block-duplicated f = kv2/kv times; REAL q heads are placed grouped by
    their original kv head (r-th real head of group j at position
    j*(h2/kv) + r) so the GQA q->kv mapping is preserved; padded q
    positions get zero wo rows (exactly no contribution)."""
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    h2, kv2 = cfg_pad.n_heads, cfg_pad.n_kv_heads
    f = kv2 // kv
    G, G2 = h // kv, h2 // kv2
    assert kv2 == kv * f and h2 == kv * f * G2 and G <= f * G2
    d = p["wq"].shape[0]

    def q_slot(i):
        j, r = divmod(i, G)
        return j * (f * G2) + r

    wq3 = p["wq"].reshape(d, h, dh)
    wo3 = p["wo"].reshape(h, dh, -1)
    slots = jnp.asarray([q_slot(i) for i in range(h)])
    wq2 = jnp.zeros((d, h2, dh), dtype).at[:, slots].set(wq3.astype(dtype))
    wo2 = jnp.zeros((h2, dh, wo3.shape[-1]), dtype) \
        .at[slots].set(wo3.astype(dtype))

    def dup(w):
        return jnp.repeat(w.reshape(d, kv, dh), f, axis=1).reshape(d, -1)

    out = dict(p, wq=wq2.reshape(d, h2 * dh), wk=dup(p["wk"]),
               wv=dup(p["wv"]), wo=wo2.reshape(h2 * dh, -1))
    return out


# ------------------------------------------------------- chunked core attn
def _chunked_attention(q, k, v, positions_q, positions_k, *, causal: bool,
                       window: int, chunk: int) -> jnp.ndarray:
    """q: [B,T,H,Dh], k/v: [B,S,KV,Dh] -> [B,T,H,Dh].

    H must be a multiple of KV (GQA groups). positions_*: [T]/[S] absolute
    positions for masking (RoPE already applied)."""
    B, T, H, Dh = q.shape
    S, KV = k.shape[1], k.shape[2]
    Dv = v.shape[-1]            # may differ from Dh (MLA)
    G = H // KV
    scale = 1.0 / math.sqrt(Dh)
    chunk = min(chunk, T)
    while T % chunk != 0:       # largest divisor <= requested chunk
        chunk -= 1
    n_chunks = T // chunk

    qc = q.reshape(B, n_chunks, chunk, KV, G, Dh)

    def do_chunk(i):
        qi = jax.lax.dynamic_slice_in_dim(qc, i, 1, axis=1)[:, 0]  # [B,c,KV,G,Dh]
        pos_qi = jax.lax.dynamic_slice_in_dim(positions_q, i * chunk, chunk)
        if window > 0 and S > window + chunk:
            # banded attention: only the [q_start - window, q_end) key slice
            start = jnp.clip(i * chunk - window, 0, S - (window + chunk))
            ki = jax.lax.dynamic_slice_in_dim(k, start, window + chunk, axis=1)
            vi = jax.lax.dynamic_slice_in_dim(v, start, window + chunk, axis=1)
            pos_ki = jax.lax.dynamic_slice_in_dim(positions_k, start,
                                                  window + chunk)
        else:
            ki, vi, pos_ki = k, v, positions_k
        scores = jnp.einsum("bckgd,bskd->bkgcs", qi.astype(jnp.float32),
                            ki.astype(jnp.float32)) * scale
        mask = jnp.ones((chunk, pos_ki.shape[0]), bool)
        if causal:
            mask &= pos_ki[None, :] <= pos_qi[:, None]
        if window > 0:
            mask &= pos_ki[None, :] > pos_qi[:, None] - window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(vi.dtype)
        out = jnp.einsum("bkgcs,bskd->bckgd", probs, vi)
        return out.reshape(B, chunk, H, Dv)

    if n_chunks == 1:
        return do_chunk(0)
    outs = jax.lax.map(do_chunk, jnp.arange(n_chunks))   # [n,B,c,H,Dv]
    return jnp.moveaxis(outs, 0, 1).reshape(B, T, H, Dv)


# ------------------------------------------------------------- GQA forward
def gqa_forward(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                positions: jnp.ndarray, compute_dtype=jnp.bfloat16,
                chunk: int = 512, use_flash: bool = False,
                return_kv: bool = False, prefix_kv=None):
    """Training / prefill forward. x: [B,T,D]; positions: [T].

    use_flash: route the core through the Pallas flash-attention kernel
    (forward-only: serving/prefill; score tiles never reach HBM).
    return_kv: also return the RoPE'd (k, v) — exactly what a decode
    cache stores — for the fused serving prefill.
    prefix_kv: (k, v) [B, S0, KV, Dh] of an already-cached shared prefix
    (RoPE'd at positions 0..S0-1). `positions` must then start at S0:
    the tail attends to prefix + tail, computing and returning K/V for
    the tail only — the shared-prefix extend-prefill."""
    B, T, D = x.shape
    h, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    x = x.astype(compute_dtype)
    q = (x @ params["wq"].astype(compute_dtype)).reshape(B, T, h, dh)
    k = (x @ params["wk"].astype(compute_dtype)).reshape(B, T, kv, dh)
    v = (x @ params["wv"].astype(compute_dtype)).reshape(B, T, kv, dh)
    if cfg.qk_norm:
        q = L.headwise_rmsnorm(params["q_norm"], q)
        k = L.headwise_rmsnorm(params["k_norm"], k)
    q = L.apply_rope(q, positions[None, :], cfg.rope_theta)
    k = L.apply_rope(k, positions[None, :], cfg.rope_theta)
    if prefix_kv is not None:
        assert cfg.sliding_window == 0, \
            "shared-prefix extend needs full attention (rolling pages churn)"
        pk, pv = prefix_kv
        if pk.shape[0] != B:     # one shared prefix for the whole group
            pk = jnp.broadcast_to(pk, (B,) + pk.shape[1:])
            pv = jnp.broadcast_to(pv, (B,) + pv.shape[1:])
        S0 = pk.shape[1]
        k_att = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
        v_att = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
        positions_k = jnp.concatenate(
            [jnp.arange(S0, dtype=positions.dtype), positions])
        out = _chunked_attention(q, k_att, v_att, positions, positions_k,
                                 causal=True, window=0, chunk=chunk)
    elif use_flash and T % 512 == 0:
        from repro.kernels.flash_attention import flash_attention
        # interpret resolves in kernels.backend: compiled on TPU,
        # interpreted elsewhere
        out = flash_attention(q, k, v, causal=True,
                              window=cfg.sliding_window)
    else:
        out = _chunked_attention(q, k, v, positions, positions, causal=True,
                                 window=cfg.sliding_window, chunk=chunk)
    out = out.reshape(B, T, h * dh) @ params["wo"].astype(compute_dtype)
    if return_kv:
        return out, (k, v)
    return out


# --------------------------------------------------------------- KV caches
class KVCache(NamedTuple):
    k: jnp.ndarray      # [B, cap, KV, Dh] (RoPE'd at write)
    v: jnp.ndarray      # [B, cap, KV, Dh]
    pos: jnp.ndarray    # int32 #tokens seen: scalar, or [B] (slotted)


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16, per_slot: bool = False) -> KVCache:
    cap = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    pos = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    return KVCache(jnp.zeros((batch, cap, kv, dh), dtype),
                   jnp.zeros((batch, cap, kv, dh), dtype), pos)


def gqa_decode_step(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                    cache: KVCache, compute_dtype=jnp.bfloat16
                    ) -> Tuple[jnp.ndarray, KVCache]:
    """One-token decode. x: [B,1,D].

    cache.pos scalar: all rows at the same position (training-style
    batch decode). cache.pos [B]: slotted serving — each row writes and
    masks at its own position/length."""
    B = x.shape[0]
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    cap = cache.k.shape[1]
    pos = cache.pos
    per_slot = pos.ndim == 1
    x = x.astype(compute_dtype)
    q = (x @ params["wq"].astype(compute_dtype)).reshape(B, 1, h, dh)
    k = (x @ params["wk"].astype(compute_dtype)).reshape(B, 1, kvh, dh)
    v = (x @ params["wv"].astype(compute_dtype)).reshape(B, 1, kvh, dh)
    if cfg.qk_norm:
        q = L.headwise_rmsnorm(params["q_norm"], q)
        k = L.headwise_rmsnorm(params["k_norm"], k)
    # rope positions: [B,1] per-slot, [1,1] shared
    posv = (pos[:, None] if per_slot else pos[None, None]).astype(jnp.float32)
    q = L.apply_rope(q, posv, cfg.rope_theta)
    k = L.apply_rope(k, posv, cfg.rope_theta)
    slot = jnp.where(cfg.sliding_window > 0, pos % cap,
                     jnp.minimum(pos, cap - 1))
    if per_slot:
        rows = jnp.arange(B)
        knew = cache.k.at[rows, slot].set(k[:, 0].astype(cache.k.dtype))
        vnew = cache.v.at[rows, slot].set(v[:, 0].astype(cache.v.dtype))
    else:
        knew = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), slot, axis=1)
        vnew = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), slot, axis=1)
    # absolute position held by each slot (rolling for SWA, linear otherwise)
    idx = jnp.arange(cap)
    posb = pos[:, None] if per_slot else pos[None, None]     # [B|1, 1]
    if cfg.sliding_window:
        slot_pos = posb - ((posb - idx[None, :]) % cap)  # latest p%cap==idx
    else:
        slot_pos = jnp.broadcast_to(idx[None, :], (posb.shape[0], cap))
    valid = (slot_pos >= 0) & (slot_pos <= posb)             # [B|1, cap]
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, kvh, h // kvh, dh)
    scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                        knew.astype(jnp.float32)) * scale
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(vnew.dtype)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, vnew).reshape(B, 1, h * dh)
    out = out.astype(compute_dtype) @ params["wo"].astype(compute_dtype)
    return out, KVCache(knew, vnew, pos + 1)


# ------------------------------------------------------------- paged caches
class PagedKVCache(NamedTuple):
    """GQA cache over a global page arena (vLLM-style PagedAttention).

    Logical row r of slot b lives at arena[page_table[b, r // ps], r % ps]
    where ps = page_size; pages_per_slot * ps == the dense cache capacity,
    so gathering a slot's pages reproduces the dense layout exactly."""
    k: jnp.ndarray           # [num_pages, page_size, KV, Dh] (RoPE'd)
    v: jnp.ndarray           # [num_pages, page_size, KV, Dh]
    page_table: jnp.ndarray  # int32 [B, pages_per_slot]; 0 = trash page
    pos: jnp.ndarray         # int32 [B] #tokens seen (always per-slot)


class PagedMLACache(NamedTuple):
    """MLA latent cache over a page arena (same addressing scheme)."""
    c_kv: jnp.ndarray        # [num_pages, page_size, kv_lora]
    k_rope: jnp.ndarray      # [num_pages, page_size, qk_rope]
    page_table: jnp.ndarray  # int32 [B, pages_per_slot]
    pos: jnp.ndarray         # int32 [B]


PAGED_CACHE_TYPES = (PagedKVCache, PagedMLACache)


def paged_capacity(cfg: ModelConfig, max_len: int) -> int:
    """The dense capacity a paged slot must reproduce (rolling window
    for SWA, max_len otherwise)."""
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def init_paged_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                        page_size: int, num_pages: int,
                        dtype=jnp.bfloat16) -> PagedKVCache:
    cap = paged_capacity(cfg, max_len)
    assert page_size > 0 and cap % page_size == 0, (cap, page_size)
    kv, dh = cfg.n_kv_heads, cfg.resolved_head_dim
    return PagedKVCache(
        jnp.zeros((num_pages, page_size, kv, dh), dtype),
        jnp.zeros((num_pages, page_size, kv, dh), dtype),
        jnp.zeros((batch, cap // page_size), jnp.int32),
        jnp.zeros((batch,), jnp.int32))


def init_paged_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                         page_size: int, num_pages: int,
                         dtype=jnp.bfloat16) -> PagedMLACache:
    assert page_size > 0 and max_len % page_size == 0, (max_len, page_size)
    return PagedMLACache(
        jnp.zeros((num_pages, page_size, cfg.kv_lora_rank), dtype),
        jnp.zeros((num_pages, page_size, cfg.qk_rope_head_dim), dtype),
        jnp.zeros((batch, max_len // page_size), jnp.int32),
        jnp.zeros((batch,), jnp.int32))


def _paged_slot(table: jnp.ndarray, row: jnp.ndarray, ps: int):
    """(physical page, offset) per slot for logical row `row` [B]."""
    pg = jnp.take_along_axis(table, (row // ps)[:, None], axis=1)[:, 0]
    return pg, row % ps


def _paged_write(arena: jnp.ndarray, pg: jnp.ndarray, off: jnp.ndarray,
                 val: jnp.ndarray) -> jnp.ndarray:
    """Write one value per slot at (page, offset). Free slots' tables
    point at trash page 0, so their garbage writes are inert."""
    return arena.at[pg, off].set(val.astype(arena.dtype))


def gqa_paged_decode_step(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                          cache: PagedKVCache, compute_dtype=jnp.bfloat16
                          ) -> Tuple[jnp.ndarray, PagedKVCache]:
    """One-token decode over the paged arena. Identical math to the
    per-slot `gqa_decode_step` on the page-gathered K/V (the gather
    reconstructs the dense layout row-for-row), so greedy tokens are
    bitwise-equal to the dense slotted cache."""
    B = x.shape[0]
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ps = cache.k.shape[1]
    cap = cache.page_table.shape[1] * ps
    pos = cache.pos
    x = x.astype(compute_dtype)
    q = (x @ params["wq"].astype(compute_dtype)).reshape(B, 1, h, dh)
    k = (x @ params["wk"].astype(compute_dtype)).reshape(B, 1, kvh, dh)
    v = (x @ params["wv"].astype(compute_dtype)).reshape(B, 1, kvh, dh)
    if cfg.qk_norm:
        q = L.headwise_rmsnorm(params["q_norm"], q)
        k = L.headwise_rmsnorm(params["k_norm"], k)
    posv = pos[:, None].astype(jnp.float32)
    q = L.apply_rope(q, posv, cfg.rope_theta)
    k = L.apply_rope(k, posv, cfg.rope_theta)
    row = jnp.where(cfg.sliding_window > 0, pos % cap,
                    jnp.minimum(pos, cap - 1))
    pg, off = _paged_slot(cache.page_table, row, ps)
    knew = _paged_write(cache.k, pg, off, k[:, 0])
    vnew = _paged_write(cache.v, pg, off, v[:, 0])
    if jax.default_backend() == "tpu":
        from repro.kernels.flash_attention import paged_decode_attention
        out = paged_decode_attention(q[:, 0], knew, vnew, cache.page_table,
                                     pos, rolling=cfg.sliding_window > 0)
        out = out.reshape(B, 1, h * dh)
    else:
        # ref path: gather the slot's pages back into the dense layout
        kfull = knew[cache.page_table].reshape(B, cap, kvh, dh)
        vfull = vnew[cache.page_table].reshape(B, cap, kvh, dh)
        idx = jnp.arange(cap)
        posb = pos[:, None]
        if cfg.sliding_window:
            slot_pos = posb - ((posb - idx[None, :]) % cap)
        else:
            slot_pos = jnp.broadcast_to(idx[None, :], (B, cap))
        valid = (slot_pos >= 0) & (slot_pos <= posb)
        scale = 1.0 / math.sqrt(dh)
        qg = q.reshape(B, kvh, h // kvh, dh)
        scores = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                            kfull.astype(jnp.float32)) * scale
        scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1).astype(vfull.dtype)
        out = jnp.einsum("bkgs,bskd->bkgd", probs, vfull).reshape(B, 1,
                                                                  h * dh)
    out = out.astype(compute_dtype) @ params["wo"].astype(compute_dtype)
    return out, PagedKVCache(knew, vnew, cache.page_table, pos + 1)


# ------------------------------------------------- speculative verification
#
# Multi-token verify steps for speculative decoding: x holds the embeds
# of [last committed token, draft_1 .. draft_{T-1}] and row b scores all
# T positions pos_b..pos_b+T-1 against the cache in ONE forward. The
# write-then-mask design keeps greedy argmax per position bitwise-equal
# to T sequential decode steps: all T K/V rows are written first, then
# query t masks rows at positions > pos_b + t to NEG_INF — exactly the
# key set (and the identical masked-softmax float program) single-token
# decode sees, with the not-yet-valid rows contributing exact fp32
# zeros, the same way trash-page garbage already cancels on the paged
# path. The returned cache keeps `pos` UNCHANGED: the caller commits the
# accepted prefix by rewriting pos (and, paged, page-table values) only
# — rejected rows beyond the new pos are masked garbage that the next
# writes overwrite, which is what makes rejection free.
#
# Caller contract (ServeEngine's speculation tick falls back to plain
# decode otherwise): per-slot pos [B], and pos + T - 1 < capacity for
# every live row — no rolling wrap-around and no linear clamping, so
# write rows are exactly pos+t and no live row is clobbered.

def _verify_rows(cfg: ModelConfig, pos: jnp.ndarray, T: int, cap: int):
    """(absolute positions [B,T], write rows [B,T]) for a verify step.
    Live rows satisfy pos+T-1 < cap so rows == positions; the mod/min
    only keeps garbage (free-slot) rows in bounds, same as decode."""
    post = pos[:, None] + jnp.arange(T)
    row = jnp.where(cfg.sliding_window > 0, post % cap,
                    jnp.minimum(post, cap - 1))
    return post, row


def _verify_valid(cfg: ModelConfig, post: jnp.ndarray, cap: int):
    """Per-query-position validity mask [B,T,cap]: query t sees exactly
    the rows a single-token decode at pos+t would (rolling or linear)."""
    B, T = post.shape
    idx = jnp.arange(cap)
    posb = post[:, :, None]                                  # [B,T,1]
    if cfg.sliding_window:
        slot_pos = posb - ((posb - idx[None, None, :]) % cap)
    else:
        slot_pos = jnp.broadcast_to(idx[None, None, :], (B, T, cap))
    return (slot_pos >= 0) & (slot_pos <= posb)


def _gqa_verify_attend(params, cfg: ModelConfig, q, kfull, vfull, valid,
                       compute_dtype):
    """Masked multi-position GQA attention: q [B,T,H,Dh] against the
    dense-layout keys [B,cap,KV,Dh] under `valid` [B,T,cap]."""
    B, T = q.shape[:2]
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    scale = 1.0 / math.sqrt(dh)
    qg = q.reshape(B, T, kvh, h // kvh, dh)
    scores = jnp.einsum("btkgd,bskd->btkgs", qg.astype(jnp.float32),
                        kfull.astype(jnp.float32)) * scale
    scores = jnp.where(valid[:, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(vfull.dtype)
    out = jnp.einsum("btkgs,bskd->btkgd", probs, vfull).reshape(B, T, h * dh)
    return out.astype(compute_dtype) @ params["wo"].astype(compute_dtype)


def _gqa_verify_qkv(params, cfg: ModelConfig, x, post, compute_dtype):
    B, T, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    x = x.astype(compute_dtype)
    q = (x @ params["wq"].astype(compute_dtype)).reshape(B, T, h, dh)
    k = (x @ params["wk"].astype(compute_dtype)).reshape(B, T, kvh, dh)
    v = (x @ params["wv"].astype(compute_dtype)).reshape(B, T, kvh, dh)
    if cfg.qk_norm:
        q = L.headwise_rmsnorm(params["q_norm"], q)
        k = L.headwise_rmsnorm(params["k_norm"], k)
    posv = post.astype(jnp.float32)
    q = L.apply_rope(q, posv, cfg.rope_theta)
    k = L.apply_rope(k, posv, cfg.rope_theta)
    return q, k, v


def gqa_verify_step(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                    cache: KVCache, compute_dtype=jnp.bfloat16
                    ) -> Tuple[jnp.ndarray, KVCache]:
    """T-token verify over the dense slotted cache. x: [B,T,D]."""
    B, T, _ = x.shape
    cap = cache.k.shape[1]
    pos = cache.pos
    post, row = _verify_rows(cfg, pos, T, cap)
    q, k, v = _gqa_verify_qkv(params, cfg, x, post, compute_dtype)
    rows_b = jnp.arange(B)[:, None]
    knew = cache.k.at[rows_b, row].set(k.astype(cache.k.dtype))
    vnew = cache.v.at[rows_b, row].set(v.astype(cache.v.dtype))
    valid = _verify_valid(cfg, post, cap)
    out = _gqa_verify_attend(params, cfg, q, knew, vnew, valid,
                             compute_dtype)
    return out, KVCache(knew, vnew, pos)


def gqa_paged_verify_step(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                          cache: PagedKVCache, compute_dtype=jnp.bfloat16
                          ) -> Tuple[jnp.ndarray, PagedKVCache]:
    """T-token verify over the paged arena: identical math to
    `gqa_verify_step` on the page-gathered K/V (free slots write through
    trash page 0, inert as ever)."""
    B, T, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    ps = cache.k.shape[1]
    cap = cache.page_table.shape[1] * ps
    pos = cache.pos
    post, row = _verify_rows(cfg, pos, T, cap)
    q, k, v = _gqa_verify_qkv(params, cfg, x, post, compute_dtype)
    pgs = jnp.take_along_axis(cache.page_table, row // ps, axis=1)
    offs = row % ps
    knew = cache.k.at[pgs, offs].set(k.astype(cache.k.dtype))
    vnew = cache.v.at[pgs, offs].set(v.astype(cache.v.dtype))
    kfull = knew[cache.page_table].reshape(B, cap, kvh, dh)
    vfull = vnew[cache.page_table].reshape(B, cap, kvh, dh)
    valid = _verify_valid(cfg, post, cap)
    out = _gqa_verify_attend(params, cfg, q, kfull, vfull, valid,
                             compute_dtype)
    return out, PagedKVCache(knew, vnew, cache.page_table, pos)


def _mla_verify_attend(params, cfg: ModelConfig, q_nope, q_rope, cfull,
                       rfull, post, compute_dtype):
    """Absorbed-latent multi-position MLA attention (linear layout only —
    MLA has no sliding window)."""
    B, T = post.shape
    h = cfg.n_heads
    qk_n, qk_r, vh, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                         cfg.v_head_dim, cfg.kv_lora_rank)
    cap = cfull.shape[1]
    kv_up = params["kv_up"].astype(compute_dtype).reshape(r, h, qk_n + vh)
    w_k = kv_up[..., :qk_n]
    w_v = kv_up[..., qk_n:]
    q_eff = jnp.einsum("bthn,rhn->bthr", q_nope, w_k)
    scores = (jnp.einsum("bthr,bsr->bths", q_eff.astype(jnp.float32),
                         cfull.astype(jnp.float32))
              + jnp.einsum("bthr,bsr->bths", q_rope.astype(jnp.float32),
                           rfull.astype(jnp.float32)))
    scores = scores / math.sqrt(qk_n + qk_r)
    valid = jnp.arange(cap)[None, None, :] <= post[:, :, None]  # [B,T,cap]
    scores = jnp.where(valid[:, :, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bths,bsr->bthr", probs.astype(cfull.dtype), cfull)
    out = jnp.einsum("bthr,rhv->bthv", lat, w_v).reshape(B, T, h * vh)
    return out.astype(compute_dtype) @ params["wo"].astype(compute_dtype)


def mla_verify_step(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                    cache: "MLACache", compute_dtype=jnp.bfloat16):
    """T-token absorbed-latent verify over the dense slotted MLA cache."""
    B, T, _ = x.shape
    pos = cache.pos
    cap = cache.c_kv.shape[1]
    x = x.astype(compute_dtype)
    post, row = _verify_rows(cfg, pos, T, cap)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x,
                                            post.astype(jnp.float32),
                                            compute_dtype)
    rows_b = jnp.arange(B)[:, None]
    cnew = cache.c_kv.at[rows_b, row].set(c_kv.astype(cache.c_kv.dtype))
    rnew = cache.k_rope.at[rows_b, row].set(k_rope.astype(
        cache.k_rope.dtype))
    out = _mla_verify_attend(params, cfg, q_nope, q_rope, cnew, rnew, post,
                             compute_dtype)
    return out, MLACache(cnew, rnew, pos)


def mla_paged_verify_step(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                          cache: PagedMLACache, compute_dtype=jnp.bfloat16
                          ) -> Tuple[jnp.ndarray, PagedMLACache]:
    """T-token absorbed-latent verify over the paged latent arena."""
    B, T, _ = x.shape
    r, qk_r = cfg.kv_lora_rank, cfg.qk_rope_head_dim
    ps = cache.c_kv.shape[1]
    cap = cache.page_table.shape[1] * ps
    pos = cache.pos
    x = x.astype(compute_dtype)
    post, row = _verify_rows(cfg, pos, T, cap)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x,
                                            post.astype(jnp.float32),
                                            compute_dtype)
    pgs = jnp.take_along_axis(cache.page_table, row // ps, axis=1)
    offs = row % ps
    cnew = cache.c_kv.at[pgs, offs].set(c_kv.astype(cache.c_kv.dtype))
    rnew = cache.k_rope.at[pgs, offs].set(k_rope.astype(cache.k_rope.dtype))
    cfull = cnew[cache.page_table].reshape(B, cap, r)
    rfull = rnew[cache.page_table].reshape(B, cap, qk_r)
    out = _mla_verify_attend(params, cfg, q_nope, q_rope, cfull, rfull,
                             post, compute_dtype)
    return out, PagedMLACache(cnew, rnew, cache.page_table, pos)


# ---------------------------------------------------------------- MLA path
class MLACache(NamedTuple):
    c_kv: jnp.ndarray    # [B, cap, kv_lora]
    k_rope: jnp.ndarray  # [B, cap, qk_rope]
    pos: jnp.ndarray


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int,
                   dtype=jnp.bfloat16, per_slot: bool = False) -> MLACache:
    return MLACache(jnp.zeros((batch, max_len, cfg.kv_lora_rank), dtype),
                    jnp.zeros((batch, max_len, cfg.qk_rope_head_dim), dtype),
                    jnp.zeros((batch,) if per_slot else (), jnp.int32))


def _mla_qkv(params, cfg, x, positions, compute_dtype):
    """positions: pre-shaped [B|1, T] (per-row for slotted decode)."""
    B, T, _ = x.shape
    h = cfg.n_heads
    qk_n, qk_r = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    cq = L.rmsnorm(params["q_norm"], x @ params["q_down"].astype(compute_dtype),
                   cfg.norm_eps)
    q = (cq @ params["q_up"].astype(compute_dtype)).reshape(B, T, h, qk_n + qk_r)
    q_nope, q_rope = q[..., :qk_n], q[..., qk_n:]
    q_rope = L.apply_rope(q_rope, positions, cfg.rope_theta)
    ckv_full = x @ params["kv_down"].astype(compute_dtype)
    c_kv = L.rmsnorm(params["kv_norm"], ckv_full[..., :cfg.kv_lora_rank],
                     cfg.norm_eps)
    k_rope = ckv_full[..., cfg.kv_lora_rank:][:, :, None, :]   # 1 shared head
    k_rope = L.apply_rope(k_rope, positions, cfg.rope_theta)[:, :, 0]
    return q_nope, q_rope, c_kv, k_rope


def mla_forward(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                positions: jnp.ndarray, compute_dtype=jnp.bfloat16,
                chunk: int = 512, return_kv: bool = False, prefix_kv=None):
    """Training/prefill MLA: materialize k/v from the latent (naive path).

    return_kv: also return the latents (c_kv, k_rope) — the decode-cache
    contents — for the fused serving prefill.
    prefix_kv: (c_kv, k_rope) [B, S0, ...] cached shared-prefix latents;
    `positions` must then start at S0 (extend-prefill, tail-only
    compute)."""
    B, T, _ = x.shape
    h = cfg.n_heads
    qk_n, vh = cfg.qk_nope_head_dim, cfg.v_head_dim
    x = x.astype(compute_dtype)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, positions[None, :],
                                            compute_dtype)
    c_all, r_all, positions_k = c_kv, k_rope, positions
    if prefix_kv is not None:
        pc, pr = prefix_kv
        if pc.shape[0] != B:     # one shared prefix for the whole group
            pc = jnp.broadcast_to(pc, (B,) + pc.shape[1:])
            pr = jnp.broadcast_to(pr, (B,) + pr.shape[1:])
        S0 = pc.shape[1]
        c_all = jnp.concatenate([pc.astype(c_kv.dtype), c_kv], axis=1)
        r_all = jnp.concatenate([pr.astype(k_rope.dtype), k_rope], axis=1)
        positions_k = jnp.concatenate(
            [jnp.arange(S0, dtype=positions.dtype), positions])
    S = c_all.shape[1]
    kv = (c_all @ params["kv_up"].astype(compute_dtype)).reshape(
        B, S, h, qk_n + vh)
    k_nope, v = kv[..., :qk_n], kv[..., qk_n:]
    # fold the shared rope-key into per-head keys by concatenation
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        r_all[:, :, None, :], (B, S, h, cfg.qk_rope_head_dim))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    out = _chunked_attention(q, k, v, positions, positions_k, causal=True,
                             window=0, chunk=chunk)
    out = out.reshape(B, T, h * vh) @ params["wo"].astype(compute_dtype)
    if return_kv:
        return out, (c_kv, k_rope)
    return out


def mla_decode_step(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                    cache: MLACache, compute_dtype=jnp.bfloat16
                    ) -> Tuple[jnp.ndarray, MLACache]:
    """Absorbed-latent decode: attention runs in the kv_lora space, so the
    cache stays compressed (the MLA memory win). cache.pos [B] = slotted
    per-row positions (serving), scalar = shared position."""
    B = x.shape[0]
    h = cfg.n_heads
    qk_n, qk_r, vh, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                         cfg.v_head_dim, cfg.kv_lora_rank)
    pos = cache.pos
    per_slot = pos.ndim == 1
    cap = cache.c_kv.shape[1]
    x = x.astype(compute_dtype)
    posv = (pos[:, None] if per_slot else pos[None, None]).astype(jnp.float32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, posv, compute_dtype)
    if per_slot:
        rows = jnp.arange(B)
        wslot = jnp.minimum(pos, cap - 1)
        cnew = cache.c_kv.at[rows, wslot].set(c_kv[:, 0].astype(
            cache.c_kv.dtype))
        rnew = cache.k_rope.at[rows, wslot].set(k_rope[:, 0].astype(
            cache.k_rope.dtype))
    else:
        cnew = jax.lax.dynamic_update_slice_in_dim(
            cache.c_kv, c_kv.astype(cache.c_kv.dtype), pos, axis=1)
        rnew = jax.lax.dynamic_update_slice_in_dim(
            cache.k_rope, k_rope.astype(cache.k_rope.dtype), pos, axis=1)
    kv_up = params["kv_up"].astype(compute_dtype).reshape(r, h, qk_n + vh)
    w_k = kv_up[..., :qk_n]                  # [r, h, qk_n]
    w_v = kv_up[..., qk_n:]                  # [r, h, vh]
    # absorb: q_eff[b,h,r] = q_nope[b,1,h,n] · w_k[r,h,n]
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_k)
    scores = (jnp.einsum("bhr,bsr->bhs", q_eff.astype(jnp.float32),
                         cnew.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                           rnew.astype(jnp.float32)))
    scores = scores / math.sqrt(qk_n + qk_r)
    posb = pos[:, None] if per_slot else pos[None, None]
    valid = jnp.arange(cap)[None, :] <= posb                 # [B|1, cap]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bhs,bsr->bhr", probs.astype(cnew.dtype), cnew)
    out = jnp.einsum("bhr,rhv->bhv", lat, w_v).reshape(B, 1, h * vh)
    out = out.astype(compute_dtype) @ params["wo"].astype(compute_dtype)
    return out, MLACache(cnew, rnew, pos + 1)


def mla_paged_decode_step(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                          cache: PagedMLACache, compute_dtype=jnp.bfloat16
                          ) -> Tuple[jnp.ndarray, PagedMLACache]:
    """Absorbed-latent decode over the paged latent arena — identical
    math to the per-slot `mla_decode_step` on the page-gathered latents
    (MLA has no sliding window, so the layout is always linear)."""
    B = x.shape[0]
    h = cfg.n_heads
    qk_n, qk_r, vh, r = (cfg.qk_nope_head_dim, cfg.qk_rope_head_dim,
                         cfg.v_head_dim, cfg.kv_lora_rank)
    pos = cache.pos
    ps = cache.c_kv.shape[1]
    cap = cache.page_table.shape[1] * ps
    x = x.astype(compute_dtype)
    posv = pos[:, None].astype(jnp.float32)
    q_nope, q_rope, c_kv, k_rope = _mla_qkv(params, cfg, x, posv,
                                            compute_dtype)
    row = jnp.minimum(pos, cap - 1)
    pg, off = _paged_slot(cache.page_table, row, ps)
    cnew = _paged_write(cache.c_kv, pg, off, c_kv[:, 0])
    rnew = _paged_write(cache.k_rope, pg, off, k_rope[:, 0])
    cfull = cnew[cache.page_table].reshape(B, cap, r)
    rfull = rnew[cache.page_table].reshape(B, cap, qk_r)
    kv_up = params["kv_up"].astype(compute_dtype).reshape(r, h, qk_n + vh)
    w_k = kv_up[..., :qk_n]
    w_v = kv_up[..., qk_n:]
    q_eff = jnp.einsum("bhn,rhn->bhr", q_nope[:, 0], w_k)
    scores = (jnp.einsum("bhr,bsr->bhs", q_eff.astype(jnp.float32),
                         cfull.astype(jnp.float32))
              + jnp.einsum("bhr,bsr->bhs", q_rope[:, 0].astype(jnp.float32),
                           rfull.astype(jnp.float32)))
    scores = scores / math.sqrt(qk_n + qk_r)
    valid = jnp.arange(cap)[None, :] <= pos[:, None]
    scores = jnp.where(valid[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    lat = jnp.einsum("bhs,bsr->bhr", probs.astype(cfull.dtype), cfull)
    out = jnp.einsum("bhr,rhv->bhv", lat, w_v).reshape(B, 1, h * vh)
    out = out.astype(compute_dtype) @ params["wo"].astype(compute_dtype)
    return out, PagedMLACache(cnew, rnew, cache.page_table, pos + 1)
