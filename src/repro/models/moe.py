"""Mixture-of-Experts layer (mixtral-8x22b: 8e top-2 TP-in-expert;
moonshot/moonlight: 64e top-6 + shared experts, expert-parallel).

Capacity-based dispatch *without* the [tokens, E, capacity] one-hot tensor:
token->slot indices are computed with a cumsum-over-one-hot position trick
and applied with gather/scatter, so the transient footprint is
O(tokens·E) int32 for the position cumsum plus the [E, capacity, D]
expert buffers. Expert weights are stacked [E, ...] so the expert dim (EP)
or the expert hidden dim (TP) can be mesh-sharded per config
(`expert_partition`).
"""
from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from repro.configs.base import ModelConfig

PyTree = Any


def moe_init(key, cfg: ModelConfig, dtype) -> PyTree:
    d, e = cfg.d_model, cfg.n_experts
    ff = cfg.moe_d_ff or cfg.d_ff
    ks = L.split_keys(key, 5)
    p = {
        "router": L.dense_init(ks[0], (d, e), dtype, scale=0.02),
        "w_gate": L.dense_init(ks[1], (e, d, ff), dtype),
        "w_up": L.dense_init(ks[2], (e, d, ff), dtype),
        "w_down": L.dense_init(ks[3], (e, ff, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, ff * cfg.n_shared_experts, dtype)
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.n_experts_per_tok / cfg.n_experts
                  * cfg.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)


def moe_apply(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
              compute_dtype=jnp.bfloat16, local_shards: int = 1
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,T,D] -> (out [B,T,D], aux load-balance loss scalar).

    local_shards > 1 enables SHARD-LOCAL dispatch: tokens are viewed as
    [local_shards, N/shards] rows matching the data-axis sharding, and
    each row dispatches into its own capacity slice. Gathers/scatters
    become batched (row-local => no cross-device coordination) and the
    expert-output psum shrinks by the shard count — found on the mixtral
    dry-run where global dispatch cost 1.8e13 collective bytes/device.
    Trade: capacity is per-shard, so imbalance drops slightly more tokens.
    """
    B, T, D = x.shape
    E, K = cfg.n_experts, cfg.n_experts_per_tok
    N = B * T
    S = local_shards if N % local_shards == 0 else 1
    NL = N // S                                                # tokens per row
    C = capacity(cfg, NL)
    xf = x.reshape(S, NL, D).astype(compute_dtype)

    logits = (xf @ params["router"].astype(compute_dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                    # [S, NL, E]
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # [S, NL, K]
    gate_vals = gate_vals / jnp.sum(gate_vals, -1, keepdims=True)

    # position of each (token, k) inside its row's expert buffer
    flat_idx = expert_idx.reshape(S, NL * K)
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)      # [S, NLK, E]
    pos_all = jnp.cumsum(onehot, axis=1) - 1
    pos = jnp.take_along_axis(pos_all, flat_idx[..., None], 2)[..., 0]
    keep = pos < C                                             # overflow drop
    slot = flat_idx * C + pos                                  # [S, NLK]
    slot = jnp.where(keep, slot, E * C)                        # OOB -> dropped

    # dispatch: scatter token ids into [S, E*C] buffers, gather tokens
    token_of_pair = jnp.broadcast_to(
        jnp.repeat(jnp.arange(NL), K)[None], (S, NL * K))
    buf_tok = jnp.full((S, E * C + 1), NL, jnp.int32)
    buf_tok = jax.vmap(lambda bt, sl, tp: bt.at[sl].set(tp, mode="drop"))(
        buf_tok, slot, token_of_pair)
    xpad = jnp.concatenate([xf, jnp.zeros((S, 1, D), compute_dtype)], axis=1)
    de = jnp.take_along_axis(
        xpad, jnp.minimum(buf_tok[:, :E * C], NL)[..., None], axis=1)
    de = jnp.where((buf_tok[:, :E * C] < NL)[..., None], de, 0.0)
    de = de.reshape(S, E, C, D)

    # expert FFN, batched over (S, E) (shardable on E or on ff)
    wg = params["w_gate"].astype(compute_dtype)
    wu = params["w_up"].astype(compute_dtype)
    wd = params["w_down"].astype(compute_dtype)
    h = jax.nn.silu(jnp.einsum("secd,edf->secf", de, wg)) \
        * jnp.einsum("secd,edf->secf", de, wu)
    ye = jnp.einsum("secf,efd->secd", h, wd).reshape(S, E * C, D)

    # combine: gather each pair's slot output, weight, sum over K
    ypad = jnp.concatenate([ye, jnp.zeros((S, 1, D), ye.dtype)], axis=1)
    y_pair = jnp.take_along_axis(
        ypad, jnp.where(keep, slot, E * C)[..., None], axis=1)  # [S, NLK, D]
    w_pair = jnp.where(keep, gate_vals.reshape(S, NL * K), 0.0)
    out = jnp.sum((y_pair * w_pair[..., None].astype(ye.dtype))
                  .reshape(S, NL, K, D), axis=2)

    if cfg.n_shared_experts:
        out = out + L.mlp_apply(params["shared"], xf, "swiglu", compute_dtype)

    # Switch-style load-balance aux loss: E * sum_e f_e * P_e
    f = jnp.mean(jax.nn.one_hot(expert_idx, E, dtype=jnp.float32).sum(2),
                 axis=(0, 1))                                  # fraction routed
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(f * pmean) / K
    return out.reshape(B, T, D).astype(x.dtype), aux
