"""Pure-JAX model zoo: dense / MoE / SSM / hybrid / VLM / audio backbones."""
from .api import Model, build_model, count_params
