"""State-space mixers: Mamba2-style SSD (hymba's parallel SSM heads) and
RWKV6 "Finch" time/channel mix with data-dependent decay.

TPU adaptation (DESIGN.md §2): both recurrences are evaluated in *chunked*
form — within a chunk the recurrence is expanded into an attention-like
score matrix (dense matmuls for the MXU), across chunks a lax.scan carries
the [heads, state, head_dim] recurrent state. Decode steps use the plain
O(1) recurrence.

Numerical strategy: decays are kept as (negative) log-decays; all
within-chunk ratios exp(cum_t - cum_s) are formed from pairwise
differences (always <= 0, never overflow).
"""
from __future__ import annotations

import math
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from repro.configs.base import ModelConfig

PyTree = Any


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv: x [B,T,C], w [K,C] (K small, unrolled)."""
    K = w.shape[0]
    out = x * w[K - 1]
    for j in range(1, K):
        shifted = jnp.pad(x, ((0, 0), (j, 0), (0, 0)))[:, :-j]
        out = out + shifted * w[K - 1 - j]
    return out


# =====================================================================
# Mamba2-style SSD mixer (hymba SSM heads)
# =====================================================================
class MambaState(NamedTuple):
    S: jnp.ndarray          # [B, H, N, P]
    conv: jnp.ndarray       # [B, K-1, d_inner] trailing inputs
    pos: jnp.ndarray


def mamba_init(key, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    H = cfg.ssm_heads or cfg.n_heads
    P = d // H
    N = cfg.ssm_state
    ks = L.split_keys(key, 7)
    return {
        "w_x": L.dense_init(ks[0], (d, H * P), dtype),
        "w_z": L.dense_init(ks[1], (d, H * P), dtype),
        "w_B": L.dense_init(ks[2], (d, N), dtype),
        "w_C": L.dense_init(ks[3], (d, N), dtype),
        "w_dt": L.dense_init(ks[4], (d, H), dtype),
        "dt_bias": jnp.zeros((H,), dtype),
        "A_log": jnp.zeros((H,), dtype),          # a = -exp(A_log) = -1 init
        "D": jnp.ones((H,), dtype),
        "conv_w": (jnp.ones((cfg.ssm_conv, H * P), jnp.float32)
                   / cfg.ssm_conv).astype(dtype),
        "norm": L.rmsnorm_init(H * P, dtype),
        "w_out": L.dense_init(ks[5], (H * P, d), dtype),
    }


def _mamba_features(params, cfg, x, compute_dtype):
    B, T, d = x.shape
    H = cfg.ssm_heads or cfg.n_heads
    P = d // H
    x = x.astype(compute_dtype)
    xs = x @ params["w_x"].astype(compute_dtype)           # [B,T,HP]
    z = x @ params["w_z"].astype(compute_dtype)
    Bm = x @ params["w_B"].astype(compute_dtype)           # [B,T,N]
    Cm = x @ params["w_C"].astype(compute_dtype)
    dt = jax.nn.softplus((x @ params["w_dt"].astype(compute_dtype))
                         .astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B,T,H]
    a = -jnp.exp(params["A_log"].astype(jnp.float32))      # [H] < 0
    return xs, z, Bm, Cm, dt, a, H, P


def mamba_forward(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                  compute_dtype=jnp.bfloat16, chunk: int = 64) -> jnp.ndarray:
    B, T, d = x.shape
    xs, z, Bm, Cm, dt, a, H, P = _mamba_features(params, cfg, x, compute_dtype)
    xs = _causal_conv(xs, params["conv_w"].astype(compute_dtype))
    xs = jax.nn.silu(xs)
    xh = xs.reshape(B, T, H, P)
    N = Bm.shape[-1]

    llog = dt * a[None, None, :]                           # [B,T,H] log-decay
    u = xh.astype(jnp.float32) * dt[..., None]             # [B,T,H,P]

    Q = min(chunk, T)
    assert T % Q == 0, (T, Q)
    nc = T // Q
    resh = lambda t, tail: t.reshape((B, nc, Q) + tail)
    lc = resh(llog, (H,))
    uc = resh(u, (H, P))
    Bc = resh(Bm.astype(jnp.float32), (N,))
    Cc = resh(Cm.astype(jnp.float32), (N,))
    cum = jnp.cumsum(lc, axis=2)                           # [B,nc,Q,H]

    mask = jnp.tril(jnp.ones((Q, Q), bool))
    CB = jnp.einsum("bqtn,bqsn->bqts", Cc, Bc)             # [B,nc,Q,Q]

    def chunk_step(S, inp):
        cumq, CBq, uq, Bq, Cq = inp                        # per-chunk slices
        diff = cumq[:, :, None, :] - cumq[:, None, :, :]   # [B,Q,Q,H] t,s
        M = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        y_intra = jnp.einsum("bts,btsh,bshp->bthp", CBq, M, uq)
        y_state = jnp.einsum("btn,bth,bhnp->bthp", Cq, jnp.exp(cumq), S)
        clast = cumq[:, -1:, :]                            # [B,1,H]
        S_new = (jnp.exp(clast)[:, 0, :, None, None] * S
                 + jnp.einsum("bsn,bsh,bshp->bhnp", Bq,
                              jnp.exp(clast - cumq), uq))
        return S_new, y_intra + y_state

    S0 = jnp.zeros((B, H, N, P), jnp.float32)
    swap = lambda t: jnp.moveaxis(t, 1, 0)                 # scan over chunks
    _, ys = jax.lax.scan(chunk_step, S0,
                         (swap(cum), swap(CB), swap(uc), swap(Bc), swap(Cc)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, H, P)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] \
        * xh.astype(jnp.float32)
    y = y.reshape(B, T, H * P).astype(compute_dtype) * jax.nn.silu(z)
    y = L.rmsnorm(params["norm"], y, cfg.norm_eps)
    return y @ params["w_out"].astype(compute_dtype)


def mamba_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32,
                     per_slot: bool = False) -> MambaState:
    d = cfg.d_model
    H = cfg.ssm_heads or cfg.n_heads
    P = d // H
    return MambaState(jnp.zeros((batch, H, cfg.ssm_state, P), jnp.float32),
                      jnp.zeros((batch, cfg.ssm_conv - 1, H * P), dtype),
                      jnp.zeros((batch,) if per_slot else (), jnp.int32))


def mamba_decode_step(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                      state: MambaState, compute_dtype=jnp.bfloat16
                      ) -> Tuple[jnp.ndarray, MambaState]:
    """x: [B,1,D] -> (out [B,1,D], state)."""
    B = x.shape[0]
    xs, z, Bm, Cm, dt, a, H, P = _mamba_features(params, cfg, x, compute_dtype)
    hist = jnp.concatenate([state.conv, xs], axis=1)       # [B,K,HP]
    w = params["conv_w"].astype(compute_dtype)
    xs = jnp.einsum("bkc,kc->bc", hist, w)[:, None]
    xs = jax.nn.silu(xs)
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    decay = jnp.exp(dt[:, 0] * a[None, :])                 # [B,H]
    u = xh * dt[:, 0, :, None]
    S = (decay[:, :, None, None] * state.S
         + jnp.einsum("bn,bhp->bhnp", Bm[:, 0].astype(jnp.float32), u))
    y = jnp.einsum("bn,bhnp->bhp", Cm[:, 0].astype(jnp.float32), S)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, H * P).astype(compute_dtype) * jax.nn.silu(z)
    y = L.rmsnorm(params["norm"], y, cfg.norm_eps)
    out = y @ params["w_out"].astype(compute_dtype)
    return out, MambaState(S, hist[:, 1:], state.pos + 1)


# =====================================================================
# RWKV6 (Finch): time-mix with data-dependent per-channel decay
# =====================================================================
class RWKVState(NamedTuple):
    S: jnp.ndarray        # [B, H, K, V] wkv state
    x_time: jnp.ndarray   # [B, D] previous token (time-mix shift)
    x_chan: jnp.ndarray   # [B, D] previous token (channel-mix shift)
    pos: jnp.ndarray


def rwkv_time_init(key, cfg: ModelConfig, dtype) -> PyTree:
    d = cfg.d_model
    K = cfg.rwkv_head_dim
    H = d // K
    lora = cfg.rwkv_decay_lora
    ks = L.split_keys(key, 8)
    return {
        "mu": 0.5 * jnp.ones((5, d), dtype),      # lerp for r,k,v,w,g
        "w_r": L.dense_init(ks[0], (d, d), dtype),
        "w_k": L.dense_init(ks[1], (d, d), dtype),
        "w_v": L.dense_init(ks[2], (d, d), dtype),
        "w_g": L.dense_init(ks[3], (d, d), dtype),
        "decay_base": -6.0 * jnp.ones((d,), dtype),
        "decay_A": L.dense_init(ks[4], (d, lora), dtype, scale=0.01),
        "decay_B": L.dense_init(ks[5], (lora, d), dtype, scale=0.01),
        "bonus": jnp.zeros((H, K), dtype),
        "ln_x": jnp.ones((d,), dtype),
        "w_o": L.dense_init(ks[6], (d, d), dtype),
    }


def _rwkv_features(params, cfg, x, x_prev, compute_dtype):
    """x: [B,T,D]; x_prev: [B,1,D] token before the window."""
    shifted = jnp.concatenate([x_prev, x[:, :-1]], axis=1)
    mu = params["mu"].astype(compute_dtype)
    mix = lambda i: x + (shifted - x) * mu[i][None, None, :]
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = xr @ params["w_r"].astype(compute_dtype)
    k = xk @ params["w_k"].astype(compute_dtype)
    v = xv @ params["w_v"].astype(compute_dtype)
    g = jax.nn.silu(xg @ params["w_g"].astype(compute_dtype))
    # data-dependent decay (the Finch contribution): w = exp(-exp(...))
    dd = jnp.tanh(xw @ params["decay_A"].astype(compute_dtype)) \
        @ params["decay_B"].astype(compute_dtype)
    logw = -jnp.exp(jnp.clip(params["decay_base"].astype(jnp.float32)
                             + dd.astype(jnp.float32), -12.0, 2.0))  # [B,T,D]<0
    return r, k, v, g, logw


def rwkv_time_forward(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                      compute_dtype=jnp.bfloat16, chunk: int = 32
                      ) -> jnp.ndarray:
    B, T, d = x.shape
    K = cfg.rwkv_head_dim
    H = d // K
    x = x.astype(compute_dtype)
    x_prev = jnp.zeros((B, 1, d), compute_dtype)
    r, k, v, g, logw = _rwkv_features(params, cfg, x, x_prev, compute_dtype)
    hd = lambda t: t.reshape(B, T, H, K).astype(jnp.float32)
    r, k, v = hd(r), hd(k), hd(v)
    lw = logw.reshape(B, T, H, K)

    Q = min(chunk, T)
    assert T % Q == 0, (T, Q)
    nc = T // Q
    resh = lambda t: t.reshape(B, nc, Q, H, K)
    rc, kc, vc, lc = resh(r), resh(k), resh(v), resh(lw)
    cum = jnp.cumsum(lc, axis=2)                      # inclusive [B,nc,Q,H,K]
    cprev = cum - lc                                  # exclusive
    u = params["bonus"].astype(jnp.float32)           # [H,K]
    mask_lt = jnp.tril(jnp.ones((Q, Q), bool), k=-1)

    def chunk_step(S, inp):
        rq, kq, vq, cq, cpq = inp                     # [B,Q,H,K] each
        # strict-lower scores: A[t,s] = sum_k r_t k_s exp(cprev_t - c_s)
        diff = cpq[:, :, None] - cq[:, None, :, :]    # [B,Q,Q,H,K]
        W = jnp.where(mask_lt[None, :, :, None, None], jnp.exp(diff), 0.0)
        A = jnp.einsum("bthk,btshk,bshk->bths", rq, W, kq)
        diag = jnp.einsum("bthk,hk,bthk->bth", rq, u, kq)
        y = jnp.einsum("bths,bshv->bthv", A, vq) \
            + diag[..., None] * vq \
            + jnp.einsum("bthk,bthk,bhkv->bthv", rq, jnp.exp(cpq), S)
        clast = cum_last = cq[:, -1]                  # [B,H,K]
        S_new = (jnp.exp(clast)[..., None] * S
                 + jnp.einsum("bshk,bshk,bshv->bhkv", jnp.exp(
                     clast[:, None] - cq), kq, vq))
        return S_new, y

    S0 = jnp.zeros((B, H, K, K), jnp.float32)
    swap = lambda t: jnp.moveaxis(t, 1, 0)
    _, ys = jax.lax.scan(chunk_step, S0,
                         (swap(rc), swap(kc), swap(vc), swap(cum), swap(cprev)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, T, d)
    y = L.rmsnorm({"scale": params["ln_x"]}, y.astype(compute_dtype),
                  cfg.norm_eps)
    y = y * g
    return y @ params["w_o"].astype(compute_dtype)


def rwkv_chan_init(key, cfg: ModelConfig, dtype) -> PyTree:
    d, ff = cfg.d_model, cfg.d_ff
    ks = L.split_keys(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d), dtype),
        "w_r": L.dense_init(ks[0], (d, d), dtype),
        "w_k": L.dense_init(ks[1], (d, ff), dtype),
        "w_v": L.dense_init(ks[2], (ff, d), dtype),
    }


def rwkv_chan_forward(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                      x_prev: jnp.ndarray, compute_dtype=jnp.bfloat16
                      ) -> jnp.ndarray:
    """x: [B,T,D]; x_prev [B,1,D]."""
    x = x.astype(compute_dtype)
    shifted = jnp.concatenate([x_prev.astype(compute_dtype), x[:, :-1]], axis=1)
    mu = params["mu"].astype(compute_dtype)
    xr = x + (shifted - x) * mu[0][None, None]
    xk = x + (shifted - x) * mu[1][None, None]
    r = jax.nn.sigmoid(xr @ params["w_r"].astype(compute_dtype))
    k = jnp.square(jax.nn.relu(xk @ params["w_k"].astype(compute_dtype)))
    return r * (k @ params["w_v"].astype(compute_dtype))


def rwkv_decode_step(tparams: PyTree, cparams: PyTree, cfg: ModelConfig,
                     x: jnp.ndarray, state: RWKVState,
                     compute_dtype=jnp.bfloat16
                     ) -> Tuple[jnp.ndarray, jnp.ndarray, RWKVState]:
    """One-token time-mix + channel-mix. x: [B,1,D] (pre-norm input for the
    time mix; the block wires norms). Returns (time_out, chan_fn, state)."""
    B, _, d = x.shape
    K = cfg.rwkv_head_dim
    H = d // K
    x = x.astype(compute_dtype)
    r, k, v, g, logw = _rwkv_features(tparams, cfg, x,
                                      state.x_time[:, None], compute_dtype)
    hd = lambda t: t.reshape(B, H, K).astype(jnp.float32)
    r, k, v = hd(r[:, 0]), hd(k[:, 0]), hd(v[:, 0])
    w = jnp.exp(logw[:, 0]).reshape(B, H, K)
    u = tparams["bonus"].astype(jnp.float32)
    wkv = state.S + u[None, :, :, None] * jnp.einsum("bhk,bhv->bhkv", k, v)
    y = jnp.einsum("bhk,bhkv->bhv", r, wkv).reshape(B, 1, d)
    S_new = (w[..., None] * state.S
             + jnp.einsum("bhk,bhv->bhkv", k, v))
    y = L.rmsnorm({"scale": tparams["ln_x"]}, y.astype(compute_dtype),
                  cfg.norm_eps)
    y = y * g
    time_out = y @ tparams["w_o"].astype(compute_dtype)
    new_state = RWKVState(S_new, x[:, 0], state.x_chan, state.pos + 1)
    return time_out, new_state
