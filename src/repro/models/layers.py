"""Shared building blocks: norms, RoPE, embeddings, gated MLPs.

Pure-JAX (pytree params, init/apply function pairs). Compute dtype is
passed explicitly; params live in param_dtype (fp32 master by default).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


# ---------------------------------------------------------------- init utils
def dense_init(key, shape, dtype, scale: Optional[float] = None):
    """Truncated-normal fan-in init."""
    fan_in = shape[0] if len(shape) > 1 else shape[-1]
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * scale).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * 0.02).astype(dtype)


def split_keys(key, n: int):
    return list(jax.random.split(key, n))


# ---------------------------------------------------------------------- norm
def rmsnorm_init(d: int, dtype) -> PyTree:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: PyTree, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def headwise_rmsnorm(scale: jnp.ndarray, x: jnp.ndarray,
                     eps: float = 1e-6) -> jnp.ndarray:
    """qk-norm (qwen3): RMS over the head_dim axis of [..., H, Dh]."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------- rope
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 10_000.0) -> jnp.ndarray:
    """x: [..., T, H, Dh]; positions broadcastable to [..., T]."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # [Dh/2]
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # [..., T, Dh/2]
    cos = jnp.cos(ang)[..., :, None, :]                 # [..., T, 1, Dh/2]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- mlp
def mlp_init(key, d_model: int, d_ff: int, dtype) -> PyTree:
    k1, k2, k3 = split_keys(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff), dtype),
        "w_up": dense_init(k2, (d_model, d_ff), dtype),
        "w_down": dense_init(k3, (d_ff, d_model), dtype),
    }


def mlp_apply(params: PyTree, x: jnp.ndarray, mlp_type: str = "swiglu",
              compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    x = x.astype(compute_dtype)
    g = x @ params["w_gate"].astype(compute_dtype)
    u = x @ params["w_up"].astype(compute_dtype)
    if mlp_type == "swiglu":
        a = jax.nn.silu(g)
    elif mlp_type == "geglu":
        a = jax.nn.gelu(g, approximate=True)
    else:
        raise KeyError(mlp_type)
    return (a * u) @ params["w_down"].astype(compute_dtype)


# ----------------------------------------------------------------- embedding
def embedding_init(key, vocab: int, d_model: int, dtype) -> PyTree:
    return {"table": embed_init(key, (vocab, d_model), dtype)}


def embed(params: PyTree, tokens: jnp.ndarray, compute_dtype=jnp.bfloat16):
    return params["table"].astype(compute_dtype)[tokens]


def unembed(params: PyTree, x: jnp.ndarray, compute_dtype=jnp.bfloat16):
    """Logits in fp32 (loss stability)."""
    return (x.astype(compute_dtype)
            @ params["table"].astype(compute_dtype).T).astype(jnp.float32)


def lm_head_init(key, d_model: int, vocab: int, dtype) -> PyTree:
    return {"w": dense_init(key, (d_model, vocab), dtype)}


def lm_head(params: PyTree, x: jnp.ndarray, compute_dtype=jnp.bfloat16):
    return (x.astype(compute_dtype)
            @ params["w"].astype(compute_dtype)).astype(jnp.float32)
