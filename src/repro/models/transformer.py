"""Decoder-only LM assembly for every family in the pool.

Block families:
  dense/moe : x += attn(norm(x));  x += mlp|moe(norm(x))
  hybrid    : x += attn(norm(x)) + mamba(norm(x))   (hymba parallel heads)
              x += mlp(norm(x))
  ssm(rwkv) : x += time_mix(norm(x)); x += channel_mix(norm(x))

Layers are *stacked* [L, ...] and driven by lax.scan (compile time stays
O(1 layer) even for 64-layer configs) with jax.checkpoint around the block
body (activation remat). MoE configs may have a dense prefix
(first_dense_layers) which scans separately.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import attention as ATT
from . import moe as MOE
from . import ssm as SSM
from repro.configs.base import ModelConfig

PyTree = Any


# ------------------------------------------------------------------- blocks
def block_init(key, cfg: ModelConfig, dtype, moe_block: bool) -> PyTree:
    ks = L.split_keys(key, 4)
    p: Dict[str, PyTree] = {}
    if cfg.family == "ssm":                       # rwkv
        p["norm1"] = L.rmsnorm_init(cfg.d_model, dtype)
        p["time"] = SSM.rwkv_time_init(ks[0], cfg, dtype)
        p["norm2"] = L.rmsnorm_init(cfg.d_model, dtype)
        p["chan"] = SSM.rwkv_chan_init(ks[1], cfg, dtype)
        return p
    p["norm1"] = L.rmsnorm_init(cfg.d_model, dtype)
    if cfg.attn_type == "mla":
        p["attn"] = ATT.mla_init(ks[0], cfg, dtype)
    else:
        p["attn"] = ATT.gqa_init(ks[0], cfg, dtype)
    if cfg.family == "hybrid":
        p["mamba"] = SSM.mamba_init(ks[2], cfg, dtype)
    p["norm2"] = L.rmsnorm_init(cfg.d_model, dtype)
    if moe_block:
        p["moe"] = MOE.moe_init(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def block_apply(params: PyTree, cfg: ModelConfig, x: jnp.ndarray,
                positions: jnp.ndarray, moe_block: bool,
                compute_dtype=jnp.bfloat16, attn_chunk: int = 512,
                moe_shards: int = 1, use_flash: bool = False,
                return_kv: bool = False, prefix_kv=None):
    """[B,T,D] -> ([B,T,D], aux_loss[, kv]).

    return_kv (attention families only): also return this block's
    decode-cache contribution — (k, v) for GQA, (c_kv, k_rope) for MLA —
    so a fused prefill can populate a cache in one forward pass.
    prefix_kv: this block's cached shared-prefix contribution (same pair
    shapes, [B, S0, ...]) for the extend-prefill — `positions` then
    starts at S0 and only the tail is computed/returned."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family == "ssm":
        assert not return_kv, "fused kv capture needs an attention family"
        h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
        x = x + SSM.rwkv_time_forward(params["time"], cfg, h, compute_dtype)
        h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        prev = jnp.zeros((x.shape[0], 1, x.shape[-1]), h.dtype)
        x = x + SSM.rwkv_chan_forward(params["chan"], cfg, h, prev,
                                      compute_dtype)
        return x, aux
    h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    kv = None
    if cfg.attn_type == "mla":
        a = ATT.mla_forward(params["attn"], cfg, h, positions, compute_dtype,
                            attn_chunk, return_kv=return_kv,
                            prefix_kv=prefix_kv)
    else:
        a = ATT.gqa_forward(params["attn"], cfg, h, positions, compute_dtype,
                            attn_chunk, use_flash, return_kv=return_kv,
                            prefix_kv=prefix_kv)
    if return_kv:
        a, kv = a
    if cfg.family == "hybrid":
        assert not return_kv, "fused kv capture needs an attention family"
        a = (a + SSM.mamba_forward(params["mamba"], cfg, h, compute_dtype)) * 0.5
    x = x + a
    h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
    if moe_block:
        m, aux = MOE.moe_apply(params["moe"], cfg, h, compute_dtype,
                               moe_shards)
    else:
        m = L.mlp_apply(params["mlp"], h, cfg.mlp_type, compute_dtype)
    if return_kv:
        return x + m, aux, kv
    return x + m, aux


# ------------------------------------------------------------------- params
def _stack_init(key, n: int, one_init):
    """Initialise n blocks with different keys, stacked on axis 0."""
    keys = jnp.stack(L.split_keys(key, n))
    return jax.vmap(one_init)(keys)


def init_params(cfg: ModelConfig, key, param_dtype=jnp.float32) -> PyTree:
    ks = L.split_keys(key, 6)
    params: Dict[str, PyTree] = {
        "embed": L.embedding_init(ks[0], cfg.vocab_size, cfg.d_model,
                                  param_dtype),
        "final_norm": L.rmsnorm_init(cfg.d_model, param_dtype),
    }
    n_moe = 0
    if cfg.n_experts:
        n_dense = cfg.first_dense_layers
        n_moe = cfg.n_layers - n_dense
        if n_dense:
            params["dense_blocks"] = _stack_init(
                ks[1], n_dense,
                lambda k: block_init(k, cfg, param_dtype, moe_block=False))
        params["blocks"] = _stack_init(
            ks[2], n_moe,
            lambda k: block_init(k, cfg, param_dtype, moe_block=True))
    else:
        params["blocks"] = _stack_init(
            ks[2], cfg.n_layers,
            lambda k: block_init(k, cfg, param_dtype, moe_block=False))
    if not cfg.tie_embeddings:
        params["lm_head"] = L.lm_head_init(ks[3], cfg.d_model, cfg.vocab_size,
                                           param_dtype)
    if cfg.frontend == "vision":
        params["projector"] = {
            "w1": L.dense_init(ks[4], (cfg.frontend_dim, cfg.d_model),
                               param_dtype),
            "w2": L.dense_init(ks[5], (cfg.d_model, cfg.d_model), param_dtype),
        }
    elif cfg.frontend == "audio":
        params["projector"] = {
            "w1": L.dense_init(ks[4], (cfg.frontend_dim, cfg.d_model),
                               param_dtype),
        }
    return params


def project_frontend(params: PyTree, cfg: ModelConfig, embeds: jnp.ndarray,
                     compute_dtype=jnp.bfloat16) -> jnp.ndarray:
    """Modality stub -> model space. embeds: [B, S, frontend_dim]."""
    x = embeds.astype(compute_dtype) @ params["projector"]["w1"].astype(
        compute_dtype)
    if "w2" in params.get("projector", {}):
        x = jax.nn.gelu(x) @ params["projector"]["w2"].astype(compute_dtype)
    return x


# ------------------------------------------------------------------ forward
def _scan_blocks(blocks: PyTree, cfg: ModelConfig, x, positions, moe_block,
                 compute_dtype, attn_chunk, remat: bool = True,
                 moe_shards: int = 1, use_flash: bool = False,
                 collect_kv: bool = False, prefix_kv=None):
    body = functools.partial(block_apply, cfg=cfg, positions=positions,
                             moe_block=moe_block, compute_dtype=compute_dtype,
                             attn_chunk=attn_chunk, moe_shards=moe_shards,
                             use_flash=use_flash, return_kv=collect_kv)

    def step(carry, inp):
        x, aux = carry
        bparams, pkv = inp
        fn = (jax.checkpoint(lambda p, y: body(p, x=y, prefix_kv=pkv))
              if remat else (lambda p, y: body(p, x=y, prefix_kv=pkv)))
        if collect_kv:
            x, a, kv = fn(bparams, x)
            return (x, aux + a), kv
        x, a = fn(bparams, x)
        return (x, aux + a), None

    # collect_kv: the scan's ys stack per-layer kv on axis 0 — exactly the
    # [L, ...] layout of DecodeCache.layers; prefix_kv rides along as a
    # per-layer xs pair ([L, B, S0, ...] stacked, sliced by the scan)
    (x, aux), kvs = jax.lax.scan(step, (x, jnp.zeros((), jnp.float32)),
                                 (blocks, prefix_kv))
    if collect_kv:
        return x, aux, kvs
    return x, aux


def forward(params: PyTree, cfg: ModelConfig, tokens: jnp.ndarray,
            frontend_embeds: Optional[jnp.ndarray] = None,
            compute_dtype=jnp.bfloat16, attn_chunk: int = 512,
            remat: bool = True, last_only: bool = False,
            moe_shards: int = 1, use_flash: bool = False
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens [B,T_text] (+ optional frontend embeds prepended) -> logits
    [B,T,V], aux. last_only: unembed only the final position (prefill)."""
    x = L.embed(params["embed"], tokens, compute_dtype)
    if frontend_embeds is not None:
        fe = project_frontend(params, cfg, frontend_embeds, compute_dtype)
        x = jnp.concatenate([fe, x], axis=1)
    T = x.shape[1]
    positions = jnp.arange(T, dtype=jnp.float32)
    aux = jnp.zeros((), jnp.float32)
    if "dense_blocks" in params:
        x, a = _scan_blocks(params["dense_blocks"], cfg, x, positions, False,
                            compute_dtype, attn_chunk, remat)
        aux += a
    x, a = _scan_blocks(params["blocks"], cfg, x, positions,
                        bool(cfg.n_experts), compute_dtype, attn_chunk, remat,
                        moe_shards, use_flash)
    aux += a
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:, :]
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x, compute_dtype)
    else:
        logits = L.lm_head(params["lm_head"], x, compute_dtype)
    return logits, aux


def lm_loss(params: PyTree, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            compute_dtype=jnp.bfloat16, attn_chunk: int = 512,
            aux_weight: float = 0.01, remat: bool = True,
            moe_shards: int = 1
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Next-token cross entropy. batch: tokens [B,T], labels [B,T]
    (-100 = masked), optional frontend_embeds."""
    logits, aux = forward(params, cfg, batch["tokens"],
                          batch.get("frontend_embeds"), compute_dtype,
                          attn_chunk, remat, moe_shards=moe_shards)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:      # frontend positions prepended
        pad = logits.shape[1] - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.full((labels.shape[0], pad), -100, labels.dtype), labels],
            axis=1)
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + aux_weight * aux
    return total, {"loss": loss, "aux": aux}


# ------------------------------------------------------------------- decode
class DecodeCache(NamedTuple):
    """Per-layer caches stacked on a leading L axis."""
    layers: PyTree
    dense_layers: Optional[PyTree] = None


def _one_layer_cache(cfg: ModelConfig, batch: int, max_len: int, dtype,
                     per_slot: bool = False, paged=None):
    pos0 = jnp.zeros((batch,) if per_slot else (), jnp.int32)
    if cfg.family == "ssm":
        return SSM.RWKVState(
            jnp.zeros((batch, cfg.d_model // cfg.rwkv_head_dim,
                       cfg.rwkv_head_dim, cfg.rwkv_head_dim), jnp.float32),
            jnp.zeros((batch, cfg.d_model), dtype),
            jnp.zeros((batch, cfg.d_model), dtype),
            pos0)
    if paged is not None:
        ps, num_pages = paged
        if cfg.attn_type == "mla":
            att = ATT.init_paged_mla_cache(cfg, batch, max_len, ps,
                                           num_pages, dtype)
        else:
            att = ATT.init_paged_kv_cache(cfg, batch, max_len, ps,
                                          num_pages, dtype)
    elif cfg.attn_type == "mla":
        att = ATT.init_mla_cache(cfg, batch, max_len, dtype, per_slot)
    else:
        att = ATT.init_kv_cache(cfg, batch, max_len, dtype, per_slot)
    if cfg.family == "hybrid":
        return {"attn": att,
                "mamba": SSM.mamba_init_state(cfg, batch, dtype, per_slot)}
    return {"attn": att}


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, per_slot: bool = False,
                      paged=None) -> DecodeCache:
    """per_slot=True: every leaf (including the pos counters, then [B])
    carries the batch axis at position 1 after layer stacking — the layout
    engine/serving's slotted-cache ops (row insert/select) rely on.

    paged=(page_size, num_pages): attention K/V lives in per-layer page
    arenas `[L, num_pages, page_size, ...]` addressed via int32 page
    tables [L, B, pages_per_slot] (recurrent state — mamba/rwkv — stays
    per-slot dense; it is O(1) per slot). Implies per-slot positions."""
    stack = lambda n: jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n,) + x.shape),
        _one_layer_cache(cfg, batch, max_len, dtype, per_slot, paged))
    dense = None
    n_moe = cfg.n_layers
    if cfg.n_experts and cfg.first_dense_layers:
        dense = stack(cfg.first_dense_layers)
        n_moe = cfg.n_layers - cfg.first_dense_layers
    return DecodeCache(stack(n_moe), dense)


def _cache_rows(t: jnp.ndarray, lengths: jnp.ndarray, cap: int,
                rolling: bool, cache_dtype, offset: int = 0) -> jnp.ndarray:
    """Place captured per-position tensors [L,B,P,...] into fixed-capacity
    cache rows [L,B,cap,...].

    Linear layout (full attention, or a rolling buffer that fits the whole
    prompt): row p holds position p; rows >= length are dead weight the
    per-slot pos mask excludes. Rolling layout (SWA, prompt longer than
    the window): row r holds the most recent prompt position p with
    p % cap == r — exactly what cap sequential decode writes would leave.

    offset: absolute position of t[..., 0] (extend-prefill: the tail
    starts after a cached shared prefix; linear layout only)."""
    Lyr, B, P = t.shape[:3]
    tail = t.shape[3:]
    if not rolling or cap >= offset + P:
        assert cap >= offset + P, \
            f"cache capacity {cap} < prompt bucket {offset}+{P}"
        out = jnp.zeros((Lyr, B, cap) + tail, cache_dtype)
        return out.at[:, :, offset:offset + P].set(t.astype(cache_dtype))
    assert offset == 0, "rolling prefill cannot extend a shared prefix"
    last = (lengths - 1)[:, None]                       # [B,1]
    idx = jnp.arange(cap)[None, :]                      # [1,cap]
    p_r = last - ((last - idx) % cap)                   # [B,cap] winner per row
    valid = p_r >= 0
    take = jnp.clip(p_r, 0, P - 1).reshape((1, B, cap) + (1,) * len(tail))
    rows = jnp.take_along_axis(t, take, axis=2)
    mask = valid.reshape((1, B, cap) + (1,) * len(tail))
    return jnp.where(mask, rows, 0).astype(cache_dtype)


def prefill_decode_cache(params: PyTree, cfg: ModelConfig,
                         tokens: jnp.ndarray, lengths: jnp.ndarray,
                         max_len: int, compute_dtype=jnp.bfloat16,
                         attn_chunk: int = 512, use_flash: bool = False,
                         cache_dtype=jnp.bfloat16, prefix_kv=None,
                         prefix_len: int = 0
                         ) -> Tuple[jnp.ndarray, DecodeCache]:
    """Fused serving prefill: ONE full-sequence forward that both computes
    the last-prompt-position logits and writes every layer's K/V into a
    fresh slotted DecodeCache — replacing T sequential decode_step
    dispatches. Attention-only families (the recurrent-state ssm/hybrid
    families prefill via a fused decode scan in engine/serving).

    tokens: [B,P] prompts right-padded to a common bucket length (causal
    attention makes the padding inert); lengths: [B] true prompt lengths.
    Returns (logits [B,1,V] at position lengths-1, cache with per-slot
    pos = lengths).

    Shared-prefix extend: with `prefix_kv` (a DecodeCache-shaped pytree
    of per-layer cached prefix pairs, [L, B, prefix_len, ...]) the tokens
    are the UNSHARED TAIL only — positions start at `prefix_len`, the
    forward computes O(tail) work attending to prefix+tail, the returned
    cache rows hold the tail at its absolute positions (the caller
    already owns the prefix rows/pages) and pos = prefix_len + lengths."""
    assert cfg.family not in ("ssm", "hybrid") and not cfg.is_encoder_decoder
    assert prefix_kv is None or (prefix_len > 0 and not cfg.sliding_window)
    B, P = tokens.shape
    x = L.embed(params["embed"], tokens, compute_dtype)
    positions = jnp.arange(prefix_len, prefix_len + P, dtype=jnp.float32)
    pfx = prefix_kv or DecodeCache(None, None)
    # accept both the bare per-layer pair and the {"attn": pair} segment
    # shape that engine/serving's gather_prefix produces
    seg = lambda s: s["attn"] if isinstance(s, dict) else s
    pfx = DecodeCache(seg(pfx.layers), seg(pfx.dense_layers)
                      if pfx.dense_layers is not None else None)
    dense_kv = None
    if "dense_blocks" in params:
        x, _, dense_kv = _scan_blocks(params["dense_blocks"], cfg, x,
                                      positions, False, compute_dtype,
                                      attn_chunk, remat=False,
                                      collect_kv=True,
                                      prefix_kv=pfx.dense_layers)
    x, _, kv = _scan_blocks(params["blocks"], cfg, x, positions,
                            bool(cfg.n_experts), compute_dtype, attn_chunk,
                            remat=False,
                            use_flash=use_flash and prefix_kv is None,
                            collect_kv=True, prefix_kv=pfx.layers)
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    last = jnp.take_along_axis(x, (lengths - 1)[:, None, None], axis=1)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], last, compute_dtype)
    else:
        logits = L.lm_head(params["lm_head"], last, compute_dtype)

    def seg_cache(pair):
        Lyr = jax.tree.leaves(pair)[0].shape[0]
        pos = jnp.broadcast_to(prefix_len + lengths[None, :], (Lyr, B))
        if cfg.attn_type == "mla":
            c_kv, k_rope = pair
            att = ATT.MLACache(
                _cache_rows(c_kv, lengths, max_len, False, cache_dtype,
                            prefix_len),
                _cache_rows(k_rope, lengths, max_len, False, cache_dtype,
                            prefix_len),
                pos)
        else:
            k, v = pair
            cap = (min(max_len, cfg.sliding_window) if cfg.sliding_window
                   else max_len)
            rolling = bool(cfg.sliding_window)
            att = ATT.KVCache(
                _cache_rows(k, lengths, cap, rolling, cache_dtype,
                            prefix_len),
                _cache_rows(v, lengths, cap, rolling, cache_dtype,
                            prefix_len), pos)
        return {"attn": att}

    dense = seg_cache(dense_kv) if dense_kv is not None else None
    return logits, DecodeCache(seg_cache(kv), dense)


def _block_decode(params: PyTree, cfg: ModelConfig, x, cache, moe_block,
                  compute_dtype):
    """One token through one block. x: [B,1,D]."""
    if cfg.family == "ssm":
        h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
        t_out, cache = SSM.rwkv_decode_step(params["time"], params["chan"],
                                            cfg, h, cache, compute_dtype)
        x = x + t_out
        h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
        c_out = SSM.rwkv_chan_forward(params["chan"], cfg, h,
                                      cache.x_chan[:, None], compute_dtype)
        cache = cache._replace(x_chan=h[:, 0])
        return x + c_out, cache, jnp.zeros((), jnp.float32)
    h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    if isinstance(cache["attn"], ATT.PagedKVCache):
        a, att = ATT.gqa_paged_decode_step(params["attn"], cfg, h,
                                           cache["attn"], compute_dtype)
    elif isinstance(cache["attn"], ATT.PagedMLACache):
        a, att = ATT.mla_paged_decode_step(params["attn"], cfg, h,
                                           cache["attn"], compute_dtype)
    elif cfg.attn_type == "mla":
        a, att = ATT.mla_decode_step(params["attn"], cfg, h, cache["attn"],
                                     compute_dtype)
    else:
        a, att = ATT.gqa_decode_step(params["attn"], cfg, h, cache["attn"],
                                     compute_dtype)
    cache = dict(cache, attn=att)
    if cfg.family == "hybrid":
        m, ms = SSM.mamba_decode_step(params["mamba"], cfg, h, cache["mamba"],
                                      compute_dtype)
        a = (a + m) * 0.5
        cache["mamba"] = ms
    x = x + a
    h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
    if moe_block:
        m, aux = MOE.moe_apply(params["moe"], cfg, h, compute_dtype)
    else:
        m = L.mlp_apply(params["mlp"], h, cfg.mlp_type, compute_dtype)
        aux = jnp.zeros((), jnp.float32)
    return x + m, cache, aux


def decode_step(params: PyTree, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: DecodeCache, compute_dtype=jnp.bfloat16
                ) -> Tuple[jnp.ndarray, DecodeCache]:
    """tokens [B,1] -> (logits [B,1,V], cache)."""
    x = L.embed(params["embed"], tokens, compute_dtype)

    def scan_seg(x, blocks, caches, moe_block):
        def step(h, inp):
            bp, c = inp
            h, c, _ = _block_decode(bp, cfg, h, c, moe_block, compute_dtype)
            return h, c
        return jax.lax.scan(step, x, (blocks, caches))

    dense_caches = cache.dense_layers
    if "dense_blocks" in params:
        x, dense_caches = scan_seg(x, params["dense_blocks"],
                                   cache.dense_layers, False)
    x, layer_caches = scan_seg(x, params["blocks"], cache.layers,
                               bool(cfg.n_experts))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x, compute_dtype)
    else:
        logits = L.lm_head(params["lm_head"], x, compute_dtype)
    return logits, DecodeCache(layer_caches, dense_caches)


def _block_verify(params: PyTree, cfg: ModelConfig, x, cache, moe_block,
                  compute_dtype):
    """T speculative tokens through one block. x: [B,T,D]. Attention
    families only — recurrent state cannot be rolled back by rewriting
    `pos`, so ssm/hybrid never reach this path."""
    h = L.rmsnorm(params["norm1"], x, cfg.norm_eps)
    if isinstance(cache["attn"], ATT.PagedKVCache):
        a, att = ATT.gqa_paged_verify_step(params["attn"], cfg, h,
                                           cache["attn"], compute_dtype)
    elif isinstance(cache["attn"], ATT.PagedMLACache):
        a, att = ATT.mla_paged_verify_step(params["attn"], cfg, h,
                                           cache["attn"], compute_dtype)
    elif cfg.attn_type == "mla":
        a, att = ATT.mla_verify_step(params["attn"], cfg, h, cache["attn"],
                                     compute_dtype)
    else:
        a, att = ATT.gqa_verify_step(params["attn"], cfg, h, cache["attn"],
                                     compute_dtype)
    cache = dict(cache, attn=att)
    x = x + a
    h = L.rmsnorm(params["norm2"], x, cfg.norm_eps)
    if moe_block:
        m, _ = MOE.moe_apply(params["moe"], cfg, h, compute_dtype)
    else:
        m = L.mlp_apply(params["mlp"], h, cfg.mlp_type, compute_dtype)
    return x + m, cache


def verify_step(params: PyTree, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: DecodeCache, compute_dtype=jnp.bfloat16
                ) -> Tuple[jnp.ndarray, DecodeCache]:
    """Speculative verification: tokens [B,T] = [last committed token,
    draft_1..draft_{T-1}] -> (logits [B,T,V], cache). ONE forward scores
    all T positions; the returned cache has K/V rows written for
    positions pos..pos+T-1 but `pos` UNCHANGED — the caller advances pos
    by accepted+1 (engine/build's make_verify_step), which is both the
    accept and the rollback. Per-position greedy argmax is bitwise-equal
    to T sequential decode_step calls (see models/attention.py)."""
    assert cfg.family not in ("ssm", "hybrid") and not cfg.is_encoder_decoder
    x = L.embed(params["embed"], tokens, compute_dtype)

    def scan_seg(x, blocks, caches, moe_block):
        def step(h, inp):
            bp, c = inp
            h, c = _block_verify(bp, cfg, h, c, moe_block, compute_dtype)
            return h, c
        return jax.lax.scan(step, x, (blocks, caches))

    dense_caches = cache.dense_layers
    if "dense_blocks" in params:
        x, dense_caches = scan_seg(x, params["dense_blocks"],
                                   cache.dense_layers, False)
    x, layer_caches = scan_seg(x, params["blocks"], cache.layers,
                               bool(cfg.n_experts))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = L.unembed(params["embed"], x, compute_dtype)
    else:
        logits = L.lm_head(params["lm_head"], x, compute_dtype)
    return logits, DecodeCache(layer_caches, dense_caches)
