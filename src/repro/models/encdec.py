"""Encoder-decoder assembly (seamless-m4t-large-v2 backbone).

The speech frontend is a STUB per the brief: `input_specs()` delivers
precomputed frame embeddings [B, S, frontend_dim]; a linear projector maps
them into the encoder. Decoder = causal self-attention + cross-attention +
MLP; decode uses a self KV-cache plus cross K/V computed once at encode
time.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import attention as ATT
from repro.configs.base import ModelConfig

PyTree = Any


def _xattn_init(key, cfg: ModelConfig, dtype) -> PyTree:
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.resolved_head_dim
    ks = L.split_keys(key, 4)
    return {
        "wq": L.dense_init(ks[0], (d, h * dh), dtype),
        "wk": L.dense_init(ks[1], (d, h * dh), dtype),
        "wv": L.dense_init(ks[2], (d, h * dh), dtype),
        "wo": L.dense_init(ks[3], (h * dh, d), dtype),
    }


def _enc_block_init(key, cfg, dtype):
    ks = L.split_keys(key, 2)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": ATT.gqa_init(ks[0], cfg, dtype),
        "norm2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _dec_block_init(key, cfg, dtype):
    ks = L.split_keys(key, 3)
    return {
        "norm1": L.rmsnorm_init(cfg.d_model, dtype),
        "attn": ATT.gqa_init(ks[0], cfg, dtype),
        "norm_x": L.rmsnorm_init(cfg.d_model, dtype),
        "xattn": _xattn_init(ks[1], cfg, dtype),
        "norm2": L.rmsnorm_init(cfg.d_model, dtype),
        "mlp": L.mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype),
    }


def init_params(cfg: ModelConfig, key, param_dtype=jnp.float32) -> PyTree:
    ks = L.split_keys(key, 6)
    stack = lambda k, n, f: jax.vmap(f)(jnp.stack(L.split_keys(k, n)))
    return {
        "frontend_proj": L.dense_init(ks[0], (cfg.frontend_dim or cfg.d_model,
                                               cfg.d_model), param_dtype),
        "enc_blocks": stack(ks[1], cfg.n_encoder_layers,
                            lambda k: _enc_block_init(k, cfg, param_dtype)),
        "enc_norm": L.rmsnorm_init(cfg.d_model, param_dtype),
        "embed": L.embedding_init(ks[2], cfg.vocab_size, cfg.d_model,
                                  param_dtype),
        "dec_blocks": stack(ks[3], cfg.n_layers,
                            lambda k: _dec_block_init(k, cfg, param_dtype)),
        "final_norm": L.rmsnorm_init(cfg.d_model, param_dtype),
        "lm_head": L.lm_head_init(ks[4], cfg.d_model, cfg.vocab_size,
                                  param_dtype),
    }


def _cross_attention(params, cfg, x, kv_k, kv_v, compute_dtype):
    """x: [B,T,D]; kv_k/kv_v: [B,S,H,Dh] precomputed from encoder output."""
    B, T, D = x.shape
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    q = (x.astype(compute_dtype) @ params["wq"].astype(compute_dtype)
         ).reshape(B, T, h, dh)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bthd,bshd->bhts", q.astype(jnp.float32),
                        kv_k.astype(jnp.float32)) * scale
    probs = jax.nn.softmax(scores, axis=-1).astype(kv_v.dtype)
    out = jnp.einsum("bhts,bshd->bthd", probs, kv_v).reshape(B, T, h * dh)
    return out.astype(compute_dtype) @ params["wo"].astype(compute_dtype)


def cross_kv(params, cfg, enc_out, compute_dtype):
    B, S, _ = enc_out.shape
    h, dh = cfg.n_heads, cfg.resolved_head_dim
    e = enc_out.astype(compute_dtype)
    k = (e @ params["wk"].astype(compute_dtype)).reshape(B, S, h, dh)
    v = (e @ params["wv"].astype(compute_dtype)).reshape(B, S, h, dh)
    return k, v


def encode(params: PyTree, cfg: ModelConfig, frames: jnp.ndarray,
           compute_dtype=jnp.bfloat16, attn_chunk: int = 512,
           remat: bool = True) -> jnp.ndarray:
    """frames: [B,S,frontend_dim] -> encoder states [B,S,D]."""
    x = frames.astype(compute_dtype) @ params["frontend_proj"].astype(
        compute_dtype)
    S = x.shape[1]
    positions = jnp.arange(S, dtype=jnp.float32)

    def body(bp, y):
        h = L.rmsnorm(bp["norm1"], y, cfg.norm_eps)
        # bidirectional: non-causal full attention
        B, T, D = h.shape
        hh, kv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
        hc = h.astype(compute_dtype)
        q = (hc @ bp["attn"]["wq"].astype(compute_dtype)).reshape(B, T, hh, dh)
        k = (hc @ bp["attn"]["wk"].astype(compute_dtype)).reshape(B, T, kv, dh)
        v = (hc @ bp["attn"]["wv"].astype(compute_dtype)).reshape(B, T, kv, dh)
        q = L.apply_rope(q, positions[None, :], cfg.rope_theta)
        k = L.apply_rope(k, positions[None, :], cfg.rope_theta)
        a = ATT._chunked_attention(q, k, v, positions, positions,
                                   causal=False, window=0, chunk=attn_chunk)
        y = y + a.reshape(B, T, hh * dh) @ bp["attn"]["wo"].astype(
            compute_dtype)
        h = L.rmsnorm(bp["norm2"], y, cfg.norm_eps)
        return y + L.mlp_apply(bp["mlp"], h, cfg.mlp_type, compute_dtype)

    def step(y, bp):
        fn = jax.checkpoint(body) if remat else body
        return fn(bp, y), None

    x, _ = jax.lax.scan(step, x, params["enc_blocks"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def decode_train(params: PyTree, cfg: ModelConfig, enc_out: jnp.ndarray,
                 tokens: jnp.ndarray, compute_dtype=jnp.bfloat16,
                 attn_chunk: int = 512, remat: bool = True,
                 last_only: bool = False) -> jnp.ndarray:
    """Teacher-forced decoder. tokens: [B,T] -> logits [B,T,V]."""
    x = L.embed(params["embed"], tokens, compute_dtype)
    T = x.shape[1]
    positions = jnp.arange(T, dtype=jnp.float32)

    def body(bp, y):
        h = L.rmsnorm(bp["norm1"], y, cfg.norm_eps)
        y = y + ATT.gqa_forward(bp["attn"], cfg, h, positions, compute_dtype,
                                attn_chunk)
        h = L.rmsnorm(bp["norm_x"], y, cfg.norm_eps)
        kk, vv = cross_kv(bp["xattn"], cfg, enc_out, compute_dtype)
        y = y + _cross_attention(bp["xattn"], cfg, h, kk, vv, compute_dtype)
        h = L.rmsnorm(bp["norm2"], y, cfg.norm_eps)
        return y + L.mlp_apply(bp["mlp"], h, cfg.mlp_type, compute_dtype)

    def step(y, bp):
        fn = jax.checkpoint(body) if remat else body
        return fn(bp, y), None

    x, _ = jax.lax.scan(step, x, params["dec_blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    if last_only:
        x = x[:, -1:, :]
    return L.lm_head(params["lm_head"], x, compute_dtype)


def encdec_loss(params: PyTree, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                compute_dtype=jnp.bfloat16, attn_chunk: int = 512,
                remat: bool = True):
    enc = encode(params, cfg, batch["frontend_embeds"], compute_dtype,
                 attn_chunk, remat)
    logits = decode_train(params, cfg, enc, batch["tokens"], compute_dtype,
                          attn_chunk, remat)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return loss, {"loss": loss, "aux": jnp.zeros((), jnp.float32)}


class EncDecCache(NamedTuple):
    self_cache: Any          # stacked [L] KVCache
    cross_k: jnp.ndarray     # [L, B, S, H, Dh]
    cross_v: jnp.ndarray


def init_cache(params: PyTree, cfg: ModelConfig, enc_out: jnp.ndarray,
               max_len: int, dtype=jnp.bfloat16) -> EncDecCache:
    B = enc_out.shape[0]
    selfc = jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape),
        ATT.init_kv_cache(cfg, B, max_len, dtype))

    def layer_kv(bp):
        return cross_kv(bp["xattn"], cfg, enc_out, jnp.bfloat16)

    ck, cv = jax.vmap(layer_kv)(params["dec_blocks"])
    return EncDecCache(selfc, ck.astype(dtype), cv.astype(dtype))


def decode_step(params: PyTree, cfg: ModelConfig, tokens: jnp.ndarray,
                cache: EncDecCache, compute_dtype=jnp.bfloat16
                ) -> Tuple[jnp.ndarray, EncDecCache]:
    x = L.embed(params["embed"], tokens, compute_dtype)

    def step(y, inp):
        bp, sc, ck, cv = inp
        h = L.rmsnorm(bp["norm1"], y, cfg.norm_eps)
        a, sc = ATT.gqa_decode_step(bp["attn"], cfg, h, sc, compute_dtype)
        y = y + a
        h = L.rmsnorm(bp["norm_x"], y, cfg.norm_eps)
        y = y + _cross_attention(bp["xattn"], cfg, h, ck, cv, compute_dtype)
        h = L.rmsnorm(bp["norm2"], y, cfg.norm_eps)
        return y + L.mlp_apply(bp["mlp"], h, cfg.mlp_type, compute_dtype), sc

    x, selfc = jax.lax.scan(step, x, (params["dec_blocks"], cache.self_cache,
                                      cache.cross_k, cache.cross_v))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.lm_head(params["lm_head"], x, compute_dtype)
    return logits, EncDecCache(selfc, cache.cross_k, cache.cross_v)
