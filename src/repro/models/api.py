"""Unified model API over all families.

    model = build_model(cfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)
    cache = model.init_cache(params, batch_size, max_len, frontier...)
    logits, cache = model.decode_step(params, cache, tokens)

The API is what the distributed train/serve steps and the dry-run lower.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from . import transformer as TF
from . import encdec as ED

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init: Callable
    loss: Callable            # (params, batch) -> (scalar, metrics)
    forward: Callable         # (params, batch) -> logits
    prefill: Callable         # (params, batch) -> last-position logits
    init_cache: Callable      # (params, batch, max_len[, per_slot][, paged])
                              # -> cache
    decode_step: Callable     # (params, tokens, cache) -> (logits, cache)
    # fused serving prefill: (params, tokens [B,P], lengths [B], max_len
    # [, prefix_kv, prefix_len]) -> (last-position logits, slotted cache).
    # prefix_kv/prefix_len: shared-prefix extend — tokens are the unshared
    # tail, rows land at absolute positions (paged prefix reuse). None for
    # families whose recurrent state cannot be captured from the parallel
    # forward (ssm/hybrid/enc-dec) — engine/serving falls back to a fused
    # scan.
    prefill_cache: Optional[Callable] = None
    # speculative verification: (params, tokens [B,T], cache) ->
    # (logits [B,T,V], cache with rows written, pos unchanged). Scores
    # T = k+1 positions in one forward for the engine's speculation
    # tick; greedy argmax per position is bitwise-equal to T decode
    # steps. None for ssm/hybrid/enc-dec (recurrent state cannot be
    # rolled back by a pos rewrite).
    verify_step: Optional[Callable] = None


def build_model(cfg: ModelConfig, *, compute_dtype=jnp.bfloat16,
                param_dtype=jnp.float32, attn_chunk: int = 512,
                remat: bool = True, moe_shards: int = 1) -> Model:
    if cfg.is_encoder_decoder:
        def init(key):
            return ED.init_params(cfg, key, param_dtype)

        def loss(params, batch):
            return ED.encdec_loss(params, cfg, batch, compute_dtype,
                                  attn_chunk, remat)

        def forward(params, batch):
            enc = ED.encode(params, cfg, batch["frontend_embeds"],
                            compute_dtype, attn_chunk, remat)
            return ED.decode_train(params, cfg, enc, batch["tokens"],
                                   compute_dtype, attn_chunk, remat)

        def init_cache(params, batch, max_len, enc_out=None,
                       frontend_embeds=None):
            if enc_out is None:
                assert frontend_embeds is not None
                enc_out = ED.encode(params, cfg, frontend_embeds,
                                    compute_dtype, attn_chunk, remat=False)
            return ED.init_cache(params, cfg, enc_out, max_len)

        def decode_step(params, tokens, cache):
            return ED.decode_step(params, cfg, tokens, cache, compute_dtype)

        def prefill(params, batch):
            enc = ED.encode(params, cfg, batch["frontend_embeds"],
                            compute_dtype, attn_chunk, remat)
            return ED.decode_train(params, cfg, enc, batch["tokens"],
                                   compute_dtype, attn_chunk, remat,
                                   last_only=True)

        return Model(cfg, init, loss, forward, prefill, init_cache,
                     decode_step)

    def init(key):
        return TF.init_params(cfg, key, param_dtype)

    def loss(params, batch):
        return TF.lm_loss(params, cfg, batch, compute_dtype, attn_chunk,
                          remat=remat, moe_shards=moe_shards)

    def forward(params, batch):
        logits, _ = TF.forward(params, cfg, batch["tokens"],
                               batch.get("frontend_embeds"), compute_dtype,
                               attn_chunk, remat, moe_shards=moe_shards)
        return logits

    def init_cache(params, batch, max_len, per_slot=False, paged=None, **_):
        # cache rows live in the compute dtype: bf16 for real configs,
        # exact fp32 for the fp32-compute test models (the serving
        # bitwise contract — incl. shared-prefix reuse — depends on
        # cached K/V reading back exactly what the forward computed)
        return TF.init_decode_cache(cfg, batch, max_len,
                                    dtype=compute_dtype, per_slot=per_slot,
                                    paged=paged)

    def decode_step(params, tokens, cache):
        return TF.decode_step(params, cfg, tokens, cache, compute_dtype)

    def prefill(params, batch):
        # use_flash routes through the Pallas flash kernel (forward-only,
        # no VJP needed). Default OFF for the dry-run: interpret-mode
        # pallas lowers to unrepresentative HLO on CPU; the kernel's TPU
        # behaviour is modeled in EXPERIMENTS.md Perf (scores stay in
        # VMEM). Enabled automatically on real TPU backends.
        logits, _ = TF.forward(params, cfg, batch["tokens"],
                               batch.get("frontend_embeds"), compute_dtype,
                               attn_chunk, remat, last_only=True,
                               moe_shards=moe_shards,
                               use_flash=(cfg.attn_type == "gqa"
                                          and jax.default_backend() == "tpu"))
        return logits

    prefill_cache = None
    verify_step = None
    if cfg.family not in ("ssm", "hybrid"):
        def prefill_cache(params, tokens, lengths, max_len,
                          prefix_kv=None, prefix_len=0):
            return TF.prefill_decode_cache(
                params, cfg, tokens, lengths, max_len, compute_dtype,
                attn_chunk,
                use_flash=(cfg.attn_type == "gqa"
                           and jax.default_backend() == "tpu"),
                cache_dtype=compute_dtype,
                prefix_kv=prefix_kv, prefix_len=prefix_len)

        def verify_step(params, tokens, cache):
            return TF.verify_step(params, cfg, tokens, cache, compute_dtype)

    return Model(cfg, init, loss, forward, prefill, init_cache, decode_step,
                 prefill_cache, verify_step)


# --------------------------------------------------------------- accounting
@functools.lru_cache(maxsize=64)
def _param_shapes(cfg: ModelConfig):
    model = build_model(cfg)
    key = jax.random.key(0)
    return jax.eval_shape(model.init, key)


def count_params(cfg: ModelConfig, active_only: bool = False) -> int:
    shapes = _param_shapes(cfg)
    total = 0
    expert_total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = jax.tree_util.keystr(path)
        if "moe" in keys and ("w_gate" in keys or "w_up" in keys
                              or "w_down" in keys):
            expert_total += n
    if active_only and cfg.n_experts:
        active_frac = cfg.n_experts_per_tok / cfg.n_experts
        total = total - expert_total + int(expert_total * active_frac)
    return total
