"""Paper §5.1.2 / Fig. 6: algorithmic efficiency of Sum vs Adasum as the
effective batch (number of combined lanes) grows. Scaled-down analogue:
a small LM on the learnable synthetic stream; we report steps-to-target
loss at 4 and 16 lanes with the SAME base hyperparameters (the paper's
headline: Adasum keeps converging where Sum needs retuning/diverges)."""
from __future__ import annotations

import numpy as np

from .common import emit, run_devices

CODE = r"""
import numpy as np, jax
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat

mcfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))
TARGET = 3.2
for op in ("sum", "adasum"):
    for span, rows in ((4, 16), (8, 32)):   # effective batch = rows
        cfg = EngineConfig(combine=op, span=span, backend="gspmd_tree",
                           optimizer="momentum", lr=0.8,   # aggressive base LR (paper Fig.6 regime)
                           seq_len=64, global_batch=rows, data_seed=5)
        sess = TrainSession.from_config(cfg, model=model, mesh=mesh,
                                        callbacks=[])
        steps_to_target = -1
        loss = float("nan")
        for step in range(200):
            loss = sess.step(sess.batch(step))["loss"]
            if not np.isfinite(loss):
                break
            if loss < TARGET:
                steps_to_target = step + 1
                break
        print(f"RESULT {op} {rows} {steps_to_target} {loss:.4f}")
"""


def main():
    out = run_devices(CODE, devices=8, timeout=1200)
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, op, rows, steps, loss = line.split()
            emit(f"fig6_{op}_batch{rows}", 0.0,
                 f"steps_to_target={steps};final_loss={loss}")


if __name__ == "__main__":
    main()
