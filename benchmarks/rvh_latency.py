"""Paper Fig. 4: ADASUMRVH latency vs plain sum-allreduce across message
sizes.

Two measurements per size:
  * wall_us on CPU-simulated devices — op-dispatch overhead only (no real
    links on this container; RVH's 2·log(n) phases cost more Python/XLA
    dispatch than one fused all-reduce, which is expected and documented);
  * wire_bytes per rank parsed from the partitioned HLO — the paper's
    actual claim (RVH-Adasum moves ~the same bytes as a bandwidth-optimal
    sum allreduce: N down + N up per rank) is structural and measurable
    here. ratio ~= 1 is the reproduction target.

64 tensors per message size, as in the paper's methodology."""
from __future__ import annotations

from .common import emit, run_devices

CODE = r"""
import time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import rvh, adasum
from repro.launch import hlo_cost

from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((8,), ("data",))
for total_bytes in (2**18, 2**21, 2**24):
    n = total_bytes // 4 // 64
    tree = {f"t{i}": np.random.randn(8, n).astype(np.float32) for i in range(64)}
    sharded = {k: jax.device_put(v, NamedSharding(mesh, P("data"))) for k, v in tree.items()}
    f_rvh = jax.jit(lambda t: rvh.adasum_rvh_pytree(t, mesh, ("data",)))
    f_sum = jax.jit(lambda t: adasum.sum_reduce(t))
    for name, f in (("rvh", f_rvh), ("sum", f_sum)):
        comp = f.lower(sharded).compile()
        wire = hlo_cost.analyze_text(comp.as_text()).coll_wire_bytes
        jax.block_until_ready(f(sharded))
        ts = []
        for _ in range(5):
            t0 = time.perf_counter(); jax.block_until_ready(f(sharded))
            ts.append(time.perf_counter() - t0)
        print(f"RESULT {name} {total_bytes} {sorted(ts)[2]*1e6:.1f} {wire:.0f}")
"""


def main():
    out = run_devices(CODE, devices=8)
    res = {}
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, name, size, us, wire = line.split()
            res[(name, int(size))] = (float(us), float(wire))
    for size in sorted({s for (_, s) in res}):
        (ru, rw), (su, sw) = res[("rvh", size)], res[("sum", size)]
        emit(f"fig4_rvh_vs_sum_{size}B", ru,
             f"sum_us={su:.1f};wire_rvh={rw:.3e};wire_sum={sw:.3e};"
             f"wire_ratio={rw / max(sw, 1):.2f}")


if __name__ == "__main__":
    main()
