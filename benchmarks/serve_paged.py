"""Paged-vs-dense KV cache benchmark: the memory-and-reuse win.

The serve shape paging targets: a MIXED-length request stream (short
chats next to long documents) where every prompt opens with the same
system prompt. Dense slots pay `max_slots * max_len` K/V capacity no
matter what; the paged arena holds only the pages live tokens occupy,
and the shared system prompt is prefilled once and mapped read-only into
every later request (tail-only prefill).

Both engines run the identical staggered workload; tokens are asserted
bitwise-equal (the paging contract), then the timed repeats interleave
the two layouts and report medians. Emits `BENCH_serve_paged.json`.

Acceptance bar: paged peak KV bytes <= 1/2 dense, tok/s within 10%.

    python -m benchmarks.serve_paged            # full run + JSON
    python -m benchmarks.serve_paged --smoke    # CI: 3 staggered
        shared-prompt requests; asserts prefix pages are shared and
        tokens match dense
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

from .common import append_history, emit

OUT = Path(__file__).resolve().parents[1] / "BENCH_serve_paged.json"

SYSTEM = 48             # shared system-prompt tokens (3 pages of 16)
# (tail_len, gen_len) per request: mostly short chats, two long outliers
WORKLOAD = [(6, 12), (10, 8), (4, 16), (90, 10), (8, 12), (5, 8),
            (70, 12), (9, 10)]
MAX_SLOTS = 4
STAGGER = 2             # decode ticks between arrivals


def _build(kv_layout: str, max_len: int):
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ModelConfig
    from repro.engine import EngineConfig, ServeEngine
    from repro.models import build_model

    mcfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257,
                       head_dim=16)
    model = build_model(mcfg, attn_chunk=32,
                        param_dtype=jnp.dtype("float32"))
    cfg = EngineConfig(max_slots=MAX_SLOTS, max_len=max_len,
                       kv_layout=kv_layout)
    params = model.init(jax.random.key(0))
    return ServeEngine(cfg, model, None, params), model


def _workload(vocab: int, workload):
    import numpy as np
    rng = np.random.RandomState(0)
    system = rng.randint(0, vocab, SYSTEM)
    return [(np.concatenate([system, rng.randint(0, vocab, t)]), g)
            for t, g in workload]


def _run(engine, reqs):
    from repro.engine import GenerationRequest
    handles = []
    for prompt, gen in reqs:
        handles.append(engine.submit(GenerationRequest(
            prompt=prompt.copy(), max_new_tokens=gen)))
        for _ in range(STAGGER):
            engine.step()
    engine.drain()
    return handles


def _fresh_stats(engine):
    for k in ("submitted", "completed", "generated_tokens",
              "prefill_calls", "decode_steps", "prefix_hits",
              "prefix_tokens_reused", "cow_copies", "preemptions"):
        engine.stats[k] = 0
    if engine.paged:
        engine.stats["peak_kv_bytes_in_use"] = 0
    engine.stats["started_at"] = None


def main(smoke: bool = False):
    import numpy as np

    workload = WORKLOAD[:3] if smoke else WORKLOAD
    max_len = SYSTEM + max(t + g for t, g in workload) + 1
    dense, model = _build("dense", max_len)
    paged, _ = _build("paged", max_len)
    reqs = _workload(model.cfg.vocab_size, workload)
    toks = sum(g for _, g in workload)

    # correctness first (doubles as compile warmup): bitwise tokens
    hd = _run(dense, reqs)
    hp = _run(paged, reqs)
    for a, b in zip(hd, hp):
        assert a.tokens == b.tokens, "paged tokens diverged from dense"
    kv = paged.kv_stats()
    assert kv["prefix_hits"] >= len(workload) - 1, kv
    assert kv["prefix_tokens_reused"] > 0, kv

    dense_peak = dense.kv_stats()["peak_kv_bytes_in_use"]
    paged_peak = kv["peak_kv_bytes_in_use"]
    ratio = dense_peak / max(paged_peak, 1)

    if smoke:
        assert ratio >= 2.0, (dense_peak, paged_peak)
        print(f"serve_paged smoke OK: peak {dense_peak} -> {paged_peak} "
              f"({ratio:.1f}x), prefix_hits={kv['prefix_hits']}, "
              f"tokens bitwise-equal")
        return {"ratio": ratio}

    # one more warmup round: with the prefix index warm, admissions now
    # take the extend-prefill path, whose (tail bucket, prefix pages)
    # combos compile on first sight — keep that out of the timings
    for eng in (dense, paged):
        _fresh_stats(eng)
        _run(eng, reqs)

    # timed repeats, interleaved so host noise hits both layouts
    iters = 5
    times = {"dense": [], "paged": []}
    peaks = {"dense": 0, "paged": 0}
    for _ in range(iters):
        for name, eng in (("dense", dense), ("paged", paged)):
            _fresh_stats(eng)
            t0 = time.perf_counter()
            _run(eng, reqs)
            times[name].append(time.perf_counter() - t0)
            peaks[name] = max(peaks[name],
                              eng.stats["peak_kv_bytes_in_use"])

    results = {}
    for name, ts in times.items():
        ts = sorted(ts)
        med = ts[len(ts) // 2]
        results[name] = {"wall_s": med, "wall_s_all": ts,
                         "tok_s": toks / med,
                         "peak_kv_bytes": peaks[name]}
        emit(f"serve_paged_{name}", med * 1e6,
             f"tok_s={results[name]['tok_s']:.1f} peak={peaks[name]}")

    ratio = peaks["dense"] / max(peaks["paged"], 1)
    tok_ratio = results["paged"]["tok_s"] / results["dense"]["tok_s"]
    result = {
        "system_prompt": SYSTEM, "workload": workload,
        "max_slots": MAX_SLOTS, "max_len": max_len, "stagger": STAGGER,
        "arch": model.cfg.name,
        "dense": results["dense"], "paged": results["paged"],
        "peak_kv_ratio": ratio,
        "tok_s_ratio_paged_over_dense": tok_ratio,
        "paged_kv_stats": {k: v for k, v in paged.kv_stats().items()},
    }
    OUT.write_text(json.dumps(result, indent=2) + "\n")
    # replicated serving (ServeEngine built with mesh=None)
    append_history("serve_paged", result, mesh=None)
    emit("serve_paged_peak_ratio", ratio,
         f"tok_s_ratio={tok_ratio:.2f} wrote {OUT.name}")
    assert ratio >= 2.0, f"peak KV ratio {ratio:.2f} < 2x"
    assert tok_ratio >= 0.9, f"paged tok/s {tok_ratio:.2f} of dense"
    return result


if __name__ == "__main__":
    out = main(smoke="--smoke" in sys.argv)
    if "--smoke" not in sys.argv:
        print(json.dumps(out, indent=2))
