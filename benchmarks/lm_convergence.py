"""Paper Table 3 analogue (BERT-Large at our scale): Adam-Sum vs
Adam-Adasum vs LAMB-Adasum at a large effective batch. The paper's
claims: Adam stops scaling with Sum but converges with Adasum; LAMB +
Adasum needs ~20-30% fewer steps than LAMB + Sum."""
from __future__ import annotations

from .common import emit, run_devices

CODE = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.parallel import make_runtime
from repro.parallel.policy import RunPolicy
from repro.data import DataConfig, make_source

cfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(cfg, attn_chunk=32)
mesh = jax.make_mesh((8, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
TARGET = 3.0
ROWS = 64          # large effective batch for this scale
for name, op, optname in (("adam_sum", "sum", "adam"),
                          ("adam_adasum", "adasum", "adam"),
                          ("lamb_sum", "sum", "lamb"),
                          ("lamb_adasum", "adasum", "lamb")):
    rpol = RunPolicy(span=8, backend="gspmd_tree", optimizer=optname,
                     combine_op=op)
    rt = make_runtime(model, mesh, rpol, lr=2e-3)
    state = rt.init_state(jax.random.key(0))
    src = make_source(DataConfig(seq_len=64, global_batch=ROWS,
                                 vocab_size=cfg.vocab_size, seed=7), cfg)
    step_fn = jax.jit(rt.train_step, donate_argnums=(0,))
    steps_to = -1
    loss = float("nan")
    for step in range(250):
        b = {k: jnp.asarray(v) for k, v in src.batch(step).items()}
        state, mets = step_fn(state, b)
        loss = float(mets["loss"])
        if not np.isfinite(loss):
            break
        if loss < TARGET:
            steps_to = step + 1
            break
    print(f"RESULT {name} {steps_to} {loss:.4f}")
"""


def main():
    out = run_devices(CODE, devices=8, timeout=2400)
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, name, steps, loss = line.split()
            emit(f"tab3_{name}", 0.0,
                 f"steps_to_target={steps};final_loss={loss}")


if __name__ == "__main__":
    main()
