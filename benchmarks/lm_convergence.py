"""Paper Table 3 analogue (BERT-Large at our scale): Adam-Sum vs
Adam-Adasum vs LAMB-Adasum at a large effective batch. The paper's
claims: Adam stops scaling with Sum but converges with Adasum; LAMB +
Adasum needs ~20-30% fewer steps than LAMB + Sum."""
from __future__ import annotations

from .common import emit, run_devices

CODE = r"""
import numpy as np, jax
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat

mcfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))
TARGET = 3.0
ROWS = 64          # large effective batch for this scale
for name, op, optname in (("adam_sum", "sum", "adam"),
                          ("adam_adasum", "adasum", "adam"),
                          ("lamb_sum", "sum", "lamb"),
                          ("lamb_adasum", "adasum", "lamb")):
    cfg = EngineConfig(combine=op, span=8, backend="gspmd_tree",
                       optimizer=optname, lr=2e-3, seq_len=64,
                       global_batch=ROWS, data_seed=7)
    sess = TrainSession.from_config(cfg, model=model, mesh=mesh,
                                    callbacks=[])
    steps_to = -1
    loss = float("nan")
    for step in range(250):
        loss = sess.step(sess.batch(step))["loss"]
        if not np.isfinite(loss):
            break
        if loss < TARGET:
            steps_to = step + 1
            break
    print(f"RESULT {name} {steps_to} {loss:.4f}")
"""


def main():
    out = run_devices(CODE, devices=8, timeout=2400)
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, name, steps, loss = line.split()
            emit(f"tab3_{name}", 0.0,
                 f"steps_to_target={steps};final_loss={loss}")


if __name__ == "__main__":
    main()
