"""Delayed-combine overlap benchmark: is the exchange actually hidden?

The combine_delay=1 contract (paper §5.2 regime, DaSGD-style) is that
the Adasum exchange of round i-1's deltas costs ~no wall-clock because
it runs while round i computes. This benchmark measures exactly that,
with the interconnect latency made visible by injection:

    1. build a combine_delay=1 session on an 8-lane mesh and take the
       split-stream executor (`DelayedCombineStream`), whose per-step
       accounting separates `compute_s` from `combine_wait_s`;
    2. size the injected interconnect latency (`comm_delay`, a sleep on
       the exchange leg only) so one exchange costs about one local
       step — the exactly-hideable regime a slow interconnect puts a
       real cluster in;
    3. race the SAME round executed two ways: `serial_step` (exchange
       inline before compute — the no-overlap baseline, bitwise-equal
       output) vs `step` (exchange on the background thread).

    hidden_fraction = (serial_step_s - overlap_step_s) / combine_s

i.e. the share of the measured exchange cost that overlap removed from
the critical path. Emits `BENCH_delayed_combine.json`; the acceptance
bar is hidden_fraction >= 0.5.

    python -m benchmarks.delayed_combine            # full run + JSON
    python -m benchmarks.delayed_combine --smoke    # CI: few iters,
        asserts the overlap removes wall-clock at all
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from .common import append_history, emit, run_devices

OUT = Path(__file__).resolve().parents[1] / "BENCH_delayed_combine.json"

CODE = r"""
import json, time, jax
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat

SMOKE = __SMOKE__
mcfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))
# span=4 < dp=8: the hierarchical regime where the FUSED delayed
# correction runs (span==dp would fall back to the reference tree)
cfg = EngineConfig(combine="adasum", span=4, backend="gspmd_tree",
                   optimizer="momentum", lr=0.1, combine_delay=1,
                   seq_len=32 if SMOKE else 64, global_batch=32,
                   data_seed=7)
sess = TrainSession.from_config(cfg, model=model, mesh=mesh, callbacks=[])
stream = sess.use_delayed_stream()

# compile every leg (overlapped step, serial step), then measure the
# bare pieces: local-step compute and the exchange's execution cost
sess.step(sess.batch(0))
st = int(jax.device_get(sess.state["step"]))
sess.state, _ = stream.serial_step(sess.state, sess.batch(st))
compute = []
for _ in range(3):
    sess.step()
    compute.append(stream.last_compute_s)
compute_s = sorted(compute)[1]
exch_exec = sorted(stream.combine_time(sess.state["pending"])
                   for _ in range(3))[1]

# inject interconnect latency sized so one exchange ~= one local step:
# the exactly-hideable slow-interconnect regime
stream.comm_delay = max(compute_s - exch_exec, 1e-3)
combine_s = sorted(stream.combine_time(sess.state["pending"])
                   for _ in range(3))[1]

iters = 3 if SMOKE else 9
overlap, waits = [], []
for _ in range(iters):
    t0 = time.perf_counter()
    m = sess.step()
    overlap.append(time.perf_counter() - t0)
    waits.append(m["combine_wait_s"])
serial = []
for _ in range(iters):
    st = int(jax.device_get(sess.state["step"]))
    t0 = time.perf_counter()
    sess.state, _ = stream.serial_step(sess.state, sess.batch(st))
    serial.append(time.perf_counter() - t0)
t_overlap = sorted(overlap)[iters // 2]
t_serial = sorted(serial)[iters // 2]
sess.close()
print("RESULT " + json.dumps({
    "compute_s": compute_s,
    "exchange_exec_s": exch_exec,
    "injected_comm_delay_s": stream.comm_delay,
    "combine_s": combine_s,
    "serial_step_s": t_serial,
    "overlap_step_s": t_overlap,
    "combine_wait_s_median": sorted(waits)[iters // 2],
    "hidden_fraction": (t_serial - t_overlap) / combine_s,
    "iters": iters,
    "run_metadata": sess.run_metadata(),
}))
"""


def main(smoke: bool = False):
    code = CODE.replace("__SMOKE__", "1" if smoke else "0")
    out = run_devices(code, devices=8, timeout=1800)
    lines = [ln for ln in out.splitlines() if ln.startswith("RESULT ")]
    result = json.loads(lines[-1][len("RESULT "):])

    if smoke:
        assert result["hidden_fraction"] > 0, result
        assert result["run_metadata"]["combine_delay"] == 1, result
        print(f"delayed_combine smoke OK: hidden_fraction="
              f"{result['hidden_fraction']:.2f} "
              f"(combine {result['combine_s'] * 1e3:.1f}ms behind "
              f"compute {result['compute_s'] * 1e3:.1f}ms, "
              f"path={result['run_metadata']['combine_path']})")
        return result

    emit("delayed_combine_serial", result["serial_step_s"] * 1e6,
         f"combine_s={result['combine_s']:.4f}")
    emit("delayed_combine_overlap", result["overlap_step_s"] * 1e6,
         f"combine_wait_s={result['combine_wait_s_median']:.4f}")
    emit("delayed_combine_hidden_fraction", result["hidden_fraction"],
         f"path={result['run_metadata']['combine_path']}")
    OUT.write_text(json.dumps(result, indent=2) + "\n")
    # topology of the measurement subprocess (run_devices), not this host
    append_history("delayed_combine", result, devices=8,
                   mesh={"data": 8, "model": 1})
    assert result["hidden_fraction"] >= 0.5, (
        f"overlap hides only {result['hidden_fraction']:.2f} of the "
        f"combine (bar: 0.5): {result}")
    return result


if __name__ == "__main__":
    res = main(smoke="--smoke" in sys.argv[1:])
    if "--smoke" not in sys.argv[1:]:
        print(json.dumps(res, indent=2))
