"""Pipelined-runtime overlap benchmark (engine/pipeline.py).

Measures per-step wall time of `TrainSession.fit` under an injected
host-side batch latency (DelayedSource — a slow tokenizer / storage
stage), across the pipeline knobs:

    sync            prefetch off, checkpoint writes block the loop
    prefetch        double-buffered host->device batch stage
    async_ckpt      off-thread checkpoint writes (ckpt every step)
    pipelined       both

plus the prefetch-depth / device-staging sweep (ROADMAP open item):

    depth1/2/4      speculative batches in flight (prefetch_depth)
    device_stage    the prefetch thread also jax.device_put()s batches
                    onto the mesh (DP-sharded dim 0)

Emits `BENCH_step_overlap.json` (the perf-trajectory artifact) and the
harness CSV. The injected latency is sized to the measured device step so
the prefetch stage can hide ~all of it; the acceptance bar is simply
pipelined < sync by a measurable margin.
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import append_history, emit

OUT = Path(__file__).resolve().parents[1] / "BENCH_step_overlap.json"


def _session(cfg_kwargs, delay_s, tmp):
    import jax.numpy as jnp
    from repro.configs.base import ModelConfig
    from repro.engine import EngineConfig, TrainSession
    from repro.launch.mesh import make_local_mesh
    from repro.models import build_model
    from repro.runtime import DelayedSource

    mcfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257,
                       head_dim=16)
    cfg = EngineConfig(combine="adasum", optimizer="momentum", lr=0.1,
                       seq_len=64, global_batch=8, ckpt_dir=str(tmp),
                       ckpt_every=1, log_every=10 ** 9, **cfg_kwargs)
    sess = TrainSession.from_config(
        cfg, model=build_model(mcfg, attn_chunk=32,
                               param_dtype=jnp.dtype("float32")),
        mesh=make_local_mesh(1, 1))
    if delay_s:
        sess.source = DelayedSource(sess.source, delay_s)
    return sess


def _time_fit(cfg_kwargs, delay_s, steps, tmp) -> float:
    """Mean per-step wall time (s) over `steps` post-warmup steps."""
    import time
    sess = _session(cfg_kwargs, delay_s, tmp)
    sess.fit(2)                  # warmup: compile + first checkpoint
    t0 = time.perf_counter()
    sess.fit(2 + steps)
    dt = (time.perf_counter() - t0) / steps
    sess.close()
    return dt


def main():
    import tempfile

    steps = 8
    base = tempfile.mkdtemp(prefix="step_overlap_")
    # size the injected host latency to the device step so prefetch can
    # hide ~all of it (measured with no delay, no pipeline features)
    probe = _time_fit(dict(prefetch=False, async_checkpoint=False),
                      0.0, 4, base + "/probe")
    delay = max(probe, 0.01)

    variants = {
        "sync": dict(prefetch=False, async_checkpoint=False),
        "prefetch": dict(prefetch=True, async_checkpoint=False),
        "async_ckpt": dict(prefetch=False, async_checkpoint=True),
        "pipelined": dict(prefetch=True, async_checkpoint=True),
    }
    times = {}
    for name, kw in variants.items():
        times[name] = _time_fit(kw, delay, steps, f"{base}/{name}")
        emit(f"step_overlap_{name}", times[name] * 1e6,
             f"delay_us={delay * 1e6:.0f}")

    # prefetch-depth / device-staging sweep (ROADMAP): does a deeper
    # speculation pipeline or explicit device_put staging buy anything
    # beyond the double buffer on this host?
    sweep = {}
    for depth in (1, 2, 4):
        for stage in (False, True):
            key = f"depth{depth}" + ("_device_stage" if stage else "")
            sweep[key] = _time_fit(
                dict(prefetch=True, async_checkpoint=True,
                     prefetch_depth=depth, device_stage=stage),
                delay, steps, f"{base}/{key}")
            emit(f"step_overlap_{key}", sweep[key] * 1e6,
                 f"delay_us={delay * 1e6:.0f}")

    result = {
        "device_step_s": probe,
        "injected_host_delay_s": delay,
        "steps_timed": steps,
        "step_time_s": times,
        "speedup_prefetch": times["sync"] / times["prefetch"],
        "speedup_pipelined": times["sync"] / times["pipelined"],
        "overlap_hidden_s": times["sync"] - times["pipelined"],
        "depth_sweep_step_time_s": sweep,
        "best_depth_config": min(sweep, key=sweep.get),
    }
    OUT.write_text(json.dumps(result, indent=2) + "\n")
    append_history("step_overlap", result, devices=1,
                   mesh={"data": 1, "model": 1})
    emit("step_overlap_speedup", result["speedup_pipelined"],
         f"wrote {OUT.name}")
    return result


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
