"""Roofline report: reads results/dryrun/*.json (produced by
repro.launch.dryrun) and emits the per-cell three-term roofline."""
from __future__ import annotations

import glob
import json
from pathlib import Path

from .common import emit


def main(pattern: str = "results/dryrun/*.json"):
    files = sorted(glob.glob(pattern))
    if not files:
        emit("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return
    n_ok = n_skip = n_fail = 0
    for f in files:
        r = json.loads(Path(f).read_text())
        tag = Path(f).stem
        if r["status"] == "SKIP":
            n_skip += 1
            continue
        if r["status"] != "OK":
            n_fail += 1
            emit(f"roofline_{tag}", 0.0, "FAILED")
            continue
        n_ok += 1
        rf = r["roofline"]
        dom_s = max(rf["compute_s"], rf["memory_s"], rf["collective_s"])
        emit(f"roofline_{tag}", dom_s * 1e6,
             f"dom={rf['dominant']};compute_s={rf['compute_s']:.4f};"
             f"memory_s={rf['memory_s']:.4f};"
             f"collective_s={rf['collective_s']:.4f};"
             f"useful={rf.get('useful_ratio', 0):.3f};"
             f"hbm_GiB={r['memory'].get('total_hbm_bytes', 0) / 2**30:.2f}")
    emit("roofline_summary", 0.0, f"ok={n_ok};skip={n_skip};fail={n_fail}")


if __name__ == "__main__":
    main()
