"""Serving-throughput benchmark: ServeEngine vs the legacy loop.

Same workload both ways — N requests, fixed prompt/gen lengths, one tiny
arch — through:

    legacy   ServeSession.generate(stepped_prefill=True): the old
             batch-synchronous loop — T jitted dispatches to prefill the
             prompt token by token, then G batched decode dispatches;
    engine   ServeEngine: fused one-dispatch prefill per request +
             continuous batching over the slotted cache.

Emits `BENCH_serve_throughput.json` (the perf-trajectory artifact). The
acceptance bar: engine tok/s >= 2x legacy tok/s on the same arch.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

from .common import append_history, emit

OUT = Path(__file__).resolve().parents[1] / "BENCH_serve_throughput.json"

REQUESTS = 8
PROMPT = 64          # prefill-heavy: the regime the fused path targets
GEN = 16


def _build():
    import jax.numpy as jnp
    from repro.configs.base import ModelConfig
    from repro.engine import EngineConfig, ServeEngine, ServeSession
    from repro.models import build_model

    mcfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257,
                       head_dim=16)
    model = build_model(mcfg, attn_chunk=32,
                        param_dtype=jnp.dtype("float32"))
    cfg = EngineConfig(max_slots=REQUESTS, max_len=PROMPT + GEN + 1)
    params = model.init(__import__("jax").random.key(0))
    engine = ServeEngine(cfg, model, None, params)
    session = ServeSession(cfg, model, None, params)
    return cfg, model, engine, session


def _run_legacy(session, prompts):
    import jax
    out = session.generate(prompts, GEN, max_len=PROMPT + GEN + 1,
                           stepped_prefill=True)
    jax.block_until_ready(out)
    return out


def _run_engine(engine, prompts):
    import numpy as np
    from repro.engine import GenerationRequest
    handles = [engine.submit(GenerationRequest(
        prompt=np.asarray(prompts[i]), max_new_tokens=GEN))
        for i in range(prompts.shape[0])]
    engine.drain()
    return handles


def main():
    import jax
    import numpy as np

    cfg, model, engine, session = _build()
    rng = np.random.RandomState(0)
    prompts = jax.numpy.asarray(
        rng.randint(0, model.cfg.vocab_size, (REQUESTS, PROMPT)))

    toks = REQUESTS * GEN
    # warmup (compile) then measure; identical tokens double as a check
    ref = np.asarray(_run_legacy(session, prompts))
    handles = _run_engine(engine, prompts)
    got = np.stack([h.output for h in handles])
    assert (got == ref).all(), "engine tokens diverged from legacy loop"

    # interleave the timed repeats so shared-host noise hits both paths;
    # report the median
    iters = 5
    times = {"legacy": [], "engine": []}
    for _ in range(iters):
        t0 = time.perf_counter()
        _run_legacy(session, prompts)
        times["legacy"].append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        _run_engine(engine, prompts)
        times["engine"].append(time.perf_counter() - t0)
    results = {}
    for name, ts in times.items():
        ts = sorted(ts)
        results[name] = {"wall_s": ts[len(ts) // 2], "wall_s_all": ts}

    for name, r in results.items():
        r["tok_s"] = toks / r["wall_s"]
        emit(f"serve_throughput_{name}", r["wall_s"] * 1e6,
             f"tok_s={r['tok_s']:.1f}")

    # per-request latency percentiles (TTFT/TPOT), accumulated across
    # the warmup + timed repeats by the engine's retirement hook
    latency = {k: v for k, v in engine.throughput().items()
               if k.startswith(("ttft_", "tpot_"))}
    result = {
        "requests": REQUESTS, "prompt_len": PROMPT, "gen_len": GEN,
        "arch": model.cfg.name,
        "legacy": results["legacy"], "engine": results["engine"],
        "speedup": results["legacy"]["wall_s"] / results["engine"]["wall_s"],
        "latency": latency,
        "engine_stats": {k: v for k, v in engine.stats.items()
                         if k != "started_at"},
    }
    OUT.write_text(json.dumps(result, indent=2) + "\n")
    # replicated serving (ServeEngine built with mesh=None)
    append_history("serve_throughput", result, mesh=None)
    emit("serve_throughput_speedup", result["speedup"],
         f"wrote {OUT.name}")
    return result


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
