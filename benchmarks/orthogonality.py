"""Paper Fig. 1: per-layer gradient orthogonality over training — starts
near 1/n (parallel gradients) and climbs toward 1 (orthogonal) as
training proceeds."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit


def main(nodes: int = 8, steps: int = 60):
    from repro.configs.base import ModelConfig
    from repro.models import build_model
    from repro.core.orthogonality import per_layer_orthogonality
    from repro.core.adasum import adasum_tree_reduce
    from repro.data import DataConfig, make_source

    cfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
    model = build_model(cfg, attn_chunk=32)
    params = model.init(jax.random.key(0))
    src = make_source(DataConfig(seq_len=64, global_batch=nodes * 4,
                                 vocab_size=cfg.vocab_size, seed=3), cfg)
    grad = jax.jit(jax.grad(lambda p, b: model.loss(p, b)[0]))
    traj = []
    for step in range(steps):
        b = {k: jnp.asarray(v) for k, v in src.batch(step).items()}
        lanes = [{kk: v[i::nodes] for kk, v in b.items()} for i in range(nodes)]
        gs = [grad(params, lb) for lb in lanes]
        o = per_layer_orthogonality(gs)
        traj.append(float(o["__mean__"]))
        combined = adasum_tree_reduce(gs)
        params = jax.tree.map(
            lambda p, g: p - 0.3 * g.astype(p.dtype), params, combined)
    early = float(np.mean(traj[:5]))
    late = float(np.mean(traj[-5:]))
    emit("fig1_orthogonality", 0.0,
         f"early={early:.3f};late={late:.3f};rises={late > early};"
         f"floor={1.0 / nodes:.3f}")
    return traj


if __name__ == "__main__":
    main()
