"""Fused bucketed combine benchmark (the paper's Fig. 8 regime).

Races three combiners on growing synthetic gradient trees:

    sum            plain lane sum — the paper's "simply summing
                   gradients" baseline every Adasum cost is judged
                   against (and AdaScale-style baselines share)
    adasum-gspmd   the per-leaf reference tree (fused=False): O(leaves)
                   reductions + FMAs per tree level
    adasum-fused   the bucketed single-pass path (default): O(buckets)
                   block_dots / block_combine ops per level

Two leaf-size regimes, each swept over leaf count and span:

    dispatch mix   many small/medium leaves (norms, biases, slivers) —
                   the "hundreds of tiny reductions per tree level"
                   regime the fusion targets; per-op dispatch dominates
    model mix      a transformer-ish mix including multi-MB matrices —
                   bandwidth-bound; the fused path pays its pack/unpack
                   copies here and the win is HLO op count (the TPU
                   dispatch/HBM-reread proxy), not CPU wall-clock

Per case we report median-of-N *interleaved* wall-clock (this container's
load drifts; interleaving hits all contestants with the same weather),
the compiled HLO op count, compile time, and the Adasum-vs-sum overhead
the paper claims is small (§4.4). A fused-vs-reference allclose runs on
every tree so the race can't quietly diverge. Emits
`BENCH_combine_fused.json`.

    python -m benchmarks.combine_fused [--smoke]

--smoke: one tiny tree (8 leaves, span 2), used by tools/ci.sh to keep
the fused path exercised end-to-end in the workflow matrix.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

from .common import append_history, emit

OUT = Path(__file__).resolve().parents[1] / "BENCH_combine_fused.json"

# dispatch-bound: the small/medium tensors that dominate leaf COUNT in a
# real model tree (norms, biases, per-layer slivers, small projections)
_DISPATCH_MIX = (64, 7, 256, 1024, 31, 512, 2048, 128, 4096, 16)
# bandwidth-bound: transformer-ish mix including big matrices
_MODEL_MIX = (4096, 64, 16384, 1024, 7, 8192, 256, 3000, 65536, 31)

_KINDS = ("sum", "adasum-gspmd", "adasum-fused")


def make_tree(n_leaves: int, span: int, mix):
    import jax.numpy as jnp
    import numpy as np
    rng = np.random.default_rng(n_leaves * 31 + span)
    return {f"l{i:03d}": jnp.asarray(
        rng.standard_normal((span, mix[i % len(mix)])), jnp.float32)
        for i in range(n_leaves)}


def build(kind: str, span: int):
    from repro.core.combine import CombineConfig
    from repro.engine.registry import make_combiner
    cfgs = {
        "sum": CombineConfig(op="sum"),
        "adasum-gspmd": CombineConfig(op="adasum", backend="gspmd_tree",
                                      span=span, fused=False),
        "adasum-fused": CombineConfig(op="adasum", backend="fused",
                                      span=span),
    }
    return make_combiner(cfgs[kind])


def run_case(regime: str, n_leaves: int, span: int, iters: int = 11):
    import jax
    import numpy as np

    mix = _DISPATCH_MIX if regime == "dispatch" else _MODEL_MIX
    tree = make_tree(n_leaves, span, mix)
    case = {"regime": regime, "leaves": n_leaves, "span": span,
            "elements": int(sum(np.prod(v.shape) for v in tree.values()))}
    fns, outs = {}, {}
    for kind in _KINDS:
        t0 = time.perf_counter()
        compiled = jax.jit(build(kind, span)).lower(tree).compile()
        case[f"{kind}_compile_s"] = time.perf_counter() - t0
        case[f"{kind}_hlo_ops"] = sum(
            1 for line in compiled.as_text().splitlines() if " = " in line)
        # time the AOT-compiled executable itself — a fresh jit wrapper
        # would recompile the identical computation (at 1024 leaves the
        # reference compile alone is ~5 min)
        fns[kind] = compiled
        outs[kind] = jax.block_until_ready(compiled(tree))    # warm + result
    # interleaved timing: every round runs all contestants back to back
    samples = {k: [] for k in _KINDS}
    for _ in range(iters):
        for kind in _KINDS:
            t0 = time.perf_counter()
            jax.block_until_ready(fns[kind](tree))
            samples[kind].append(time.perf_counter() - t0)
    for kind in _KINDS:
        s = sorted(samples[kind])
        case[f"{kind}_us"] = s[len(s) // 2] * 1e6
        emit(f"combine_{kind}_{regime}_L{n_leaves}_S{span}",
             case[f"{kind}_us"], f"hlo_ops={case[f'{kind}_hlo_ops']}")
    # the race is void if the contestants disagree
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(outs["adasum-fused"][k]),
            np.asarray(outs["adasum-gspmd"][k]), rtol=1e-4, atol=1e-4)
    case["fused_vs_reference_speedup"] = (
        case["adasum-gspmd_us"] / case["adasum-fused_us"])
    case["fused_vs_reference_hlo_ratio"] = (
        case["adasum-gspmd_hlo_ops"] / case["adasum-fused_hlo_ops"])
    case["fused_overhead_vs_sum"] = (
        case["adasum-fused_us"] / case["sum_us"])
    case["reference_overhead_vs_sum"] = (
        case["adasum-gspmd_us"] / case["sum_us"])
    return case


def main(smoke: bool = False):
    if smoke:
        grid = [("dispatch", 8, 2)]
    else:
        grid = [("dispatch", 16, 4), ("dispatch", 64, 2),
                ("dispatch", 64, 4), ("dispatch", 256, 2),
                ("dispatch", 256, 4), ("dispatch", 1024, 4),
                ("model", 64, 4)]
    cases = [run_case(r, n, s, iters=3 if smoke else 11) for r, n, s in grid]
    big = [c for c in cases
           if c["regime"] == "dispatch" and c["leaves"] >= 64]
    speedups = sorted(c["fused_vs_reference_speedup"] for c in big)
    result = {
        "smoke": smoke,
        "cases": cases,
        # acceptance: at >=64-leaf trees the fused path wins the
        # dispatch-bound regime — median wall-clock speedup over the
        # >=64-leaf cases (single cases swing +-30% on this container)
        # and the HLO op count (the structural claim) on every case
        "median_speedup_at_64plus_leaves": (
            speedups[len(speedups) // 2] if speedups else None),
        "fused_beats_reference_at_64_leaves": bool(
            speedups and speedups[len(speedups) // 2] > 1.0),
        "fused_fewer_hlo_ops_everywhere": bool(all(
            c["fused_vs_reference_hlo_ratio"] > 1.0 for c in cases)),
        "max_fused_overhead_vs_sum": max(
            c["fused_overhead_vs_sum"] for c in cases),
    }
    if not smoke:
        OUT.write_text(json.dumps(result, indent=2) + "\n")
        # in-process, no mesh: combiners run with global (GSPMD) semantics
        append_history("combine_fused", result, mesh=None)
        emit("combine_fused_written", 0.0, f"wrote {OUT.name}")
    return result


if __name__ == "__main__":
    res = main(smoke="--smoke" in sys.argv[1:])
    print(json.dumps(res, indent=2))
    if res["smoke"]:
        c = res["cases"][0]
        assert c["fused_vs_reference_hlo_ratio"] > 1.0, c
        print("combine_fused smoke OK")
