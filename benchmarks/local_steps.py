"""Paper Table 2 (§5.2): local optimizer steps before communicating.
Reports time/step and loss after a fixed token budget for k=1 vs k=4
local steps — the slow-interconnect trade (fewer syncs, slightly worse
algorithmic efficiency, better wall clock)."""
from __future__ import annotations

from .common import emit, run_devices

CODE = r"""
import time, numpy as np, jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.models import build_model
from repro.parallel import make_runtime
from repro.parallel.policy import RunPolicy
from repro.data import DataConfig, make_source

cfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(cfg, attn_chunk=32)
mesh = jax.make_mesh((8, 1), ("data", "model"), axis_types=(jax.sharding.AxisType.Auto,)*2)
TOKENS = 64 * 32 * 40          # fixed data budget
for k in (1, 4):
    rows = 32
    rpol = RunPolicy(span=8, backend="gspmd_tree", optimizer="momentum",
                     combine_op="adasum", local_steps=k)
    rt = make_runtime(model, mesh, rpol, lr=0.3)
    state = rt.init_state(jax.random.key(0))
    src = make_source(DataConfig(seq_len=64, global_batch=rows * k,
                                 vocab_size=cfg.vocab_size, seed=5), cfg)
    step_fn = jax.jit(rt.train_step, donate_argnums=(0,))
    n_steps = TOKENS // (64 * rows * k)
    b = {kk: jnp.asarray(v) for kk, v in src.batch(0).items()}
    state, mets = step_fn(state, b)      # compile
    t0 = time.perf_counter()
    loss = None
    for step in range(1, n_steps):
        b = {kk: jnp.asarray(v) for kk, v in src.batch(step).items()}
        state, mets = step_fn(state, b)
        loss = float(mets["loss"])
    dt = (time.perf_counter() - t0) / max(n_steps - 1, 1)
    print(f"RESULT {k} {dt*1e6:.1f} {loss:.4f} {n_steps}")
"""


def main():
    out = run_devices(CODE, devices=8, timeout=1200)
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, k, us, loss, steps = line.split()
            emit(f"tab2_local_steps_k{k}", float(us),
                 f"loss_after_budget={loss};sync_rounds={steps}")


if __name__ == "__main__":
    main()
