"""Paper Table 2 (§5.2): local optimizer steps before communicating.
Reports time/step and loss after a fixed token budget for k=1 vs k=4
local steps — the slow-interconnect trade (fewer syncs, slightly worse
algorithmic efficiency, better wall clock)."""
from __future__ import annotations

from .common import emit, run_devices

CODE = r"""
import time, numpy as np, jax
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat

mcfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))
TOKENS = 64 * 32 * 40          # fixed data budget
for k in (1, 4):
    rows = 32
    cfg = EngineConfig(combine="adasum", span=8, backend="gspmd_tree",
                       optimizer="momentum", lr=0.3, local_steps=k,
                       seq_len=64, global_batch=rows * k, data_seed=5)
    sess = TrainSession.from_config(cfg, model=model, mesh=mesh,
                                    callbacks=[])
    n_steps = TOKENS // (64 * rows * k)
    sess.step(sess.batch(0))             # compile
    t0 = time.perf_counter()
    loss = None
    for step in range(1, n_steps):
        loss = sess.step(sess.batch(step))["loss"]
    dt = (time.perf_counter() - t0) / max(n_steps - 1, 1)
    print(f"RESULT {k} {dt*1e6:.1f} {loss:.4f} {n_steps}")
"""


def main():
    out = run_devices(CODE, devices=8, timeout=1200)
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, k, us, loss, steps = line.split()
            emit(f"tab2_local_steps_k{k}", float(us),
                 f"loss_after_budget={loss};sync_rounds={steps}")


if __name__ == "__main__":
    main()
