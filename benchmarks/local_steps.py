"""Paper Table 2 / Fig. 6 regime: convergence at equal wall-clock on a
slow interconnect.

Three ways to spend a synchronization budget, raced on the same tiny LM
with the same per-round data:

    every_step   k=1, synchronous Adasum each round (paper baseline)
    local_step   k=4 local optimizer steps per exchange (§5.2 Table 2:
                 fewer syncs, 4x data per round, slightly worse
                 algorithmic efficiency)
    delayed      combine_delay=1: every-round cadence, but the exchange
                 of round i-1's deltas overlaps round i's compute, so a
                 round costs max(compute, sync) instead of compute+sync

Each mode trains for a fixed number of rounds recording the loss
trajectory and its measured pure-compute round time; the harness then
prices the trajectories under an injected interconnect cost C (sized to
2x the every-step compute — the slow-interconnect regime where syncs
dominate):

    every_step round:  t_compute + C
    local_step round:  t_compute(k=4 scan) + C       (C amortized 4x)
    delayed round:     max(t_compute, C)             (exchange hidden)

and reports time-to-target-loss per mode (linear interpolation between
rounds). Emits `BENCH_local_steps.json`; the acceptance bar is that
delayed reaches the target no later than every_step. The old Table-2
time/step + loss-after-budget lines are still emitted per mode.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from .common import append_history, emit, run_devices

OUT = Path(__file__).resolve().parents[1] / "BENCH_local_steps.json"

CODE = r"""
import json, time, numpy as np, jax
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat

mcfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))
ROUNDS = 60
ROWS = 32                      # rows per local step per round
# span=4 < dp=8: the hierarchical regime (fused combine + fused delayed
# correction); span==dp would fall back to the reference tree
MODES = {
    "every_step": dict(local_steps=1, combine_delay=0,
                       global_batch=ROWS),
    "local_step": dict(local_steps=4, combine_delay=0,
                       global_batch=ROWS * 4),
    "delayed":    dict(local_steps=1, combine_delay=1,
                       global_batch=ROWS),
}
# optimizer=sgd keeps the three arms step-size-comparable: with a
# linear stateless optimizer the delayed round telescopes to exactly
# the synchronous Adasum update (one round late on the correction
# term), so the race isolates the scheduling trade — when the sync is
# paid — from optimizer-state effects (momentum combines raw grads at
# its pre point, which Adasum treats as near-orthogonal and sum-like,
# handing the synchronous arm a ~span-times larger effective step than
# the delayed arm's Adasum of correlated momentum deltas).
for name, kw in MODES.items():
    cfg = EngineConfig(combine="adasum", span=4, backend="gspmd_tree",
                       optimizer="sgd", lr=1.0, seq_len=64,
                       data_seed=5, **kw)
    sess = TrainSession.from_config(cfg, model=model, mesh=mesh,
                                    callbacks=[])
    sess.step(sess.batch(0))              # compile
    losses, times = [], []
    for step in range(1, ROUNDS + 1):
        t0 = time.perf_counter()
        losses.append(float(sess.step(sess.batch(step))["loss"]))
        times.append(time.perf_counter() - t0)
    sess.close()
    print("RESULT " + json.dumps({
        "mode": name, "losses": losses,
        "compute_s": sorted(times)[len(times) // 2],
        "run_metadata": sess.run_metadata()}))
"""


def _time_to_target(losses, per_round_s, target):
    """Wall-clock (s) when the trajectory first crosses `target`, linear
    between round boundaries; None if it never does."""
    t = 0.0
    prev = None
    for loss in losses:
        if loss < target:
            if prev is None or prev <= target:
                return t + per_round_s
            frac = (prev - target) / (prev - loss)
            return t + frac * per_round_s
        t += per_round_s
        prev = loss
    return None


def main():
    out = run_devices(CODE, devices=8, timeout=3600)
    runs = {r["mode"]: r for r in
            (json.loads(ln[len("RESULT "):]) for ln in out.splitlines()
             if ln.startswith("RESULT "))}

    # slow interconnect: one sync costs 2x the every-step compute
    sync_s = 2.0 * runs["every_step"]["compute_s"]
    per_round = {
        "every_step": runs["every_step"]["compute_s"] + sync_s,
        "local_step": runs["local_step"]["compute_s"] + sync_s,
        "delayed": max(runs["delayed"]["compute_s"], sync_s),
    }
    # target: what every_step reaches at 80% of its run — all three
    # trajectories comfortably cross it, so interpolation is meaningful
    es = runs["every_step"]["losses"]
    target = es[int(len(es) * 0.8) - 1]

    modes = {}
    for name, r in runs.items():
        tt = _time_to_target(r["losses"], per_round[name], target)
        modes[name] = {
            "compute_s_per_round": r["compute_s"],
            "modeled_round_s": per_round[name],
            "final_loss": r["losses"][-1],
            "time_to_target_s": tt,
            "combine_path": r["run_metadata"]["combine_path"],
            "combine_delay": r["run_metadata"]["combine_delay"],
        }
        k = {"every_step": 1, "local_step": 4, "delayed": 1}[name]
        emit(f"tab2_local_steps_k{k}" + ("_delayed" if name == "delayed"
                                         else ""),
             r["compute_s"] * 1e6,
             f"loss_after_budget={r['losses'][-1]:.4f};"
             f"time_to_target_s={tt if tt is None else round(tt, 4)}")

    result = {
        "rounds": int(len(es)),
        "target_loss": target,
        "injected_sync_s": sync_s,
        "modes": modes,
    }
    OUT.write_text(json.dumps(result, indent=2) + "\n")
    # topology of the measurement subprocess (run_devices), not this host
    append_history("local_steps", result, devices=8,
                   mesh={"data": 8, "model": 1})
    emit("local_steps_done", 0.0, f"wrote {OUT.name}")

    tt_e = modes["every_step"]["time_to_target_s"]
    tt_d = modes["delayed"]["time_to_target_s"]
    assert tt_d is not None, f"delayed never reached {target}: {modes}"
    assert tt_e is None or tt_d <= tt_e, (
        f"delayed time-to-target {tt_d:.3f}s later than every_step "
        f"{tt_e:.3f}s at equal wall-clock: {modes}")
    return result


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
