"""Benchmark harness — one entry per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only NAME]

Prints ``name,us_per_call,derived`` CSV. Mapping to the paper:
    fig1  orthogonality during training          (§3.6)
    fig2  exact-Hessian emulation error          (§3.7)
    fig4  ADASUMRVH vs sum-allreduce latency     (§4.2.3)
    fig6  Sum-vs-Adasum convergence vs batch     (§5.4 / §5.1.2)
    tab1  partitioned Adasum + optimizer state   (§4.3)
    tab2  local steps before communicating       (§5.2)
    tab3  Adam/LAMB x Sum/Adasum convergence     (§5.3)
    roofline  dry-run roofline terms per cell    (EXPERIMENTS.md §Roofline)
"""
from __future__ import annotations

import argparse
import sys
import traceback

from . import (algorithmic_efficiency, hessian_emulation, lm_convergence,
               local_steps, orthogonality, partitioned_adasum, roofline,
               rvh_latency, step_overlap)

BENCHES = {
    "fig1_orthogonality": orthogonality.main,
    "fig2_hessian_emulation": hessian_emulation.main,
    "fig4_rvh_latency": rvh_latency.main,
    "fig6_algorithmic_efficiency": algorithmic_efficiency.main,
    "tab1_partitioned_adasum": partitioned_adasum.main,
    "tab2_local_steps": local_steps.main,
    "tab3_lm_convergence": lm_convergence.main,
    "roofline": roofline.main,
    "step_overlap": step_overlap.main,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failed = []
    for name, fn in BENCHES.items():
        if args.only and args.only not in name:
            continue
        try:
            fn()
        except Exception as e:
            failed.append(name)
            print(f"{name},0.0,ERROR:{type(e).__name__}:{e}")
            traceback.print_exc(file=sys.stderr)
    if failed:
        raise SystemExit(f"benchmarks failed: {failed}")


if __name__ == "__main__":
    main()
