"""Paper Fig. 2: relative error of Adasum vs synchronous-SGD Sum against
the exact-Hessian sequential emulation, on a small NLL model (the
paper uses LeNet-5/MNIST; we use multinomial logistic regression where
the Fisher approximation H ~ g gT the derivation assumes holds exactly
in expectation, and jax.hessian is cheap)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .common import emit


def make_problem(d=12, c=4, n=512, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.standard_normal((d, c))
    X = rng.standard_normal((n, d))
    y = np.argmax(X @ w_true + 0.5 * rng.standard_normal((n, c)), axis=1)
    return jnp.asarray(X, jnp.float32), jnp.asarray(y)


def nll(w, X, y):
    logits = X @ w.reshape(12, 4)
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(len(y)), y])


def run_regime(lr_scale: float, steps: int = 25, nodes: int = 8):
    """lr = lr_scale / ||g||^2. The paper's LeNet-5 setup (§3.7/§5.4) uses
    a deliberately AGGRESSIVE schedule ('barely reaches the target
    accuracy'); the sequential-emulation advantage of Adasum lives in that
    regime (the Hessian correction alpha*H*g is O(1) there). At small lr
    the exact emulation degenerates to a plain sum and Sum trivially
    matches it."""
    from repro.core.adasum import adasum_tree_reduce, sum_reduce
    X, y = make_problem()
    w = jnp.zeros((48,))
    grad = jax.jit(jax.grad(nll))
    hess = jax.jit(jax.hessian(nll))
    per = len(y) // nodes
    errs_ada, errs_sum = [], []
    for step in range(steps):
        gs = [grad(w, X[i * per:(i + 1) * per], y[i * per:(i + 1) * per])
              for i in range(nodes)]
        H = hess(w, X, y)
        gn = np.mean([float(jnp.vdot(g, g)) for g in gs])
        lr = lr_scale / (gn + 1e-12)

        def emulate(g1, g2):
            c12 = g2 - lr * H @ g1          # g2 evaluated after g1's step
            c21 = g1 - lr * H @ g2
            return 0.5 * ((g1 + c12) + (g2 + c21))

        items = list(gs)
        while len(items) > 1:
            items = [emulate(items[2 * i], items[2 * i + 1])
                     for i in range(len(items) // 2)]
        g_exact = items[0]
        g_ada = adasum_tree_reduce([{"w": g} for g in gs])["w"]
        g_sum = sum_reduce([{"w": g} for g in gs])["w"]
        nrm = float(jnp.linalg.norm(g_exact)) + 1e-12
        errs_ada.append(float(jnp.linalg.norm(g_ada - g_exact)) / nrm)
        errs_sum.append(float(jnp.linalg.norm(g_sum - g_exact)) / nrm)
        w = w - lr * g_exact
    return float(np.mean(errs_ada)), float(np.mean(errs_sum))


def main():
    ada_a, sum_a = run_regime(2.0)    # aggressive (the paper's regime)
    ada_c, sum_c = run_regime(0.1)    # conservative (honest ablation)
    emit("fig2_emulation_relerr_aggressive_lr", 0.0,
         f"adasum={ada_a:.4f};sum={sum_a:.4f};adasum_better={ada_a < sum_a}")
    emit("fig2_emulation_relerr_conservative_lr", 0.0,
         f"adasum={ada_c:.4f};sum={sum_c:.4f};adasum_better={ada_c < sum_c}")
    return ada_a, sum_a


if __name__ == "__main__":
    main()
