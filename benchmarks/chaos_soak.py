"""Chaos soak: seeded fault injection across the whole stack, with the
resilience invariants asserted end to end.

Four phases, each in its own subprocess, all driven by deterministic
`ChaosSchedule`s (same seed => same faults => replayable failures):

  train   node loss -> elastic shrink; bit-flipped boundary checkpoint
          -> quarantine + last-good fallback; capacity return ->
          grow-back with AdaScale-rescaled LR. Invariant: the
          (seed, step) batch stream is BITWISE aligned across every
          restart (a replayed step fetches the exact batch the aborted
          attempt saw), and the cumulative resilience counters surface
          in run_metadata.
  sigterm SIGTERM mid-run -> exit 143 with a consistent, integrity-valid
          last-good checkpoint on disk.
  serve   slow prefill + page pressure + corrupt hot-reload step +
          deadline + drain, pressure ladder on. Invariants: every
          submitted request terminal (never hung), reload fell back past
          the corrupt step, ZERO leaked KV pages after drain + prefix
          flush.
  bitwise comm-latency spikes through the delayed combine stream are
          latency-only (spiked run == un-spiked run, bitwise), and the
          chaos machinery with an EMPTY schedule is a bitwise no-op on
          the plain sync path.

    python -m benchmarks.chaos_soak --smoke   # CI: fixed seed, >=5
        fault classes, every invariant asserted
    python -m benchmarks.chaos_soak           # longer soak + random
        generated schedule, JSON + history record
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from .common import SRC, append_history, run_devices

OUT = Path(__file__).resolve().parents[1] / "BENCH_chaos_soak.json"

TRAIN = r"""
import json, numpy as np, tempfile
from repro.chaos import (CapacityReturnCallback, ChaosCallback,
                         ChaosSchedule, FaultEvent, make_chaos_on_restart)
from repro.engine import (CheckpointCallback, EngineConfig, LoggingCallback,
                          StragglerCallback, fit_elastic)

STEPS = %(steps)d
seen, dps, sums = [], [], {}
class Record:
    def on_fit_end(self, session, history): ...
    def on_step_end(self, session, step, metrics, dt): ...
    def on_fit_start(self, session, start):
        dps.append((start, session.runtime.dp_total))
    def on_step_start(self, session, step):
        seen.append(step)
        key = float(np.asarray(session.batch(step)["tokens"],
                               np.float64).sum())
        # bitwise stream alignment across restarts: a replayed step
        # must fetch the exact batch the aborted attempt saw
        assert sums.setdefault(step, key) == key, (step, key, sums[step])

with tempfile.TemporaryDirectory() as root:
    ck = root + "/ck"
    sched = ChaosSchedule([FaultEvent(2, "node_loss"),
                           FaultEvent(0, "ckpt_bitflip")] + %(extra)s)
    cfg = EngineConfig(arch="hymba-1p5b", reduced=True, combine="adasum",
                       seq_len=32, global_batch=8, lr=%(lr)s, ckpt_dir=ck,
                       ckpt_every=1, log_every=1, elastic=True,
                       combine_stats=True)
    cbs = [LoggingCallback(1), StragglerCallback(), Record(),
           CheckpointCallback(1), ChaosCallback(sched),
           CapacityReturnCallback(delay=1)]
    hist, sess = fit_elastic(cfg, STEPS, callbacks=cbs,
                             on_restart=make_chaos_on_restart(sched, ck))
    res = sess.run_metadata()["resilience"]
    # corrupted boundary checkpoint -> quarantine + last-good fallback
    assert res["restore_fallbacks"] >= 1, res
    assert res["quarantined_steps"], res
    assert res["restarts"] >= 1 and res["grow_backs"] >= 1, res
    # shrink then grow-back, ending at the full degree
    assert dps[0][1] == 8 and dps[-1][1] == 8 and 4 in [d for _, d in dps]
    # every step executed; history ends at the last step
    assert sorted(set(seen)) == list(range(STEPS)), seen
    assert hist[-1]["step"] == STEPS - 1
    assert np.isfinite([h["loss"] for h in hist]).all()
    gb = [p for p in sess.elastic_log["plans"] if p["kind"] == "grow_back"]
    assert gb and sess.config.lr == gb[-1]["new_lr"]
    sess.close()
print("RESULT " + json.dumps({
    "steps": STEPS, "restarts": res["restarts"],
    "grow_backs": res["grow_backs"],
    "restore_fallbacks": res["restore_fallbacks"],
    "quarantined": res["quarantined_steps"],
    "grow_back_gain": gb[-1]["gain"],
    "faults": sorted(e.kind for e in sched.applied)}))
"""

SIGTERM_INNER = r"""
from repro.chaos import ChaosCallback, ChaosSchedule, FaultEvent
from repro.engine import EngineConfig, TrainSession, default_callbacks

cfg = EngineConfig(arch="hymba-1p5b", reduced=True, combine="adasum",
                   seq_len=32, global_batch=8, ckpt_dir=%(ck)r,
                   ckpt_every=2, log_every=1, async_checkpoint=True)
sched = ChaosSchedule([FaultEvent(3, "sigterm")])
cbs = default_callbacks(cfg) + [ChaosCallback(sched)]
TrainSession.from_config(cfg, callbacks=cbs).fit(8)
"""

SERVE = r"""
import json, os, signal, tempfile
import numpy as np, jax, jax.numpy as jnp
from repro.chaos import bitflip_leaf, slow_prefill
from repro.checkpoint import CheckpointManager
from repro.configs.base import ModelConfig
from repro.engine import (EngineConfig, GenerationRequest, HotReloader,
                          ServeEngine)
from repro.models import build_model

mcfg = ModelConfig("soak-tiny", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, compute_dtype=jnp.float32, attn_chunk=16)
params = model.init(jax.random.key(0))
root = tempfile.mkdtemp()
mgr = CheckpointManager(root + "/ck", keep=5)
mgr.save(1, {"params": jax.tree.map(lambda x: np.asarray(x) * 1.01,
                                    params)})
mgr.save(2, {"params": jax.tree.map(lambda x: np.asarray(x) * 1.02,
                                    params)})
bitflip_leaf(mgr.root)               # corrupt the newest (reload_corrupt)

cfg = EngineConfig(max_slots=2, max_len=48, kv_layout="paged",
                   page_size=8, kv_pages=9, pressure_ladder=True)
eng = ServeEngine(cfg, model, None, params)
eng._reloader = HotReloader(mgr, params)
eng.install_drain_handler()
undo = slow_prefill(eng, 0.01)       # slow_prefill fault, whole run

rng = np.random.RandomState(0)
req = lambda n, g, **kw: GenerationRequest(
    prompt=rng.randint(0, 257, n), max_new_tokens=g, **kw)
handles = [eng.submit(req(16, %(gen)d))]            # page pressure
eng.step()
handles.append(eng.submit(req(16, %(gen)d, max_retries=1)))
handles.append(eng.submit(req(8, 4, deadline_s=1e-6)))  # deadline kill
for _ in range(3):
    eng.step()
os.kill(os.getpid(), signal.SIGTERM)  # handled: drain mode, no exit
handles.append(eng.submit(req(8, 4)))               # queued -> drained
eng.drain()
undo()

tp = eng.throughput()
# every submitted request terminal, never hung
assert all(h.done for h in handles), [h.status for h in handles]
assert tp["completed"] + tp["failed"] == len(handles), tp
assert tp["completed"] >= 1, tp
assert tp["deadline_kills"] >= 1 and tp["drained"] >= 1, tp
# hot-reload fell back past the corrupt step (quarantined on disk)
assert eng.loaded_step == 1 and tp["restore_fallbacks"] == 1, tp
assert (mgr.root / "step_00000002.bad").exists()
# zero leaked pages after drain + prefix flush
assert eng.leaked_pages() == 0
eng.flush_prefix()
assert eng._pool.pages_used == 0, eng._pool.pages_used
print("RESULT " + json.dumps({
    "completed": tp["completed"], "failed": tp["failed"],
    "deadline_kills": tp["deadline_kills"], "drained": tp["drained"],
    "retries": tp["retries"], "preemptions": tp["preemptions"],
    "restore_fallbacks": tp["restore_fallbacks"],
    "degradation_changes": tp["degradation_changes"],
    "leaked_pages": 0}))
"""

BITWISE = r"""
import json, numpy as np, jax
from repro.chaos import ChaosCallback, ChaosSchedule, FaultEvent
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.launch.mesh import make_mesh_compat
from repro.models import build_model

mcfg = ModelConfig("soak-tiny", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))
STEPS = %(steps)d

def run(delay, sched):
    cfg = EngineConfig(combine="adasum", span=2, backend="gspmd_tree",
                       optimizer="momentum", lr=0.05, seq_len=32,
                       global_batch=8, data_seed=7, combine_delay=delay)
    sess = TrainSession.from_config(cfg, model=model, mesh=mesh,
                                    callbacks=[])
    cb = ChaosCallback(sched) if sched is not None else None
    if delay:
        sess.use_delayed_stream()
    for s in range(STEPS):
        if cb:
            cb.on_step_start(sess, s)
        m = sess.step(sess.batch(s))
        if cb:
            cb.on_step_end(sess, s, m, 0.0)
    out = [np.asarray(x) for x in jax.tree.leaves(sess.state["params"])]
    sess.close()
    return out

def same(a, b):
    return all((x == y).all() for x, y in zip(a, b))

# comm spikes through the delayed stream are latency-only: bitwise
spikes = ChaosSchedule([FaultEvent(1, "comm_spike", 0.02),
                        FaultEvent(3, "comm_spike", 0.01)])
assert same(run(1, None), run(1, spikes))
assert len(spikes.applied) == 2
# empty schedule on the plain sync path (combine_delay=0): bitwise no-op
assert same(run(0, None), run(0, ChaosSchedule([])))
print("RESULT " + json.dumps({"bitwise_comm_spike": True,
                              "bitwise_no_fault": True}))
"""


def _result(out: str) -> dict:
    for line in out.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in soak output:\n{out[-2000:]}")


def _sigterm_phase(tmp_ck: str) -> dict:
    """Run the SIGTERM-mid-run drill; the inner process must exit 143
    and leave an integrity-valid last-good checkpoint behind."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run(
        [sys.executable, "-c", SIGTERM_INNER % {"ck": tmp_ck}],
        env=env, capture_output=True, text=True, timeout=900)
    if res.returncode != 143:
        raise RuntimeError(f"SIGTERM drill exited {res.returncode}, "
                           f"wanted 143:\n{res.stderr[-2000:]}")
    from repro.checkpoint import CheckpointManager
    mgr = CheckpointManager(tmp_ck)
    latest = mgr.latest_step()
    assert latest is not None, "no checkpoint survived SIGTERM"
    problems = mgr.validate_step(latest)
    assert problems == [], problems
    return {"exit_code": 143, "last_good_step": latest, "valid": True}


def main(smoke: bool = False):
    import tempfile

    steps = 6 if smoke else 12
    # full mode adds a flagged straggler before the node loss: two
    # independent shrink -> grow-back round trips, each with the LR
    # rescaled by the live AdaScale gain. The base LR is dropped so the
    # compounded gains stay in the stable regime at 12 steps.
    extra = "[]" if smoke else "[FaultEvent(1, 'straggler')]"
    phases = {}
    phases["train"] = _result(run_devices(
        TRAIN % {"steps": steps, "extra": extra,
                 "lr": "0.01" if smoke else "0.003"},
        devices=8, timeout=1800))
    with tempfile.TemporaryDirectory() as d:
        phases["sigterm"] = _sigterm_phase(d + "/ck")
    phases["serve"] = _result(run_devices(
        SERVE % {"gen": 16 if smoke else 28}, devices=1, timeout=1800))
    phases["bitwise"] = _result(run_devices(
        BITWISE % {"steps": 4 if smoke else 8}, devices=8, timeout=1800))

    classes = set(phases["train"]["faults"]) | {
        "sigterm", "slow_prefill", "reload_corrupt", "comm_spike"}
    if phases["serve"]["deadline_kills"]:
        classes.add("deadline")
    if phases["serve"]["preemptions"]:
        classes.add("page_exhaustion")
    result = {"mode": "smoke" if smoke else "full",
              "fault_classes": sorted(classes), "phases": phases}
    assert len(classes) >= 5, classes

    if smoke:
        print(f"chaos_soak smoke OK: {len(classes)} fault classes, "
              f"all invariants held")
    else:
        OUT.write_text(json.dumps(result, indent=2) + "\n")
        append_history("chaos_soak", result, devices=8,
                       mesh={"data": 8, "model": 1})
        print(f"chaos_soak full OK: wrote {OUT.name}")
    return result


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv[1:])
