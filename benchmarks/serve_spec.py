"""Speculative-decoding benchmark: draft propose + one-forward verify
vs plain paged decode, at EQUAL tokens.

The serve shape speculation targets: decode-heavy greedy streams, where
the plain engine pays one target dispatch per token per batch and the
speculative engine pays one draft scan + ONE target verify for up to
k+1 tokens per slot. The workload runs the same requests through both
engines; tokens are asserted bitwise-equal first (the speculation
contract — verification recomputes every position, so the draft can
only change speed, never content), then the timed repeats interleave
the two engines and report medians.

The draft must be genuinely cheaper than the target AND agree with it,
without training anything: the target's blocks past the first get their
output projections zeroed (attention `wo`, MLP down-projection — each
block becomes a residual passthrough), so the 4-layer target computes
EXACTLY what its first layer computes, and a 1-layer draft sliced from
the same params proposes the target's own greedy continuation at ~1/4
the depth. Acceptance is deterministically 1.0 — the upper bound; a
real deployment's win scales with its measured acceptance rate
(reported per run), while the bitwise guarantee is
acceptance-independent.

Emits `BENCH_serve_spec.json`. Acceptance bar: >= 2x fewer target
dispatches per generated token, tok/s >= 1.5x plain paged.

    python -m benchmarks.serve_spec            # full run + JSON
    python -m benchmarks.serve_spec --smoke    # CI: tokens bitwise vs
        plain decode, acceptance > 0
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

from .common import append_history, emit

OUT = Path(__file__).resolve().parents[1] / "BENCH_serve_spec.json"

# (prompt_len, gen_len): decode-heavy, mixed lengths, staggered arrivals
WORKLOAD = [(12, 96), (17, 92), (9, 100), (14, 94)]
MAX_SLOTS = 4
STAGGER = 2
SPEC_K = 4


def _models():
    import jax
    import jax.numpy as jnp
    from repro.configs.base import ModelConfig
    from repro.models import build_model

    mcfg = ModelConfig("bench", "dense", 4, 256, 8, 4, 512, 257,
                       head_dim=32)
    model = build_model(mcfg, attn_chunk=32,
                        param_dtype=jnp.dtype("float32"))
    params = model.init(jax.random.key(0))
    # blocks 1..3: zero the output projections -> residual passthrough;
    # the 4-layer target now computes exactly its first layer, and the
    # 1-layer slice below is an EXACT draft at ~1/4 the depth
    mask = jnp.asarray([1.0] + [0.0] * (mcfg.n_layers - 1), jnp.float32)
    blocks = dict(params["blocks"])
    blocks["attn"] = dict(blocks["attn"],
                          wo=blocks["attn"]["wo"] * mask[:, None, None])
    blocks["mlp"] = dict(blocks["mlp"],
                         w_down=blocks["mlp"]["w_down"]
                         * mask[:, None, None])
    params = dict(params, blocks=blocks)
    dparams = dict(params,
                   blocks=jax.tree.map(lambda x: x[:1], params["blocks"]))
    return model, params, dparams


def _build(model, params, dparams, speculate: bool, max_len: int):
    from repro.engine import EngineConfig, ServeEngine

    cfg = EngineConfig(max_slots=MAX_SLOTS, max_len=max_len,
                       kv_layout="paged",
                       speculation_k=SPEC_K if speculate else 0,
                       draft_config={"n_layers": 1, "name": "bench-draft"}
                       if speculate else None)
    return ServeEngine(cfg, model, None, params,
                       draft_params=dparams if speculate else None)


def _workload(vocab: int, workload):
    import numpy as np
    rng = np.random.RandomState(0)
    return [(rng.randint(0, vocab, p), g) for p, g in workload]


def _run(engine, reqs):
    from repro.engine import GenerationRequest
    handles = []
    for prompt, gen in reqs:
        handles.append(engine.submit(GenerationRequest(
            prompt=prompt.copy(), max_new_tokens=gen)))
        for _ in range(STAGGER):
            engine.step()
    engine.drain()
    return handles


def _fresh_stats(engine):
    for k in ("submitted", "completed", "generated_tokens",
              "prefill_calls", "decode_steps", "prefix_hits",
              "prefix_tokens_reused", "cow_copies", "preemptions",
              "spec_ticks", "spec_tokens_proposed",
              "spec_tokens_accepted", "draft_prefills"):
        engine.stats[k] = 0
    engine.stats["started_at"] = None


def main(smoke: bool = False):
    # smoke trims generation (CI wall clock) but keeps every assertion
    workload = ([(p, g // 4) for p, g in WORKLOAD[:3]] if smoke
                else WORKLOAD)
    plain_max = max(p + g for p, g in workload) + 1
    # speculation stops within k of capacity; pad so the LAST tokens of
    # the longest request still speculate (equal-token comparison)
    max_len = plain_max + SPEC_K
    model, params, dparams = _models()
    plain = _build(model, params, dparams, False, max_len)
    spec = _build(model, params, dparams, True, max_len)
    reqs = _workload(model.cfg.vocab_size, workload)
    toks = sum(g for _, g in workload)

    # correctness first (doubles as compile warmup): bitwise tokens
    hp = _run(plain, reqs)
    hs = _run(spec, reqs)
    for a, b in zip(hp, hs):
        assert a.tokens == b.tokens, "spec tokens diverged from plain"
    kv = spec.kv_stats()
    assert kv["spec_acceptance_rate"] > 0, kv
    dpt = {n: e.stats["decode_steps"] / e.stats["generated_tokens"]
           for n, e in (("plain", plain), ("spec", spec))}

    if smoke:
        ratio = dpt["plain"] / dpt["spec"]
        assert ratio >= 2.0, dpt
        print(f"serve_spec smoke OK: acceptance="
              f"{kv['spec_acceptance_rate']:.2f}, dispatches/token "
              f"{dpt['plain']:.3f} -> {dpt['spec']:.3f} ({ratio:.1f}x), "
              f"tokens bitwise-equal")
        return {"dispatch_ratio": ratio}

    # timed repeats, interleaved so host noise hits both engines
    iters = 5
    times = {"plain": [], "spec": []}
    for _ in range(iters):
        for name, eng in (("plain", plain), ("spec", spec)):
            _fresh_stats(eng)
            t0 = time.perf_counter()
            _run(eng, reqs)
            times[name].append(time.perf_counter() - t0)

    results = {}
    for name, eng in (("plain", plain), ("spec", spec)):
        ts = sorted(times[name])
        med = ts[len(ts) // 2]
        results[name] = {
            "wall_s": med, "wall_s_all": ts, "tok_s": toks / med,
            "dispatches_per_token":
                eng.stats["decode_steps"] / eng.stats["generated_tokens"],
        }
        emit(f"serve_spec_{name}", med * 1e6,
             f"tok_s={results[name]['tok_s']:.1f} "
             f"dpt={results[name]['dispatches_per_token']:.3f}")

    kv = spec.kv_stats()
    dispatch_ratio = (results["plain"]["dispatches_per_token"]
                      / results["spec"]["dispatches_per_token"])
    tok_ratio = results["spec"]["tok_s"] / results["plain"]["tok_s"]
    result = {
        "workload": workload, "max_slots": MAX_SLOTS,
        "stagger": STAGGER, "speculation_k": SPEC_K,
        "arch": model.cfg.name,
        "draft": "1-layer slice of the 4-layer target (upper blocks "
                 "zeroed: exact agreement, acceptance upper bound)",
        "plain": results["plain"], "spec": results["spec"],
        "acceptance_rate": kv["spec_acceptance_rate"],
        "dispatch_ratio_plain_over_spec": dispatch_ratio,
        "tok_s_ratio_spec_over_plain": tok_ratio,
        "spec_stats": {k: spec.stats[k] for k in
                       ("spec_ticks", "spec_tokens_proposed",
                        "spec_tokens_accepted", "draft_prefills")},
    }
    OUT.write_text(json.dumps(result, indent=2) + "\n")
    # replicated serving (ServeEngine built with mesh=None)
    append_history("serve_spec", result, mesh=None)
    emit("serve_spec_dispatch_ratio", dispatch_ratio,
         f"tok_s_ratio={tok_ratio:.2f} wrote {OUT.name}")
    assert dispatch_ratio >= 2.0, \
        f"dispatch ratio {dispatch_ratio:.2f} < 2x"
    assert tok_ratio >= 1.5, f"spec tok/s {tok_ratio:.2f}x of plain"
    return result


if __name__ == "__main__":
    out = main(smoke="--smoke" in sys.argv)
    if "--smoke" not in sys.argv:
        print(json.dumps(out, indent=2))
