"""Shared benchmark helpers: timing, CSV output, subprocess multi-device."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

HISTORY = Path(__file__).resolve().parents[1] / "BENCH_history.jsonl"


def append_history(bench: str, result: dict, *, devices: int = None,
                   mesh: dict = None, config=None,
                   config_hash: str = None) -> None:
    """Append one run to the cross-run perf trajectory
    (BENCH_history.jsonl at the repo root). The per-bench BENCH_*.json
    files hold only the latest run; the history line is what lets a
    regression be dated to a commit.

    Every record carries `devices` (the device count the bench ran on;
    defaults to this process's jax.device_count()) and `mesh` (axis-name
    -> size, None when the bench built no mesh) — without them, history
    lines from different hosts/topologies are incomparable. Benches that
    run in a subprocess must pass the SUBPROCESS topology explicitly.
    It also carries `git_sha` (the commit the bench ran at) and
    `config_hash` (sha of the EngineConfig, pass `config=` or a
    precomputed `config_hash=` from the subprocess) so a history line
    pins both the code and the settings that produced it."""
    if devices is None:
        try:
            import jax
            devices = jax.device_count()
        except Exception:
            devices = None
    from repro.control import telemetry
    if config_hash is None and config is not None:
        config_hash = telemetry.config_hash(config)
    row = {"bench": bench,
           "ts": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
           "devices": devices,
           "mesh": mesh,
           "git_sha": telemetry.git_sha(),
           "config_hash": config_hash,
           "result": result}
    with HISTORY.open("a") as f:
        f.write(json.dumps(row, sort_keys=True) + "\n")


def emit(name: str, us_per_call: float, derived: str = ""):
    print(f"{name},{us_per_call:.1f},{derived}")


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time (us) of a jitted call."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def run_devices(code: str, devices: int = 8, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    if res.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{res.stderr[-3000:]}")
    return res.stdout
