"""Noise-adaptive batch/span growth on the paper's Fig. 6 regime.

The PR-8 controller (`repro.control`) watches the gradient-noise scale
the CombineStats piggyback surfaces and grows global batch + Adasum
span (AdaBatch-style doubling, LR rescaled by the AdaScale gain) when
the noise says larger batches stop costing convergence. This benchmark
races three arms on the tiny LM of `adascale_vs_adasum`:

  fixed_small — Adasum at the starting batch (8 rows, span 2), the
                arm the controller is supposed to beat in steps;
  fixed_big   — Adasum at the adaptive arm's batch cap (64 rows,
                span 8): defines the fixed-batch Adasum target quality;
  adaptive    — starts at the small arm's operating point, controller
                grows toward the cap (`fit_adaptive`, checkpoint +
                rebuild + resume per resize).

Records steps-to-target and final loss per arm plus the executed
resize log; asserts the adaptive arm resized at least once, kept the
(seed, step) stream contiguous across resizes, and reached the target
with >= 1.2x fewer steps than fixed_small. Emits
`BENCH_adaptive_batch.json`.

`--smoke` runs a short adaptive-only slice (few steps, aggressive
controller) asserting >= 1 resize + stream contiguity — the CI hook.
"""
from __future__ import annotations

import json
import sys
from pathlib import Path

from .common import append_history, emit, run_devices

OUT = Path(__file__).resolve().parents[1] / "BENCH_adaptive_batch.json"

TARGET = 4.6
MAX_STEPS = 160

COMMON = r"""
import json, tempfile, numpy as np
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat

mcfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))

def base_cfg(**kw):
    kw.setdefault("combine", "adasum")
    kw.setdefault("backend", "gspmd_tree")
    kw.setdefault("optimizer", "momentum")
    kw.setdefault("lr", 0.02)
    kw.setdefault("seq_len", 32)
    kw.setdefault("data_seed", 11)
    return EngineConfig(**kw)

def contiguous(history):
    return [r["step"] for r in history] == list(range(len(history)))

def steps_to(history, target):
    for r in history:
        if r["loss"] < target:
            return r["step"] + 1
    return -1
"""

FULL = COMMON + r"""
TARGET = %(target)s
MAX_STEPS = %(max_steps)d

arms = {}
for name, rows, span in (("fixed_small", 8, 2), ("fixed_big", 64, 8)):
    cfg = base_cfg(global_batch=rows, span=span)
    sess = TrainSession.from_config(cfg, model=model, mesh=mesh,
                                    callbacks=[])
    hist = []
    for step in range(MAX_STEPS):
        loss = sess.step(sess.batch(step))["loss"]
        hist.append({"step": step, "loss": float(loss)})
    arms[name] = {"batch": rows, "span": span,
                  "steps_to_target": steps_to(hist, TARGET),
                  "final_loss": round(float(hist[-1]["loss"]), 4)}

from repro.control import fit_adaptive
from repro.control.telemetry import config_hash
with tempfile.TemporaryDirectory() as ckpt:
    cfg = base_cfg(global_batch=8, span=2, steps=MAX_STEPS,
                   ckpt_dir=ckpt, adaptive_batch=True,
                   grow_threshold=2.0, grow_patience=2, grow_cooldown=8,
                   max_global_batch=64, ckpt_every=0)
    hist, sess = fit_adaptive(cfg, MAX_STEPS, callbacks=[],
                              model=model, mesh=mesh)
    arms["adaptive"] = {
        "start_batch": 8, "start_span": 2,
        "final_batch": sess.config.global_batch,
        "final_span": sess.runtime.span,
        "final_lr": round(float(sess.config.lr), 6),
        "steps_to_target": steps_to(hist, TARGET),
        "final_loss": round(float(hist[-1]["loss"]), 4),
        "resizes": sess.resize_log,
        "contiguous": contiguous(hist)}
    chash = config_hash(cfg)
    sess.close()

print("RESULT " + json.dumps({"arms": arms, "config_hash": chash}))
"""

SMOKE = COMMON + r"""
from repro.control import fit_adaptive
with tempfile.TemporaryDirectory() as ckpt:
    cfg = base_cfg(global_batch=8, span=2, steps=14, ckpt_dir=ckpt,
                   adaptive_batch=True, grow_threshold=1.0,
                   grow_patience=2, grow_cooldown=3, max_global_batch=32,
                   ckpt_every=0)
    hist, sess = fit_adaptive(cfg, 14, callbacks=[], model=model, mesh=mesh)
    assert sess.resize_log, "controller never resized in the smoke window"
    assert contiguous(hist), "step stream broke across resize"
    assert all(np.isfinite(r["loss"]) for r in hist)
    sess.close()
print("RESULT " + json.dumps({"resizes": len(sess.resize_log),
                              "steps": len(hist)}))
"""


def _run(code: str) -> dict:
    out = run_devices(code, devices=8, timeout=3600)
    for line in out.splitlines():
        if line.startswith("RESULT "):
            return json.loads(line[len("RESULT "):])
    raise RuntimeError(f"no RESULT line in bench output:\n{out[-2000:]}")


def main(smoke: bool = False):
    if smoke:
        res = _run(SMOKE)
        emit("adaptive_smoke", 0.0,
             f"resizes={res['resizes']};steps={res['steps']}")
        print("adaptive_batch smoke OK")
        return res

    res = _run(FULL % {"target": TARGET, "max_steps": MAX_STEPS})
    arms = res["arms"]
    ada, small = arms["adaptive"], arms["fixed_small"]
    checks = {
        "resized": len(ada["resizes"]) >= 1,
        "contiguous": ada["contiguous"],
        "reached_target": ada["steps_to_target"] > 0,
        "quality_match": (small["steps_to_target"] < 0
                          or ada["final_loss"] <= small["final_loss"]
                          + 0.05),
    }
    if small["steps_to_target"] > 0 and ada["steps_to_target"] > 0:
        speedup = small["steps_to_target"] / ada["steps_to_target"]
    else:
        # baseline never reached the target inside MAX_STEPS while the
        # adaptive arm did: an unbounded step win, report the floor
        speedup = float("inf") if ada["steps_to_target"] > 0 else 0.0
    checks["speedup_1p2x"] = speedup >= 1.2
    result = {"target_loss": TARGET, "max_steps": MAX_STEPS,
              "arms": arms,
              "speedup_vs_fixed_small": (round(speedup, 3)
                                         if speedup != float("inf")
                                         else "inf"),
              "checks": checks}
    OUT.write_text(json.dumps(result, indent=2) + "\n")
    for name, arm in arms.items():
        emit(f"adaptive_{name}", 0.0,
             f"steps_to_target={arm['steps_to_target']};"
             f"final_loss={arm['final_loss']}")
    append_history("adaptive_batch", result, devices=8,
                   mesh={"data": 8, "model": 1},
                   config_hash=res.get("config_hash"))
    emit("adaptive_done", 0.0, f"wrote {OUT.name}")
    bad = [k for k, ok in checks.items() if not ok]
    if bad:
        raise SystemExit(f"adaptive_batch acceptance failed: {bad}")
    return result


if __name__ == "__main__":
    print(json.dumps(main(smoke="--smoke" in sys.argv[1:]), indent=2))
