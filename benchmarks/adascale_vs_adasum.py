"""AdaScale vs Adasum on the paper's Fig. 6 regime: convergence as the
effective batch grows.

The paper's Fig. 6 claim is that Adasum keeps converging (in steps to a
target loss) as batch size scales into the regime where plain averaging
stalls; AdaScale (Johnson et al.) is the published gain-ratio alternative
the PR-2 combiner registry grew. This benchmark races the two combiners
(`adascale` vs `adasum` on the gspmd_tree backend, 8 lanes) at growing
global batch on the tiny LM and records steps-to-target + final loss per
batch size. Emits `BENCH_adascale_fig6.json`.
"""
from __future__ import annotations

import json
from pathlib import Path

from .common import append_history, emit, run_devices

OUT = Path(__file__).resolve().parents[1] / "BENCH_adascale_fig6.json"

CODE = r"""
import json, numpy as np
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat

mcfg = ModelConfig("bench", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))
TARGET = 3.5
MAX_STEPS = 120
for rows in (16, 64, 128):              # growing effective batch
    for name in ("adascale", "adasum"):
        cfg = EngineConfig(combine=name, span=8, backend="gspmd_tree",
                           optimizer="momentum", lr=0.05, seq_len=32,
                           global_batch=rows, data_seed=11)
        sess = TrainSession.from_config(cfg, model=model, mesh=mesh,
                                        callbacks=[])
        steps_to = -1
        loss = float("nan")
        for step in range(MAX_STEPS):
            loss = sess.step(sess.batch(step))["loss"]
            if not np.isfinite(loss):
                break
            if loss < TARGET and steps_to < 0:
                steps_to = step + 1
        print("RESULT " + json.dumps({
            "batch": rows, "combine": name, "steps_to_target": steps_to,
            "final_loss": round(float(loss), 4)}))
"""


def main():
    out = run_devices(CODE, devices=8, timeout=3600)
    runs = [json.loads(line[len("RESULT "):])
            for line in out.splitlines() if line.startswith("RESULT ")]
    by_batch = {}
    for r in runs:
        by_batch.setdefault(r["batch"], {})[r["combine"]] = {
            "steps_to_target": r["steps_to_target"],
            "final_loss": r["final_loss"]}
        emit(f"fig6_b{r['batch']}_{r['combine']}", 0.0,
             f"steps_to_target={r['steps_to_target']};"
             f"final_loss={r['final_loss']}")
    result = {"target_loss": 3.5, "span": 8, "max_steps": 120,
              "batches": by_batch}
    OUT.write_text(json.dumps(result, indent=2) + "\n")
    # topology of the measurement subprocess (run_devices), not this host
    append_history("adascale_fig6", result, devices=8,
                   mesh={"data": 8, "model": 1})
    emit("fig6_done", 0.0, f"wrote {OUT.name}")
    return result


if __name__ == "__main__":
    print(json.dumps(main(), indent=2))
