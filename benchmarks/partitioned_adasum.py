"""Paper Table 1 (§4.3): parallelizing the Adasum computation + optimizer
state partitioning (Marian/ZeRO-1 style). Compares the model-update phase
with the optimizer+combine partitioned over the data axis vs fully
replicated: wall time per update and per-device state bytes."""
from __future__ import annotations

from .common import emit, run_devices

CODE = r"""
import time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core.combine import CombineConfig
from repro.core.dist_opt import DistributedOptimizer
from repro.engine import make_combiner
from repro.optim.optimizers import adam
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((8,), ("data",))
D = 1 << 20
tree = lambda: {f"l{i}": np.random.randn(8, D).astype(np.float32) / 100
                for i in range(4)}
params = {k: jnp.asarray(v[0]) for k, v in tree().items()}

for mode in ("replicated", "partitioned"):
    ccfg = CombineConfig(op="adasum", backend="gspmd_tree", span=8)
    combiner = make_combiner(ccfg)
    dopt = DistributedOptimizer(adam(1e-3), ccfg, combiner, span=8)
    state = dopt.init(params)
    lane_sh = NamedSharding(mesh, P("data", None))
    if mode == "partitioned":
        st_sh = jax.tree.map(lambda _: lane_sh, state["inner"])
        state = {"inner": jax.tree.map(jax.device_put, state["inner"], st_sh),
                 "step": state["step"]}
    G = {k: jax.device_put(jnp.asarray(v), lane_sh) for k, v in tree().items()}

    @jax.jit
    def update(G, state, params):
        delta, st = dopt.update(G, state, params)
        return dopt.apply(params, delta), st

    p2, st = update(G, state, params); jax.block_until_ready(p2)
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        p2, st2 = update(G, state, params)
        jax.block_until_ready(p2)
        ts.append(time.perf_counter() - t0)
    bytes_per_dev = sum(
        np.prod(x.shape) * 4 / 8 if mode == "partitioned"
        else np.prod(x.shape) * 4
        for x in jax.tree.leaves(state["inner"])) / 2**20
    print(f"RESULT {mode} {sorted(ts)[2]*1e6:.1f} {bytes_per_dev:.1f}")
"""


def main():
    out = run_devices(CODE, devices=8, timeout=900)
    res = {}
    for line in out.splitlines():
        if line.startswith("RESULT"):
            _, mode, us, mb = line.split()
            res[mode] = (float(us), float(mb))
    if "replicated" in res and "partitioned" in res:
        ru, rm = res["replicated"]
        pu, pm = res["partitioned"]
        emit("tab1_partitioned_adasum", pu,
             f"replicated_us={ru:.1f};speedup={ru / pu:.2f};"
             f"state_MiB_dev={pm:.1f}_vs_{rm:.1f}")


if __name__ == "__main__":
    main()
