"""engine/serving: fused prefill == stepped prefill, continuous batching
== solo decoding, checkpoint hot-reload, params-only restore, and the
serve config surface.

Token-level equivalence is the contract: greedy argmax ids must be
identical between the fused request-level paths and the legacy stepped
loop (fp32 compute keeps the comparisons exact on CPU)."""
import dataclasses
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointManager, CheckpointManager
from repro.configs.base import ModelConfig, get_reduced
from repro.engine import (EngineConfig, GenerationRequest, ServeEngine,
                          ServeSession, TrainSession)
from repro.engine.serving import ContinuousBatchingScheduler, RequestHandle
from repro.engine.serving.scheduler import GenerationRequest as _Req
from repro.models import build_model

TINY = ModelConfig("serve-tiny", "dense", 2, 64, 4, 2, 128, 257,
                   head_dim=16)


def tiny_model():
    return build_model(TINY, compute_dtype=jnp.float32, attn_chunk=16)


def reduced_model(arch):
    cfg = get_reduced(arch)
    if cfg.n_experts:     # no-drop capacity: keep rows independent
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return build_model(cfg, compute_dtype=jnp.float32, attn_chunk=8)


def serve_cfg(**kw):
    kw.setdefault("max_slots", 4)
    kw.setdefault("max_len", 48)
    return EngineConfig(**kw)


# ------------------------------------------------------- fused prefill
class TestFusedPrefill:
    """generate() through the engine (fused prefill + slotted decode)
    must produce tokens identical to the stepped_prefill legacy loop."""

    # gqa: parallel prefill; swa: rolling-layout parallel prefill;
    # mla: latent-cache parallel prefill; hybrid/rwkv: fused scan prefill
    CASES = {
        "gqa": "qwen3-32b",
        "swa": "mixtral-8x22b",
        "mla": "minicpm3-4b",
        "hybrid": "hymba-1.5b",
        "rwkv": "rwkv6-7b",
    }

    @pytest.mark.parametrize("name", sorted(CASES))
    def test_engine_matches_stepped(self, name):
        model = reduced_model(self.CASES[name])
        cfg = serve_cfg()
        sess = ServeSession.from_config(cfg, model=model)
        B, T, G = 2, 10, 6
        prompts = jax.random.randint(jax.random.key(2), (B, T), 0,
                                     model.cfg.vocab_size)
        ref = sess.generate(prompts, G, max_len=cfg.max_len,
                            stepped_prefill=True)
        out = sess.generate(prompts, G, max_len=cfg.max_len)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_swa_prompt_longer_than_window(self):
        model = reduced_model("mixtral-8x22b")
        w = model.cfg.sliding_window
        cfg = serve_cfg(max_len=w + 24)
        sess = ServeSession.from_config(cfg, model=model)
        T = w + 7                     # rolling-layout prefill path
        prompts = jax.random.randint(jax.random.key(3), (2, T), 0,
                                     model.cfg.vocab_size)
        ref = sess.generate(prompts, 5, max_len=cfg.max_len,
                            stepped_prefill=True)
        out = sess.generate(prompts, 5, max_len=cfg.max_len)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_prefill_mode_validation(self):
        model = reduced_model("rwkv6-7b")     # recurrent: no parallel path
        assert model.prefill_cache is None
        with pytest.raises(ValueError, match="parallel prefill"):
            ServeEngine(serve_cfg(prefill_mode="parallel"), model, None,
                        model.init(jax.random.key(0)))

    def test_frontend_rejected(self):
        cfg = dataclasses.replace(TINY, frontend="vision", frontend_dim=8,
                                  frontend_tokens=4)
        model = build_model(cfg, compute_dtype=jnp.float32, attn_chunk=16)
        with pytest.raises(ValueError, match="decoder-only"):
            ServeEngine(serve_cfg(), model, None,
                        model.init(jax.random.key(0)))


# ------------------------------------------------- continuous batching
class TestContinuousBatching:
    def test_staggered_arrivals_match_solo(self):
        """Requests of unequal length admitted at different ticks into a
        2-slot pool produce exactly the tokens each would get decoded
        alone (per-slot positions/masks keep rows independent)."""
        model = tiny_model()
        cfg = serve_cfg(max_slots=2)
        params = model.init(jax.random.key(0))
        eng = ServeEngine(cfg, model, None, params)
        rng = np.random.RandomState(0)
        V = model.cfg.vocab_size
        specs = [(7, 5), (13, 9), (4, 12), (21, 3)]   # (prompt_len, gen)
        handles = []
        for plen, gen in specs:
            handles.append(eng.submit(GenerationRequest(
                prompt=rng.randint(0, V, plen), max_new_tokens=gen)))
            eng.step()                                # staggered admission
        eng.drain()
        assert all(h.done for h in handles)

        sess = ServeSession(cfg, model, None, params)
        for h in handles:
            T = len(h.request.prompt)
            ref = sess.generate(jnp.asarray(h.request.prompt)[None],
                                h.request.max_new_tokens,
                                max_len=cfg.max_len, stepped_prefill=True)
            np.testing.assert_array_equal(
                np.asarray(h.tokens), np.asarray(ref)[0, T:])

    def test_no_recompilation_as_slots_churn(self):
        """Slot admission/retirement must never change decode shapes."""
        model = tiny_model()
        eng = ServeEngine(serve_cfg(max_slots=2), model, None,
                          model.init(jax.random.key(0)))
        rng = np.random.RandomState(1)
        for plen, gen in [(5, 3), (9, 6), (6, 2), (12, 4)]:
            eng.submit(GenerationRequest(prompt=rng.randint(0, 257, plen),
                                         max_new_tokens=gen))
            eng.step()
        eng.drain()
        assert eng.throughput()["completed"] == 4
        size = getattr(eng._decode, "_cache_size", lambda: 1)()
        assert size == 1, f"decode retraced {size} times"

    def test_eos_retires_early_and_slot_is_reused(self):
        model = tiny_model()
        eng = ServeEngine(serve_cfg(max_slots=1), model, None,
                          model.init(jax.random.key(0)))
        rng = np.random.RandomState(2)
        prompt = rng.randint(0, 257, 6)
        # find the first greedy token, then use it as the eos id
        probe = eng.submit(GenerationRequest(prompt=prompt.copy(),
                                             max_new_tokens=1))
        eng.drain()
        eos = probe.tokens[0]
        h1 = eng.submit(GenerationRequest(prompt=prompt.copy(),
                                          max_new_tokens=10, eos_id=eos))
        h2 = eng.submit(GenerationRequest(prompt=rng.randint(0, 257, 5),
                                          max_new_tokens=2))
        eng.drain()
        assert h1.finish_reason == "eos" and len(h1.tokens) == 1
        assert h2.done and len(h2.tokens) == 2

    def test_streaming_callbacks_fire_per_token(self):
        model = tiny_model()
        eng = ServeEngine(serve_cfg(), model, None,
                          model.init(jax.random.key(0)))
        seen = []
        h = eng.submit(GenerationRequest(
            prompt=np.arange(5), max_new_tokens=4,
            stream=lambda hd, tok: seen.append(tok)))
        eng.drain()
        assert seen == h.tokens and len(seen) == 4


# ------------------------------------------------------------ scheduler
class TestScheduler:
    def test_fifo_admission_and_slot_reuse(self):
        s = ContinuousBatchingScheduler(max_slots=2, max_len=32)
        hs = [RequestHandle(_Req(prompt=np.arange(4), max_new_tokens=4))
              for _ in range(3)]
        for h in hs:
            s.submit(h)
        admitted = s.admit()
        assert [h.slot for h in hs[:2]] == [0, 1] and hs[2].slot is None
        assert len(admitted) == 2 and not s.free_slots
        s.retire(0, "length")
        assert hs[0].done and hs[0].finish_reason == "length"
        (slot, h3), = s.admit()
        assert h3 is hs[2] and slot == 0
        assert s.occupancy() == 1.0

    def test_oversized_request_rejected_up_front(self):
        s = ContinuousBatchingScheduler(max_slots=1, max_len=16)
        with pytest.raises(ValueError, match="exceeds the slot capacity"):
            s.submit(RequestHandle(_Req(prompt=np.arange(10),
                                        max_new_tokens=10)))

    def test_retirement_conditions(self):
        s = ContinuousBatchingScheduler(max_slots=1, max_len=64)
        h = RequestHandle(_Req(prompt=np.arange(3), max_new_tokens=2,
                               eos_id=7))
        h.tokens = [5]
        assert s.should_retire(h, 7) == "eos"
        assert s.should_retire(h, 4) is None
        h.tokens = [5, 4]
        assert s.should_retire(h, 4) == "length"


# ------------------------------------------------------------ hot reload
class TestHotReload:
    def _train(self, tmp, steps):
        # global_batch=8: divisible by span for any simulated device
        # count ci.sh uses (the 8-device flag made batch=4 invalid)
        cfg = EngineConfig(combine="mean", optimizer="momentum", lr=0.05,
                           seq_len=16, global_batch=8, steps=steps,
                           ckpt_dir=tmp, ckpt_every=10 ** 6,
                           log_every=10 ** 6)
        return TrainSession.from_config(cfg, model=tiny_model(),
                                        callbacks=[])

    def test_mid_stream_swap_preserves_in_flight(self, tmp_path):
        """A save from a concurrent TrainSession (async manager, write in
        flight) is picked up by the running engine: the in-flight request
        finishes on the OLD weights, a later request sees the NEW ones,
        nothing is dropped. The shared AsyncCheckpointManager's
        latest_step/restore_params barriers make the poll race-free."""
        tmp = str(tmp_path)
        ts = self._train(tmp, 2)
        assert isinstance(ts.checkpoint, AsyncCheckpointManager)
        ts.fit(2)
        ts.save_sync(2)

        cfg = serve_cfg(max_slots=2, max_len=40, ckpt_dir=tmp,
                        hot_reload=True)
        eng = ServeEngine.from_config(cfg, model=ts.model,
                                      checkpoint=ts.checkpoint)
        assert eng.loaded_step == 2
        rng = np.random.RandomState(3)
        V = ts.model.cfg.vocab_size
        h_old = eng.submit(GenerationRequest(prompt=rng.randint(0, V, 6),
                                             max_new_tokens=12))
        eng.step()                     # h_old in flight on version 0
        assert not h_old.done
        ts.fit(4)
        ts.save(4)                     # async write scheduled, NOT waited
        h_new = eng.submit(GenerationRequest(prompt=rng.randint(0, V, 6),
                                             max_new_tokens=4))
        eng.drain()                    # poll hits the barrier, then swaps
        assert eng.stats["reloads"] == 1 and eng.loaded_step == 4
        assert h_old.done and len(h_old.tokens) == 12
        assert h_new.done and len(h_new.tokens) == 4
        assert h_old.version == 0 and h_new.version == 1

        # reference decodes under each checkpoint's weights
        mgr = CheckpointManager(tmp)
        template = jax.eval_shape(ts.model.init, jax.random.key(0))
        for h, step in ((h_old, 2), (h_new, 4)):
            sess = ServeSession(cfg, ts.model, None,
                                mgr.restore_params(template, step))
            ref = sess.generate(jnp.asarray(h.request.prompt)[None],
                                h.request.max_new_tokens, max_len=40,
                                stepped_prefill=True)
            np.testing.assert_array_equal(
                np.asarray(h.tokens),
                np.asarray(ref)[0, len(h.request.prompt):])
        # old params version garbage-collected once its slots drained
        assert list(eng._params) == [1]
        ts.close()


# ----------------------------------------------------- restore_params
class TestRestoreParams:
    def test_serves_trained_weights(self, tmp_path):
        tmp = str(tmp_path)
        tcfg = EngineConfig(combine="mean", optimizer="momentum", lr=0.05,
                            seq_len=16, global_batch=8, steps=2,
                            ckpt_dir=tmp, ckpt_every=10 ** 6,
                            log_every=10 ** 6)
        ts = TrainSession.from_config(tcfg, model=tiny_model(),
                                      callbacks=[])
        ts.fit(2)
        ts.save_sync(2)
        ts.close()

        scfg = serve_cfg(ckpt_dir=tmp)
        sess = ServeSession.from_config(scfg, model=tiny_model())
        for got, want in zip(jax.tree.leaves(sess.params),
                             jax.tree.leaves(ts.state["params"])):
            np.testing.assert_allclose(np.asarray(got), np.asarray(want))

    def test_legacy_manifest_rejected_with_hint(self, tmp_path):
        import json
        mgr = CheckpointManager(str(tmp_path))
        state = {"params": {"w": jnp.ones((2,))}, "step": jnp.zeros(())}
        path = mgr.save(1, state)
        meta = json.loads((path / "manifest.json").read_text())
        for leaf in meta["leaves"]:
            del leaf["path"]          # simulate a pre-PR-3 checkpoint
        (path / "manifest.json").write_text(json.dumps(meta))
        with pytest.raises(ValueError, match="path-indexed"):
            mgr.restore_params({"w": jnp.zeros((2,))})

    def test_incompatible_model_is_a_clear_error(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path))
        state = {"params": {"w": jnp.ones((2,))}, "step": jnp.zeros(())}
        mgr.save(1, state)
        # structural mismatch is one clear ValueError naming the step
        # and the missing leaves — never a raw KeyError
        with pytest.raises(ValueError, match=r"step 1 is missing 1 params"):
            mgr.restore_params({"other": jnp.zeros((2,))})
        with pytest.raises(ValueError, match="shape"):
            mgr.restore_params({"w": jnp.zeros((3,))})


# ------------------------------------------------------------- config
class TestServeConfig:
    def test_serve_fields_roundtrip(self):
        cfg = EngineConfig(arch="qwen3-32b", max_slots=16, max_len=512,
                           hot_reload=True, ckpt_dir="/tmp/x",
                           prefill_mode="scan")
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg

    def test_from_cli_serve_flags(self):
        cfg = EngineConfig.from_cli(
            ["--arch", "hymba-1p5b", "--max-slots", "3", "--max-len",
             "96", "--hot-reload", "--ckpt-dir", "/tmp/ck",
             "--prefill-mode", "scan"])
        assert (cfg.max_slots, cfg.max_len, cfg.hot_reload,
                cfg.prefill_mode) == (3, 96, True, "scan")
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg

    def test_validation(self):
        with pytest.raises(ValueError, match="max_slots"):
            EngineConfig(max_slots=0).validate()
        with pytest.raises(ValueError, match="hot_reload"):
            EngineConfig(hot_reload=True).validate()
        with pytest.raises(ValueError, match="prefill_mode"):
            EngineConfig(prefill_mode="lazy").validate()


# ------------------------------------------------------------ sampling
class TestSampling:
    """Per-request temperature / top-k / top-p next to the argmax:
    greedy (temperature 0) stays the default and the bitwise path;
    sampled decode is a pure function of (seed, position)."""

    def _run(self, model, reqs, **cfg_kw):
        eng = ServeEngine(serve_cfg(**cfg_kw), model,
                          None, model.init(jax.random.key(0)))
        handles = [eng.submit(GenerationRequest(**r)) for r in reqs]
        eng.drain()
        return [h.tokens for h in handles]

    def test_request_validation(self):
        with pytest.raises(ValueError, match="temperature"):
            _Req(prompt=[1, 2], temperature=-0.5)
        with pytest.raises(ValueError, match="top_k"):
            _Req(prompt=[1, 2], top_k=-1)
        with pytest.raises(ValueError, match="top_p"):
            _Req(prompt=[1, 2], top_p=0.0)
        r = _Req(prompt=[1, 2])   # seed defaults to the request id
        assert r.sampling_seed == r.request_id
        assert _Req(prompt=[1, 2], seed=11).sampling_seed == 11

    def test_greedy_row_bitwise_unaffected_by_sampled_neighbor(self):
        model = tiny_model()
        p = list(range(1, 9))
        solo = self._run(model, [dict(prompt=p, max_new_tokens=8)])
        mixed = self._run(model, [
            dict(prompt=p, max_new_tokens=8),
            dict(prompt=p, max_new_tokens=8, temperature=1.3, seed=3)])
        assert solo[0] == mixed[0]

    def test_seeded_reproducible_and_batch_independent(self):
        model = tiny_model()
        p = list(range(1, 9))
        req = dict(prompt=p, max_new_tokens=8, temperature=1.0, seed=7)
        a = self._run(model, [req])
        b = self._run(model, [dict(prompt=[5, 6, 7], max_new_tokens=4,
                                   temperature=0.7, seed=1), req])
        assert a[0] == b[1]            # same (seed, t) stream in any batch
        c = self._run(model, [dict(prompt=p, max_new_tokens=8,
                                   temperature=1.0, seed=8)])
        assert a[0] != c[0]            # a different seed diverges

    def test_top_k_one_is_argmax_at_any_temperature(self):
        model = tiny_model()
        p = list(range(1, 9))
        greedy = self._run(model, [dict(prompt=p, max_new_tokens=8)])
        k1 = self._run(model, [dict(prompt=p, max_new_tokens=8,
                                    temperature=9.0, top_k=1)])
        assert greedy[0] == k1[0]

    def test_sample_logits_truncation(self):
        """top-k masks ranks >= k; tiny top-p collapses to argmax."""
        from repro.engine.build import sample_logits
        logits = jnp.asarray([[0.0, 1.0, 2.0, 3.0]] * 2)
        keys = jnp.stack([jax.random.PRNGKey(0), jax.random.PRNGKey(1)])
        pos = jnp.zeros((2,), jnp.int32)
        temp = jnp.full((2,), 5.0)
        # top_p -> ~0: only the argmax survives the nucleus
        out = sample_logits(logits, keys, pos, temp,
                            jnp.zeros((2,), jnp.int32),
                            jnp.full((2,), 1e-6))
        assert out.tolist() == [3, 3]
        # top_k=2 at extreme temperature: only ids {2, 3} possible
        draws = set()
        for s in range(16):
            k = jnp.stack([jax.random.PRNGKey(s)] * 2)
            out = sample_logits(logits, k, pos + s, temp,
                                jnp.full((2,), 2, jnp.int32),
                                jnp.ones((2,)))
            draws.update(out.tolist())
        assert draws <= {2, 3} and len(draws) == 2

    def test_scan_prefill_samples_first_token_too(self):
        """Recurrent families (scan prefill) honor sampling from the very
        first generated token: two seeds diverge immediately for a
        high-entropy model."""
        model = reduced_model("rwkv6-7b")
        p = list(range(1, 7))
        outs = {s: self._run(model, [dict(prompt=p, max_new_tokens=4,
                                          temperature=2.0, seed=s)],
                             max_len=32)[0]
                for s in (0, 1, 2, 3)}
        assert len({tuple(v) for v in outs.values()}) > 1
