"""Pipelined runtime tests: prefetch determinism + overlap, async
checkpointing barriers, elastic restart, and the adascale combiner."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.checkpoint import AsyncCheckpointManager
from repro.core.combine import CombineConfig
from repro.data import DataConfig, make_source
from repro.engine import EngineConfig, TrainSession, make_combiner
from repro.runtime import DelayedSource, Prefetcher, plan_shrink


def small_source(seed=0):
    return make_source(DataConfig(seq_len=16, global_batch=4,
                                  vocab_size=64, seed=seed))


# ----------------------------------------------------------------- prefetch

class TestPrefetcher:
    def test_stream_bitwise_identical(self):
        """Prefetched batches == synchronous batches, bit for bit."""
        src = small_source()
        with Prefetcher(src) as pf:
            for step in (0, 1, 2, 3):
                got = pf.get(step)
                want = src.batch(step)
                for k in want:
                    np.testing.assert_array_equal(np.asarray(got[k]),
                                                  want[k])

    def test_seek_preserves_determinism(self):
        """A restart (seek to an arbitrary step) must not consume stale
        speculative batches — the pure-(seed, step) contract."""
        src = small_source()
        with Prefetcher(src) as pf:
            pf.get(0)
            pf.get(1)           # step 2 now speculatively in flight
            got = pf.get(7)     # simulated resume at step 7
            np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                          src.batch(7)["tokens"])
            got = pf.get(8)     # the speculation after the seek is used
            np.testing.assert_array_equal(np.asarray(got["tokens"]),
                                          src.batch(8)["tokens"])
        assert pf.hits >= 1     # at least one overlap won after warmup

    def test_overlap_hides_host_latency(self):
        """With a slow host stage, sequential gets must not pay the
        latency serially once the pipeline is warm."""
        delay = 0.05
        src = DelayedSource(small_source(), delay)
        with Prefetcher(src) as pf:
            pf.get(0)           # warmup (paid synchronously)
            t0 = time.perf_counter()
            for step in (1, 2, 3):
                pf.get(step)
                time.sleep(delay * 1.5)   # "device step" longer than host
            waited = time.perf_counter() - t0 - 3 * delay * 1.5
        # three synchronous pulls would add 3*delay of waiting; the
        # prefetched path should wait far less than that
        assert waited < 2 * delay, waited

    def test_limit_stops_end_of_run_speculation(self):
        """No batch is ever produced past the end of the run (wasted
        host work), but explicit gets beyond the limit still answer."""
        src = small_source()
        with Prefetcher(src, limit=4) as pf:
            pf.get(3)                  # final step: nothing to speculate
            assert not pf._pending
            np.testing.assert_array_equal(
                np.asarray(pf.get(4)["tokens"]), src.batch(4)["tokens"])

    def test_close_falls_back_synchronous(self):
        src = small_source()
        pf = Prefetcher(src)
        pf.close()
        np.testing.assert_array_equal(
            np.asarray(pf.get(3)["tokens"]), src.batch(3)["tokens"])


# --------------------------------------------------------- async checkpoint

def state_like(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((4, 3)),
                                        jnp.float32)},
            "step": jnp.asarray(seed, jnp.int32)}


class TestAsyncCheckpoint:
    def test_roundtrip_through_barrier(self, tmp_path):
        cm = AsyncCheckpointManager(tmp_path)
        s = state_like(7)
        cm.save(7, s)
        # latest_step is a barrier: the write must be visible after it
        assert cm.latest_step() == 7
        r = cm.restore(jax.tree.map(jnp.zeros_like, s))
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        cm.close()

    def test_snapshot_survives_donation(self, tmp_path):
        """The host snapshot is taken before save() returns, so the
        donated/reused device buffer cannot corrupt the checkpoint."""
        cm = AsyncCheckpointManager(tmp_path)
        w = np.arange(12, dtype=np.float32).reshape(4, 3)
        state = {"w": jnp.asarray(w)}
        cm.save(1, state)
        # simulate the runtime overwriting the buffer right after save()
        state["w"] = state["w"] * 0 - 1.0
        cm.wait()
        r = cm.restore({"w": jnp.zeros((4, 3), jnp.float32)})
        np.testing.assert_array_equal(np.asarray(r["w"]), w)
        cm.close()

    def test_overlapping_saves_serialize(self, tmp_path):
        cm = AsyncCheckpointManager(tmp_path, keep=10)
        for s in range(5):
            cm.save(s, state_like(s))
        assert cm.all_steps() == [0, 1, 2, 3, 4]
        cm.close()

    def test_sigterm_drains_inflight_write_then_saves(self, tmp_path):
        """SIGTERM during a background write must not be dropped: drain,
        final save, exit 143 — with both checkpoints durable."""
        run_in_subprocess(rf"""
import os, signal
import jax.numpy as jnp
from repro.checkpoint import AsyncCheckpointManager
cm = AsyncCheckpointManager(r"{tmp_path}/ck")
state = {{"w": jnp.zeros((4, 3)), "step": jnp.asarray(1)}}
cm.install_preemption_handler(lambda: (cm.save(9, state), cm.wait()))
cm.save(1, state)                      # in-flight background write
try:
    os.kill(os.getpid(), signal.SIGTERM)
except SystemExit as e:
    assert e.code == 143, e.code
    assert cm.all_steps() == [1, 9], cm.all_steps()
    print("OK")
""", devices=1)

    def test_writer_error_surfaces_at_barrier(self, tmp_path):
        cm = AsyncCheckpointManager(tmp_path)
        cm.save(1, {"w": jnp.zeros(3)})
        cm.wait()
        cm._future = cm._pool.submit(lambda: (_ for _ in ()).throw(
            OSError("disk full")))
        with pytest.raises(OSError, match="disk full"):
            cm.wait()
        cm.close()


# ----------------------------------------------------------------- adascale

class TestAdaScale:
    def stacked(self, lanes):
        return {"w": jnp.stack(lanes)}

    def test_equals_mean_at_gain_one(self):
        """Identical lanes => zero variance => gain 1 => adascale == mean
        (the satellite's required equivalence)."""
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
        stacked = self.stacked([x] * 4)
        for per_layer in (True, False):
            out = make_combiner(CombineConfig(op="adascale",
                                              per_layer=per_layer))(stacked)
            np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(x),
                                       rtol=1e-5, atol=1e-6)

    def test_orthogonal_lanes_reach_full_gain(self):
        """Orthogonal equal-norm lanes => gain S => adascale == sum."""
        eye = np.eye(4, dtype=np.float32) * 3.0
        stacked = self.stacked([jnp.asarray(eye[i]) for i in range(4)])
        out = make_combiner(CombineConfig(op="adascale"))(stacked)
        np.testing.assert_allclose(np.asarray(out["w"]), eye.sum(0),
                                   rtol=1e-4, atol=1e-5)

    def test_gain_bounded_by_span(self):
        rng = np.random.default_rng(1)
        lanes = [jnp.asarray(rng.standard_normal(32), jnp.float32)
                 for _ in range(4)]
        stacked = self.stacked(lanes)
        out = make_combiner(CombineConfig(op="adascale"))(stacked)
        mean = np.mean([np.asarray(l) for l in lanes], axis=0)
        summ = np.sum([np.asarray(l) for l in lanes], axis=0)
        # combined = r * mean with r in [1, 4]: between mean and sum
        r = np.asarray(out["w"]) / np.where(np.abs(mean) < 1e-12, 1, mean)
        r = np.median(r)
        assert 1.0 - 1e-4 <= r <= 4.0 + 1e-4, r

    def test_selectable_via_engine_config(self):
        EngineConfig(combine="adascale").validate()
        from repro.configs.base import ModelConfig
        from repro.launch.mesh import make_local_mesh
        from repro.models import build_model
        mcfg = ModelConfig("tiny", "dense", 1, 32, 2, 1, 64, 97,
                           head_dim=16)
        sess = TrainSession.from_config(
            EngineConfig(combine="adascale", seq_len=16, global_batch=4,
                         optimizer="sgd"),
            model=build_model(mcfg, attn_chunk=16),
            mesh=make_local_mesh(1, 1), callbacks=[])
        m = sess.step(sess.batch(0))
        assert np.isfinite(m["loss"])


# ------------------------------------------------------------ pipelined fit

class TestPipelinedFit:
    def test_prefetch_bitwise_equals_synchronous_across_resume(
            self, tmp_path):
        """Acceptance: the prefetched stream (and hence the loss curve)
        is bitwise identical to the synchronous one across a
        save/restore/resume cycle."""
        from repro.configs.base import ModelConfig
        from repro.launch.mesh import make_local_mesh
        from repro.models import build_model

        from repro.engine import CheckpointCallback

        def run(prefetch, async_ckpt, root):
            mcfg = ModelConfig("tiny", "dense", 1, 32, 2, 1, 64, 97,
                               head_dim=16)
            cfg = EngineConfig(combine="adasum", seq_len=16,
                               global_batch=4, ckpt_dir=str(root),
                               ckpt_every=2, prefetch=prefetch,
                               async_checkpoint=async_ckpt)
            mk = lambda: TrainSession.from_config(
                cfg, model=build_model(mcfg, attn_chunk=16),
                mesh=make_local_mesh(1, 1),
                callbacks=[CheckpointCallback(2)])
            h = mk().fit(2)
            h += mk().fit(4)          # fresh session resumes from ckpt
            return [(e["step"], e["loss"]) for e in h]

        pipelined = run(True, True, tmp_path / "a")
        synchronous = run(False, False, tmp_path / "b")
        assert [s for s, _ in pipelined] == [0, 1, 2, 3]
        assert pipelined == synchronous      # bitwise: same floats

    def test_elastic_restart_halves_dp_and_resumes(self):
        """Acceptance: injected failure + flagged straggler => checkpoint
        -> mesh rebuild at halved DP degree -> resume from the manifest,
        loss continuing from the restored step with the same config."""
        run_in_subprocess(r"""
import numpy as np
from repro.engine import (Callback, EngineConfig, FailureInjectionCallback,
                          LoggingCallback, StragglerCallback, fit_elastic)
import tempfile
root = tempfile.mkdtemp()
cfg = EngineConfig(arch="hymba-1p5b", reduced=True, combine="adasum",
                   seq_len=32, global_batch=8, ckpt_dir=root + "/ck",
                   ckpt_every=100, log_every=1, elastic=True)

scb = StragglerCallback()
class FlagAt(Callback):
    # simulate the monitor flagging a persistent straggler at step 5
    def on_step_end(self, session, step, metrics, dt):
        if step == 5:
            scb.monitor.flagged = True

dps = []
class RecordDP(Callback):
    def on_fit_start(self, session, start):
        dps.append((start, session.runtime.dp_total, session.runtime.span))

cbs = [LoggingCallback(1), scb, FlagAt(), RecordDP(),
       FailureInjectionCallback([3])]
hist, session = fit_elastic(cfg, 7, callbacks=cbs)

# two restarts: node loss at step 3 (8 -> 4), straggler flag after
# step 5 (4 -> 2); each resumed from the checkpointed step
assert dps == [(0, 8, 8), (3, 4, 4), (6, 2, 2)], dps
assert [h["step"] for h in hist] == list(range(7)), hist
assert np.isfinite([h["loss"] for h in hist]).all()
assert session.runtime.dp_total == 2
# no hyperparameter change across restarts (paper §5.4)
assert session.config.lr == cfg.lr and session.config.combine == "adasum"
print("OK")
""", devices=8, timeout=900)


class TestPipelineConfig:
    def test_new_fields_roundtrip(self):
        cfg = EngineConfig(prefetch=True, async_checkpoint=False,
                           elastic=True, ckpt_dir="/tmp/x",
                           prefetch_depth=4, device_stage=True)
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg
        cfg.validate()
        off = EngineConfig(prefetch=False, async_checkpoint=False)
        assert EngineConfig.from_dict(off.to_dict()) == off
        off.validate()

    def test_prefetch_depth_validation_and_cli(self):
        with pytest.raises(ValueError, match="prefetch_depth"):
            EngineConfig(prefetch_depth=0).validate()
        # staging/depth knobs configure the prefetch stage: with
        # prefetch off they'd be silently ignored — reject instead
        with pytest.raises(ValueError, match="prefetch"):
            EngineConfig(prefetch=False, device_stage=True).validate()
        with pytest.raises(ValueError, match="prefetch"):
            EngineConfig(prefetch=False, prefetch_depth=2).validate()
        cfg = EngineConfig.from_cli(
            ["--arch", "gemma-7b", "--prefetch-depth", "4",
             "--device-stage"])
        assert cfg.prefetch_depth == 4 and cfg.device_stage
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg

    def test_deep_prefetch_speculates_ahead(self):
        """depth=3 keeps up to three batches in flight; the stream stays
        bitwise identical to the synchronous source."""
        src = small_source()
        with Prefetcher(src, depth=3) as pf:
            for step in range(5):
                got = pf.get(step)
                np.testing.assert_array_equal(
                    np.asarray(got["tokens"]), src.batch(step)["tokens"])
            assert pf.hits >= 3

    def test_device_stage_batches_land_on_device_presharded(self):
        """make_device_stage puts batches on the mesh from the prefetch
        thread — leaves arrive as committed jax arrays, same values."""
        from repro.engine.pipeline import make_device_stage
        from repro.launch.mesh import make_local_mesh
        mesh = make_local_mesh(1, 1)
        src = small_source()
        stage = make_device_stage(mesh, ("data",))
        with Prefetcher(src, depth=2, stage=stage) as pf:
            got = pf.get(0)
            want = src.batch(0)
            for k in want:
                assert isinstance(got[k], jax.Array)
                assert got[k].committed
                np.testing.assert_array_equal(np.asarray(got[k]), want[k])

    def test_fit_with_depth_and_staging_matches_default(self):
        """End to end: deeper prefetch + device staging must not change
        the loss curve (pure-(seed, step) batches, same math)."""
        import jax.numpy as jnp
        from repro.configs.base import ModelConfig
        from repro.launch.mesh import make_local_mesh
        from repro.models import build_model

        mcfg = ModelConfig("pf-tiny", "dense", 2, 64, 4, 2, 128, 257,
                           head_dim=16)

        def losses(**kw):
            cfg = EngineConfig(combine="sum", optimizer="momentum",
                               lr=0.1, seq_len=16, global_batch=4,
                               steps=4, log_every=10 ** 9, **kw)
            sess = TrainSession.from_config(
                cfg, model=build_model(mcfg, attn_chunk=16,
                                       param_dtype=jnp.dtype("float32")),
                mesh=make_local_mesh(1, 1))
            hist = sess.fit()
            sess.close()
            return [h["loss"] for h in hist]

        base = losses()
        deep = losses(prefetch_depth=4, device_stage=True)
        np.testing.assert_allclose(base, deep, rtol=0, atol=0)

    def test_elastic_requires_ckpt_dir(self):
        with pytest.raises(ValueError, match="elastic"):
            EngineConfig(elastic=True).validate()
        from repro.engine import fit_elastic
        with pytest.raises(ValueError, match="ckpt_dir"):
            fit_elastic(EngineConfig(arch="gemma-7b"))

    def test_cli_flags(self):
        cfg = EngineConfig.from_cli(
            ["--arch", "gemma-7b", "--no-prefetch", "--sync-checkpoint",
             "--elastic", "--ckpt-dir", "/tmp/x"])
        assert not cfg.prefetch and not cfg.async_checkpoint
        assert cfg.elastic and cfg.ckpt_dir == "/tmp/x"
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg
        # defaults: pipelined on, elastic off
        dflt = EngineConfig.from_cli(["--arch", "gemma-7b"])
        assert dflt.prefetch and dflt.async_checkpoint and not dflt.elastic


def test_plan_shrink_powers_of_two():
    assert plan_shrink(8).new_dp == 4
    assert plan_shrink(6).new_dp == 4
    assert plan_shrink(2).new_dp == 1
    assert not plan_shrink(1).shrunk


def test_failure_injector_raises_typed_node_loss():
    """The elastic driver catches exactly NodeLossError — generic
    RuntimeErrors (even ones mentioning 'failure') must propagate."""
    from repro.runtime import FailureInjector, NodeLossError
    inj = FailureInjector([2])
    inj.check(1)
    with pytest.raises(NodeLossError, match="injected node failure"):
        inj.check(2)
    inj.check(2)            # fires exactly once
    assert issubclass(NodeLossError, RuntimeError)   # legacy callers
