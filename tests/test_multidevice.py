"""Multi-device integration tests (8 simulated devices via subprocess —
the main pytest process keeps 1 device per the brief)."""
import pytest

from conftest import run_in_subprocess


class TestRVHDistributed:
    def test_rvh_matches_reference_mixed_tp(self):
        run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import adasum, rvh
np.random.seed(0)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4,2), ("data","model"))
lanes = 4
tree = {"wq": np.random.randn(lanes, 8, 16).astype(np.float32),
        "wo": np.random.randn(lanes, 16, 8).astype(np.float32),
        "norm": np.random.randn(lanes, 8).astype(np.float32)}
specs = {"wq": P(None, "model"), "wo": P("model", None), "norm": P()}
sharded = {k: jax.device_put(v, NamedSharding(mesh, P(("data",), *(specs[k] or ()))))
           for k, v in tree.items()}
ref = adasum.adasum_tree_reduce(
    [{k: jnp.asarray(v[i]) for k, v in tree.items()} for i in range(lanes)])
for pallas in (False, True):
    out = jax.jit(lambda t: rvh.adasum_rvh_pytree(
        t, mesh, ("data",), leaf_specs=specs, use_pallas=pallas))(sharded)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=2e-5, atol=2e-5)
print("OK")
""")

    def test_rvh_multi_axis_pod_tree(self):
        run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import adasum, rvh
np.random.seed(1)
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((2,2,2), ("pod","data","model"))
tree = {"w": np.random.randn(4, 10).astype(np.float32)}
sharded = {"w": jax.device_put(tree["w"], NamedSharding(mesh, P(("pod","data"))))}
ref = adasum.adasum_tree_reduce([{"w": jnp.asarray(tree["w"][i])} for i in range(4)])
out = jax.jit(lambda t: rvh.adasum_rvh_pytree(t, mesh, ("data","pod")))(sharded)
np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(ref["w"]), rtol=2e-5)
print("OK")
""")


class TestTrainingModes:
    def test_all_combine_modes_converge(self):
        run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_reduced
from repro.models import build_model
from repro.engine import build_runtime
from repro.parallel.policy import RunPolicy
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4,2), ("data","model"))
cfg = get_reduced("qwen3-32b")
model = build_model(cfg, attn_chunk=16)
toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}
for desc, rpol in [
    ("rvh", RunPolicy(span=0, backend="rvh", optimizer="adam")),
    ("hier", RunPolicy(span=2, fsdp=True, scatter_grads=True,
                       backend="gspmd_tree", optimizer="adam")),
    ("sum", RunPolicy(span=0, optimizer="adam", combine_op="sum")),
    ("lamb", RunPolicy(span=0, backend="rvh", optimizer="lamb")),
    ("momentum", RunPolicy(span=0, backend="rvh", optimizer="momentum")),
    ("local2", RunPolicy(span=0, backend="rvh", optimizer="adam",
                         local_steps=2)),
]:
    rt = build_runtime(model, mesh, rpol, lr=3e-3)
    state = rt.init_state(jax.random.key(0))
    step = jax.jit(rt.train_step, donate_argnums=(0,))
    first = last = None
    for i in range(6):
        state, m = step(state, batch)
        l = float(m["loss"])
        first = first if first is not None else l
        last = l
    assert np.isfinite(last) and last < first, (desc, first, last)
print("OK")
""", timeout=1200)

    def test_adasum_spmd_matches_single_process_reference(self):
        """The distributed train step's combined gradient must equal the
        single-device reference tree reduce of per-lane grads."""
        run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_reduced
from repro.models import build_model
from repro.core.adasum import adasum_tree_reduce
from repro.engine import build_runtime
from repro.parallel.policy import RunPolicy
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4,1), ("data","model"))
cfg = get_reduced("minitron-4b")
model = build_model(cfg, attn_chunk=16)
rpol = RunPolicy(span=0, backend="rvh", optimizer="sgd")
rt = build_runtime(model, mesh, rpol, lr=1.0)   # sgd pre: delta = -combined
state = rt.init_state(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (4, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}
params0 = jax.device_get(state["params"])
state2, _ = jax.jit(rt.train_step)(state, batch)
delta = jax.tree.map(lambda a, b: np.asarray(b, np.float32)
                     - np.asarray(a, np.float32),
                     params0, jax.device_get(state2["params"]))
# reference: per-lane grads + tree adasum on one device
grad = jax.grad(lambda p, b: model.loss(p, b)[0])
lanes = [{k: v[i:i+1] for k, v in batch.items()} for i in range(4)]
gs = [grad(state["params"] if False else params0, lb) for lb in lanes]
ref = adasum_tree_reduce([jax.tree.map(jnp.asarray, g) for g in gs])
for (pa, dv), (pb, rv) in zip(jax.tree_util.tree_flatten_with_path(delta)[0],
                              jax.tree_util.tree_flatten_with_path(ref)[0]):
    # atol covers CPU reduction-order noise on the jax 0.4.x host backend;
    # the embedding table needs more headroom: its scatter-add gradient
    # accumulates in a different order under the distributed vmap than on
    # one device (~1.6e-2 on 0.8% of elements, identical pre/post engine
    # refactor — verified against the seed step builder)
    atol = 2e-2 if "embed" in str(pa) else 2e-3
    np.testing.assert_allclose(dv, -np.asarray(rv, np.float32),
                               rtol=5e-3, atol=atol)
print("OK")
""", timeout=900)


class TestDryRunSmall:
    def test_production_mesh_builds_512(self):
        run_in_subprocess(r"""
from repro.launch.mesh import make_production_mesh
m1 = make_production_mesh()
assert m1.devices.shape == (16, 16)
m2 = make_production_mesh(multi_pod=True)
assert m2.devices.shape == (2, 16, 16)
print("OK")
""", devices=512)

    def test_dryrun_cell_api(self):
        run_in_subprocess(r"""
from repro.launch.dryrun import lower_cell
from repro.launch.mesh import make_production_mesh
mesh = make_production_mesh()
lowered, info = lower_cell("seamless-m4t-large-v2", "train_4k", mesh)
assert info["status"] == "OK"
compiled = lowered.compile()
assert compiled.memory_analysis() is not None
lowered2, info2 = lower_cell("gemma-7b", "long_500k", mesh)
assert info2["status"] == "SKIP"
print("OK")
""", devices=512, timeout=900)
