"""Chaos-injection + end-to-end resilience (repro.chaos and friends).

The contract under test, per fault class:

  * checkpoint corruption (bit-flip / torn write / missing leaf) ->
    restore validates per-leaf crc32s, quarantines the bad step
    (`*.bad`) and falls back to last-good — one clear ValueError when
    nothing valid remains, never a raw KeyError/FileNotFoundError;
  * capacity loss -> `fit_elastic` shrinks DP; capacity return
    (`GrowBackSignal`) re-expands through the SAME save -> rebuild ->
    resume machinery with the LR rescaled by the AdaScale gain, the
    pure-(seed, step) stream staying contiguous across both directions;
  * noise collapse -> the BatchController's shrink band halves
    batch/span through the planned-resize machinery (growth's inverse);
  * SIGTERM -> train exits 143 with a consistent last-good checkpoint
    (including mid-elastic-rebuild); serve drains: in-flight requests
    finish, queued ones end terminally;
  * serve pressure -> deadlines kill overdue requests, retry budgets
    bound preemption churn, the PressureLadder sheds speculation /
    admissions / slots in order — and every submitted request is
    ALWAYS terminal, with zero leaked KV pages after drain.
"""
import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.chaos import (ChaosSchedule, FaultEvent, bitflip_leaf,
                         drop_leaf, drop_manifest, tear_leaf)
from repro.checkpoint import CheckpointIntegrityError, CheckpointManager
from repro.control.controller import BatchController, ControllerConfig
from repro.runtime import plan_grow_back, plan_shrink_batch
from repro.engine.serving.scheduler import PressureLadder


def _state(seed=0):
    rng = np.random.RandomState(seed)
    return {"step": np.int64(seed),
            "params": {"w": rng.randn(4, 3).astype(np.float32),
                       "b": rng.randn(3).astype(np.float32)}}


# ===================================================== checkpoint integrity
class TestCheckpointIntegrity:
    def _mgr(self, tmp_path, steps=(1, 2)):
        mgr = CheckpointManager(tmp_path / "ck", keep=5)
        for s in steps:
            mgr.save(s, _state(s))
        return mgr

    def test_bitflip_quarantines_and_falls_back(self, tmp_path, capsys):
        mgr = self._mgr(tmp_path)
        assert bitflip_leaf(mgr.root) == 2
        out = mgr.restore(_state())          # step=None: newest-first walk
        assert int(out["step"]) == 1         # fell back to last-good
        assert mgr.restore_fallbacks == 1
        assert [q["step"] for q in mgr.quarantined] == [2]
        assert (mgr.root / "step_00000002.bad").exists()
        assert mgr.latest_step() == 1        # .bad invisible to listing
        assert "checksum mismatch" in str(mgr.quarantined[0]["problems"])
        assert "quarantined step 2" in capsys.readouterr().out

    def test_torn_write_falls_back(self, tmp_path):
        mgr = self._mgr(tmp_path)
        assert tear_leaf(mgr.root) == 2
        assert int(mgr.restore(_state())["step"]) == 1
        assert "unreadable leaf" in str(mgr.quarantined[0]["problems"])

    def test_missing_leaf_falls_back(self, tmp_path):
        mgr = self._mgr(tmp_path)
        assert drop_leaf(mgr.root) == 2
        assert int(mgr.restore(_state())["step"]) == 1
        assert "missing leaf" in str(mgr.quarantined[0]["problems"])

    def test_drop_manifest_step_invisible(self, tmp_path):
        mgr = self._mgr(tmp_path)
        assert drop_manifest(mgr.root) == 2
        # no manifest => the dir no longer matches all_steps at all:
        # silent fallback, not quarantine
        assert mgr.latest_step() == 1
        assert int(mgr.restore(_state())["step"]) == 1
        assert mgr.restore_fallbacks == 0 and not mgr.quarantined

    def test_explicit_bad_step_raises_naming_step(self, tmp_path):
        mgr = self._mgr(tmp_path)
        bitflip_leaf(mgr.root)
        with pytest.raises(CheckpointIntegrityError,
                           match="step 2 failed integrity"):
            mgr.restore(_state(), step=2)
        # the explicit restore still quarantined it
        assert (mgr.root / "step_00000002.bad").exists()

    def test_all_corrupt_is_one_clear_valueerror(self, tmp_path):
        mgr = self._mgr(tmp_path)
        for d in mgr.root.glob("step_*"):    # tear a leaf in EVERY step
            f = sorted(d.glob("leaf-*.npy"))[0]
            f.write_bytes(f.read_bytes()[:8])
        with pytest.raises(ValueError, match="no valid checkpoints"):
            mgr.restore(_state())
        assert len(mgr.quarantined) == 2

    def test_empty_dir_is_valueerror(self, tmp_path):
        mgr = CheckpointManager(tmp_path / "ck")
        with pytest.raises(ValueError, match="no checkpoints under"):
            mgr.restore(_state())

    def test_restore_params_missing_leaves_named(self, tmp_path):
        """Structural mismatch must be ONE ValueError naming the step
        and the missing leaves — never a raw KeyError."""
        mgr = self._mgr(tmp_path, steps=(3,))
        template = {"w": np.zeros((4, 3), np.float32),
                    "b": np.zeros(3, np.float32),
                    "extra": np.zeros(2, np.float32),
                    "more": np.zeros(2, np.float32)}
        with pytest.raises(ValueError) as ei:
            mgr.restore_params(template)
        msg = str(ei.value)
        assert "step 3" in msg and "2 params" in msg
        assert "['extra']" in msg and "['more']" in msg
        assert not isinstance(ei.value, KeyError)

    def test_validate_step_lists_every_problem(self, tmp_path):
        mgr = self._mgr(tmp_path, steps=(1,))
        assert mgr.validate_step(1) == []
        tear_leaf(mgr.root, index=0)
        drop_leaf(mgr.root, index=1)
        probs = mgr.validate_step(1)
        assert len(probs) == 2
        assert any("unreadable" in p for p in probs)
        assert any("missing leaf" in p for p in probs)

    def test_legacy_manifest_without_crc_tolerated(self, tmp_path):
        import json
        mgr = self._mgr(tmp_path, steps=(1,))
        mf = mgr.root / "step_00000001" / "manifest.json"
        meta = json.loads(mf.read_text())
        for leaf in meta["leaves"]:
            leaf.pop("crc32", None)
        mf.write_text(json.dumps(meta))
        assert mgr.validate_step(1) == []    # pre-integrity ckpt loads
        assert int(mgr.restore(_state())["step"]) == 1


# ========================================================== chaos schedule
class TestChaosSchedule:
    def test_seeded_generation_is_deterministic(self):
        a = ChaosSchedule.generate(11, 200, rate=0.2)
        b = ChaosSchedule.generate(11, 200, rate=0.2)
        assert a.pending() == b.pending() and len(a) > 5
        c = ChaosSchedule.generate(12, 200, rate=0.2)
        assert a.pending() != c.pending()

    def test_at_take_consume_events(self):
        s = ChaosSchedule([FaultEvent(3, "node_loss"),
                           FaultEvent(3, "comm_spike", 0.01),
                           FaultEvent(5, "ckpt_bitflip")])
        assert [e.kind for e in s.at(3, kinds=("node_loss",))] \
            == ["node_loss"]
        assert len(s) == 2                   # popped, not copied
        e = s.take_one(("ckpt_bitflip", "ckpt_torn"))
        assert e.kind == "ckpt_bitflip" and len(s) == 1
        assert s.take_one(("ckpt_torn",)) is None
        assert [e.kind for e in s.take(("comm_spike",))] == ["comm_spike"]
        assert not s.pending() and len(s.applied) == 3

    def test_unknown_kind_rejected(self):
        with pytest.raises(ValueError, match="unknown fault kind"):
            ChaosSchedule([FaultEvent(1, "meteor")])
        with pytest.raises(ValueError, match="unknown fault kind"):
            ChaosSchedule.generate(0, 10, kinds=("meteor",))


# =========================================================== elastic plans
class TestElasticPlans:
    def test_grow_back_to_power_of_two(self):
        p = plan_grow_back(2, 8, 0.1, lr_scale=1.5)
        assert (p.old_dp, p.new_dp) == (2, 8) and p.grew
        assert p.new_lr == pytest.approx(0.15)
        assert plan_grow_back(2, 7, 0.1).new_dp == 4   # largest pow2 <= 7

    def test_grow_back_noop_at_or_below_current(self):
        for target in (8, 4, 0):
            p = plan_grow_back(8, target, 0.1)
            assert not p.grew and p.new_dp == 8 and p.new_lr == 0.1

    def test_shrink_batch_halves_batch_and_span(self):
        p = plan_shrink_batch(16, 4, 8, 0.2, lr_scale=0.5)
        assert (p.new_batch, p.new_span) == (8, 2) and p.shrank
        assert p.new_lr == pytest.approx(0.1)
        assert plan_shrink_batch(16, 4, 8, 0.2,
                                 shrink_span=False).new_span == 4

    def test_shrink_batch_floors(self):
        p = plan_shrink_batch(8, 2, 8, 0.2, min_global_batch=8)
        assert not p.changed and p.reason == "floored"
        p = plan_shrink_batch(2, 2, 8, 0.2)   # new batch 1 < span 1? no:
        assert p.changed and (p.new_batch, p.new_span) == (1, 1)
        p = plan_shrink_batch(1, 1, 8, 0.2)   # nothing below 1
        assert not p.changed


# ======================================================== controller shrink
class TestControllerShrink:
    # ema=0.0: the EMA tracks the raw value, so scripted noise
    # sequences drive the bands deterministically
    CFG = ControllerConfig(grow_threshold=2.0, shrink_threshold=0.25,
                           patience=2, cooldown=0, warmup=1, ema=0.0,
                           lr_rescale="linear", min_global_batch=8)

    def _ctrl(self, cfg=None):
        return BatchController(cfg or self.CFG, global_batch=16, span=2,
                               dp_total=8, lr=0.2)

    def test_shrink_fires_below_band(self):
        c = self._ctrl()
        plans = [c.observe(s, {"noise_scale": 1.0}) for s in range(4)]
        plan = next(p for p in plans if p)
        assert plan.shrank and (plan.new_batch, plan.new_span) == (8, 1)
        assert plan.new_lr == pytest.approx(0.1)     # linear: lr / factor
        assert "ema_noise" in plan.reason and "<" in plan.reason

    def test_reset_band_clears_shrink_patience(self):
        c = self._ctrl()
        assert c.observe(0, {"noise_scale": 1.0}) is None
        # above 2x the shrink band: patience resets, so two more
        # low-noise steps are needed before a plan fires
        assert c.observe(1, {"noise_scale": 30.0}) is None
        assert c.observe(2, {"noise_scale": 1.0}) is None
        plan = c.observe(3, {"noise_scale": 1.0})
        assert plan is not None and plan.shrank

    def test_floor_stops_shrinking_grow_reenables(self):
        c = self._ctrl()
        plan = next(p for p in (c.observe(s, {"noise_scale": 1.0})
                                for s in range(4)) if p)
        c.notify_resized(plan)               # now at batch 8 == floor
        for s in range(4, 10):
            assert c.observe(s, {"noise_scale": 1.0}) is None
        assert c._shrink_stopped
        # high noise grows again, which re-arms the shrink direction
        grow = next(p for p in (c.observe(s, {"noise_scale": 100.0})
                                for s in range(10, 16)) if p)
        assert grow.grew
        c.notify_resized(grow)
        assert not c._shrink_stopped

    def test_shrink_reenables_exhausted_growth(self):
        cfg = ControllerConfig(grow_threshold=2.0, shrink_threshold=0.25,
                               patience=1, cooldown=0, warmup=1, ema=0.0,
                               lr_rescale="none", max_global_batch=16)
        c = self._ctrl(cfg)                  # already at the 16 cap
        for s in range(3):
            assert c.observe(s, {"noise_scale": 100.0}) is None
        assert c._exhausted
        plan = next(p for p in (c.observe(s, {"noise_scale": 1.0})
                                for s in range(3, 8)) if p)
        assert plan.shrank
        c.notify_resized(plan)
        assert not c._exhausted              # headroom under the cap again

    def test_band_overlap_rejected(self):
        with pytest.raises(AssertionError):
            BatchController(
                ControllerConfig(grow_threshold=2.0, shrink_threshold=2.0),
                global_batch=8, span=1, dp_total=8, lr=0.1)

    def test_engine_config_validation_and_cli(self):
        from repro.engine import EngineConfig
        with pytest.raises(ValueError, match="shrink_threshold"):
            EngineConfig(shrink_threshold=-1.0).validate()
        with pytest.raises(ValueError, match="oscillates"):
            EngineConfig(grow_threshold=2.0,
                         shrink_threshold=2.5).validate()
        with pytest.raises(ValueError, match="min_global_batch"):
            EngineConfig(min_global_batch=-4).validate()
        cfg = EngineConfig.from_cli(
            ["--arch", "hymba-1p5b", "--shrink-threshold", "0.5",
             "--min-global-batch", "4", "--pressure-ladder"])
        assert cfg.shrink_threshold == 0.5
        assert cfg.min_global_batch == 4
        assert cfg.pressure_ladder is True
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg


# ========================================================== pressure ladder
class TestPressureLadder:
    def test_escalates_and_decays_with_hysteresis(self):
        lad = PressureLadder(enter=(0.25, 0.10, 0.02), exit_margin=1.5)
        up = lambda f, q=0: lad.update(free_frac=f, queue_len=q,
                                       max_slots=4)
        assert up(0.9) == 0 and lad.name == "normal"
        assert up(0.2) == 1 and lad.name == "no_spec"
        assert up(0.05) == 2 and lad.name == "no_admit"
        assert up(0.0) == 3 and lad.name == "preempt"
        # decay needs 1.5x the rung's entry margin, one rung at a time
        assert up(0.025) == 3                # 0.025 < 0.02*1.5
        assert up(0.05) == 2                 # >= 0.03: drop one rung
        assert up(0.05) == 2                 # < 0.10*1.5: held
        assert up(0.2) == 1
        assert up(0.9) == 0
        assert lad.changes == 6              # 3 up + 3 down

    def test_queue_pressure_alone_degrades(self):
        lad = PressureLadder(queue_factor=4)
        assert lad.update(free_frac=1.0, queue_len=3, max_slots=1) == 0
        assert lad.update(free_frac=1.0, queue_len=4, max_slots=1) == 1
        # hot queue also blocks decay from a deeper rung
        assert lad.update(free_frac=0.01, queue_len=4, max_slots=1) >= 2
        assert lad.update(free_frac=1.0, queue_len=0, max_slots=1) < 2


# ============================================== grow-back / shrink e2e (8dv)
class TestElasticRoundTrip:
    def test_shrink_then_grow_back_resumes_contiguous(self):
        """Acceptance: node loss shrinks 8 -> 4; CapacityReturnCallback
        grows back 4 -> 8 through the same machinery; the (seed, step)
        stream is consumed exactly once in order; LR ends rescaled by
        the logged AdaScale gain; run_metadata carries the counts."""
        run_in_subprocess(r"""
import numpy as np, tempfile
from repro.chaos import CapacityReturnCallback
from repro.engine import (EngineConfig, FailureInjectionCallback,
                          LoggingCallback, StragglerCallback, fit_elastic)

seen, dps = [], []
class Record:
    def on_fit_end(self, session, history): ...
    def on_step_end(self, session, step, metrics, dt): ...
    def on_fit_start(self, session, start):
        dps.append((start, session.runtime.dp_total))
    def on_step_start(self, session, step):
        seen.append(step)

with tempfile.TemporaryDirectory() as root:
    cfg = EngineConfig(arch="hymba-1p5b", reduced=True, combine="adasum",
                       seq_len=32, global_batch=8, lr=0.01,
                       ckpt_dir=root + "/ck", ckpt_every=100,
                       log_every=1, elastic=True, combine_stats=True)
    cbs = [LoggingCallback(1), StragglerCallback(), Record(),
           FailureInjectionCallback([2]), CapacityReturnCallback(delay=1)]
    hist, sess = fit_elastic(cfg, 6, callbacks=cbs)

    # 8 -> (loss at step 2) -> 4 -> (capacity back after step 2) -> 8
    assert dps == [(0, 8), (2, 4), (3, 8)], dps
    # stream contiguity: step 2 is recorded, aborted by the injected
    # loss before executing, then replayed once after the rebuild —
    # every step EXECUTES exactly once, in order
    assert seen == [0, 1, 2, 2, 3, 4, 5], seen
    assert [h["step"] for h in hist] == list(range(6))
    assert np.isfinite([h["loss"] for h in hist]).all()
    log = sess.elastic_log
    assert log["restarts"] == 1 and log["grow_backs"] == 1
    kinds = [p["kind"] for p in log["plans"]]
    assert kinds == ["shrink", "grow_back"], kinds
    gb = log["plans"][-1]
    assert (gb["old_dp"], gb["new_dp"]) == (4, 8)
    # LR restarted at exactly the planned gain-rescaled value
    assert sess.config.lr == gb["new_lr"]
    assert 1.0 <= gb["gain"] <= 2.0 + 1e-6, gb
    md = sess.run_metadata()["resilience"]
    assert md["restarts"] == 1 and md["grow_backs"] == 1
    assert md["restore_fallbacks"] == 0 and md["quarantined_steps"] == []
    sess.close()
print("OK")
""", devices=8, timeout=900)

    def test_corrupt_boundary_checkpoint_restores_last_good(self):
        """on_restart corrupts the just-written boundary checkpoint;
        the rebuild must quarantine it, fall back to the previous save,
        and REPLAY the lost steps — same final step set, fallback
        counted in run_metadata."""
        run_in_subprocess(r"""
import numpy as np, tempfile
from repro.chaos import ChaosSchedule, FaultEvent, make_chaos_on_restart
from repro.engine import (CheckpointCallback, EngineConfig,
                          FailureInjectionCallback, LoggingCallback,
                          StragglerCallback, fit_elastic)

seen = []
class Record:
    def on_fit_start(self, session, start): ...
    def on_fit_end(self, session, history): ...
    def on_step_end(self, session, step, metrics, dt): ...
    def on_step_start(self, session, step):
        seen.append(step)

with tempfile.TemporaryDirectory() as root:
    ck = root + "/ck"
    cfg = EngineConfig(arch="hymba-1p5b", reduced=True, combine="adasum",
                       seq_len=32, global_batch=8, ckpt_dir=ck,
                       ckpt_every=2, log_every=1, elastic=True)
    sched = ChaosSchedule([FaultEvent(0, "ckpt_bitflip")])
    cbs = [LoggingCallback(1), StragglerCallback(), Record(),
           CheckpointCallback(2), FailureInjectionCallback([3])]
    hist, sess = fit_elastic(cfg, 5, callbacks=cbs,
                             on_restart=make_chaos_on_restart(sched, ck))

    # boundary save at step 3 was bit-flipped: restore quarantined it
    # and resumed from the periodic step-2 save, replaying step 2
    assert seen == [0, 1, 2, 3, 2, 3, 4], seen
    # step 3's first attempt aborted at step START, so it has no
    # history row; the replayed 2 does (recorded both times it ran)
    assert [h["step"] for h in hist] == [0, 1, 2, 2, 3, 4], hist
    res = sess.run_metadata()["resilience"]
    assert res["restore_fallbacks"] == 1, res
    assert res["quarantined_steps"] == [3], res
    assert not sched.pending()
    sess.close()
print("OK")
""", devices=8, timeout=900)

    def test_sigterm_during_elastic_rebuild_window(self):
        """SIGTERM landing between the shrink and the first resumed step
        must exit 143 with the boundary checkpoint intact + valid."""
        run_in_subprocess(r"""
import os, signal, subprocess, sys, tempfile
root = tempfile.mkdtemp()
code = '''
import os, signal
from repro.engine import (Callback, EngineConfig, FailureInjectionCallback,
                          LoggingCallback, StragglerCallback, fit_elastic)

class TermInWindow(Callback):
    # first step of the REBUILT (dp=4) session: the rebuild window
    def on_step_start(self, session, step):
        if session.runtime.dp_total < 8 and step == 2:
            os.kill(os.getpid(), signal.SIGTERM)

cfg = EngineConfig(arch="hymba-1p5b", reduced=True, combine="adasum",
                   seq_len=32, global_batch=8, ckpt_dir=%r,
                   ckpt_every=100, log_every=1, elastic=True,
                   async_checkpoint=True)
cbs = [LoggingCallback(1), StragglerCallback(), TermInWindow(),
       FailureInjectionCallback([2])]
fit_elastic(cfg, 6, callbacks=cbs)
''' % (root + "/ck")
env = dict(os.environ)
env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
res = subprocess.run([sys.executable, "-c", code], env=env,
                     capture_output=True, text=True, timeout=600)
assert res.returncode == 143, (res.returncode, res.stdout, res.stderr)

# the checkpoint left behind is consistent and restorable
from repro.checkpoint import CheckpointManager
mgr = CheckpointManager(root + "/ck")
latest = mgr.latest_step()
assert latest is not None and mgr.validate_step(latest) == [], latest
print("OK")
""", devices=1, timeout=900)

    def test_grow_then_shrink_contiguity_through_resize_machinery(self):
        """Regression (satellite): a scripted grow at step 3 then shrink
        at step 7 both execute through the planned-resize machinery with
        the stream contiguous and batch rows tracking the plans."""
        run_in_subprocess(r"""
import numpy as np, tempfile
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat
from repro.control import fit_adaptive
from repro.control.controller import BatchController, ControllerConfig
from repro.runtime.elastic import plan_grow, plan_shrink_batch

mcfg = ModelConfig("ctl-tiny", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))

class Scripted(BatchController):
    # deterministic plans at fixed steps: the machinery is under test,
    # not the noise statistics
    def observe(self, step, metrics):
        if step == 3 and self.global_batch == 8:
            return plan_grow(self.global_batch, self.span, self.dp_total,
                             self.lr, lr_scale=2.0)
        if step == 7 and self.global_batch == 16:
            return plan_shrink_batch(self.global_batch, self.span,
                                     self.dp_total, self.lr, lr_scale=0.5)
        return None

seen = []
class Record:
    def on_fit_start(self, session, start): ...
    def on_fit_end(self, session, history): ...
    def on_step_end(self, session, step, metrics, dt): ...
    def on_step_start(self, session, step):
        seen.append((step, session.config.global_batch))

with tempfile.TemporaryDirectory() as ckpt:
    cfg = EngineConfig(combine="adasum", span=2, backend="gspmd_tree",
                       optimizer="momentum", lr=0.02, seq_len=32,
                       global_batch=8, data_seed=11, steps=10,
                       ckpt_dir=ckpt, ckpt_every=0, adaptive_batch=True)
    ctrl = Scripted(ControllerConfig(), global_batch=8, span=2,
                    dp_total=8, lr=0.02)
    hist, sess = fit_adaptive(cfg, 10, callbacks=[Record()],
                              controller=ctrl, model=model, mesh=mesh)
    # contiguous: each step once, in order, across grow AND shrink
    assert [s for s, _ in seen] == list(range(10)), seen
    assert [h["step"] for h in hist] == list(range(10))
    batches = dict(seen)
    assert batches[3] == 8 and batches[4] == 16    # grew at boundary 4
    assert batches[7] == 16 and batches[8] == 8    # shrank at boundary 8
    assert sess.config.global_batch == 8
    assert sess.config.lr == 0.02                  # 2.0 then 0.5: back
    kinds = [("grow" if p["new_batch"] > p["old_batch"] else "shrink")
             for p in sess.resize_log]
    assert kinds == ["grow", "shrink"], sess.resize_log
    assert np.isfinite([h["loss"] for h in hist]).all()
    sess.close()
print("OK")
""", devices=8, timeout=900)


# ================================================== serve-side resilience
class TestServeResilience:
    """In-process: tiny model, 1 host device is enough."""

    def _engine(self, **cfg_kw):
        import jax
        import jax.numpy as jnp
        from repro.configs.base import ModelConfig
        from repro.engine import EngineConfig, ServeEngine
        from repro.models import build_model
        mcfg = ModelConfig("chaos-tiny", "dense", 2, 64, 4, 2, 128, 257,
                           head_dim=16)
        model = build_model(mcfg, compute_dtype=jnp.float32, attn_chunk=16)
        params = model.init(jax.random.key(0))
        cfg_kw.setdefault("max_slots", 2)
        cfg_kw.setdefault("max_len", 48)
        cfg_kw.setdefault("kv_layout", "paged")
        return ServeEngine(EngineConfig(**cfg_kw), model, None, params)

    def _req(self, n=8, gen=8, **kw):
        from repro.engine import GenerationRequest
        rng = np.random.RandomState(3)
        return GenerationRequest(prompt=rng.randint(0, 257, n),
                                 max_new_tokens=gen, **kw)

    def test_deadline_kills_are_terminal(self):
        from repro.chaos import slow_prefill
        eng = self._engine()
        undo = slow_prefill(eng, 0.05)
        h = eng.submit(self._req(deadline_s=1e-6))
        eng.drain()
        undo()
        assert h.done and h.failed and h.finish_reason == "deadline"
        tp = eng.throughput()
        assert tp["deadline_kills"] == 1 and tp["failed"] == 1
        assert tp["completed"] == 0
        assert eng.leaked_pages() == 0

    def test_no_deadline_requests_unaffected(self):
        eng = self._engine()
        h = eng.submit(self._req())
        eng.drain()
        assert h.done and not h.failed and h.finish_reason == "length"
        assert len(h.tokens) == 8

    def test_retry_budget_bounds_preemption(self):
        """max_retries=0: the first pool-pressure preemption fails the
        request terminally instead of thrashing."""
        eng = self._engine(max_slots=2, max_len=48, page_size=8,
                           kv_pages=7)       # too few pages for 2 slots
        a = eng.submit(self._req(16, 24))
        eng.step()
        b = eng.submit(self._req(16, 24, max_retries=0))
        eng.drain()
        assert a.done and not a.failed       # oldest ran to completion
        assert b.done
        tp = eng.throughput()
        assert tp["preemptions"] >= 1
        if b.failed:                         # b was the preemption victim
            assert b.finish_reason == "retries"
            assert tp["failed"] >= 1
        assert eng.leaked_pages() == 0

    def test_drain_terminates_queued_requests(self):
        eng = self._engine(max_slots=1)
        a = eng.submit(self._req(8, 4))
        eng.step()                           # a admitted into the slot
        b = eng.submit(self._req(8, 4))      # b stuck in the queue
        eng.request_drain()
        assert eng.draining
        eng.drain()
        assert a.done and not a.failed       # in-flight finished
        assert b.done and b.failed and b.finish_reason == "drained"
        tp = eng.throughput()
        assert tp["drained"] == 1 and tp["failed"] == 1
        assert eng.leaked_pages() == 0
        eng.flush_prefix()
        assert eng._pool.pages_used == 0     # zero-leak after full flush

    def test_sigterm_handler_drains(self):
        import os, signal
        eng = self._engine(max_slots=1)
        eng.install_drain_handler()
        a = eng.submit(self._req(8, 4))
        eng.step()
        b = eng.submit(self._req(8, 4))
        os.kill(os.getpid(), signal.SIGTERM)  # handled: drain, no exit
        eng.drain()
        signal.signal(signal.SIGTERM, signal.SIG_DFL)
        assert a.done and not a.failed
        assert b.failed and b.finish_reason == "drained"

    def test_pressure_ladder_sheds_speculation_first(self):
        """Ladder level >= 1 must gate _can_speculate; level history is
        surfaced in throughput()."""
        eng = self._engine(max_slots=2, max_len=48, page_size=8,
                           kv_pages=9, pressure_ladder=True)
        a = eng.submit(self._req(16, 20))
        b = eng.submit(self._req(16, 20))
        eng.drain()
        tp = eng.throughput()
        assert "degradation_level" in tp and "degradation_changes" in tp
        assert tp["degradation_changes"] >= 1     # pressure was seen
        assert a.done and b.done
        assert eng.leaked_pages() == 0

    def test_ladder_off_by_default_keeps_behavior(self):
        eng = self._engine()
        tp_keys_engine = eng.throughput().keys()
        assert "degradation_level" not in tp_keys_engine
        assert eng._ladder is None

    def test_hot_reload_corrupt_step_falls_back(self, tmp_path):
        """A bit-flipped newest checkpoint must be quarantined by the
        reloader's poll, which falls back to the previous good step —
        serving never sees the corrupt weights."""
        import jax
        from repro.chaos import bitflip_leaf
        from repro.checkpoint import CheckpointManager
        eng = self._engine()
        mgr = CheckpointManager(tmp_path / "ck", keep=5)
        p1 = jax.tree.map(lambda x: np.asarray(x) * 1.01, eng.params)
        p2 = jax.tree.map(lambda x: np.asarray(x) * 1.02, eng.params)
        mgr.save(1, {"params": p1})
        mgr.save(2, {"params": p2})
        bitflip_leaf(mgr.root)               # newest (step 2) corrupted
        from repro.engine import HotReloader
        eng._reloader = HotReloader(mgr, eng.params)
        h = eng.submit(self._req(8, 4))
        eng.drain()
        assert h.done and not h.failed
        assert eng.loaded_step == 1          # fell back past step 2
        assert eng._reloader.fallbacks == 1
        assert eng.throughput()["restore_fallbacks"] == 1
        assert (mgr.root / "step_00000002.bad").exists()

    def test_request_validation(self):
        from repro.engine import GenerationRequest
        with pytest.raises(ValueError):
            GenerationRequest(prompt=np.arange(4), max_new_tokens=2,
                              deadline_s=0.0)
        with pytest.raises(ValueError):
            GenerationRequest(prompt=np.arange(4), max_new_tokens=2,
                              max_retries=-1)
