"""Unit tests for the Adasum combiner (paper §3)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adasum as A
from repro.core.orthogonality import per_layer_orthogonality


def rnd(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).standard_normal(shape),
                       jnp.float32)


class TestPairwise:
    def test_orthogonal_gradients_sum(self):
        g1 = jnp.array([1.0, 0.0, 0.0])
        g2 = jnp.array([0.0, 2.0, 0.0])
        out = A.adasum_pair(g1, g2)
        np.testing.assert_allclose(out, g1 + g2, rtol=1e-6)

    def test_parallel_equal_gradients_average(self):
        g = rnd((32,), 1)
        out = A.adasum_pair(g, g)
        np.testing.assert_allclose(np.asarray(out), np.asarray(g), rtol=1e-5)

    def test_parallel_scaled(self):
        """g and 3g parallel: Adasum = (1-3/2)g + (1-1/6)3g = 2g."""
        g = rnd((16,), 2)
        out = A.adasum_pair(g, 3 * g)
        np.testing.assert_allclose(np.asarray(out), np.asarray(2 * g),
                                   rtol=1e-4, atol=1e-5)

    def test_commutative(self):
        g1, g2 = rnd((64,), 3), rnd((64,), 4)
        np.testing.assert_allclose(np.asarray(A.adasum_pair(g1, g2)),
                                   np.asarray(A.adasum_pair(g2, g1)),
                                   rtol=1e-5)

    def test_zero_gradient_degrades_to_sum(self):
        g = rnd((16,), 5)
        out = A.adasum_pair(jnp.zeros_like(g), g)
        np.testing.assert_allclose(np.asarray(out), np.asarray(g), rtol=1e-6)

    def test_formula_matches_paper(self):
        g1, g2 = rnd((32,), 6), rnd((32,), 7)
        dot = float(jnp.vdot(g1, g2))
        n1, n2 = float(jnp.vdot(g1, g1)), float(jnp.vdot(g2, g2))
        want = (1 - dot / (2 * n1)) * g1 + (1 - dot / (2 * n2)) * g2
        np.testing.assert_allclose(np.asarray(A.adasum_pair(g1, g2)),
                                   np.asarray(want), rtol=1e-5)


class TestTreeReduce:
    def test_tree_matches_explicit_recursion(self):
        gs = [ {"a": rnd((8,), i), "b": rnd((4, 3), 10 + i)} for i in range(8)]
        got = A.adasum_tree_reduce(gs)
        # explicit: adjacent pairs, 3 levels
        l1 = [A.adasum_pair_pytree(gs[2*i], gs[2*i+1]) for i in range(4)]
        l2 = [A.adasum_pair_pytree(l1[0], l1[1]),
              A.adasum_pair_pytree(l1[2], l1[3])]
        want = A.adasum_pair_pytree(l2[0], l2[1])
        for k in ("a", "b"):
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]), rtol=1e-5)

    def test_stacked_input_equivalent(self):
        gs = [{"w": rnd((6,), i)} for i in range(4)]
        stacked = {"w": jnp.stack([g["w"] for g in gs])}
        a = A.adasum_tree_reduce(gs)
        b = A.adasum_tree_reduce(stacked)
        np.testing.assert_allclose(np.asarray(a["w"]), np.asarray(b["w"]),
                                   rtol=1e-6)

    def test_non_power_of_two_raises(self):
        with pytest.raises(AssertionError):
            A.adasum_tree_reduce([{"w": rnd((4,), i)} for i in range(3)])

    def test_linear_differs_from_tree_in_general(self):
        gs = [{"w": rnd((16,), i)} for i in range(4)]
        t = A.adasum_tree_reduce(gs)["w"]
        l = A.adasum_linear_reduce(gs)["w"]
        assert not np.allclose(np.asarray(t), np.asarray(l))

    def test_whole_model_vs_per_layer(self):
        gs = [{"a": rnd((8,), i), "b": rnd((8,), 100 + i)} for i in range(2)]
        pl = A.adasum_tree_reduce(gs, per_layer=True)
        wm = A.adasum_tree_reduce(gs, per_layer=False)
        assert not np.allclose(np.asarray(pl["a"]), np.asarray(wm["a"]))


class TestOrthogonality:
    def test_orthogonal_set_gives_one(self):
        gs = [{"w": jnp.eye(4)[i]} for i in range(4)]
        o = per_layer_orthogonality(gs)
        assert abs(float(o["__mean__"]) - 1.0) < 1e-5

    def test_parallel_set_gives_one_over_n(self):
        g = rnd((32,), 0)
        gs = [{"w": g} for _ in range(4)]
        o = per_layer_orthogonality(gs)
        assert abs(float(o["__mean__"]) - 0.25) < 1e-4
