"""End-to-end system behaviour tests: the train/serve drivers, failure
recovery, elastic restart, and the optimizer/combine semantics the paper
specifies (§4.1)."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess


def test_train_driver_end_to_end(tmp_path):
    run_in_subprocess(rf"""
from repro.launch.train import main
hist = main(["--arch", "minitron-4b", "--reduced", "--steps", "12",
             "--seq", "32", "--batch", "8", "--data-mesh", "2",
             "--model-mesh", "2", "--ckpt-dir", r"{tmp_path}/ck",
             "--ckpt-every", "5"])
assert hist[-1]["loss"] < hist[0]["loss"]
print("OK")
""", devices=4, timeout=900)


def test_failure_recovery_resume_exact(tmp_path):
    """Crash at step 9, restart, and the data pipeline + checkpoint must
    continue the run deterministically."""
    code = rf"""
from repro.launch.train import main
import sys
try:
    main(["--arch", "gemma-7b", "--reduced", "--steps", "14", "--seq", "32",
          "--batch", "8", "--data-mesh", "2", "--model-mesh", "1",
          "--ckpt-dir", r"{tmp_path}/ck2", "--ckpt-every", "4",
          "--fail-at", "9"])
except RuntimeError as e:
    assert "injected" in str(e)
    print("CRASHED-AS-PLANNED")
"""
    out = run_in_subprocess(code, devices=2, timeout=900)
    assert "CRASHED-AS-PLANNED" in out
    out2 = run_in_subprocess(rf"""
from repro.launch.train import main
hist = main(["--arch", "gemma-7b", "--reduced", "--steps", "14", "--seq",
             "32", "--batch", "8", "--data-mesh", "2", "--model-mesh", "1",
             "--ckpt-dir", r"{tmp_path}/ck2", "--ckpt-every", "4"])
assert hist[0]["step"] == 8, hist[0]
assert hist[-1]["step"] == 13
print("OK")
""", devices=2, timeout=900)
    assert "resumed from step 8" in out2


def test_elastic_restart_smaller_mesh(tmp_path):
    """Train on dp=4, checkpoint, resume on dp=2 (half the 'nodes') —
    elastic scaling. Adasum needs no retuning when the DP degree changes
    (paper §5.4)."""
    run_in_subprocess(rf"""
from repro.launch.train import main
main(["--arch", "minitron-4b", "--reduced", "--steps", "6", "--seq", "32",
      "--batch", "8", "--data-mesh", "4", "--model-mesh", "1",
      "--ckpt-dir", r"{tmp_path}/ck3", "--ckpt-every", "3"])
print("OK")
""", devices=4, timeout=900)
    out = run_in_subprocess(rf"""
from repro.launch.train import main
hist = main(["--arch", "minitron-4b", "--reduced", "--steps", "10",
             "--seq", "32", "--batch", "8", "--data-mesh", "2",
             "--model-mesh", "1", "--ckpt-dir", r"{tmp_path}/ck3",
             "--ckpt-every", "3"])
import numpy as np
assert np.isfinite([h["loss"] for h in hist]).all()
print("OK")
""", devices=2, timeout=900)
    assert "resumed" in out


def test_serve_driver():
    run_in_subprocess(r"""
from repro.launch.serve import main
handles = main(["--arch", "minicpm3-4b", "--reduced", "--requests", "2",
                "--prompt-len", "8", "--gen", "4", "--max-slots", "2"])
assert len(handles) == 2 and all(len(h.tokens) == 4 for h in handles)
print("OK")
""", devices=1, timeout=900)


def test_post_optimizer_semantics():
    """Paper §4.1/Fig. 3: with Adam, Adasum combines the post-optimizer
    delta, NOT raw gradients — per-lane optimizer states must diverge
    (each sees only its own gradient stream)."""
    run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import get_reduced
from repro.models import build_model
from repro.engine import build_runtime
from repro.parallel.policy import RunPolicy
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4,1), ("data","model"))
cfg = get_reduced("minitron-4b")
model = build_model(cfg, attn_chunk=16)
rt = build_runtime(model, mesh, RunPolicy(span=0, backend="gspmd_tree",
                                         optimizer="adam"), lr=1e-3)
assert rt.span == 4
state = rt.init_state(jax.random.key(0))
m_leaf = jax.tree.leaves(state["opt"]["inner"]["m"])[0]
assert m_leaf.shape[0] == 4, "per-lane optimizer state (Horovod semantics)"
toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size)
state, _ = jax.jit(rt.train_step)(state, {"tokens": toks, "labels": toks})
m = np.asarray(jax.tree.leaves(jax.device_get(state["opt"]["inner"]["m"]))[0],
               np.float32)
assert not np.allclose(m[0], m[1]), \
    "each lane's Adam state follows its own gradients"
print("OK")
""", devices=4, timeout=900)


def test_straggler_monitor_flags_outliers():
    from repro.runtime import StepMonitor, StragglerConfig
    mon = StepMonitor(StragglerConfig(min_steps=5, patience=2))
    for _ in range(20):
        mon.observe(0.10)
    mon.observe(2.0)
    assert not mon.flagged          # one outlier: not yet
    mon.observe(2.0)
    assert mon.flagged              # persistent straggler
