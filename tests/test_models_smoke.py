"""Per-architecture smoke tests: every assigned arch instantiates a
REDUCED same-family config and runs one forward/train step + one decode
step on CPU, asserting shapes and finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_reduced
from repro.models import build_model, count_params

B, T = 2, 32


def make_batch(cfg, key):
    toks = jax.random.randint(key, (B, T), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.is_encoder_decoder:
        batch["frontend_embeds"] = jnp.ones((B, T // 2, cfg.frontend_dim),
                                            jnp.float32) * 0.1
        batch["tokens"] = toks[:, :T // 2]
        batch["labels"] = toks[:, :T // 2]
    elif cfg.frontend != "none":
        ft = cfg.frontend_tokens or 4
        batch["frontend_embeds"] = jnp.ones((B, ft, cfg.frontend_dim),
                                            jnp.float32) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg, attn_chunk=16)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, jax.random.key(1))
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(model.loss, has_aux=True))(params, batch)
    assert np.isfinite(float(loss)), arch
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert np.isfinite(np.asarray(g)).all(), (arch, path)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_decode_step(arch):
    cfg = get_reduced(arch)
    model = build_model(cfg, attn_chunk=16)
    params = model.init(jax.random.key(0))
    if cfg.is_encoder_decoder:
        fe = jnp.ones((B, 8, cfg.frontend_dim), jnp.float32)
        cache = model.init_cache(params, B, 64, frontend_embeds=fe)
    else:
        cache = model.init_cache(params, B, 64)
    toks = jax.random.randint(jax.random.key(2), (B, 1), 0, cfg.vocab_size)
    logits, cache2 = jax.jit(model.decode_step)(params, toks, cache)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    # stepping twice advances positions
    logits3, _ = jax.jit(model.decode_step)(params, toks, cache2)
    assert np.isfinite(np.asarray(logits3)).all()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count_positive(arch):
    cfg = get_config(arch)
    n = count_params(cfg)
    assert n > 1e9, (arch, n)   # every assigned arch is >1B params
    if cfg.n_experts:
        assert count_params(cfg, active_only=True) < n
