"""Strong correctness property: one-token decode with caches/states must
reproduce the teacher-forced forward logits position by position. This
validates KV caches, rolling SWA buffers, MLA absorbed-latent decode, and
the chunked-scan <-> recurrent equivalence of the SSM/RWKV algebra."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, get_reduced
from repro.models import build_model

B, T = 2, 24


import dataclasses

CASES = {
    "gqa": get_reduced("qwen3-32b"),
    "swa": dataclasses.replace(get_reduced("mixtral-8x22b"),
                               capacity_factor=8.0),
    "mla": get_reduced("minicpm3-4b"),
    # no-drop capacity: capacity overflow drops are a train-time
    # approximation and would differ between full-seq and 1-token calls
    "moe": dataclasses.replace(get_reduced("moonshot-v1-16b-a3b"),
                               capacity_factor=8.0),
    "hybrid": get_reduced("hymba-1.5b"),
    "rwkv": get_reduced("rwkv6-7b"),
}


@pytest.mark.parametrize("name", sorted(CASES))
def test_decode_matches_forward(name):
    cfg = CASES[name]
    # fp32 compute to make the comparison tight; chunk < T exercises the
    # chunked paths.
    model = build_model(cfg, compute_dtype=jnp.float32, attn_chunk=8)
    params = model.init(jax.random.key(0))
    toks = jax.random.randint(jax.random.key(1), (B, T), 0, cfg.vocab_size)
    full = jax.jit(model.forward)(params, {"tokens": toks})

    cache = model.init_cache(params, B, T + 1, dtype=jnp.float32) \
        if False else model.init_cache(params, B, T + 1)
    step = jax.jit(model.decode_step)
    outs = []
    for t in range(T):
        logits, cache = step(params, toks[:, t:t + 1], cache)
        outs.append(logits[:, 0])
    dec = np.stack([np.asarray(o) for o in outs], axis=1)
    full = np.asarray(full)
    # bf16 caches => modest tolerance. MoE routers may flip a top-k
    # choice on a near-tie between the full-seq and 1-token computation
    # orders, which swings a single position's logits — allow isolated
    # flips (<=5% of positions) but require everything else tight.
    per_pos = np.abs(dec - full).reshape(-1, T, dec.shape[-1]).max(axis=(0, 2))
    bad = (per_pos > 0.1).sum()
    assert bad <= max(1, int(0.05 * T)), (name, per_pos.round(3))
    good = per_pos <= 0.1
    np.testing.assert_allclose(dec[:, good], full[:, good], rtol=0.05,
                               atol=0.05)


def test_swa_decode_beyond_window():
    """Rolling cache correctness past the window boundary."""
    cfg = dataclasses.replace(get_reduced("mixtral-8x22b"),
                              capacity_factor=8.0)
    assert cfg.sliding_window < T * 2
    model = build_model(cfg, compute_dtype=jnp.float32, attn_chunk=8)
    params = model.init(jax.random.key(0))
    T2 = cfg.sliding_window + 16
    toks = jax.random.randint(jax.random.key(1), (B, T2), 0, cfg.vocab_size)
    full = jax.jit(model.forward)(params, {"tokens": toks})
    cache = model.init_cache(params, B, T2 + 1)
    step = jax.jit(model.decode_step)
    for t in range(T2):
        logits, cache = step(params, toks[:, t:t + 1], cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full[:, -1]), rtol=0.05, atol=0.05)
