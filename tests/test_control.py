"""PR-8 `repro.control` subsystem: CombineStats surfacing, the
gradient-noise estimator + AdaScale gain at their analytic extremes,
the hysteresis batch controller, planned-resize machinery, and the
end-to-end adaptive driver (subprocess, 8 fake devices)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.control.controller import BatchController, ControllerConfig
from repro.control.noise import (STAT_KEYS, NoiseEMA, gain_for_factor,
                                 summarize_stats)
from repro.control.telemetry import config_hash, git_sha, run_fingerprint
from repro.core.combine import CombineConfig
from repro.engine import EngineConfig
from repro.engine.registry import make_combiner
from repro.runtime import plan_grow


def _ccfg(span, *, op="adasum", fused=False, per_layer=True):
    return CombineConfig(op=op, backend="gspmd_tree", span=span,
                         per_layer=per_layer, acc_dtype="float32",
                         fused=fused)


def _stacked(span, seed=0, dtype=jnp.float32):
    """Tiny two-leaf pytree with a leading lane axis."""
    k = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(k)
    return {"w": jax.random.normal(k1, (span, 6, 5), dtype),
            "b": jax.random.normal(k2, (span, 7), dtype)}


def _orthogonal(span, width=32):
    """Lanes with disjoint support and equal norm: exactly orthogonal."""
    x = np.zeros((span, span * width), np.float32)
    for i in range(span):
        x[i, i * width:(i + 1) * width] = np.linspace(0.5, 1.5, width)
    return {"w": jnp.asarray(x)}


def _identical(span, width=32):
    row = np.linspace(-1.0, 1.0, width, dtype=np.float32)
    return {"w": jnp.asarray(np.tile(row, (span, 1)))}


class TestGainEstimatorExtremes:
    """The two analytic endpoints of §3 / AdaScale: orthogonal lanes are
    pure noise (gain -> span, combined -> sum), identical lanes are pure
    signal (gain -> 1, combined -> mean)."""

    def test_summarize_orthogonal_gain_is_span(self):
        span = 4
        _, stats = make_combiner(_ccfg(span), with_stats=True)(
            _orthogonal(span))
        m = summarize_stats(stats, span, lane_rows=8)
        assert float(m["gain_ratio"]) == pytest.approx(span, rel=1e-5)
        assert abs(float(m["lane_cos"])) < 1e-5
        assert float(m["grad_mu2"]) == pytest.approx(0.0, abs=1e-6)
        assert float(m["noise_scale"]) > 1e6     # mu2 ~ 0: noise-dominated

    def test_summarize_identical_gain_is_one(self):
        span = 4
        _, stats = make_combiner(_ccfg(span), with_stats=True)(
            _identical(span))
        m = summarize_stats(stats, span, lane_rows=8)
        assert float(m["gain_ratio"]) == pytest.approx(1.0, abs=1e-5)
        assert float(m["lane_cos"]) == pytest.approx(1.0, rel=1e-5)
        assert float(m["grad_var"]) == pytest.approx(0.0, abs=1e-6)
        assert float(m["noise_scale"]) == pytest.approx(0.0, abs=1e-3)

    @pytest.mark.parametrize("per_layer", [True, False])
    def test_adascale_combiner_extremes(self, per_layer):
        span = 4
        comb = make_combiner(_ccfg(span, op="adascale",
                                   per_layer=per_layer))
        orth = _orthogonal(span)
        out = comb(orth)["w"]
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(orth["w"].sum(0)),
                                   rtol=1e-5)
        same = _identical(span)
        out = comb(same)["w"]
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(same["w"].mean(0)),
                                   rtol=1e-5)

    def test_adasum_combiner_extremes(self):
        span = 4
        comb = make_combiner(_ccfg(span))
        orth = _orthogonal(span)
        np.testing.assert_allclose(np.asarray(comb(orth)["w"]),
                                   np.asarray(orth["w"].sum(0)),
                                   rtol=1e-5)
        same = _identical(span)
        np.testing.assert_allclose(np.asarray(comb(same)["w"]),
                                   np.asarray(same["w"].mean(0)),
                                   rtol=1e-5)

    def test_gain_for_factor_limits(self):
        assert gain_for_factor(1.0, 0.0, 4.0) == pytest.approx(4.0)
        assert gain_for_factor(0.0, 1.0, 4.0) == pytest.approx(1.0)
        assert gain_for_factor(1.0, 1.0, 1.0) == 1.0     # factor <= 1
        g = gain_for_factor(1.0, 1.0, 2.0)
        assert 1.0 < g < 2.0


class TestCombineStats:
    @pytest.mark.parametrize("per_layer", [True, False])
    def test_fused_matches_reference_fp32(self, per_layer):
        span = 8
        stacked = _stacked(span)
        out_f, st_f = make_combiner(
            _ccfg(span, fused=True, per_layer=per_layer),
            with_stats=True)(stacked)
        out_r, st_r = make_combiner(
            _ccfg(span, fused=False, per_layer=per_layer),
            with_stats=True)(stacked)
        levels = int(np.log2(span))
        assert st_f["levels"].shape == (levels, 3)
        assert st_r["levels"].shape == (levels, 3)
        np.testing.assert_allclose(np.asarray(st_f["levels"]),
                                   np.asarray(st_r["levels"]),
                                   rtol=1e-5, atol=1e-6)
        for k in out_f:
            np.testing.assert_allclose(np.asarray(out_f[k]),
                                       np.asarray(out_r[k]), rtol=1e-5)

    @pytest.mark.parametrize("fused", [True, False])
    def test_stats_do_not_perturb_combine(self, fused):
        """The stats path must be the SAME combine program — outputs
        bitwise equal to the plain combiner's."""
        span = 4
        stacked = _stacked(span, seed=3)
        cfg = _ccfg(span, fused=fused)
        plain = make_combiner(cfg)(stacked)
        with_stats, _ = make_combiner(cfg, with_stats=True)(stacked)
        for k in plain:
            np.testing.assert_array_equal(np.asarray(plain[k]),
                                          np.asarray(with_stats[k]))

    @pytest.mark.parametrize("op", ["sum", "mean", "adascale"])
    def test_probe_wraps_other_combiners(self, op):
        span = 4
        stacked = _stacked(span, seed=5)
        cfg = _ccfg(span, op=op)
        base = make_combiner(cfg)(stacked)
        out, stats = make_combiner(cfg, with_stats=True)(stacked)
        assert stats["levels"].shape == (1, 3)       # level-0 probe
        for k in base:
            np.testing.assert_array_equal(np.asarray(base[k]),
                                          np.asarray(out[k]))

    def test_span_one_summary_is_neutral(self):
        m = summarize_stats({"levels": jnp.zeros((0, 3), jnp.float32)},
                            span=1, lane_rows=8)
        assert float(m["gain_ratio"]) == 1.0
        assert float(m["noise_scale"]) == 0.0
        assert set(m) == set(STAT_KEYS)


class TestNoiseEMA:
    def test_debiased_first_value(self):
        ema = NoiseEMA(0.9)
        assert ema.value is None
        assert ema.update(5.0) == pytest.approx(5.0)   # debiased: no warmup lag

    def test_nan_inf_guarded(self):
        ema = NoiseEMA(0.5)
        ema.update(2.0)
        assert ema.update(float("nan")) == pytest.approx(2.0)
        assert ema.update(float("inf")) == pytest.approx(2.0)
        assert ema.count == 1                          # poison not counted


def _controller(**kw):
    kw.setdefault("grow_factor", 2)
    kw.setdefault("grow_threshold", 2.0)
    kw.setdefault("patience", 3)
    kw.setdefault("cooldown", 5)
    kw.setdefault("warmup", 2)
    kw.setdefault("max_global_batch", 32)
    cfg = ControllerConfig(**kw)
    return BatchController(cfg, global_batch=8, span=2, dp_total=8, lr=0.1)


def _noisy(ns, var=1.0, mu2=0.0):
    return {"noise_scale": ns, "grad_var": var, "grad_mu2": mu2}


class TestBatchController:
    def test_hysteresis_patience_and_growth(self):
        ctrl = _controller()
        plan = None
        for i in range(10):
            plan = ctrl.observe(i, _noisy(1000.0))
            if plan is not None:
                break
        # warmup gates the first step (EMA count 1 < 2); patience then
        # needs 3 consecutive in-band steps: earliest fire at call 3
        assert plan is not None and i == 3
        assert (plan.new_batch, plan.new_span) == (16, 4)
        # grad_var=1, grad_mu2=0: pure-noise regime, adascale gain = factor
        assert plan.new_lr == pytest.approx(0.2, rel=1e-6)

    def test_reset_band_clears_patience(self):
        # ema=0 makes the EMA track the last sample exactly, so a single
        # low reading drops it into the reset band
        ctrl = _controller(ema=0.0)
        ctrl.observe(0, _noisy(1000.0))            # warmup
        ctrl.observe(1, _noisy(1000.0))            # above: 1
        ctrl.observe(2, _noisy(1000.0))            # above: 2
        assert ctrl.observe(3, _noisy(0.0)) is None  # < hi/2: reset
        assert ctrl.observe(4, _noisy(1000.0)) is None  # above: 1 again
        assert ctrl.observe(5, _noisy(1000.0)) is None  # above: 2
        assert ctrl.observe(6, _noisy(1000.0)) is not None

    def test_cooldown_after_resize(self):
        ctrl = _controller()
        plan = None
        step = 0
        while plan is None:
            plan = ctrl.observe(step, _noisy(1000.0))
            step += 1
        ctrl.notify_resized(plan)
        assert ctrl.global_batch == 16 and ctrl.span == 4
        # cooldown=5 swallows the next 5 observations outright
        for i in range(5):
            assert ctrl.observe(step + i, _noisy(1e6)) is None

    def test_cap_exhausts_controller(self):
        ctrl = _controller(max_global_batch=8, warmup=1, patience=1)
        assert ctrl.observe(0, _noisy(1000.0)) is None   # warmup
        assert ctrl.observe(1, _noisy(1000.0)) is None   # capped
        assert ctrl._exhausted
        for i in range(2, 6):
            assert ctrl.observe(i, _noisy(1e9)) is None

    def test_missing_noise_metric_ignored(self):
        ctrl = _controller(warmup=1, patience=1)
        for i in range(6):
            assert ctrl.observe(i, {"loss": 1.0}) is None
        assert ctrl.noise.count == 0

    @pytest.mark.parametrize("mode,want", [("linear", 0.2), ("none", 0.1)])
    def test_lr_rescale_ablations(self, mode, want):
        ctrl = _controller(lr_rescale=mode, warmup=1, patience=1)
        ctrl.observe(0, _noisy(1000.0))
        plan = ctrl.observe(1, _noisy(1000.0))
        assert plan is not None
        assert plan.new_lr == pytest.approx(want, rel=1e-6)

    def test_from_engine_projection(self):
        ecfg = EngineConfig(arch="gemma-7b", grow_factor=4,
                            grow_threshold=1.5, grow_patience=3,
                            grow_cooldown=7, max_global_batch=128,
                            grow_span=False, lr_rescale="linear",
                            noise_ema=0.8)
        c = ControllerConfig.from_engine(ecfg)
        assert (c.grow_factor, c.grow_threshold, c.patience, c.cooldown,
                c.max_global_batch, c.grow_span, c.lr_rescale, c.ema) == \
               (4, 1.5, 3, 7, 128, False, "linear", 0.8)


class TestPlanGrow:
    def test_doubles_batch_and_span(self):
        p = plan_grow(8, 2, 8, 0.1, factor=2, lr_scale=1.7)
        assert p.grew
        assert (p.new_batch, p.new_span) == (16, 4)
        assert p.new_lr == pytest.approx(0.17)

    def test_span_capped_by_dp(self):
        p = plan_grow(64, 8, 8, 0.1, factor=2)
        assert p.grew and p.new_batch == 128
        assert p.new_span == 8            # 16 is no divisor of dp=8

    def test_grow_span_off(self):
        p = plan_grow(8, 2, 8, 0.1, factor=2, grow_span=False)
        assert p.grew and (p.new_batch, p.new_span) == (16, 2)

    def test_batch_cap_blocks_growth(self):
        p = plan_grow(8, 2, 8, 0.1, factor=2, max_global_batch=8)
        assert not p.grew
        assert p.reason == "capped"
        assert (p.new_batch, p.new_span, p.new_lr) == (8, 2, 0.1)


class TestConfigAndTelemetry:
    def test_adaptive_requires_ckpt_dir(self):
        with pytest.raises(ValueError, match="ckpt_dir"):
            EngineConfig(adaptive_batch=True).validate()

    def test_adaptive_excludes_delay_elastic_and_needs_stats(self):
        with pytest.raises(ValueError, match="combine_delay"):
            EngineConfig(adaptive_batch=True, ckpt_dir="/tmp/x",
                         combine_delay=1).validate()
        with pytest.raises(ValueError, match="elastic"):
            EngineConfig(adaptive_batch=True, ckpt_dir="/tmp/x",
                         elastic=True).validate()
        with pytest.raises(ValueError, match="combine_stats"):
            EngineConfig(adaptive_batch=True, ckpt_dir="/tmp/x",
                         combine_stats=False).validate()

    def test_controller_knob_validation(self):
        with pytest.raises(ValueError, match="grow_factor"):
            EngineConfig(grow_factor=3).validate()
        with pytest.raises(ValueError, match="grow_threshold"):
            EngineConfig(grow_threshold=0.0).validate()
        with pytest.raises(ValueError, match="lr_rescale"):
            EngineConfig(lr_rescale="sqrt").validate()
        with pytest.raises(ValueError, match="noise_ema"):
            EngineConfig(noise_ema=1.0).validate()

    def test_cli_roundtrip(self):
        cfg = EngineConfig.from_cli(
            ["--arch", "gemma-7b", "--adaptive-batch", "--ckpt-dir",
             "/tmp/ck", "--grow-factor", "4", "--grow-threshold", "1.5",
             "--grow-patience", "3", "--grow-cooldown", "9",
             "--max-global-batch", "256", "--no-grow-span",
             "--lr-rescale", "linear", "--noise-ema", "0.8"])
        assert cfg.adaptive_batch and cfg.grow_factor == 4
        assert cfg.grow_threshold == 1.5 and cfg.grow_patience == 3
        assert cfg.grow_cooldown == 9 and cfg.max_global_batch == 256
        assert not cfg.grow_span and cfg.lr_rescale == "linear"
        assert cfg.noise_ema == 0.8
        assert EngineConfig.from_dict(cfg.to_dict()) == cfg
        off = EngineConfig.from_cli(["--arch", "gemma-7b",
                                     "--no-combine-stats"])
        assert not off.combine_stats

    def test_fit_adaptive_requires_ckpt_dir(self):
        from repro.control import fit_adaptive
        with pytest.raises(ValueError, match="ckpt_dir"):
            fit_adaptive(EngineConfig(arch="gemma-7b"))

    def test_ckpt_every_zero_disables_periodic_saves(self):
        """ckpt_every=0 means explicit/final saves only — the periodic
        callback must not divide by it (the adaptive driver checkpoints
        at resize boundaries itself)."""
        from repro.engine.session import CheckpointCallback

        class _Sess:
            checkpoint = object()
            saved = []

            def save(self, step):
                self.saved.append(step)

        s = _Sess()
        cb = CheckpointCallback(every=0)
        for step in range(3):
            cb.on_step_end(s, step, {}, 0.0)     # must not raise
        assert s.saved == []
        CheckpointCallback(every=2).on_step_end(s, 1, {}, 0.0)
        assert s.saved == [2]

    def test_telemetry_fingerprint(self):
        sha = git_sha()
        assert isinstance(sha, str) and len(sha) >= 7   # repo is git
        a = EngineConfig(arch="gemma-7b")
        b = EngineConfig(arch="gemma-7b", lr=0.123)
        assert config_hash(a) == config_hash(a)
        assert config_hash(a) != config_hash(b)
        fp = run_fingerprint(a)
        assert fp["git_sha"] == sha
        assert fp["config_hash"] == config_hash(a)


class TestAdaptiveEndToEnd:
    def test_stats_on_is_bitwise_noop_and_surfaces_metrics(self):
        """combine_stats=True must not perturb training (bitwise params)
        while surfacing the STAT_KEYS metrics + run_metadata fields."""
        run_in_subprocess(r"""
import numpy as np, jax
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig, TrainSession
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat
from repro.control.noise import STAT_KEYS

mcfg = ModelConfig("ctl-tiny", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))

def run(stats):
    cfg = EngineConfig(combine="adasum", span=2, backend="gspmd_tree",
                       optimizer="momentum", lr=0.05, seq_len=32,
                       global_batch=8, data_seed=7, combine_stats=stats)
    sess = TrainSession.from_config(cfg, model=model, mesh=mesh,
                                    callbacks=[])
    hist = [sess.step(sess.batch(s)) for s in range(4)]
    return sess, hist

s_on, h_on = run(True)
s_off, h_off = run(False)
for a, b in zip(jax.tree.leaves(s_on.state["params"]),
                jax.tree.leaves(s_off.state["params"])):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
assert [m["loss"] for m in h_on] == [m["loss"] for m in h_off]
for k in STAT_KEYS:
    assert k in h_on[-1], k
    assert k not in h_off[-1], k
md = s_on.run_metadata()
assert md["stats_enabled"] is True
assert set(STAT_KEYS) <= set(md["combine_stats"])
assert md["combine_stats"]["noise_scale"] > 0
assert len(md["git_sha"]) >= 7 and md["config_hash"]
md_off = s_off.run_metadata()
assert md_off["stats_enabled"] is False
print("OK")
""", devices=8, timeout=900)

    def test_fit_adaptive_resizes_and_keeps_stream_aligned(self):
        """Acceptance: >=1 controller-triggered resize end-to-end, the
        (seed, step) stream contiguous across resizes (no skipped or
        replayed batches), effective batch/span/LR validated + logged
        after each rebuild."""
        run_in_subprocess(r"""
import numpy as np, tempfile
from repro.configs.base import ModelConfig
from repro.engine import EngineConfig
from repro.models import build_model
from repro.launch.mesh import make_mesh_compat
from repro.control import fit_adaptive
from repro.control.resize import log_effective

mcfg = ModelConfig("ctl-tiny", "dense", 2, 64, 4, 2, 128, 257, head_dim=16)
model = build_model(mcfg, attn_chunk=32)
mesh = make_mesh_compat((8, 1), ("data", "model"))

seen = []
class Record:
    def on_fit_start(self, session, start): ...
    def on_fit_end(self, session, history): ...
    def on_step_end(self, session, step, metrics, dt): ...
    def on_step_start(self, session, step):
        seen.append((step, session.config.global_batch,
                     int(np.asarray(session.batch(step)["tokens"]).shape[0])))

with tempfile.TemporaryDirectory() as ckpt:
    cfg = EngineConfig(combine="adasum", span=2, backend="gspmd_tree",
                       optimizer="momentum", lr=0.02, seq_len=32,
                       global_batch=8, data_seed=11, steps=14,
                       ckpt_dir=ckpt, ckpt_every=0, adaptive_batch=True,
                       grow_threshold=1.0, grow_patience=2,
                       grow_cooldown=3, max_global_batch=32)
    hist, sess = fit_adaptive(cfg, 14, callbacks=[Record()],
                              model=model, mesh=mesh)
    # >=1 planned resize actually executed
    assert len(sess.resize_log) >= 1, sess.resize_log
    # stream alignment: each step consumed exactly once, in order
    assert [s for s, _, _ in seen] == list(range(14)), seen
    assert [h["step"] for h in hist] == list(range(14))
    assert np.isfinite([h["loss"] for h in hist]).all()
    # batch rows actually grew at the resize boundary
    first = sess.resize_log[0]
    rows_before = dict((s, r) for s, _, r in seen)[first["step"] - 1]
    rows_after = dict((s, r) for s, _, r in seen)[first["step"]]
    assert rows_after == rows_before * 2, (rows_before, rows_after)
    # effective operating point validates after the rebuilds
    eff = log_effective(sess)
    assert eff["global_batch"] == sess.config.global_batch
    assert eff["global_batch"] > 8 and eff["span"] > 2
    assert sess.config.lr > 0.02          # adascale-rescaled upward
    md = sess.run_metadata()
    assert md["adaptive_batch"] is True
    assert md["global_batch"] == eff["global_batch"]
    sess.close()
print("OK")
""", devices=8, timeout=900)
