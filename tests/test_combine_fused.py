"""Fused bucketed combine (the gspmd_tree fast path): equivalence to the
per-leaf reference tree within fp32-accumulation tolerance, bucketing /
block-selection contracts, registry dispatch, and — in an 8-device
subprocess — sharded-lane packing that never reshards or replicates
TP/FSDP-sharded leaves (the `_split_lanes` failure mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess

from repro.core import combine as C
from repro.core import fusion
from repro.core.combine import CombineConfig
from repro.engine.registry import make_combiner
from repro.kernels.adasum_dots import auto_block_elems

RAGGED = [3, 700, 1025, 8192, 64, 2, 5000, 300, 12_000, 9]


def ragged_tree(span, sizes=RAGGED, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed + span)
    return {f"l{i}": jnp.asarray(rng.standard_normal((span, s)),
                                 jnp.float32).astype(dtype)
            for i, s in enumerate(sizes)}


# -------------------------------------------------------------- equivalence

@pytest.mark.parametrize("span", [2, 4, 8])
@pytest.mark.parametrize("per_layer", [True, False])
def test_fused_matches_reference_fp32(span, per_layer):
    tree = ragged_tree(span)
    ref_fn = (C.tree_combine_per_layer if per_layer
              else C.tree_combine_whole)
    ref = ref_fn(tree, jnp.float32)
    cfg = CombineConfig(per_layer=per_layer)
    out = jax.jit(C.build_fused_combiner(cfg))(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5, err_msg=k)


@pytest.mark.parametrize("span", [2, 4])
def test_fused_matches_reference_bf16_lanes(span):
    """bf16 gradients: dots still accumulate in fp32 (§4.4.1); outputs
    agree with the per-leaf reference within bf16 resolution."""
    tree = ragged_tree(span, dtype=jnp.bfloat16)
    ref = C.tree_combine_per_layer(tree, jnp.float32)
    out = jax.jit(C.build_fused_combiner(CombineConfig()))(tree)
    for k in tree:
        np.testing.assert_allclose(
            np.asarray(out[k], np.float32), np.asarray(ref[k], np.float32),
            rtol=3e-2, atol=3e-2, err_msg=k)


def test_fused_mixed_dtype_tree_groups_by_dtype():
    """fp32 + bf16 leaves in one tree: grouped into separate buckets, each
    combined in its own dtype."""
    span = 4
    tree = ragged_tree(span)
    tree.update({f"b{i}": v.astype(jnp.bfloat16) for i, v in
                 enumerate(ragged_tree(span, sizes=[257, 4000]).values())})
    ref = C.tree_combine_per_layer(tree, jnp.float32)
    out = jax.jit(C.build_fused_combiner(CombineConfig()))(tree)
    for k in tree:
        assert out[k].dtype == tree[k].dtype
        tol = 3e-2 if out[k].dtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(
            np.asarray(out[k], np.float32), np.asarray(ref[k], np.float32),
            rtol=tol, atol=tol, err_msg=k)


def test_fused_multi_bucket_matches_single_bucket():
    """A 1 MB threshold that forces several buckets must not change the
    per-layer result (bucketing only regroups independent layers)."""
    span = 2
    tree = {f"m{i}": jnp.asarray(
        np.random.default_rng(i).standard_normal((span, 400_000)),
        jnp.float32) for i in range(4)}
    ref = C.tree_combine_per_layer(tree, jnp.float32)
    out = jax.jit(C.build_fused_combiner(
        CombineConfig(fusion_threshold_mb=1)))(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)


def test_fused_pallas_interpret_matches_ref_path():
    tree = ragged_tree(4, sizes=[3, 700, 9000, 64])
    ref = jax.jit(C.build_fused_combiner(CombineConfig()))(tree)
    out = jax.jit(C.build_fused_combiner(
        CombineConfig(use_pallas=True)))(tree)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(ref[k]),
                                   rtol=1e-5, atol=1e-5)


def test_fused_zero_lanes_degrade_to_sum():
    """All-zero partner lanes (untouched MoE experts): s1 = s2 = 1, the
    plain-sum limit — the fused padding segments rely on the same rule."""
    span = 2
    live = np.random.default_rng(0).standard_normal((5000,))
    tree = {"w": jnp.asarray(np.stack([live, np.zeros_like(live)]),
                             jnp.float32)}
    out = C.build_fused_combiner(CombineConfig())(tree)
    np.testing.assert_allclose(np.asarray(out["w"]), live, rtol=1e-6,
                               atol=1e-6)


# ------------------------------------------------------------ registry wiring

def test_registry_default_is_fused_and_optout_is_reference():
    tree = ragged_tree(4)
    ref = C.tree_combine_per_layer(tree, jnp.float32)
    via_default = make_combiner(CombineConfig(op="adasum",
                                              backend="gspmd_tree"))(tree)
    via_optout = make_combiner(CombineConfig(
        op="adasum", backend="gspmd_tree", fused=False))(tree)
    via_forced = make_combiner(CombineConfig(op="adasum",
                                             backend="fused"))(tree)
    for k in tree:
        # opt-out is the bit-exact reference; default/forced are the
        # fused path (equal within fp32-accumulation tolerance)
        np.testing.assert_array_equal(np.asarray(via_optout[k]),
                                      np.asarray(ref[k]))
        np.testing.assert_allclose(np.asarray(via_default[k]),
                                   np.asarray(ref[k]), rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(via_default[k]),
                                      np.asarray(via_forced[k]))


def test_every_registry_backend_agrees_with_its_reference():
    """Acceptance: every adasum registry backend reachable on one device
    agrees with its reference implementation within tolerance (linear is
    a different recursion ORDER — its reference is the ring reduce, not
    the tree)."""
    from repro.core import adasum as A
    tree = ragged_tree(4, sizes=[64, 1025, 300])
    tree_ref = C.tree_combine_per_layer(tree, jnp.float32)
    lanes = [jax.tree.map(lambda x, i=i: x[i], tree) for i in range(4)]
    refs = {
        "gspmd_tree": tree_ref,
        "fused": tree_ref,
        "linear": A.adasum_linear_reduce(lanes, per_layer=True,
                                         acc_dtype=jnp.float32),
    }
    for backend, ref in refs.items():
        out = make_combiner(CombineConfig(op="adasum",
                                          backend=backend))(tree)
        for k in tree:
            np.testing.assert_allclose(
                np.asarray(out[k]), np.asarray(ref[k]), rtol=1e-5,
                atol=1e-5, err_msg=backend)


def test_fused_refuses_device_sharded_lane_axis():
    """span == dp (the RVH lane layout): fused returns None / the forced
    entry errors — local pairing would cross devices."""
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(1, 1)
    assert C.build_fused_combiner(CombineConfig(span=0), mesh=mesh,
                                  dp_axes=("data",)) is not None  # dp == 1
    # fake a dp>1 mesh shape via the config contract: span==dp declared
    cfg = CombineConfig(span=2)
    # single-device mesh: dp_total == 1 != span -> fused applies
    assert C.build_fused_combiner(cfg, mesh=mesh,
                                  dp_axes=("data",)) is not None


# --------------------------------------------------- block / layout contracts

def test_auto_block_elems_contract():
    assert auto_block_elems(8192) == 8192
    assert auto_block_elems(3 * 1024) == 3072
    assert auto_block_elems(5 * 1024) == 5120
    assert auto_block_elems(1024) == 1024
    assert auto_block_elems(1 << 20) == 8192
    with pytest.raises(ValueError, match="multiple"):
        auto_block_elems(1000)
    with pytest.raises(ValueError, match="multiple"):
        auto_block_elems(0)


def test_block_dots_auto_block_on_odd_bucket():
    """block_elems=None never trips the shape asserts on odd-but-aligned
    bucket lengths (the satellite contract)."""
    from repro.kernels.adasum_dots import block_dots
    from repro.kernels import ref
    n = 5 * 1024
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.standard_normal(n), jnp.float32)
    b = jnp.asarray(rng.standard_normal(n), jnp.float32)
    got = block_dots(a, b, block_elems=None, interpret=True)
    want = ref.block_dots_ref(a, b, auto_block_elems(n))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-3)


def test_select_block_elems_bounds_padding_waste():
    # tiny leaves degrade to the 1024 granule
    assert fusion.select_block_elems([7, 9, 31]) == 1024
    # big uniform leaves take the full block
    assert fusion.select_block_elems([65536, 16384]) == 8192
    # the choice always bounds padding to 25% of the raw payload
    for sizes in ([5, 5000, 123], [8192] * 4, [100] * 50):
        b = fusion.select_block_elems(sizes)
        padded = sum((s + b - 1) // b * b for s in sizes)
        assert b == 1024 or padded - sum(sizes) <= 0.25 * sum(sizes)


def test_pack_stacked_roundtrip():
    span = 3
    tree = tuple(ragged_tree(span, sizes=[5, 300, 1025]).values())
    payload = tuple(jax.ShapeDtypeStruct(t.shape[1:], t.dtype)
                    for t in tree)
    layout = fusion.make_layout(payload, leaf_align=1024)
    buf = fusion.pack_stacked(list(tree), layout)
    assert buf.shape == (span, layout.padded_len)
    for lane in range(span):
        lane_tree = fusion.unpack(buf[lane], layout)
        for got, want in zip(lane_tree, tree):
            np.testing.assert_array_equal(np.asarray(got),
                                          np.asarray(want[lane]))


def test_bucketize_sizes_never_splits_and_covers():
    sizes = [10, 2000, 5, 8000, 8000, 1]
    buckets = fusion.bucketize_sizes(sizes, 8000)
    assert buckets[0][0] == 0 and buckets[-1][1] == len(sizes)
    for (s1, e1), (s2, e2) in zip(buckets, buckets[1:]):
        assert e1 == s2
    for s, e in buckets:
        assert sum(sizes[s:e]) <= 8000 or e - s == 1


# ------------------------------------------------------- sharded (8 devices)

class TestShardedFused:
    def test_sharded_lanes_no_resharding(self):
        """TP/FSDP-sharded leaves, lanes replicated over dp (the span<dp
        hierarchical regime): the fused combine must match the reference
        AND compile to zero all-gathers — local shards are packed in
        place, never replicated (the `_split_lanes` failure mode)."""
        run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.core import combine as C
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((4, 2), ("data", "model"))
rng = np.random.default_rng(2)
span = 2
tree = {"wq":  jnp.asarray(rng.standard_normal((span, 8, 4096)), jnp.float32),
        "wo":  jnp.asarray(rng.standard_normal((span, 4096, 8)), jnp.float32),
        "norm": jnp.asarray(rng.standard_normal((span, 8)), jnp.float32),
        "z2":  jnp.asarray(rng.standard_normal((span, 4096, 4)), jnp.float32)}
specs = {"wq": P(None, "model"), "wo": P("model", None), "norm": P(),
         "z2": P("data", None)}   # z2: ZeRO-2-scattered over data
sharded = {k: jax.device_put(v, NamedSharding(mesh, P(None, *(specs[k] or ()))))
           for k, v in tree.items()}
ref = C.tree_combine_per_layer(tree, jnp.float32)
for per_layer in (True, False):
    cfg = C.CombineConfig(span=span, per_layer=per_layer)
    comb = C.build_fused_combiner(cfg, mesh=mesh, dp_axes=("data",),
                                  leaf_specs=specs)
    fn = jax.jit(comb)
    out = fn(sharded)
    want = (ref if per_layer
            else C.tree_combine_whole(tree, jnp.float32))
    for k in tree:
        np.testing.assert_allclose(np.asarray(out[k]), np.asarray(want[k]),
                                   rtol=2e-5, atol=2e-5, err_msg=k)
    txt = fn.lower(sharded).compile().as_text()
    n_ag = sum(1 for l in txt.splitlines() if "all-gather" in l)
    assert n_ag == 0, f"fused combine replicated sharded leaves: {n_ag} all-gathers"
    # output keeps the input payload sharding (no resharding on exit)
    for k in tree:
        assert out[k].sharding.is_equivalent_to(
            NamedSharding(mesh, P(*(specs[k] or ()))), out[k].ndim), k
print("OK")
""")

    def test_span_dp_falls_back_to_reference_in_runtime(self):
        """backend=gspmd_tree at span==dp (lane axis device-sharded):
        the registry quietly keeps the reference tree and training still
        converges (the fused path must not hijack that regime)."""
        run_in_subprocess(r"""
import jax, numpy as np
from repro.configs.base import get_reduced
from repro.models import build_model
from repro.engine import build_runtime
from repro.parallel.policy import RunPolicy
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4, 2), ("data", "model"))
cfg = get_reduced("qwen3-32b")
model = build_model(cfg, attn_chunk=16)
rpol = RunPolicy(span=0, backend="gspmd_tree", optimizer="adam")
rt = build_runtime(model, mesh, rpol, lr=3e-3)
state = rt.init_state(jax.random.key(0))
toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}
step = jax.jit(rt.train_step, donate_argnums=(0,))
first = last = None
for _ in range(4):
    state, m = step(state, batch)
    l = float(m["loss"])
    first = first if first is not None else l
    last = l
assert np.isfinite(last) and last < first, (first, last)
print("OK")
""", timeout=900)

    def test_hierarchical_span2_fused_step_matches_reference_step(self):
        """The span<dp training step (ZeRO-2 + TP, the mixtral/qwen
        preset shape) must produce the same parameters whether the
        combiner is fused (default) or the per-leaf reference."""
        run_in_subprocess(r"""
import dataclasses, jax, numpy as np
from repro.configs.base import get_reduced
from repro.models import build_model
from repro.engine import build_runtime
from repro.parallel.policy import RunPolicy
from repro.launch.mesh import make_mesh_compat
mesh = make_mesh_compat((4, 2), ("data", "model"))
cfg = get_reduced("qwen3-32b")
model = build_model(cfg, attn_chunk=16)
toks = jax.random.randint(jax.random.key(1), (8, 32), 0, cfg.vocab_size)
batch = {"tokens": toks, "labels": toks}
outs = {}
for fused in (True, False):
    rpol = RunPolicy(span=2, fsdp=True, scatter_grads=True,
                     backend="gspmd_tree", optimizer="adam",
                     fused_combine=fused)
    rt = build_runtime(model, mesh, rpol, lr=3e-3)
    state = rt.init_state(jax.random.key(0))
    step = jax.jit(rt.train_step)
    for _ in range(2):
        state, m = step(state, batch)
    outs[fused] = jax.device_get(state["params"])
for (pa, a), (pb, b) in zip(
        jax.tree_util.tree_flatten_with_path(outs[True])[0],
        jax.tree_util.tree_flatten_with_path(outs[False])[0]):
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=5e-4, atol=5e-4, err_msg=str(pa))
print("OK")
""", timeout=1200)

    def test_rvh_bucketed_matches_single_buffer(self):
        """Tiny bucket budget => several independent RVH chains; result
        must match the single-buffer reduction (and the reference)."""
        run_in_subprocess(r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import adasum, rvh
from repro.launch.mesh import make_mesh_compat
np.random.seed(0)
mesh = make_mesh_compat((4, 2), ("data", "model"))
lanes = 4
tree = {f"w{i}": np.random.randn(lanes, 600 + 13 * i).astype(np.float32)
        for i in range(6)}
ref = adasum.adasum_tree_reduce(
    [{k: jnp.asarray(v[i]) for k, v in tree.items()} for i in range(lanes)])
single = jax.jit(lambda t: rvh.adasum_rvh_pytree(t, mesh, ("data",)))(tree)
bucketed = jax.jit(lambda t: rvh.adasum_rvh_pytree(
    t, mesh, ("data",), bucket_bytes=4 * 1024))(tree)
for k in tree:
    np.testing.assert_allclose(np.asarray(bucketed[k]), np.asarray(ref[k]),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(bucketed[k]), np.asarray(single[k]),
                               rtol=2e-5, atol=2e-5)
print("OK")
""")
