"""Hypothesis property tests for the paper's convergence lemmas and the
combiner's invariants (Appendix A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st, assume

from repro.core import adasum as A

DIM = 8


def vec(seed, scale=1.0):
    rng = np.random.default_rng(seed)
    return rng.standard_normal(DIM) * scale


vec_st = st.builds(vec, seed=st.integers(0, 2**31 - 1),
                   scale=st.floats(0.1, 10.0))


@settings(max_examples=100, deadline=None)
@given(vec_st, vec_st)
def test_commutativity(a, b):
    g1, g2 = jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)
    out1 = np.asarray(A.adasum_pair(g1, g2, acc_dtype=jnp.float64))
    out2 = np.asarray(A.adasum_pair(g2, g1, acc_dtype=jnp.float64))
    np.testing.assert_allclose(out1, out2, rtol=1e-5, atol=1e-6)


@settings(max_examples=100, deadline=None)
@given(vec_st, vec_st, st.floats(0.01, 100.0))
def test_positive_homogeneity(a, b, c):
    """Adasum(c·g1, c·g2) = c·Adasum(g1, g2): scale invariance => no new
    hyperparameters (paper §3.2)."""
    g1, g2 = jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)
    lhs = np.asarray(A.adasum_pair(c * g1, c * g2, acc_dtype=jnp.float64))
    rhs = c * np.asarray(A.adasum_pair(g1, g2, acc_dtype=jnp.float64))
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-5)


@settings(max_examples=100, deadline=None)
@given(vec_st, vec_st)
def test_norm_bounds_lemma_a3(a, b):
    """Lemma A.3 (deterministic form): Adasum(a,b) = (2I - P)·m where
    m=(a+b)/2-ish... operationally we check the implied bound
    ‖Adasum(a,b)‖ <= ‖a‖ + ‖b‖ and the sum/average envelope."""
    g1, g2 = jnp.asarray(a, jnp.float64), jnp.asarray(b, jnp.float64)
    out = np.asarray(A.adasum_pair(g1, g2, acc_dtype=jnp.float64))
    assert np.linalg.norm(out) <= (np.linalg.norm(a) + np.linalg.norm(b)) \
        * (1 + 1e-6) * 2.0


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 5.0))
def test_lemma_a2_angle_bound(seed, scale):
    """Lemma A.2: for Y = (2I - a·aᵀ/‖a‖²)·r, the angle between Y and r is
    at most ~0.108π (cos >= 0.9428)."""
    rng = np.random.default_rng(seed)
    r = rng.standard_normal(DIM)
    a = rng.standard_normal(DIM) * scale
    P = np.outer(a, a) / (a @ a)
    y = (2 * np.eye(DIM) - P) @ r
    cos = (r @ y) / (np.linalg.norm(r) * np.linalg.norm(y))
    assert cos >= 0.9428 - 1e-6


@settings(max_examples=200, deadline=None)
@given(st.integers(0, 2**31 - 1), st.floats(0.1, 5.0))
def test_lemma_a3_eigenvalue_bound(seed, scale):
    """Lemma A.3: eigenvalues of (2I - a·aᵀ/‖a‖²) lie in [1, 2], so
    ‖r‖ <= ‖(2I-P)r‖ <= 2‖r‖."""
    rng = np.random.default_rng(seed)
    r = rng.standard_normal(DIM)
    a = rng.standard_normal(DIM) * scale
    P = np.outer(a, a) / (a @ a)
    y = (2 * np.eye(DIM) - P) @ r
    nr, ny = np.linalg.norm(r), np.linalg.norm(y)
    assert nr * (1 - 1e-9) <= ny <= 2 * nr * (1 + 1e-9)


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 2**31 - 1))
def test_pseudogradient_positive_inner_product(seed):
    """Theorem A.4 ingredient: E[Adasum] keeps a positive inner product
    with the true gradient for gradient-like samples (mean + noise)."""
    rng = np.random.default_rng(seed)
    true = rng.standard_normal(DIM)
    gs = [{"w": jnp.asarray(true + 0.5 * rng.standard_normal(DIM),
                            jnp.float64)} for _ in range(8)]
    out = np.asarray(A.adasum_tree_reduce(gs, acc_dtype=jnp.float64)["w"])
    assert out @ true > 0


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 5), st.integers(0, 2**31 - 1))
def test_tree_reduce_norm_growth(levels, seed):
    """‖Adasum of 2^k gradients‖ <= sum of norms (boundedness used in
    Theorem A.4)."""
    rng = np.random.default_rng(seed)
    n = 2 ** levels
    gs = [{"w": jnp.asarray(rng.standard_normal(DIM), jnp.float64)}
          for _ in range(n)]
    out = np.asarray(A.adasum_tree_reduce(gs, acc_dtype=jnp.float64)["w"])
    total = sum(np.linalg.norm(np.asarray(g["w"])) for g in gs)
    assert np.linalg.norm(out) <= total * (1 + 1e-9)
