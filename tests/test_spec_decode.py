"""Speculative decoding on the serving engine (draft propose -> one
fused verify -> page-table rollback).

The contract under test: greedy tokens with speculation enabled are
BITWISE identical to plain decode — across attention families, both KV
layouts, across page-boundary and COW rollbacks, and through preemption
mid-speculation — because verification recomputes every position under
the target model and the masked verify rows are exact (write-then-mask,
fp32 on CPU). Speculation only ever changes HOW MANY dispatches produce
the same tokens, never the tokens.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, get_reduced
from repro.engine import EngineConfig, GenerationRequest, ServeEngine
from repro.engine.build import EngineWarning
from repro.engine.serving.engine import derive_draft_config
from repro.models import build_model

TINY = ModelConfig("spec-tiny", "dense", 2, 64, 4, 2, 128, 257,
                   head_dim=16)


def tiny_model():
    return build_model(TINY, compute_dtype=jnp.float32, attn_chunk=16)


def reduced_model(arch):
    cfg = get_reduced(arch)
    if cfg.n_experts:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    return build_model(cfg, compute_dtype=jnp.float32, attn_chunk=8)


def run_engine(model, params, reqs, *, stagger=1, draft_params=None,
               **cfg_kw):
    cfg_kw.setdefault("max_slots", 2)
    cfg_kw.setdefault("max_len", 48)
    eng = ServeEngine(EngineConfig(**cfg_kw), model, None, params,
                      draft_params=draft_params)
    handles = []
    for r in reqs:
        handles.append(eng.submit(GenerationRequest(**r)))
        for _ in range(stagger):
            eng.step()
    eng.drain()
    return eng, [h.tokens for h in handles]


def self_draft(model):
    """Draft == target (same config under another name, same params):
    every proposal matches, acceptance is 1.0 — the deterministic way to
    drive the deep-accept paths without training a real draft."""
    return dict(draft_config={"name": f"{model.cfg.name}-self"})


# -------------------------------------------------- bitwise token matrix
class TestSpecBitwise:
    """Plain vs speculative across families and layouts. The auto-
    derived fresh-init draft proposes near-random tokens (acceptance
    ~0): every tick exercises propose -> verify -> full rollback, and
    the streams must STILL match plain decode bitwise."""

    CASES = {
        "gqa": "qwen3-32b",
        "swa": "mixtral-8x22b",     # window caps speculation feasibility
        "mla": "minicpm3-4b",       # absorbed-latent verify path
    }

    @pytest.mark.parametrize("name", sorted(CASES))
    def test_tokens_bitwise_matrix(self, name):
        model = reduced_model(self.CASES[name])
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(0)
        V = model.cfg.vocab_size
        reqs = [dict(prompt=rng.randint(0, V, n), max_new_tokens=g)
                for n, g in [(7, 6), (13, 9), (19, 4)]]
        streams = {}
        for layout in ("dense", "paged"):
            _, streams["plain", layout] = run_engine(
                model, params, reqs, kv_layout=layout)
            eng, streams["spec", layout] = run_engine(
                model, params, reqs, kv_layout=layout, speculation_k=2)
            assert eng.stats["spec_ticks"] > 0, (name, layout)
        ref = streams["plain", "dense"]
        for key, toks in streams.items():
            assert toks == ref, (name, key)

    def test_self_draft_accepts_everything(self):
        """A draft that IS the target proposes exactly the target's
        greedy continuation: acceptance 1.0, k+1 tokens per target
        dispatch, same tokens."""
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(1)
        reqs = [dict(prompt=rng.randint(0, 257, n), max_new_tokens=g)
                for n, g in [(7, 8), (13, 9)]]
        _, plain = run_engine(model, params, reqs, kv_layout="paged")
        eng, spec = run_engine(model, params, reqs, kv_layout="paged",
                               speculation_k=3, draft_params=params,
                               **self_draft(model))
        assert spec == plain
        kv = eng.kv_stats()
        assert kv["spec_acceptance_rate"] == 1.0
        # every verify dispatch committed k+1 tokens for its slots
        assert eng.stats["spec_ticks"] < eng.stats["generated_tokens"]

    def test_recurrent_targets_fall_back_loudly(self):
        """ssm/hybrid targets have no pos-rewrite rollback: speculation
        disables itself with ONE EngineWarning at build and every tick
        runs plain decode — same tokens, zero spec ticks."""
        for arch in ("rwkv6-7b", "hymba-1.5b"):
            model = reduced_model(arch)
            params = model.init(jax.random.key(0))
            reqs = [dict(prompt=list(range(1, 8)), max_new_tokens=4)]
            _, plain = run_engine(model, params, reqs, kv_layout="dense")
            with pytest.warns(EngineWarning, match="speculation disabled"):
                eng, spec = run_engine(model, params, reqs,
                                       kv_layout="dense", speculation_k=2)
            assert spec == plain, arch
            assert eng.spec_k == 0 and eng.stats["spec_ticks"] == 0

    def test_sampled_requests_bypass_speculation(self):
        """temperature>0 anywhere in the active set makes the tick run
        the plain sampling path — speculation is greedy-only."""
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(2)
        mixed = [dict(prompt=rng.randint(0, 257, 9), max_new_tokens=6,
                      temperature=0.8, seed=7),
                 dict(prompt=rng.randint(0, 257, 11), max_new_tokens=6)]
        _, plain = run_engine(model, params, mixed, stagger=0,
                              kv_layout="paged")
        eng, spec = run_engine(model, params, mixed, stagger=0,
                               kv_layout="paged", speculation_k=2,
                               draft_params=params, **self_draft(model))
        assert spec == plain        # sampled stream reproducible by seed
        assert eng.stats["spec_ticks"] == 0


# ------------------------------------------------------ rollback surface
class TestSpecRollback:
    def _one_slot(self, prompt_len, gen, k=3, seed=4, **kw):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(seed)
        reqs = [dict(prompt=rng.randint(0, 257, prompt_len),
                     max_new_tokens=gen)]
        _, ref = run_engine(model, params, reqs, kv_layout="dense",
                            max_slots=1, max_len=64)
        eng = ServeEngine(EngineConfig(max_slots=1, max_len=64,
                                       kv_layout="paged",
                                       speculation_k=k, **kw),
                          model, None, params)
        h = eng.submit(GenerationRequest(**reqs[0]))
        return eng, h, ref[0]

    def test_rollback_across_page_boundary(self):
        """Verify rows 15..18 straddle pages 0|1; the fresh-init draft
        is rejected wholesale (acceptance ~0), so the page claimed for
        the overhang must be RETURNED: table entry back to trash, pool
        usage back to the pre-tick footprint."""
        eng, h, ref = self._one_slot(prompt_len=15, gen=20)
        eng.step()                 # admit + first spec tick
        slot = h.slot
        assert eng.stats["spec_ticks"] == 1
        assert eng.stats["spec_tokens_accepted"] == 0    # random draft
        # rows 15..18 crossed into page 1; rollback returned it
        assert int(eng._tables[slot, 1]) == 0
        assert not eng._owned[slot, 1] and not eng._shared[slot, 1]
        assert eng._pool.pages_used == 1                 # page 0 only
        eng.drain()
        assert h.tokens == ref

    def test_rollback_restores_cow_shared_page(self):
        """A SHARED page sitting beyond the accept point: the spec claim
        copies it (COW), rejection releases the copy and restores the
        read-only original — same table entry, same refcount, tokens
        bitwise."""
        eng, h, ref = self._one_slot(prompt_len=15, gen=25)
        for _ in range(15):        # acceptance ~0: pos 15 -> 30
            eng.step()
        slot = h.slot
        assert int(eng._host_pos[slot]) == 30
        # map logical page 2 (rows 32..47, strictly beyond pos) to an
        # externally shared page, as rolling-over-a-registered-prefix
        # would: the slot holds it read-only, someone else holds a ref
        pid = eng._pool.alloc(1)[0]
        eng._pool.ref([pid])                 # the external holder
        eng._tables[slot, 2] = pid
        eng._shared[slot, 2] = True
        eng._tables_dirty = True
        cows = eng.stats["cow_copies"]
        eng.step()                 # rows 30..33 straddle pages 1|2
        assert eng.stats["cow_copies"] == cows + 1
        # rollback restored the ORIGINAL shared mapping, not the copy
        assert int(eng._tables[slot, 2]) == pid
        assert eng._shared[slot, 2] and not eng._owned[slot, 2]
        assert eng._pool.refcount(pid) == 2
        eng.drain()
        eng._pool.release([pid])             # drop the external ref
        assert h.tokens == ref

    def test_preempt_mid_speculation_is_bitwise(self):
        """Pool pressure during spec-tick growth preempts the youngest
        request; its re-admission re-prefills prompt+accepted (both
        target and draft caches) and the streams still match the
        unconstrained run."""
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(5)
        reqs = [dict(prompt=rng.randint(0, 257, n), max_new_tokens=20)
                for n in (20, 25, 18)]
        kw = dict(max_slots=3, max_len=48, prefix_sharing=False,
                  speculation_k=3, draft_params=params,
                  **self_draft(model))
        _, full = run_engine(model, params, reqs, kv_layout="paged", **kw)
        eng, tight = run_engine(model, params, reqs, kv_layout="paged",
                                kv_pages=6, **kw)
        assert tight == full
        assert eng.stats["preemptions"] >= 1
        assert eng.stats["draft_prefills"] > 3   # re-admissions re-prefill
        assert eng.throughput()["completed"] == 3

    def test_swa_stops_speculating_at_window(self):
        """A rolling-window target speculates only while pos + k stays
        below the window: once it fills, ticks fall back to plain decode
        (no wrap healing exists) — and tokens stay bitwise."""
        model = build_model(
            dataclasses.replace(TINY, name="spec-swa", sliding_window=16),
            compute_dtype=jnp.float32, attn_chunk=16)
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(6)
        reqs = [dict(prompt=rng.randint(0, 257, 9), max_new_tokens=16)]
        _, plain = run_engine(model, params, reqs, kv_layout="paged")
        eng, spec = run_engine(model, params, reqs, kv_layout="paged",
                               speculation_k=2, draft_params=params,
                               **self_draft(model))
        assert spec == plain
        # 9 prompt + 16 gen crosses the 16-row window: some ticks must
        # have run plain (spec stops with pos+k at the window)
        assert 0 < eng.stats["spec_ticks"]
        assert eng.stats["spec_tokens_accepted"] > 0


# ------------------------------------------------- accounting + config
class TestSpecAccounting:
    def test_per_request_and_engine_counters_agree(self):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(7)
        reqs = [dict(prompt=rng.randint(0, 257, n), max_new_tokens=g)
                for n, g in [(7, 8), (12, 6)]]
        eng = ServeEngine(EngineConfig(max_slots=2, max_len=48,
                                       kv_layout="paged", speculation_k=2,
                                       **self_draft(tiny_model())),
                          model, None, params, draft_params=params)
        handles = [eng.submit(GenerationRequest(**r)) for r in reqs]
        eng.drain()
        assert sum(h.spec_proposed for h in handles) == \
            eng.stats["spec_tokens_proposed"] > 0
        assert sum(h.spec_accepted for h in handles) == \
            eng.stats["spec_tokens_accepted"] > 0
        kv = eng.kv_stats()
        assert kv["spec_acceptance_rate"] == pytest.approx(
            eng.stats["spec_tokens_accepted"]
            / eng.stats["spec_tokens_proposed"])
        tp = eng.throughput()
        assert tp["dispatches_per_token"] < 1.0      # the perf claim
        assert tp["ttft_mean_s"] > 0 and tp["tpot_mean_s"] > 0

    def test_latency_percentiles_reported(self):
        model = tiny_model()
        params = model.init(jax.random.key(0))
        rng = np.random.RandomState(8)
        reqs = [dict(prompt=rng.randint(0, 257, 9), max_new_tokens=4)
                for _ in range(3)]
        eng, _ = run_engine(model, params, reqs)
        tp = eng.throughput()
        for k in ("ttft_mean_s", "ttft_p50_s", "ttft_p99_s",
                  "tpot_mean_s", "tpot_p50_s", "tpot_p99_s"):
            assert tp[k] > 0, k
        assert tp["ttft_p50_s"] <= tp["ttft_p99_s"]

    def test_config_validation(self):
        with pytest.raises(ValueError, match="speculation_k"):
            EngineConfig(speculation_k=-1).validate()
        with pytest.raises(ValueError, match="draft_config"):
            EngineConfig(draft_config={"arch": "x"}).validate()
        cfg = EngineConfig(speculation_k=4,
                           draft_config={"n_layers": 1}).validate()
        assert cfg.speculation_k == 4

    def test_derive_draft_config(self):
        tgt = get_reduced("qwen3-32b")
        auto = derive_draft_config(tgt)
        assert auto.n_layers == max(1, tgt.n_layers // 4)
        assert auto.vocab_size == tgt.vocab_size and auto.n_experts == 0
        swa = derive_draft_config(get_reduced("mixtral-8x22b"))
        assert swa.sliding_window == 0       # drafts run full attention
        with pytest.raises(ValueError, match="vocab"):
            derive_draft_config(tgt, {"vocab_size": tgt.vocab_size + 1})
        with pytest.raises(ValueError, match="attention-family"):
            derive_draft_config(tgt, {"arch": "rwkv6-7b", "reduced": True,
                                      "vocab_size": tgt.vocab_size})
