import os
import sys
from pathlib import Path

# NOTE: conftest itself does not set xla_force_host_platform_device_count:
# in-process tests must pass under ANY host device count (plain local runs
# see 1 device; tools/ci.sh exports 8). Multi-device tests pin their own
# count via run_in_subprocess, and the dry-run sets its own flag.
SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 600):
    """Run a python snippet with N fake JAX devices in a fresh process."""
    import subprocess
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, (
        f"subprocess failed\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr}")
    return res.stdout
