"""Checkpoint manager + data pipeline tests (fault tolerance substrate)."""
import json
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, reshard_lanes
from repro.data import DataConfig, make_source
from repro.configs.base import get_reduced


def state_like(seed=0):
    rng = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(rng.standard_normal((4, 3)),
                                        jnp.float32)},
            "opt": {"m": jnp.zeros((2, 4, 3))},
            "step": jnp.asarray(7, jnp.int32)}


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        cm = CheckpointManager(tmp_path)
        s = state_like()
        cm.save(7, s)
        r = cm.restore(jax.tree.map(jnp.zeros_like, s))
        for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(r)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomic_no_partial_visible(self, tmp_path):
        cm = CheckpointManager(tmp_path)
        # a stale tmp dir (simulated crash) must not count as a checkpoint
        (tmp_path / "step_00000005.tmp").mkdir()
        assert cm.latest_step() is None
        cm.save(5, state_like())
        assert cm.latest_step() == 5

    def test_keep_n_gc(self, tmp_path):
        cm = CheckpointManager(tmp_path, keep=2)
        for s in (1, 2, 3, 4):
            cm.save(s, state_like(s))
        assert cm.all_steps() == [3, 4]

    def test_elastic_lane_reshard(self):
        arr = np.arange(8 * 3, dtype=np.float32).reshape(8, 3)
        down = reshard_lanes(arr, (4, 3))
        assert down.shape == (4, 3)
        np.testing.assert_allclose(down[0], arr[:2].mean(0))
        up = reshard_lanes(down, (8, 3))
        assert up.shape == (8, 3)

    def test_elastic_restore_different_span(self, tmp_path):
        cm = CheckpointManager(tmp_path)
        s = state_like()
        cm.save(1, s)
        like = {"params": s["params"],
                "opt": {"m": jnp.zeros((4, 4, 3))},   # span 2 -> 4
                "step": jnp.zeros((), jnp.int32)}
        r = cm.restore(like)
        assert r["opt"]["m"].shape == (4, 4, 3)


class TestDataPipeline:
    def test_deterministic_across_restarts(self):
        cfg = DataConfig(seq_len=32, global_batch=4, vocab_size=101, seed=9)
        a = make_source(cfg).batch(17)
        b = make_source(cfg).batch(17)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        cfg = DataConfig(seq_len=32, global_batch=4, vocab_size=101, seed=9)
        src = make_source(cfg)
        assert not np.array_equal(src.batch(0)["tokens"],
                                  src.batch(1)["tokens"])

    def test_learnable_structure(self):
        """The synthetic stream is a planted Markov chain — bigram
        predictability must be far above chance."""
        cfg = DataConfig(seq_len=256, global_batch=8, vocab_size=64, seed=1)
        src = make_source(cfg)
        toks = src.batch(0)["tokens"]
        # for each (prev -> next) pair, check membership in the 4 planted
        # successors ~90% of the time
        hits = 0
        total = 0
        for row in toks:
            for t in range(1, len(row)):
                total += 1
                if row[t] in src._succ[row[t - 1]]:
                    hits += 1
        assert hits / total > 0.7

    def test_frontend_batches(self):
        mc = get_reduced("llava-next-34b")
        cfg = DataConfig(seq_len=32, global_batch=2, vocab_size=mc.vocab_size)
        b = make_source(cfg, mc).batch(0)
        assert b["frontend_embeds"].shape == (2, mc.frontend_tokens,
                                              mc.frontend_dim)

    def test_host_slicing(self):
        full = DataConfig(seq_len=16, global_batch=8, vocab_size=64, seed=2)
        part = DataConfig(seq_len=16, global_batch=8, vocab_size=64, seed=2,
                          host_rows=4)
        a = make_source(full).batch(3)["tokens"]
        b = make_source(part).batch(3)["tokens"]
        assert b.shape[0] == 4
